//! `cargo bench` — scaled-down versions of every paper table/figure runner
//! (criterion is unavailable offline; this is a plain harness=false binary).
//!
//! Full-size reproductions run via the CLI (`ssnal-en bench-table1 ...`); this
//! binary proves every row-generator works and gives quick comparative numbers
//! on CI-sized instances. Output mirrors the paper's table structure.

use ssnal_en::bench::tables;
use ssnal_en::data::libsvm::ReferenceSet;
use ssnal_en::data::snp::SnpSpec;
use ssnal_en::util::timer::time_it;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { 1 } else { 4 };

    println!("== ssnal-en benchmark suite (scaled; see EXPERIMENTS.md for full sizes) ==\n");

    // Figure 1 — analytic series (always instant)
    let ((_, rows), secs) = time_it(|| tables::fig1_series(241));
    println!("fig1: {} series points in {secs:.3}s\n", rows.len());

    // Table 1 — sim1–3 across n
    let ns: Vec<usize> = vec![2_000 * scale, 10_000 * scale];
    let (t1, secs) = time_it(|| tables::table1(&ns, 200, 2020, 1e-6));
    t1.print();
    println!("(table1 took {secs:.1}s)\n");

    // Table 2 — polynomial expansion (truncated)
    let (t2, secs) =
        time_it(|| tables::table2(&[ReferenceSet::Housing], 4_000 * scale, 2020, 1e-6));
    t2.print();
    println!("(table2 took {secs:.1}s)\n");

    // Figure 2 + Table 3 — INSIGHT-style cohort (one phenotype, scaled)
    let spec = SnpSpec {
        m: 120,
        n_snps: 2_000 * scale,
        n_causal: 6,
        dominant_effect: 1.5,
        seed: 2020,
        ..Default::default()
    };
    let (run, secs) = time_it(|| tables::insight_run(&spec, &[0.9, 0.6], 15, 0));
    let hits = run.selected.iter().filter(|(s, _)| run.causal.contains(s)).count();
    println!(
        "insight (fig2+table3): {} curve rows, selected {} SNPs ({} causal) in {secs:.1}s\n",
        run.curves.len(),
        run.selected.len(),
        hits
    );

    // Table D.1 — replication standard errors
    let (d1, secs) = time_it(|| tables::table_d1(&[2_000 * scale], &[0.5], 200, 5, 1e-6));
    d1.print();
    println!("(d1 took {secs:.1}s)\n");

    // Table D.2 — parameter sweeps (two panels)
    let (d2, secs) = time_it(|| {
        tables::table_d2(&[2_000 * scale], &[("m", 1000.0), ("alpha", 0.3)], 1e-6, 2020)
    });
    d2.print();
    println!("(d2 took {secs:.1}s)\n");

    // Table D.3 — screening solvers
    let (d3, secs) =
        time_it(|| tables::table_d3(&[(4_000 * scale, 200, 50)], &[0.9, 0.5, 0.3], 1e-6, 2020));
    d3.print();
    println!("(d3 took {secs:.1}s)\n");

    // Table D.4 — solution paths
    let (d4, secs) = time_it(|| tables::table_d4(&[5_000 * scale], &[0.8], 200, 40, 1e-6, 2020));
    d4.print();
    println!("(d4 took {secs:.1}s)\n");

    // Parallel λ-path engine — threads vs wall-clock
    let ((tp, _, _), secs) = time_it(|| {
        tables::parallel_path_rows(5_000 * scale, 200, 30, &[1, 2, 4], 1e-6, 2020, true)
    });
    tp.print();
    println!("(parallel-path took {secs:.1}s)\n");

    println!("== benchmark suite complete ==");
}
