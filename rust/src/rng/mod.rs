//! Pseudo-random number generation substrate.
//!
//! The offline build environment does not ship the `rand` crate, so this module
//! implements the generators the paper's experiments need from scratch:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator (Steele et al. 2014).
//! * [`Xoshiro256pp`] — the main generator (Blackman & Vigna 2019), used for all
//!   synthetic designs in the benchmark suite.
//! * Standard-normal variates via the polar (Marsaglia) method.
//! * Fisher–Yates shuffling for cross-validation fold assignment.
//!
//! All generators are deterministic given a seed, which makes every experiment in
//! EXPERIMENTS.md exactly replayable.

/// SplitMix64: fast, well-distributed 64-bit generator, used here mainly to
/// expand a user seed into the 256-bit state of [`Xoshiro256pp`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse PRNG for all synthetic data generation.
///
/// Period 2^256 − 1, passes BigCrush; the `++` output scrambler avoids the
/// low-linear-complexity lower bits of the `+` variant.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (the construction recommended by the authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1): 53 random mantissa bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1]; never returns exactly 0 (safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method (exact, no table needed).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return u * f;
            }
        }
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        // Polar method yields pairs; use both for throughput on the big designs.
        let mut i = 0;
        while i + 1 < out.len() {
            let (a, b) = self.next_gaussian_pair();
            out[i] = a;
            out[i + 1] = b;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.next_gaussian();
        }
    }

    /// One polar-method rejection loop producing two independent normals.
    #[inline]
    pub fn next_gaussian_pair(&mut self) -> (f64, f64) {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return (u * f, v * f);
            }
        }
    }

    /// Binomial(n, p) by direct simulation — n is tiny (2 for SNP genotypes).
    pub fn next_binomial(&mut self, n: u32, p: f64) -> u32 {
        let mut k = 0;
        for _ in 0..n {
            if self.next_f64() < p {
                k += 1;
            }
        }
        k
    }

    /// In-place Fisher–Yates shuffle (used for CV fold assignment).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≪ n assumed; rejection set).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            // dense case: shuffle a full index vector
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            return idx;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let j = self.next_below(n);
            if seen.insert(j) {
                out.push(j);
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 (cross-checked against the C reference).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
            let v = r.next_f64_open();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(99);
        let nsamp = 200_000;
        let mut mean = 0.0;
        let mut m2 = 0.0;
        for i in 1..=nsamp {
            let x = r.next_gaussian();
            let d = x - mean;
            mean += d / i as f64;
            m2 += d * (x - mean);
        }
        let var = m2 / (nsamp - 1) as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fill_gaussian_matches_len() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        for len in [0usize, 1, 2, 7, 64, 1001] {
            let mut v = vec![0.0; len];
            r.fill_gaussian(&mut v);
            if len > 2 {
                assert!(v.iter().any(|&x| x != 0.0));
            }
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.next_below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order differs w.h.p.");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        for (n, k) in [(1000, 10), (50, 25), (10, 10), (5, 0)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1], "sorted + distinct");
            }
            assert!(idx.iter().all(|&j| j < n));
        }
    }

    #[test]
    fn binomial_range_and_mean() {
        let mut r = Xoshiro256pp::seed_from_u64(17);
        let mut total = 0u64;
        let reps = 50_000;
        for _ in 0..reps {
            let g = r.next_binomial(2, 0.3);
            assert!(g <= 2);
            total += g as u64;
        }
        let mean = total as f64 / reps as f64;
        assert!((mean - 0.6).abs() < 0.02, "mean {mean}");
    }
}
