//! The serve wire format, in one place: every JSON body the server emits —
//! success or error, fit or stats — is built by an encoder in this module.
//!
//! Fit-shaped responses render through the same
//! [`crate::api::fit::solve_json`] as [`crate::api::Fit::to_json`], and
//! workspace stats render through [`crate::api::StatsSnapshot::to_json`] —
//! the single-source-of-truth contract behind the pinned
//! "server bytes == direct `api::` bytes" tests: a schema can only change by
//! changing the one encoder both sides share.

use crate::api::fit::{solve_json, PathFit};
use crate::api::StatsSnapshot;
use crate::serve::metrics::MetricsSnapshot;
use crate::serve::registry::{Solved, StoredDesign};
use crate::util::json::Json;

/// One fully-rendered HTTP response: status, JSON body, and the optional
/// `Retry-After` header admission rejections carry.
#[derive(Clone, Debug)]
pub struct Reply {
    /// HTTP status code.
    pub status: u16,
    /// JSON body.
    pub body: String,
    /// `Retry-After` header value, seconds (503s from admission control).
    pub retry_after_secs: Option<u64>,
}

impl Reply {
    /// A 200 with the given body.
    pub fn ok(body: String) -> Reply {
        Reply { status: 200, body, retry_after_secs: None }
    }

    /// An error reply with the uniform error body.
    pub fn error(status: u16, message: &str) -> Reply {
        Reply { status, body: error_body(status, message), retry_after_secs: None }
    }

    /// Attach a `Retry-After` header (builder-style).
    pub fn retry_after(mut self, secs: u64) -> Reply {
        self.retry_after_secs = Some(secs);
        self
    }
}

/// The uniform JSON error body.
pub fn error_body(status: u16, message: &str) -> String {
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.error".to_string())),
        ("status", Json::Num(status as f64)),
        ("error", Json::Str(message.to_string())),
    ])
    .to_string()
}

/// `GET /v1/health` body.
pub fn health_body(designs: usize, sessions: usize, threads: usize, draining: bool) -> String {
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.health".to_string())),
        ("status", Json::Str(if draining { "draining" } else { "ok" }.to_string())),
        ("designs", Json::Num(designs as f64)),
        ("sessions", Json::Num(sessions as f64)),
        ("threads", Json::Num(threads as f64)),
        ("draining", Json::Bool(draining)),
    ])
    .to_string()
}

/// `POST /v1/designs` body: the registered design's id and shape.
pub fn design_body(stored: &StoredDesign) -> String {
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.design".to_string())),
        ("design_id", Json::Str(stored.id.clone())),
        ("m", Json::Num(stored.design.m() as f64)),
        ("n", Json::Num(stored.design.n() as f64)),
        ("sparse", Json::Bool(stored.design.is_sparse())),
        (
            "storage",
            Json::Str(
                if stored.design.is_out_of_core() {
                    "out_of_core"
                } else if stored.design.is_sparse() {
                    "csc"
                } else {
                    "dense"
                }
                .to_string(),
            ),
        ),
    ])
    .to_string()
}

/// One solve as JSON — [`solve_json`] with the session's resolved penalties;
/// byte-identical to [`crate::api::Fit::to_json`] on the same solve.
pub fn fit_json(m: usize, n: usize, s: &Solved) -> Json {
    solve_json(m, n, s.lam1, s.lam2, &s.result)
}

/// `POST /v1/fit` / single-`b` `POST /v1/refit` body.
pub fn fit_body(m: usize, n: usize, s: &Solved) -> String {
    fit_json(m, n, s).to_string()
}

/// Batch `POST /v1/refit` body: every solve of the batch, each rendered by
/// the same encoder as a single fit.
pub fn refit_batch_body(m: usize, n: usize, solved: &[Solved]) -> String {
    let fits: Vec<Json> = solved.iter().map(|s| fit_json(m, n, s)).collect();
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.refit_batch".to_string())),
        ("count", Json::Num(fits.len() as f64)),
        ("fits", Json::Arr(fits)),
    ])
    .to_string()
}

/// `POST /v1/predict` body.
pub fn predictions_body(preds: &[f64]) -> String {
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.predictions".to_string())),
        ("m", Json::Num(preds.len() as f64)),
        ("predictions", Json::Arr(preds.iter().map(|&v| Json::Num(v)).collect())),
    ])
    .to_string()
}

/// `POST /v1/path` body.
pub fn path_body(m: usize, n: usize, path: &PathFit) -> String {
    let points: Vec<Json> = path
        .points()
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("c_lambda", Json::Num(p.c_lambda)),
                ("converged", Json::Bool(p.result.converged)),
                ("objective", Json::Num(p.result.objective)),
                ("iterations", Json::Num(p.result.iterations as f64)),
                (
                    "active_set",
                    Json::Arr(p.result.active_set.iter().map(|&j| Json::Num(j as f64)).collect()),
                ),
                (
                    "coefficients",
                    Json::Arr(
                        p.result.active_set.iter().map(|&j| Json::Num(p.result.x[j])).collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.path".to_string())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("lambda_max", Json::Num(path.lambda_max())),
        ("runs", Json::Num(path.runs() as f64)),
        ("truncated", Json::Bool(path.truncated())),
        ("points", Json::Arr(points)),
    ])
    .to_string()
}

/// One warm session's entry in the stats `sessions` array.
#[derive(Clone, Debug)]
pub struct SessionStatsEntry {
    /// The registry key: `design_id:model-spec`.
    pub key: String,
    /// Whether the session was mid-solve when stats were read (its workspace
    /// counters are then omitted rather than waiting on the lock).
    pub busy: bool,
    /// Solves this session has run (cold + refits).
    pub solves: u64,
    /// Workspace reuse counters, absent while busy.
    pub workspace: Option<StatsSnapshot>,
}

impl SessionStatsEntry {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("key", Json::Str(self.key.clone())),
            ("busy", Json::Bool(self.busy)),
            ("solves", Json::Num(self.solves as f64)),
        ];
        match &self.workspace {
            Some(ws) => fields.push(("workspace", ws.to_json())),
            None => fields.push(("workspace", Json::Null)),
        }
        Json::obj(fields)
    }
}

/// `GET /v1/stats` body: server-wide counters ([`MetricsSnapshot`]), the
/// admission gauges, coalescing economics, per-endpoint latency histograms,
/// and per-session workspace stats.
pub fn stats_body(snap: &MetricsSnapshot, sessions: &[SessionStatsEntry]) -> String {
    let g = snap.gauges;
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.stats".to_string())),
        ("uptime_seconds", Json::Num(snap.uptime_seconds)),
        ("inflight", Json::Num(g.inflight as f64)),
        ("max_inflight", Json::Num(g.max_inflight as f64)),
        (
            "queue",
            Json::obj(vec![
                ("depth", Json::Num(g.queue_depth as f64)),
                ("capacity", Json::Num(g.queue_capacity as f64)),
                ("queued_total", Json::Num(snap.queued_total as f64)),
                ("rejected_full", Json::Num(snap.rejected_queue_full as f64)),
            ]),
        ),
        (
            "deadlines",
            Json::obj(vec![
                ("read_timeouts_408", Json::Num(snap.timeouts_read as f64)),
                ("expired_503", Json::Num(snap.rejected_deadline as f64)),
            ]),
        ),
        (
            "coalesce",
            Json::obj(vec![
                ("batches", Json::Num(snap.coalesce_batches as f64)),
                ("requests", Json::Num(snap.coalesce_requests as f64)),
                ("coalesced_requests", Json::Num(snap.coalesced_requests as f64)),
                ("ratio", Json::Num(snap.coalesce_ratio())),
            ]),
        ),
        ("admitted", Json::Num(snap.admitted as f64)),
        ("endpoints", Json::Arr(snap.endpoints.iter().map(|e| e.to_json()).collect())),
        ("sessions", Json::Arr(sessions.iter().map(|s| s.to_json()).collect())),
    ])
    .to_string()
}
