//! Request handlers: JSON wire format in, JSON out, typed errors throughout.
//!
//! The contract that matters here is **no panic is reachable from a request
//! body**: every malformed field becomes a [`ServeError`] (and so an HTTP
//! status), every solver failure arrives as a typed
//! [`EnetError`] — and the status mapping below matches on every variant by
//! name, so adding an error variant without classifying it is a compile
//! error, not a 500 at 2am.
//!
//! Response bodies are built exclusively by [`crate::serve::wire`] — the one
//! encoder set shared with the `api::` layer, which makes a server response
//! byte-identical to the equivalent direct `api::` call.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::api::{EnetError, EnetModel};
use crate::linalg::{CscMat, DesignStorage, Mat};
use crate::parallel::resolve_threads;
use crate::serve::http::Request;
use crate::serve::metrics::ServeMetrics;
use crate::serve::registry::{SessionSlot, StoredDesign};
use crate::serve::server::ServerState;
use crate::serve::wire::{self, Reply, SessionStatsEntry};
use crate::solver::types::Algorithm;
use crate::util::json::Json;

/// Everything a request can fail with, mapped totally onto HTTP statuses.
#[derive(Debug)]
pub enum ServeError {
    /// A typed error from the solve stack.
    Api(EnetError),
    /// The request body or fields did not parse.
    BadRequest(String),
    /// Unknown route or unknown `design_id`.
    NotFound(String),
    /// Known path, wrong method.
    MethodNotAllowed,
    /// Admission control rejected the request: the queue in front of the
    /// in-flight cap is full.
    Busy {
        /// Requests waiting in the admission queue.
        queued: usize,
        /// The queue capacity.
        queue_capacity: usize,
    },
}

impl From<EnetError> for ServeError {
    fn from(e: EnetError) -> Self {
        ServeError::Api(e)
    }
}

impl ServeError {
    /// The HTTP status for this error. The `EnetError` arm lists every
    /// variant — no wildcard — so the mapping stays total by construction.
    pub fn status(&self) -> u16 {
        match self {
            ServeError::Api(e) => match e {
                EnetError::ShapeMismatch { .. }
                | EnetError::EmptyDesign { .. }
                | EnetError::NonFinite { .. }
                | EnetError::InvalidPenalty { .. }
                | EnetError::InvalidAlpha { .. }
                | EnetError::InvalidCLambda { .. }
                | EnetError::InvalidGrid { .. }
                | EnetError::InvalidTolerance { .. }
                | EnetError::InvalidIterations
                | EnetError::InvalidFolds { .. }
                | EnetError::InvalidDesign { .. }
                | EnetError::PredictShape { .. }
                | EnetError::WarmStartShape { .. } => 400,
                EnetError::Unsupported { .. } => 422,
                EnetError::Backend(_) => 502,
                // The request's budget ran out before the solve was
                // dispatched — the server never started the work, so the
                // client can safely retry.
                EnetError::Deadline { .. } => 503,
            },
            ServeError::BadRequest(_) => 400,
            ServeError::NotFound(_) => 404,
            ServeError::MethodNotAllowed => 405,
            ServeError::Busy { .. } => 503,
        }
    }

    /// Human-readable description for the error body.
    pub fn message(&self) -> String {
        match self {
            ServeError::Api(e) => e.to_string(),
            ServeError::BadRequest(msg) => msg.clone(),
            ServeError::NotFound(what) => format!("{what} not found"),
            ServeError::MethodNotAllowed => "method not allowed".to_string(),
            ServeError::Busy { queued, queue_capacity } => format!(
                "server at capacity (admission queue full: {queued} waiting, cap \
                 {queue_capacity}); retry"
            ),
        }
    }

    /// `Retry-After` seconds for errors where a retry is the protocol
    /// (admission-control 503s), `None` otherwise.
    pub fn retry_after_secs(&self) -> Option<u64> {
        match self {
            ServeError::Busy { .. } | ServeError::Api(EnetError::Deadline { .. }) => Some(1),
            _ => None,
        }
    }

    /// Render as a full HTTP reply.
    pub fn reply(&self) -> Reply {
        let status = self.status();
        let reply = Reply::error(status, &self.message());
        match self.retry_after_secs() {
            Some(secs) => reply.retry_after(secs),
            None => reply,
        }
    }
}

/// Dispatch one request to its handler; errors become typed replies.
pub fn handle(state: &ServerState, req: &Request) -> Reply {
    match route(state, req) {
        Ok(body) => Reply::ok(body),
        Err(e) => {
            if matches!(e, ServeError::Api(EnetError::Deadline { .. })) {
                ServeMetrics::bump(&state.metrics.rejected_deadline);
            }
            e.reply()
        }
    }
}

fn route(state: &ServerState, req: &Request) -> Result<String, ServeError> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/health") => health(state),
        ("GET", "/v1/stats") => stats(state),
        ("POST", "/v1/designs") => register_design(state, &parse_body(&req.body)?),
        ("POST", "/v1/fit") => fit(state, req, &parse_body(&req.body)?),
        ("POST", "/v1/refit") => refit(state, req, &parse_body(&req.body)?),
        ("POST", "/v1/predict") => predict(state, req, &parse_body(&req.body)?),
        ("POST", "/v1/path") => path(state, req, &parse_body(&req.body)?),
        (
            _,
            "/v1/health" | "/v1/stats" | "/v1/designs" | "/v1/fit" | "/v1/refit" | "/v1/predict"
            | "/v1/path",
        ) => Err(ServeError::MethodNotAllowed),
        _ => Err(ServeError::NotFound(format!("route {} {}", req.method, req.path))),
    }
}

/// Fail with a typed 503 if the request's deadline expired before the
/// expensive part (the solve) was dispatched — a request that spent its whole
/// budget queued must not burn a solver slot on an answer nobody is waiting
/// for.
fn check_deadline(req: &Request) -> Result<(), ServeError> {
    match (req.deadline, req.budget_ms) {
        (Some(d), Some(budget_ms)) if Instant::now() >= d => {
            Err(ServeError::Api(EnetError::Deadline { budget_ms }))
        }
        _ => Ok(()),
    }
}

// ---- request parsing --------------------------------------------------------

fn parse_body(body: &[u8]) -> Result<Json, ServeError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServeError::BadRequest("body is not UTF-8".to_string()))?;
    if text.trim().is_empty() {
        return Ok(Json::Obj(BTreeMap::new()));
    }
    Json::parse(text).map_err(|e| ServeError::BadRequest(format!("invalid JSON body: {e}")))
}

fn num_field(spec: &Json, key: &str) -> Result<Option<f64>, ServeError> {
    match spec.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_f64() {
            Some(x) => Ok(Some(x)),
            None => Err(ServeError::BadRequest(format!("field {key:?} must be a number"))),
        },
    }
}

fn usize_field(spec: &Json, key: &str) -> Result<Option<usize>, ServeError> {
    match spec.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_usize() {
            Some(x) => Ok(Some(x)),
            None => Err(ServeError::BadRequest(format!(
                "field {key:?} must be a non-negative integer"
            ))),
        },
    }
}

fn str_field<'a>(spec: &'a Json, key: &str) -> Result<Option<&'a str>, ServeError> {
    match spec.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_str() {
            Some(s) => Ok(Some(s)),
            None => Err(ServeError::BadRequest(format!("field {key:?} must be a string"))),
        },
    }
}

fn f64_vec(v: &Json, what: &str) -> Result<Vec<f64>, ServeError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| ServeError::BadRequest(format!("{what} must be an array of numbers")))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| ServeError::BadRequest(format!("{what} must contain only numbers")))
        })
        .collect()
}

fn usize_vec(v: &Json, what: &str) -> Result<Vec<usize>, ServeError> {
    let arr = v.as_arr().ok_or_else(|| {
        ServeError::BadRequest(format!("{what} must be an array of non-negative integers"))
    })?;
    arr.iter()
        .map(|x| {
            x.as_usize().ok_or_else(|| {
                ServeError::BadRequest(format!("{what} must contain only non-negative integers"))
            })
        })
        .collect()
}

/// Parse a matrix spec: `{"m", "n", "dense": [row-major values]}` or
/// `{"m", "n", "col_ptr", "row_idx", "values"}` (CSC). CSC structure defects
/// surface as `EnetError::InvalidDesign` via `CscMat::try_new` — the same
/// validation the library applies, never a panic.
fn parse_matrix(spec: &Json, what: &str) -> Result<DesignStorage, ServeError> {
    let m = usize_field(spec, "m")?
        .ok_or_else(|| ServeError::BadRequest(format!("{what}: missing \"m\" (rows)")))?;
    let n = usize_field(spec, "n")?
        .ok_or_else(|| ServeError::BadRequest(format!("{what}: missing \"n\" (columns)")))?;
    match (spec.get("dense"), spec.get("col_ptr")) {
        (Some(dense), None) => {
            let values = f64_vec(dense, &format!("{what}.dense"))?;
            let expect = m
                .checked_mul(n)
                .ok_or_else(|| ServeError::BadRequest(format!("{what}: m*n overflows")))?;
            if values.len() != expect {
                return Err(ServeError::BadRequest(format!(
                    "{what}: \"dense\" has {} values, expected m*n = {expect}",
                    values.len()
                )));
            }
            Ok(DesignStorage::from(Mat::from_row_major(m, n, &values)))
        }
        (None, Some(col_ptr)) => {
            let col_ptr = usize_vec(col_ptr, &format!("{what}.col_ptr"))?;
            let row_idx = match spec.get("row_idx") {
                Some(v) => usize_vec(v, &format!("{what}.row_idx"))?,
                None => {
                    return Err(ServeError::BadRequest(format!("{what}: missing \"row_idx\"")))
                }
            };
            let values = match spec.get("values") {
                Some(v) => f64_vec(v, &format!("{what}.values"))?,
                None => return Err(ServeError::BadRequest(format!("{what}: missing \"values\""))),
            };
            let csc = CscMat::try_new(m, n, col_ptr, row_idx, values)
                .map_err(|reason| ServeError::Api(EnetError::InvalidDesign { reason }))?;
            Ok(DesignStorage::from(csc))
        }
        (Some(_), Some(_)) => Err(ServeError::BadRequest(format!(
            "{what}: give \"dense\" or CSC arrays, not both"
        ))),
        (None, None) => Err(ServeError::BadRequest(format!(
            "{what}: missing matrix payload (\"dense\" or \"col_ptr\"/\"row_idx\"/\"values\")"
        ))),
    }
}

/// Parse the string name of an [`Algorithm`] — the same names
/// `Algorithm::name` renders and the CLI accepts.
fn parse_algorithm(name: &str) -> Result<Algorithm, ServeError> {
    match name {
        "ssnal-en" => Ok(Algorithm::SsnalEn),
        "cd-naive" => Ok(Algorithm::CdNaive),
        "cd-cov" => Ok(Algorithm::CdCovariance),
        "fista" => Ok(Algorithm::Fista),
        "prox-grad" => Ok(Algorithm::ProximalGradient),
        "admm" => Ok(Algorithm::Admm),
        "gap-safe" => Ok(Algorithm::CdGapSafe),
        "celer" => Ok(Algorithm::Celer),
        other => Err(ServeError::BadRequest(format!(
            "unknown algorithm {other:?} (ssnal-en|cd-naive|cd-cov|fista|prox-grad|admm|gap-safe|celer)"
        ))),
    }
}

/// Parse the optional `"model"` object into an [`EnetModel`] plus the
/// canonical session key (the spec re-serialized — `Json::Obj` is a
/// `BTreeMap`, so equivalent specs produce the same key regardless of field
/// order in the request).
fn parse_model(spec: Option<&Json>) -> Result<(EnetModel, String), ServeError> {
    let Some(spec) = spec else {
        return Ok((EnetModel::new(), "{}".to_string()));
    };
    let Json::Obj(fields) = spec else {
        return Err(ServeError::BadRequest("\"model\" must be an object".to_string()));
    };
    for key in fields.keys() {
        match key.as_str() {
            "alpha" | "c" | "lam1" | "lam2" | "tol" | "max_iters" | "algorithm" | "grid"
            | "max_active" => {}
            "threads" => {
                return Err(ServeError::BadRequest(
                    "\"model.threads\" is not accepted: the server owns thread budgeting \
                     (see the --threads server flag)"
                        .to_string(),
                ))
            }
            other => return Err(ServeError::BadRequest(format!("unknown model field {other:?}"))),
        }
    }
    let mut model = EnetModel::new();
    let alpha = num_field(spec, "alpha")?;
    let c = num_field(spec, "c")?;
    let lam1 = num_field(spec, "lam1")?;
    let lam2 = num_field(spec, "lam2")?;
    match (lam1, lam2, c) {
        (Some(l1), Some(l2), None) => {
            if alpha.is_some() {
                return Err(ServeError::BadRequest(
                    "\"alpha\" does not combine with explicit (\"lam1\", \"lam2\")".to_string(),
                ));
            }
            model = model.lambda(l1, l2);
        }
        (None, None, Some(c)) => {
            // The paper's (α, c_λ) parametrization; α defaults to the
            // builder's 0.8 when absent.
            model = model.alpha_c(alpha.unwrap_or(0.8), c);
        }
        (None, None, None) => {
            if let Some(a) = alpha {
                model = model.alpha(a);
            }
        }
        _ => {
            return Err(ServeError::BadRequest(
                "penalty spec must be (\"lam1\" and \"lam2\") or \"c\" (optionally with \"alpha\")"
                    .to_string(),
            ))
        }
    }
    if let Some(tol) = num_field(spec, "tol")? {
        model = model.tol(tol);
    }
    if let Some(iters) = usize_field(spec, "max_iters")? {
        model = model.max_iters(iters);
    }
    if let Some(name) = str_field(spec, "algorithm")? {
        model = model.algorithm(parse_algorithm(name)?);
    }
    if let Some(grid) = spec.get("grid") {
        let hi = num_field(grid, "hi")?
            .ok_or_else(|| ServeError::BadRequest("\"model.grid\" needs \"hi\"".to_string()))?;
        let lo = num_field(grid, "lo")?
            .ok_or_else(|| ServeError::BadRequest("\"model.grid\" needs \"lo\"".to_string()))?;
        let points = usize_field(grid, "points")?
            .ok_or_else(|| ServeError::BadRequest("\"model.grid\" needs \"points\"".to_string()))?;
        model = model.grid(hi, lo, points);
    }
    if let Some(max_active) = usize_field(spec, "max_active")? {
        model = model.max_active(max_active);
    }
    Ok((model, spec.to_string()))
}

fn lookup_design(state: &ServerState, body: &Json) -> Result<Arc<StoredDesign>, ServeError> {
    let id = str_field(body, "design_id")?
        .ok_or_else(|| ServeError::BadRequest("missing \"design_id\"".to_string()))?;
    state
        .registry
        .design(id)
        .ok_or_else(|| ServeError::NotFound(format!("design {id:?}")))
}

fn lookup_session(state: &ServerState, body: &Json) -> Result<Arc<SessionSlot>, ServeError> {
    let design = lookup_design(state, body)?;
    let (model, model_key) = parse_model(body.get("model"))?;
    Ok(state.registry.session(&design, &model, &model_key)?)
}

// ---- handlers ---------------------------------------------------------------

fn health(state: &ServerState) -> Result<String, ServeError> {
    Ok(wire::health_body(
        state.registry.design_count(),
        state.registry.session_count(),
        resolve_threads(state.cfg.threads),
        state.draining(),
    ))
}

/// `GET /v1/stats` — the observability surface: admission gauges, queue and
/// deadline counters, coalescing economics, per-endpoint latency histograms,
/// and per-session workspace reuse stats. Never blocks on a solve: busy
/// sessions are reported as such with their counters omitted.
fn stats(state: &ServerState) -> Result<String, ServeError> {
    let snap = state.metrics.snapshot(state.admission_gauges());
    let entries: Vec<SessionStatsEntry> = state
        .registry
        .sessions_snapshot()
        .into_iter()
        .map(|(key, slot)| match slot.try_session() {
            Some(session) => SessionStatsEntry {
                key,
                busy: false,
                solves: session.solves(),
                workspace: Some(session.workspace_snapshot()),
            },
            None => SessionStatsEntry { key, busy: true, solves: 0, workspace: None },
        })
        .collect();
    Ok(wire::stats_body(&snap, &entries))
}

/// `POST /v1/designs` — body: a matrix spec plus `"b"` (response vector),
/// or `{"path": "...", "b": [...]}` (+ optional `"cache_bytes"`) registering
/// an on-disk out-of-core design by reference. Registration is idempotent;
/// the returned `design_id` is a content fingerprint (for `"path"`
/// registrations it is derived from the file header, whose `content_hash`
/// covers the encoded payload — no matrix body crosses the wire).
fn register_design(state: &ServerState, body: &Json) -> Result<String, ServeError> {
    let storage = match body.get("path") {
        Some(path) => {
            // On-disk out-of-core registration: no matrix upload, the
            // fingerprint comes from the file header's content hash.
            if body.get("dense").is_some() || body.get("col_ptr").is_some() {
                return Err(ServeError::BadRequest(
                    "give \"path\" or an inline matrix payload, not both".to_string(),
                ));
            }
            let path = path
                .as_str()
                .ok_or_else(|| ServeError::BadRequest("\"path\" must be a string".to_string()))?;
            let cache_bytes = usize_field(body, "cache_bytes")?
                .unwrap_or(crate::linalg::ooc::DEFAULT_CACHE_BYTES);
            let ooc = crate::linalg::OocDesign::open_with_cache(
                std::path::Path::new(path),
                cache_bytes,
            )
            .map_err(|e| {
                ServeError::Api(EnetError::InvalidDesign { reason: format!("{path}: {e}") })
            })?;
            DesignStorage::OutOfCore(ooc)
        }
        None => parse_matrix(body, "design")?,
    };
    let b = body
        .get("b")
        .ok_or_else(|| ServeError::BadRequest("missing \"b\" (response vector)".to_string()))?;
    let b = f64_vec(b, "b")?;
    let stored = state.registry.register(storage, b)?;
    Ok(wire::design_body(&stored))
}

/// `POST /v1/fit` — body: `"design_id"`, optional `"model"`, optional `"b"`
/// override. Without `"b"` the design's stored response is fit (cached: a
/// repeat call returns the same solve without re-running it); with `"b"` the
/// warm session refits on the new response.
fn fit(state: &ServerState, req: &Request, body: &Json) -> Result<String, ServeError> {
    let slot = lookup_session(state, body)?;
    check_deadline(req)?;
    let mut session = slot.session();
    if let Some(b) = body.get("b") {
        let b = f64_vec(b, "b")?;
        session.refit(&b)?;
    }
    Ok(session.solved_json()?.to_string())
}

/// `POST /v1/refit` — body: `"design_id"`, optional `"model"`, and exactly
/// one of `"b"` (single response → one fit object) or `"bs"` (batch → all
/// fits, λmax sweeps fused across the batch).
///
/// Single-`b` refits go through the session's coalescer: concurrent requests
/// on the same warm session merge into one `refit_many` batch. The response
/// bytes are identical either way (the pinned `refit_many` == sequential
/// `refit` bitwise contract).
fn refit(state: &ServerState, req: &Request, body: &Json) -> Result<String, ServeError> {
    let slot = lookup_session(state, body)?;
    let (m, n) = (slot.design().design.m(), slot.design().design.n());
    match (body.get("b"), body.get("bs")) {
        (Some(b), None) => {
            let b = f64_vec(b, "b")?;
            check_deadline(req)?;
            let solved = slot.refit_coalesced(b, &state.metrics)?;
            Ok(wire::fit_body(m, n, &solved))
        }
        (None, Some(bs)) => {
            let arr = bs.as_arr().ok_or_else(|| {
                ServeError::BadRequest("\"bs\" must be an array of response vectors".to_string())
            })?;
            let mut batch = Vec::with_capacity(arr.len());
            for (i, b) in arr.iter().enumerate() {
                batch.push(f64_vec(b, &format!("bs[{i}]"))?);
            }
            check_deadline(req)?;
            let solved = slot.session().refit_many(&batch)?;
            Ok(wire::refit_batch_body(m, n, &solved))
        }
        _ => Err(ServeError::BadRequest(
            "give exactly one of \"b\" (single response) or \"bs\" (batch)".to_string(),
        )),
    }
}

/// `POST /v1/predict` — body: `"design_id"`, optional `"model"`, `"a_new"`
/// (matrix spec, dense or CSC). Fits lazily on the stored response if the
/// session has no solve yet.
fn predict(state: &ServerState, req: &Request, body: &Json) -> Result<String, ServeError> {
    let slot = lookup_session(state, body)?;
    let a_new = body
        .get("a_new")
        .ok_or_else(|| ServeError::BadRequest("missing \"a_new\" (matrix spec)".to_string()))?;
    let storage = parse_matrix(a_new, "a_new")?;
    check_deadline(req)?;
    let mut session = slot.session();
    let preds = session.predict(storage.as_ref())?;
    Ok(wire::predictions_body(&preds))
}

/// `POST /v1/path` — body: `"design_id"`, optional `"model"` (its `grid`
/// drives the sweep). Coefficients per point are sparse: values at
/// `active_set`'s indices, like the fit export.
fn path(state: &ServerState, req: &Request, body: &Json) -> Result<String, ServeError> {
    let slot = lookup_session(state, body)?;
    check_deadline(req)?;
    let session = slot.session();
    let path = session.path()?;
    let (m, n) = (slot.design().design.m(), slot.design().design.n());
    Ok(wire::path_body(m, n, &path))
}
