//! Lock-cheap serving metrics: per-endpoint request counters and
//! fixed-bucket latency histograms, plus the admission/coalescing counters
//! behind `GET /v1/stats`.
//!
//! Everything on the record path is a relaxed atomic increment — no locks,
//! no allocation — so instrumentation cannot perturb the request paths it
//! measures. Reads (`/v1/stats`) take a point-in-time snapshot into plain
//! structs; the snapshot is not a consistent cut across counters (readers
//! race writers by design), which is fine for observability and disastrous
//! for nothing.
//!
//! Histogram buckets are fixed at compile time: half-decade log spacing from
//! 100µs to 10s plus an overflow bucket. Fixed buckets keep recording O(1),
//! make histograms mergeable across processes, and give `/v1/stats` a stable
//! schema; quantiles are read off the cumulative bucket counts (reported as
//! the upper bound of the bucket containing the rank, i.e. conservatively).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Upper bounds (seconds) of the finite latency buckets; one overflow bucket
/// follows. Half-decade log spacing: 100µs … 10s.
pub const BUCKET_BOUNDS_SECONDS: [f64; 11] =
    [1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2, 1e-1, 3.16e-1, 1.0, 3.16, 10.0];

/// Finite buckets + overflow.
pub const BUCKETS: usize = BUCKET_BOUNDS_SECONDS.len() + 1;

/// The serve endpoints metrics are kept for, in display order. `Other`
/// absorbs unknown routes (404s) so they are visible rather than untracked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// `GET /v1/health`
    Health,
    /// `POST /v1/designs`
    Designs,
    /// `POST /v1/fit`
    Fit,
    /// `POST /v1/refit`
    Refit,
    /// `POST /v1/predict`
    Predict,
    /// `POST /v1/path`
    Path,
    /// `GET /v1/stats`
    Stats,
    /// Anything else (unknown routes, wrong methods).
    Other,
}

/// All endpoints, in the order `/v1/stats` reports them.
pub const ENDPOINTS: [Endpoint; 8] = [
    Endpoint::Health,
    Endpoint::Designs,
    Endpoint::Fit,
    Endpoint::Refit,
    Endpoint::Predict,
    Endpoint::Path,
    Endpoint::Stats,
    Endpoint::Other,
];

impl Endpoint {
    /// Classify a request path (method-independent: a wrong-method hit on a
    /// known path still counts against that path's endpoint).
    pub fn from_path(path: &str) -> Endpoint {
        match path {
            "/v1/health" => Endpoint::Health,
            "/v1/designs" => Endpoint::Designs,
            "/v1/fit" => Endpoint::Fit,
            "/v1/refit" => Endpoint::Refit,
            "/v1/predict" => Endpoint::Predict,
            "/v1/path" => Endpoint::Path,
            "/v1/stats" => Endpoint::Stats,
            _ => Endpoint::Other,
        }
    }

    /// Stable name used in the stats schema.
    pub fn name(&self) -> &'static str {
        match self {
            Endpoint::Health => "health",
            Endpoint::Designs => "designs",
            Endpoint::Fit => "fit",
            Endpoint::Refit => "refit",
            Endpoint::Predict => "predict",
            Endpoint::Path => "path",
            Endpoint::Stats => "stats",
            Endpoint::Other => "other",
        }
    }

    fn index(&self) -> usize {
        match self {
            Endpoint::Health => 0,
            Endpoint::Designs => 1,
            Endpoint::Fit => 2,
            Endpoint::Refit => 3,
            Endpoint::Predict => 4,
            Endpoint::Path => 5,
            Endpoint::Stats => 6,
            Endpoint::Other => 7,
        }
    }
}

/// Fixed-bucket latency histogram; every operation is a relaxed atomic.
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    sum_nanos: AtomicU64,
}

impl LatencyHistogram {
    fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_nanos: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    pub fn record(&self, seconds: f64) {
        let idx = BUCKET_BOUNDS_SECONDS
            .iter()
            .position(|&bound| seconds <= bound)
            .unwrap_or(BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        let nanos = (seconds * 1e9).clamp(0.0, u64::MAX as f64 / 2.0) as u64;
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Point-in-time copy.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
            sum_seconds: self.sum_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

/// Plain-struct copy of a [`LatencyHistogram`].
#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramSnapshot {
    /// Per-bucket counts (finite buckets in [`BUCKET_BOUNDS_SECONDS`] order,
    /// then the overflow bucket).
    pub counts: [u64; BUCKETS],
    /// Sum of all observations, seconds (for means).
    pub sum_seconds: f64,
}

impl HistogramSnapshot {
    /// Total observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// rank-`q` observation (the overflow bucket reports the last finite
    /// bound — a floor, clearly saturated). `0.0` with no observations.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return BUCKET_BOUNDS_SECONDS[i.min(BUCKET_BOUNDS_SECONDS.len() - 1)];
            }
        }
        BUCKET_BOUNDS_SECONDS[BUCKET_BOUNDS_SECONDS.len() - 1]
    }

    /// The canonical JSON shape: cumulative-style bucket list plus count,
    /// mean, p50, p95.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let le = match BUCKET_BOUNDS_SECONDS.get(i) {
                    Some(&bound) => Json::Num(bound),
                    None => Json::Str("inf".to_string()),
                };
                Json::obj(vec![("le_seconds", le), ("count", Json::Num(c as f64))])
            })
            .collect();
        let count = self.count();
        let mean = if count == 0 { 0.0 } else { self.sum_seconds / count as f64 };
        Json::obj(vec![
            ("count", Json::Num(count as f64)),
            ("mean_seconds", Json::Num(mean)),
            ("p50_seconds", Json::Num(self.quantile(0.50))),
            ("p95_seconds", Json::Num(self.quantile(0.95))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// One endpoint's counters.
#[derive(Debug)]
struct EndpointMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    latency: LatencyHistogram,
}

/// Plain-struct copy of one endpoint's counters.
#[derive(Clone, Copy, Debug)]
pub struct EndpointSnapshot {
    /// Which endpoint.
    pub endpoint: Endpoint,
    /// Requests answered (all statuses).
    pub requests: u64,
    /// Requests answered with status ≥ 400.
    pub errors: u64,
    /// Latency distribution (request read end → response written).
    pub latency: HistogramSnapshot,
}

impl EndpointSnapshot {
    /// JSON for one entry of the stats `endpoints` array.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("endpoint", Json::Str(self.endpoint.name().to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("latency", self.latency.to_json()),
        ])
    }
}

/// All server-wide counters behind `GET /v1/stats`. Gauges that live in the
/// admission structure (queue depth, in-flight) are passed in at snapshot
/// time by the server.
#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    endpoints: [EndpointMetrics; ENDPOINTS.len()],
    /// Requests admitted to run (immediately or after queueing).
    pub admitted: AtomicU64,
    /// Requests that waited in the admission queue before running.
    pub queued_total: AtomicU64,
    /// 503s: admission queue full.
    pub rejected_queue_full: AtomicU64,
    /// 503s: deadline expired while queued or before solve dispatch.
    pub rejected_deadline: AtomicU64,
    /// 408s: header or body read stalled past the request deadline.
    pub timeouts_read: AtomicU64,
    /// Coalesced-refit batches executed (one `refit_many` call each).
    pub coalesce_batches: AtomicU64,
    /// Single-refit requests served through those batches.
    pub coalesce_requests: AtomicU64,
    /// Of those, requests that shared a batch with at least one other.
    pub coalesced_requests: AtomicU64,
}

impl ServeMetrics {
    /// Fresh counters; `started` anchors the uptime gauge.
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            endpoints: std::array::from_fn(|_| EndpointMetrics {
                requests: AtomicU64::new(0),
                errors: AtomicU64::new(0),
                latency: LatencyHistogram::new(),
            }),
            admitted: AtomicU64::new(0),
            queued_total: AtomicU64::new(0),
            rejected_queue_full: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            timeouts_read: AtomicU64::new(0),
            coalesce_batches: AtomicU64::new(0),
            coalesce_requests: AtomicU64::new(0),
            coalesced_requests: AtomicU64::new(0),
        }
    }

    /// Record one answered request.
    pub fn record(&self, endpoint: Endpoint, seconds: f64, status: u16) {
        let e = &self.endpoints[endpoint.index()];
        e.requests.fetch_add(1, Ordering::Relaxed);
        if status >= 400 {
            e.errors.fetch_add(1, Ordering::Relaxed);
        }
        e.latency.record(seconds);
    }

    /// Record one coalesced-refit batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        self.coalesce_batches.fetch_add(1, Ordering::Relaxed);
        self.coalesce_requests.fetch_add(size as u64, Ordering::Relaxed);
        if size >= 2 {
            self.coalesced_requests.fetch_add(size as u64, Ordering::Relaxed);
        }
    }

    /// Bump a plain counter (relaxed).
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of everything; the admission gauges come from the
    /// server, which owns them.
    pub fn snapshot(&self, gauges: AdmissionGauges) -> MetricsSnapshot {
        MetricsSnapshot {
            uptime_seconds: self.started.elapsed().as_secs_f64(),
            gauges,
            admitted: self.admitted.load(Ordering::Relaxed),
            queued_total: self.queued_total.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            rejected_deadline: self.rejected_deadline.load(Ordering::Relaxed),
            timeouts_read: self.timeouts_read.load(Ordering::Relaxed),
            coalesce_batches: self.coalesce_batches.load(Ordering::Relaxed),
            coalesce_requests: self.coalesce_requests.load(Ordering::Relaxed),
            coalesced_requests: self.coalesced_requests.load(Ordering::Relaxed),
            endpoints: ENDPOINTS.map(|ep| EndpointSnapshot {
                endpoint: ep,
                requests: self.endpoints[ep.index()].requests.load(Ordering::Relaxed),
                errors: self.endpoints[ep.index()].errors.load(Ordering::Relaxed),
                latency: self.endpoints[ep.index()].latency.snapshot(),
            }),
        }
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

/// Instantaneous admission-control gauges, read from the server's admission
/// structure at snapshot time.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionGauges {
    /// Requests currently executing.
    pub inflight: usize,
    /// The in-flight cap.
    pub max_inflight: usize,
    /// Requests currently waiting in the admission queue.
    pub queue_depth: usize,
    /// The queue capacity.
    pub queue_capacity: usize,
}

/// Point-in-time copy of [`ServeMetrics`] — the typed struct `/v1/stats`
/// renders (via `serve::wire`).
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    /// Seconds since the metrics (== the server) were created.
    pub uptime_seconds: f64,
    /// Instantaneous admission gauges.
    pub gauges: AdmissionGauges,
    /// Requests admitted to run.
    pub admitted: u64,
    /// Requests that waited in the queue before running.
    pub queued_total: u64,
    /// 503s from a full queue.
    pub rejected_queue_full: u64,
    /// 503s from an expired deadline (queued or pre-dispatch).
    pub rejected_deadline: u64,
    /// 408s from stalled header/body reads.
    pub timeouts_read: u64,
    /// Coalesced-refit batches executed.
    pub coalesce_batches: u64,
    /// Single-refit requests served through batches.
    pub coalesce_requests: u64,
    /// Requests that shared a batch with at least one other.
    pub coalesced_requests: u64,
    /// Per-endpoint counters in [`ENDPOINTS`] order.
    pub endpoints: [EndpointSnapshot; ENDPOINTS.len()],
}

impl MetricsSnapshot {
    /// Requests per executed batch (`1.0` when every batch was a singleton,
    /// higher when coalescing merged concurrent refits; `0.0` before any
    /// batch ran).
    pub fn coalesce_ratio(&self) -> f64 {
        if self.coalesce_batches == 0 {
            0.0
        } else {
            self.coalesce_requests as f64 / self.coalesce_batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        for &s in &[2e-4, 2e-4, 2e-4, 5e-3, 5e-3, 0.2, 100.0] {
            h.record(s);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 7);
        // 2e-4 lands in (1e-4, 3.16e-4]; 100s overflows
        assert_eq!(snap.counts[1], 3);
        assert_eq!(snap.counts[BUCKETS - 1], 1);
        assert_eq!(snap.quantile(0.5), 3.16e-4, "p50 is the 4th of 7 → 2nd bucket bound");
        assert_eq!(snap.quantile(0.95), 10.0, "p95 saturates at the last finite bound");
        assert_eq!(HistogramSnapshot::default().quantile(0.5), 0.0);
    }

    #[test]
    fn endpoint_classification_is_total() {
        assert_eq!(Endpoint::from_path("/v1/refit"), Endpoint::Refit);
        assert_eq!(Endpoint::from_path("/v1/stats"), Endpoint::Stats);
        assert_eq!(Endpoint::from_path("/nope"), Endpoint::Other);
        for ep in ENDPOINTS {
            assert_eq!(ENDPOINTS[ep.index()], ep, "index/order agreement");
        }
    }

    #[test]
    fn snapshot_carries_counters_and_ratio() {
        let m = ServeMetrics::new();
        m.record(Endpoint::Fit, 1e-3, 200);
        m.record(Endpoint::Fit, 2e-3, 400);
        m.record_batch(3);
        m.record_batch(1);
        ServeMetrics::bump(&m.rejected_queue_full);
        let snap = m.snapshot(AdmissionGauges {
            inflight: 1,
            max_inflight: 4,
            queue_depth: 2,
            queue_capacity: 8,
        });
        let fit = &snap.endpoints[Endpoint::Fit.index()];
        assert_eq!((fit.requests, fit.errors), (2, 1));
        assert_eq!(fit.latency.count(), 2);
        assert_eq!(snap.rejected_queue_full, 1);
        assert_eq!(snap.coalesce_batches, 2);
        assert_eq!(snap.coalesce_requests, 4);
        assert_eq!(snap.coalesced_requests, 3);
        assert!((snap.coalesce_ratio() - 2.0).abs() < 1e-15);
        assert_eq!(snap.gauges.queue_depth, 2);
    }
}
