//! `ssnal-en serve` — a zero-dependency HTTP/1.1 model server over the
//! estimator facade.
//!
//! The serving scenario this targets is the paper's solver used as a warm
//! backend: register a design once, then fit, refit (singly or in batches),
//! predict, and sweep λ-paths against it over JSON, with the Newton
//! workspace and Gram/Cholesky cache staying hot between requests exactly as
//! they do in a [`crate::api::Fit`] session.
//!
//! Layout:
//!
//! * [`http`] — HTTP/1.1 framing over `std::net` with deadline-aware reads
//!   (requests, responses, a keep-alive client for tests and benches),
//! * [`wire`] — every JSON body the server emits, in one encoder set shared
//!   with the `api::` layer (server bytes == api bytes by construction),
//! * [`registry`] — fingerprint-keyed design store and the warm-session LRU,
//!   including the cross-request refit coalescer,
//! * [`handlers`] — routing, request parsing, and the total
//!   `EnetError` → status mapping (no panic reachable from a request),
//! * [`metrics`] — lock-cheap counters and fixed-bucket latency histograms
//!   behind `GET /v1/stats`,
//! * [`server`] — accept loop, bounded-FIFO admission queue, request
//!   deadlines, graceful drain (SIGTERM), per-request thread budgeting,
//!   panic containment.
//!
//! Everything rides on the determinism contracts the rest of the crate pins:
//! because solves are bitwise-identical at every thread count, warm
//! workspaces are bitwise-identical to cold ones, and `refit_many` is
//! bitwise-identical to sequential refits, the server can cache sessions,
//! rebalance threads per request, and *coalesce concurrent refits into one
//! batch* without ever changing a response byte
//! (`tests/serve_integration.rs`).
//!
//! Wire format in one sitting:
//!
//! ```text
//! POST /v1/designs  {"m":2,"n":2,"dense":[1,0,0,1],"b":[3,-1]}   → {"design_id":"d…",…}
//! POST /v1/fit      {"design_id":"d…","model":{"c":0.5}}          → fit JSON (== Fit::export_json)
//! POST /v1/refit    {"design_id":"d…","bs":[[…],[…]]}             → batched fit JSONs
//! POST /v1/predict  {"design_id":"d…","a_new":{…matrix spec…}}    → predictions
//! POST /v1/path     {"design_id":"d…","model":{"grid":{…}}}       → λ-path
//! GET  /v1/health                                                 → liveness + counters
//! GET  /v1/stats                                                  → queue/deadline/coalesce
//!                                                                   counters, per-endpoint
//!                                                                   latency, session stats
//! ```
//!
//! Matrix specs are dense (`"dense"`: row-major values) or CSC
//! (`"col_ptr"`/`"row_idx"`/`"values"`) — sparse designs round-trip through
//! the server without densification.
//!
//! Overload and lifecycle behavior: a request beyond `max_inflight` queues
//! (FIFO, bounded by `--queue-depth`); only a full queue answers `503`, with
//! a `Retry-After` header. Each request has a total time budget
//! (`--request-timeout-ms`): stalled header/body reads answer `408`, and a
//! budget spent entirely in the queue answers `503` without running the
//! solve. SIGTERM begins a graceful drain — late connects refused, admitted
//! work finishes, exit 0.

pub mod handlers;
pub mod http;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod wire;

pub use http::{http_request, Client};
pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use registry::{Registry, Session, SessionSlot, StoredDesign};
pub use server::{install_sigterm_drain, Server, ServerConfig, ServerHandle, ServerState};
