//! `ssnal-en serve` — a zero-dependency HTTP/1.1 model server over the
//! estimator facade.
//!
//! The serving scenario this targets is the paper's solver used as a warm
//! backend: register a design once, then fit, refit (singly or in batches),
//! predict, and sweep λ-paths against it over JSON, with the Newton
//! workspace and Gram/Cholesky cache staying hot between requests exactly as
//! they do in a [`crate::api::Fit`] session.
//!
//! Layout:
//!
//! * [`http`] — HTTP/1.1 framing over `std::net` (requests, responses, a
//!   keep-alive client for tests and benches),
//! * [`registry`] — fingerprint-keyed design store and the warm-session LRU,
//! * [`handlers`] — wire format, routing, and the total
//!   `EnetError` → status mapping (no panic reachable from a request),
//! * [`server`] — accept loop, admission control, per-request thread
//!   budgeting, panic containment.
//!
//! Everything rides on the determinism contracts the rest of the crate pins:
//! because solves are bitwise-identical at every thread count and warm
//! workspaces are bitwise-identical to cold ones, the server can cache
//! sessions and rebalance threads per request without ever changing a
//! response byte (`tests/serve_integration.rs`).
//!
//! Wire format in one sitting:
//!
//! ```text
//! POST /v1/designs  {"m":2,"n":2,"dense":[1,0,0,1],"b":[3,-1]}   → {"design_id":"d…",…}
//! POST /v1/fit      {"design_id":"d…","model":{"c":0.5}}          → fit JSON (== Fit::export_json)
//! POST /v1/refit    {"design_id":"d…","bs":[[…],[…]]}             → batched fit JSONs
//! POST /v1/predict  {"design_id":"d…","a_new":{…matrix spec…}}    → predictions
//! POST /v1/path     {"design_id":"d…","model":{"grid":{…}}}       → λ-path
//! GET  /v1/health                                                 → counters
//! ```
//!
//! Matrix specs are dense (`"dense"`: row-major values) or CSC
//! (`"col_ptr"`/`"row_idx"`/`"values"`) — sparse designs round-trip through
//! the server without densification.

pub mod handlers;
pub mod http;
pub mod registry;
pub mod server;

pub use http::{http_request, Client};
pub use registry::{Registry, Session, StoredDesign};
pub use server::{Server, ServerConfig, ServerHandle, ServerState};
