//! The TCP front end: accept loop, per-connection threads, admission
//! control, and panic containment.
//!
//! Threading model: one OS thread per connection (requests on a connection
//! are serial per HTTP/1.1), with two server-wide controls layered on top:
//!
//! * **Admission** — an atomic in-flight counter; past `max_inflight` a
//!   request is answered `503` immediately instead of queueing unboundedly.
//!   The counter is released by a drop guard, so every exit path — success,
//!   typed error, even a handler panic — frees the slot.
//! * **Thread budget** — each admitted request runs under
//!   `shard::with_threads(total / inflight)`, an even share of the server's
//!   worker budget (floored at one thread). Because every kernel in the
//!   solve stack is thread-count invariant (`tests/determinism.rs`), the
//!   budget affects latency only — response bytes are identical at every
//!   concurrency level, which is what makes this scheduling safe to do at
//!   all.
//!
//! A handler panic (there should be none — see `handlers`' no-panic
//! contract) is caught per-request and answered as a 500; the worker thread
//! and the listener survive.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::parallel::{resolve_threads, shard};
use crate::serve::handlers::{self, error_body, ServeError};
use crate::serve::http::{self, read_request, write_response, ParseError};
use crate::serve::registry::Registry;

/// Server configuration (all CLI-settable; see `ssnal-en serve --help`).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address.
    pub host: String,
    /// Bind port (0 = ephemeral, for tests and benches).
    pub port: u16,
    /// Warm-session LRU capacity.
    pub sessions: usize,
    /// Admission cap: requests in flight before `503`s.
    pub max_inflight: usize,
    /// Total solver thread budget shared across requests (0 = all cores).
    pub threads: usize,
    /// Request body cap in bytes.
    pub max_body: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            host: "127.0.0.1".to_string(),
            port: 7878,
            sessions: 16,
            max_inflight: 32,
            threads: 0,
            max_body: 256 << 20,
        }
    }
}

/// State shared by every connection thread.
pub struct ServerState {
    /// Design store + warm-session LRU.
    pub registry: Registry,
    /// The configuration the server was built with.
    pub cfg: ServerConfig,
    inflight: AtomicUsize,
    shutdown: AtomicBool,
}

/// Releases one admission slot on drop — every exit path, panics included.
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind the listener and build the shared state.
    pub fn bind(cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let state = Arc::new(ServerState {
            registry: Registry::new(cfg.sessions),
            inflight: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            cfg,
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Run the accept loop on the calling thread — the CLI entry point;
    /// returns only on listener error or [`ServerHandle::stop`].
    pub fn run(self) -> std::io::Result<()> {
        for conn in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let state = Arc::clone(&self.state);
            std::thread::spawn(move || serve_connection(state, stream));
        }
        Ok(())
    }

    /// Run the accept loop on a background thread — the test/bench entry
    /// point. The returned handle stops and joins the server on
    /// [`ServerHandle::stop`].
    pub fn spawn(self) -> std::io::Result<ServerHandle> {
        let addr = self.local_addr()?;
        let state = Arc::clone(&self.state);
        let join = std::thread::spawn(move || {
            let _ = self.run();
        });
        Ok(ServerHandle { addr, state, join })
    }
}

/// Handle to a server running on a background thread.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    join: JoinHandle<()>,
}

impl ServerHandle {
    /// The server's `host:port` address for clients.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Stop the accept loop and join its thread. Connections already accepted
    /// finish their current request; no new connections are accepted.
    pub fn stop(self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        // `accept` blocks with no timeout in std; a throwaway connection
        // wakes it so it observes the shutdown flag.
        let _ = TcpStream::connect(self.addr);
        let _ = self.join.join();
    }
}

/// Serial request loop for one connection.
fn serve_connection(state: Arc<ServerState>, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader, state.cfg.max_body) {
            Ok(req) => req,
            Err(ParseError::Eof) => return,
            Err(ParseError::Malformed(msg)) => {
                let body = error_body(400, &format!("malformed request: {msg}"));
                let _ = write_response(&mut writer, 400, &body, true);
                return;
            }
            Err(ParseError::TooLarge { declared, limit }) => {
                let body = error_body(
                    413,
                    &format!("body of {declared} bytes exceeds the {limit}-byte limit"),
                );
                let _ = write_response(&mut writer, 413, &body, true);
                return;
            }
            Err(ParseError::Io(_)) => return,
        };
        let keep_alive = req.keep_alive;
        let (status, body) = dispatch(&state, &req);
        if write_response(&mut writer, status, &body, !keep_alive).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

/// Admission, thread budgeting, and panic containment around one request.
fn dispatch(state: &ServerState, req: &http::Request) -> (u16, String) {
    let inflight = state.inflight.fetch_add(1, Ordering::SeqCst) + 1;
    let _guard = InflightGuard(&state.inflight);
    if inflight > state.cfg.max_inflight {
        let e = ServeError::Busy { inflight, max_inflight: state.cfg.max_inflight };
        let status = e.status();
        return (status, error_body(status, &e.message()));
    }
    let budget = (resolve_threads(state.cfg.threads) / inflight).max(1);
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        shard::with_threads(budget, || handlers::handle(state, req))
    }));
    match outcome {
        Ok(response) => response,
        Err(_) => (500, error_body(500, "internal error: request handler panicked")),
    }
}
