//! The TCP front end: accept loop, per-connection threads, admission
//! control, request deadlines, graceful drain, and panic containment.
//!
//! Threading model: one OS thread per connection (requests on a connection
//! are serial per HTTP/1.1), with server-wide controls layered on top:
//!
//! * **Admission** — a bounded FIFO queue in front of an in-flight cap.
//!   A request past `max_inflight` waits its turn in ticket order instead of
//!   failing; only a *full queue* answers `503` (with `Retry-After`), so
//!   short bursts above capacity absorb into latency rather than errors.
//!   Permits are released by drop guards, so every exit path — success,
//!   typed error, even a handler panic — frees the slot.
//! * **Deadlines** — each request gets a total time budget
//!   (`request_timeout_ms`), enforced on the header read, the body read, and
//!   again at solve dispatch. A peer that stalls mid-request is answered
//!   `408` and closed (never a wedged connection thread); a request whose
//!   budget expires while queued is answered `503` without burning a solver
//!   slot.
//! * **Thread budget** — each admitted request runs under
//!   `shard::with_threads(total / inflight)`, an even share of the server's
//!   worker budget (floored at one thread). Because every kernel in the
//!   solve stack is thread-count invariant (`tests/determinism.rs`), the
//!   budget affects latency only — response bytes are identical at every
//!   concurrency level, which is what makes this scheduling safe to do at
//!   all.
//! * **Drain** — on SIGTERM (see [`install_sigterm_drain`]) or
//!   [`ServerHandle::begin_drain`], the listener closes immediately (late
//!   connects are refused), in-flight *and queued* requests run to
//!   completion (bounded by `drain_timeout_ms`), connections are told
//!   `Connection: close`, and [`Server::run`] returns cleanly.
//!
//! A handler panic (there should be none — see `handlers`' no-panic
//! contract) is caught per-request and answered as a 500; the worker thread
//! and the listener survive.

use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::EnetError;
use crate::parallel::{resolve_threads, shard};
use crate::serve::handlers::{self, ServeError};
use crate::serve::http::{read_request, write_response, ParseError, Request};
use crate::serve::metrics::{AdmissionGauges, Endpoint, ServeMetrics};
use crate::serve::registry::Registry;
use crate::serve::wire::Reply;

/// Server configuration (all CLI-settable; see `ssnal-en serve --help`).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address.
    pub host: String,
    /// Bind port (0 = ephemeral, for tests and benches).
    pub port: u16,
    /// Warm-session LRU capacity.
    pub sessions: usize,
    /// Admission cap: requests executing concurrently.
    pub max_inflight: usize,
    /// Total solver thread budget shared across requests (0 = all cores).
    pub threads: usize,
    /// Request body cap in bytes.
    pub max_body: usize,
    /// Admission queue capacity in front of the in-flight cap; only a full
    /// queue rejects with `503`.
    pub queue_depth: usize,
    /// Per-request time budget in milliseconds, enforced on header read,
    /// body read, and solve dispatch (0 = no deadline).
    pub request_timeout_ms: u64,
    /// How long a graceful drain waits for in-flight and queued requests
    /// before giving up, milliseconds.
    pub drain_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            host: "127.0.0.1".to_string(),
            port: 7878,
            sessions: 16,
            max_inflight: 32,
            threads: 0,
            max_body: 256 << 20,
            queue_depth: 64,
            request_timeout_ms: 30_000,
            drain_timeout_ms: 30_000,
        }
    }
}

impl ServerConfig {
    /// The per-request deadline as a `Duration` (`None` when disabled).
    fn request_timeout(&self) -> Option<Duration> {
        match self.request_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        }
    }
}

/// Set by the SIGTERM handler; polled by every accept loop in the process.
static SIGTERM_DRAIN: AtomicBool = AtomicBool::new(false);

/// Whether a SIGTERM arrived since [`install_sigterm_drain`].
pub fn sigterm_requested() -> bool {
    SIGTERM_DRAIN.load(Ordering::SeqCst)
}

/// Install a SIGTERM handler that flips the process-wide drain flag: the
/// accept loop stops taking connections, finishes in-flight and queued work,
/// and [`Server::run`] returns `Ok` so the process exits 0.
///
/// Declares libc's `signal` directly (std already links libc on unix) — the
/// handler body is a single atomic store, which is async-signal-safe.
#[cfg(unix)]
pub fn install_sigterm_drain() {
    extern "C" fn on_sigterm(_sig: i32) {
        SIGTERM_DRAIN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_sigterm as extern "C" fn(i32) as usize);
    }
}

/// No-op off unix: drain remains reachable programmatically via
/// [`ServerHandle::begin_drain`].
#[cfg(not(unix))]
pub fn install_sigterm_drain() {}

/// Admission book-keeping, all under one mutex: the FIFO ticket queue in
/// front of the in-flight cap, plus the `active` request count drain waits
/// on (`active` spans parse → response written, so a drain cannot complete
/// with a response half-sent).
struct AdmissionState {
    /// Requests between parse and response written (admitted, queued, or
    /// being answered with a rejection).
    active: usize,
    /// Requests currently executing a handler.
    inflight: usize,
    /// Tickets of requests waiting for an execution slot, FIFO.
    queue: VecDeque<u64>,
    next_ticket: u64,
}

/// The bounded FIFO admission queue + in-flight cap.
pub(crate) struct Admission {
    max_inflight: usize,
    queue_capacity: usize,
    state: Mutex<AdmissionState>,
    cv: Condvar,
}

/// How one admission attempt resolved.
enum Admitted<'a> {
    /// Run now; `queued` says whether the request waited in the queue first.
    Ready { permit: Permit<'a>, queued: bool },
    /// The queue is full — reject with `503` + `Retry-After`.
    QueueFull { queued: usize },
    /// The request's deadline expired while it waited in the queue.
    Expired,
}

/// Releases one execution slot on drop — every exit path, panics included.
pub(crate) struct Permit<'a>(&'a Admission);

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.0.release_permit();
    }
}

/// Marks one request active from parse until its response is written; drain
/// waits for all of these to drop.
struct RequestGuard<'a>(&'a Admission);

impl Drop for RequestGuard<'_> {
    fn drop(&mut self) {
        self.0.end_request();
    }
}

impl Admission {
    fn new(max_inflight: usize, queue_capacity: usize) -> Admission {
        Admission {
            max_inflight: max_inflight.max(1),
            queue_capacity,
            state: Mutex::new(AdmissionState {
                active: 0,
                inflight: 0,
                queue: VecDeque::new(),
                next_ticket: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Lock the admission state, recovering from poisoning (counters are
    /// valid at rest; a panicking holder can only have been between
    /// increments).
    fn lock_state(&self) -> MutexGuard<'_, AdmissionState> {
        match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Mark a request active (parse done, response not yet written).
    fn begin_request(&self) -> RequestGuard<'_> {
        self.lock_state().active += 1;
        RequestGuard(self)
    }

    fn end_request(&self) {
        let mut st = self.lock_state();
        st.active = st.active.saturating_sub(1);
        drop(st);
        self.cv.notify_all();
    }

    fn release_permit(&self) {
        let mut st = self.lock_state();
        st.inflight = st.inflight.saturating_sub(1);
        drop(st);
        self.cv.notify_all();
    }

    /// Wait for an execution slot in strict FIFO order. Fast path: no queue
    /// and a free slot. Otherwise take a ticket and wait until it is at the
    /// head with a slot free, the queue is full (reject), or the request's
    /// deadline passes (the ticket is withdrawn from wherever it sits).
    fn admit(&self, deadline: Option<Instant>) -> Admitted<'_> {
        let mut st = self.lock_state();
        if st.queue.is_empty() && st.inflight < self.max_inflight {
            st.inflight += 1;
            return Admitted::Ready { permit: Permit(self), queued: false };
        }
        if st.queue.len() >= self.queue_capacity {
            return Admitted::QueueFull { queued: st.queue.len() };
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push_back(ticket);
        loop {
            if st.queue.front() == Some(&ticket) && st.inflight < self.max_inflight {
                st.queue.pop_front();
                st.inflight += 1;
                drop(st);
                // another slot may also be free — wake the next ticket
                self.cv.notify_all();
                return Admitted::Ready { permit: Permit(self), queued: true };
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        if let Some(pos) = st.queue.iter().position(|&t| t == ticket) {
                            st.queue.remove(pos);
                        }
                        drop(st);
                        self.cv.notify_all();
                        return Admitted::Expired;
                    }
                    st = match self.cv.wait_timeout(st, d - now) {
                        Ok((guard, _)) => guard,
                        Err(poisoned) => poisoned.into_inner().0,
                    };
                }
                None => {
                    st = match self.cv.wait(st) {
                        Ok(guard) => guard,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            }
        }
    }

    /// Requests currently executing (for the per-request thread budget).
    fn inflight(&self) -> usize {
        self.lock_state().inflight
    }

    /// Instantaneous gauges for `/v1/stats`.
    fn gauges(&self) -> AdmissionGauges {
        let st = self.lock_state();
        AdmissionGauges {
            inflight: st.inflight,
            max_inflight: self.max_inflight,
            queue_depth: st.queue.len(),
            queue_capacity: self.queue_capacity,
        }
    }

    /// Block until no request is active (parse → response written) or the
    /// deadline passes; returns whether idle was reached.
    fn wait_idle(&self, deadline: Instant) -> bool {
        let mut st = self.lock_state();
        while st.active > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            st = match self.cv.wait_timeout(st, deadline - now) {
                Ok((guard, _)) => guard,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
        true
    }
}

/// State shared by every connection thread.
pub struct ServerState {
    /// Design store + warm-session LRU.
    pub registry: Registry,
    /// The configuration the server was built with.
    pub cfg: ServerConfig,
    /// Server-wide counters behind `GET /v1/stats`.
    pub metrics: ServeMetrics,
    admission: Admission,
    drain: AtomicBool,
}

impl ServerState {
    /// Whether a drain has begun (programmatic or SIGTERM).
    pub fn draining(&self) -> bool {
        self.drain.load(Ordering::SeqCst) || sigterm_requested()
    }

    /// Instantaneous admission gauges for `/v1/stats`.
    pub fn admission_gauges(&self) -> AdmissionGauges {
        self.admission.gauges()
    }
}

/// A bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

/// Accept-loop poll interval: how often the drain flag is observed while no
/// connections arrive.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

impl Server {
    /// Bind the listener and build the shared state.
    pub fn bind(cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let state = Arc::new(ServerState {
            registry: Registry::new(cfg.sessions),
            metrics: ServeMetrics::new(),
            admission: Admission::new(cfg.max_inflight, cfg.queue_depth),
            drain: AtomicBool::new(false),
            cfg,
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Run the accept loop on the calling thread — the CLI entry point.
    /// Returns `Ok(())` after a graceful drain (SIGTERM or
    /// [`ServerHandle::begin_drain`]): the listener closes first (late
    /// connects refused), then in-flight and queued requests finish, bounded
    /// by `drain_timeout_ms`.
    pub fn run(self) -> std::io::Result<()> {
        let Server { listener, state } = self;
        // Nonblocking accept so the drain flag is observed promptly even
        // with no traffic; a wake drains the whole backlog before sleeping.
        listener.set_nonblocking(true)?;
        loop {
            if state.draining() {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&state);
                    std::thread::spawn(move || serve_connection(state, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
        // Make the drain observable to connection threads even when only the
        // process-wide SIGTERM flag was set, then refuse new connections
        // while the admitted work completes.
        state.drain.store(true, Ordering::SeqCst);
        drop(listener);
        let deadline = Instant::now() + Duration::from_millis(state.cfg.drain_timeout_ms.max(1));
        state.admission.wait_idle(deadline);
        Ok(())
    }

    /// Run the accept loop on a background thread — the test/bench entry
    /// point. The returned handle drains and joins the server on
    /// [`ServerHandle::stop`].
    pub fn spawn(self) -> std::io::Result<ServerHandle> {
        let addr = self.local_addr()?;
        let state = Arc::clone(&self.state);
        let join = std::thread::spawn(move || {
            let _ = self.run();
        });
        Ok(ServerHandle { addr, state, join })
    }
}

/// Handle to a server running on a background thread.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    join: JoinHandle<()>,
}

impl ServerHandle {
    /// The server's `host:port` address for clients.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Begin a graceful drain without blocking: the accept loop closes the
    /// listener on its next poll (late connects refused) while in-flight and
    /// queued requests run to completion.
    pub fn begin_drain(&self) {
        self.state.drain.store(true, Ordering::SeqCst);
    }

    /// Drain gracefully and join the server thread: no new connections,
    /// in-flight and queued requests finish (bounded by `drain_timeout_ms`).
    pub fn stop(self) {
        self.begin_drain();
        let _ = self.join.join();
    }
}

/// Write one reply, honoring its `Retry-After`.
fn write_reply(stream: &mut TcpStream, reply: &Reply, close: bool) -> std::io::Result<()> {
    write_response(stream, reply.status, &reply.body, close, reply.retry_after_secs)
}

/// Serial request loop for one connection.
fn serve_connection(state: Arc<ServerState>, stream: TcpStream) {
    // The listener is nonblocking; this stream must block (with timeouts).
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let timeout = state.cfg.request_timeout();
    let _ = stream.set_write_timeout(timeout);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        if state.draining() {
            return;
        }
        let req = match read_request(&mut reader, state.cfg.max_body, timeout) {
            Ok(req) => req,
            // Peer closed, or a keep-alive connection went quiet: no request
            // exists, nothing to answer.
            Err(ParseError::Eof) | Err(ParseError::IdleTimeout) => return,
            Err(ParseError::Stalled { budget_ms }) => {
                ServeMetrics::bump(&state.metrics.timeouts_read);
                let reply = Reply::error(
                    408,
                    &format!("request stalled: not fully received within {budget_ms} ms"),
                );
                let _ = write_reply(&mut writer, &reply, true);
                return;
            }
            Err(ParseError::Malformed(msg)) => {
                let reply = Reply::error(400, &format!("malformed request: {msg}"));
                let _ = write_reply(&mut writer, &reply, true);
                return;
            }
            Err(ParseError::TooLarge { declared, limit }) => {
                let reply = Reply::error(
                    413,
                    &format!("body of {declared} bytes exceeds the {limit}-byte limit"),
                );
                let _ = write_reply(&mut writer, &reply, true);
                return;
            }
            Err(ParseError::Io(_)) => return,
        };
        // Active from here until the response is written — drain waits on
        // this guard, so it can never cut off a half-answered request.
        let request_guard = state.admission.begin_request();
        let endpoint = Endpoint::from_path(&req.path);
        let started = Instant::now();
        let (reply, permit) = dispatch(&state, &req);
        let close = !req.keep_alive || state.draining();
        let write_ok = write_reply(&mut writer, &reply, close).is_ok();
        state.metrics.record(endpoint, started.elapsed().as_secs_f64(), reply.status);
        drop(permit);
        drop(request_guard);
        if !write_ok || close {
            return;
        }
    }
}

/// Admission (queue + deadline), thread budgeting, and panic containment
/// around one request. The returned [`Permit`] (when admitted) must be held
/// until the response is written, so drain and the thread budget account for
/// the full request lifetime.
fn dispatch<'a>(state: &'a ServerState, req: &Request) -> (Reply, Option<Permit<'a>>) {
    match state.admission.admit(req.deadline) {
        Admitted::QueueFull { queued } => {
            ServeMetrics::bump(&state.metrics.rejected_queue_full);
            let e = ServeError::Busy { queued, queue_capacity: state.cfg.queue_depth };
            (e.reply(), None)
        }
        Admitted::Expired => {
            ServeMetrics::bump(&state.metrics.rejected_deadline);
            let e = ServeError::from(EnetError::Deadline {
                budget_ms: req.budget_ms.unwrap_or(0),
            });
            (e.reply(), None)
        }
        Admitted::Ready { permit, queued } => {
            ServeMetrics::bump(&state.metrics.admitted);
            if queued {
                ServeMetrics::bump(&state.metrics.queued_total);
            }
            let inflight = state.admission.inflight().max(1);
            let budget = (resolve_threads(state.cfg.threads) / inflight).max(1);
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                shard::with_threads(budget, || handlers::handle(state, req))
            }));
            let reply = match outcome {
                Ok(reply) => reply,
                Err(_) => Reply::error(500, "internal error: request handler panicked"),
            };
            (reply, Some(permit))
        }
    }
}
