//! Minimal HTTP/1.1 framing over `std::net` — just enough protocol for the
//! serving front end (and its tests and benches) without a dependency.
//!
//! Scope: request line + headers + `Content-Length` bodies, keep-alive
//! connections with strictly serial request handling per connection, and
//! fixed JSON responses. No chunked transfer encoding, no TLS — the front
//! end targets trusted internal traffic, not the open internet.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Uppercased method token (`GET`, `POST`, ...).
    pub method: String,
    /// Request path, query string stripped.
    pub path: String,
    /// Raw body bytes (empty without a `Content-Length`).
    pub body: Vec<u8>,
    /// Whether the client asked to keep the connection open.
    pub keep_alive: bool,
}

/// Why a request could not be framed. Everything here is a transport-level
/// defect — handler-level defects (bad JSON, unknown routes) are typed
/// responses, not parse errors.
#[derive(Debug)]
pub enum ParseError {
    /// The peer closed the connection before a request line arrived — the
    /// normal end of a keep-alive connection, not an error to report.
    Eof,
    /// Malformed request line or headers — answer 400 and close.
    Malformed(String),
    /// Declared body exceeds the configured cap — answer 413 and close
    /// without reading the body.
    TooLarge {
        /// The declared `Content-Length`.
        declared: usize,
        /// The configured cap.
        limit: usize,
    },
    /// Transport error mid-request.
    Io(std::io::Error),
}

/// Read one request from a buffered stream, enforcing the body-size cap
/// before any body byte is read.
pub fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
) -> Result<Request, ParseError> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Err(ParseError::Eof),
        Ok(_) => {}
        Err(e) => return Err(ParseError::Io(e)),
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed(format!("bad request line {:?}", line.trim_end())));
    }
    let path = target.split('?').next().unwrap_or("").to_string();

    let mut content_length = 0usize;
    let mut keep_alive = true; // the HTTP/1.1 default
    loop {
        let mut header = String::new();
        match reader.read_line(&mut header) {
            Ok(0) => return Err(ParseError::Malformed("truncated headers".to_string())),
            Ok(_) => {}
            Err(e) => return Err(ParseError::Io(e)),
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            return Err(ParseError::Malformed(format!("bad header {header:?}")));
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse::<usize>()
                .map_err(|_| ParseError::Malformed(format!("bad content-length {value:?}")))?;
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        }
    }
    if content_length > max_body {
        return Err(ParseError::TooLarge { declared: content_length, limit: max_body });
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body).map_err(ParseError::Io)?;
    }
    Ok(Request { method, path, body, keep_alive })
}

/// Reason phrases for the status codes the handlers emit.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Write one JSON response; `close` adds `Connection: close`.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    close: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n{}\r\n",
        reason(status),
        body.len(),
        if close { "connection: close\r\n" } else { "" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Parse one response from a buffered stream into `(status, body)`.
pub fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, String)> {
    use std::io::{Error, ErrorKind};
    fn bad(msg: &str) -> Error {
        Error::new(ErrorKind::InvalidData, msg.to_string())
    }
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(bad("connection closed before a status line"));
    }
    let status = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Err(bad("truncated response headers"));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse::<usize>().map_err(|_| bad("bad content-length"))?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    String::from_utf8(body).map(|b| (status, b)).map_err(|_| bad("non-utf8 body"))
}

/// A keep-alive client connection: strictly serial requests over one TCP
/// stream. This is the test/bench driver, not a general HTTP client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { writer, reader: BufReader::new(stream) })
    }

    /// Send one request and block for its response: `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<(u16, String)> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: ssnal-en\r\ncontent-type: application/json\r\n\
             content-length: {}\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes())?;
        self.writer.write_all(body.as_bytes())?;
        self.writer.flush()?;
        read_response(&mut self.reader)
    }

    /// Send raw bytes down the stream and read one response — for tests that
    /// exercise the server's handling of malformed requests.
    pub fn request_raw(&mut self, raw: &[u8]) -> std::io::Result<(u16, String)> {
        self.writer.write_all(raw)?;
        self.writer.flush()?;
        read_response(&mut self.reader)
    }
}

/// One-shot convenience: connect, send a single `Connection: close` request,
/// return `(status, body)`.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: ssnal-en\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}
