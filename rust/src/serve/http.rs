//! Minimal HTTP/1.1 framing over `std::net` — just enough protocol for the
//! serving front end (and its tests and benches) without a dependency.
//!
//! Scope: request line + headers + `Content-Length` bodies, keep-alive
//! connections with strictly serial request handling per connection, and
//! fixed JSON responses. No chunked transfer encoding, no TLS — the front
//! end targets trusted internal traffic, not the open internet.
//!
//! Reads are deadline-aware: the caller hands [`read_request`] a per-request
//! time budget, and the budget is enforced with socket read timeouts on the
//! request line, every header line, and the body. A peer that stalls
//! mid-request (the slow-loris shape: partial headers, then silence) comes
//! back as [`ParseError::Stalled`] — answered `408` and closed — instead of
//! holding a connection thread forever; a connection that goes quiet
//! *between* requests is a normal keep-alive idle timeout
//! ([`ParseError::IdleTimeout`]) and closes silently.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Uppercased method token (`GET`, `POST`, ...).
    pub method: String,
    /// Request path, query string stripped.
    pub path: String,
    /// Raw body bytes (empty without a `Content-Length`).
    pub body: Vec<u8>,
    /// Whether the client asked to keep the connection open.
    pub keep_alive: bool,
    /// When this request's time budget expires (set at request-line arrival;
    /// `None` when the server runs without request timeouts).
    pub deadline: Option<Instant>,
    /// The budget behind [`Request::deadline`], milliseconds (for error
    /// bodies).
    pub budget_ms: Option<u64>,
}

impl Request {
    /// Whether the request's deadline has already passed.
    pub fn deadline_expired(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }
}

/// Why a request could not be framed. Everything here is a transport-level
/// defect — handler-level defects (bad JSON, unknown routes) are typed
/// responses, not parse errors.
#[derive(Debug)]
pub enum ParseError {
    /// The peer closed the connection before a request line arrived — the
    /// normal end of a keep-alive connection, not an error to report.
    Eof,
    /// No request bytes arrived within the budget — a keep-alive connection
    /// gone quiet. Close silently.
    IdleTimeout,
    /// The peer sent a partial request (request line, headers, or body) and
    /// then stalled past the deadline — answer `408` and close.
    Stalled {
        /// The request time budget that was exhausted, milliseconds.
        budget_ms: u64,
    },
    /// Malformed request line or headers — answer 400 and close.
    Malformed(String),
    /// Declared body exceeds the configured cap — answer 413 and close
    /// without reading the body.
    TooLarge {
        /// The declared `Content-Length`.
        declared: usize,
        /// The configured cap.
        limit: usize,
    },
    /// Transport error mid-request.
    Io(std::io::Error),
}

/// Whether an I/O error is a read-timeout expiry (Linux surfaces
/// `SO_RCVTIMEO` as `EAGAIN` → `WouldBlock`; other platforms use
/// `TimedOut`).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Arm the stream's read timeout with the remaining budget, or fail with
/// `Stalled` when the budget is already spent. With no deadline the stream
/// reads block indefinitely (the pre-timeout behavior).
fn arm_read_timeout(
    stream: &TcpStream,
    deadline: Option<Instant>,
    budget_ms: u64,
) -> Result<(), ParseError> {
    let timeout = match deadline {
        None => None,
        Some(d) => {
            let remaining = d.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ParseError::Stalled { budget_ms });
            }
            // set_read_timeout rejects a zero Duration; floor at 1ms.
            Some(remaining.max(Duration::from_millis(1)))
        }
    };
    stream.set_read_timeout(timeout).map_err(ParseError::Io)
}

/// Read one request from a buffered stream, enforcing the body-size cap
/// before any body byte is read and `timeout` (when given) as the total
/// budget for the request line, headers, and body.
pub fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
    timeout: Option<Duration>,
) -> Result<Request, ParseError> {
    let budget_ms = timeout.map(|t| t.as_millis() as u64).unwrap_or(0);
    // Idle wait: the full budget to produce a complete request line.
    reader.get_ref().set_read_timeout(timeout).map_err(ParseError::Io)?;
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Err(ParseError::Eof),
        Ok(_) => {}
        Err(e) if is_timeout(&e) => {
            // Nothing read: a quiet keep-alive connection. Partial line:
            // a stalled (slow-loris) request.
            return if line.is_empty() {
                Err(ParseError::IdleTimeout)
            } else {
                Err(ParseError::Stalled { budget_ms })
            };
        }
        Err(e) => return Err(ParseError::Io(e)),
    }
    // The request exists from here on; its deadline starts now.
    let deadline = timeout.map(|t| Instant::now() + t);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed(format!("bad request line {:?}", line.trim_end())));
    }
    let path = target.split('?').next().unwrap_or("").to_string();

    let mut content_length = 0usize;
    let mut keep_alive = true; // the HTTP/1.1 default
    loop {
        arm_read_timeout(reader.get_ref(), deadline, budget_ms)?;
        let mut header = String::new();
        match reader.read_line(&mut header) {
            Ok(0) => return Err(ParseError::Malformed("truncated headers".to_string())),
            Ok(_) => {}
            Err(e) if is_timeout(&e) => return Err(ParseError::Stalled { budget_ms }),
            Err(e) => return Err(ParseError::Io(e)),
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            return Err(ParseError::Malformed(format!("bad header {header:?}")));
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse::<usize>()
                .map_err(|_| ParseError::Malformed(format!("bad content-length {value:?}")))?;
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        }
    }
    if content_length > max_body {
        return Err(ParseError::TooLarge { declared: content_length, limit: max_body });
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        arm_read_timeout(reader.get_ref(), deadline, budget_ms)?;
        match reader.read_exact(&mut body) {
            Ok(()) => {}
            Err(e) if is_timeout(&e) => return Err(ParseError::Stalled { budget_ms }),
            Err(e) => return Err(ParseError::Io(e)),
        }
    }
    Ok(Request { method, path, body, keep_alive, deadline, budget_ms: timeout.map(|_| budget_ms) })
}

/// Reason phrases for the status codes the handlers emit.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Write one JSON response; `close` adds `Connection: close`, `retry_after`
/// a `Retry-After: <seconds>` header (admission-control 503s).
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    close: bool,
    retry_after: Option<u64>,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n",
        reason(status),
        body.len(),
    );
    if let Some(secs) = retry_after {
        head.push_str(&format!("retry-after: {secs}\r\n"));
    }
    if close {
        head.push_str("connection: close\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Parse one response from a buffered stream into
/// `(status, headers, body)` — headers lowercased.
pub fn read_response_full(
    reader: &mut BufReader<TcpStream>,
) -> std::io::Result<(u16, Vec<(String, String)>, String)> {
    use std::io::{Error, ErrorKind};
    fn bad(msg: &str) -> Error {
        Error::new(ErrorKind::InvalidData, msg.to_string())
    }
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(bad("connection closed before a status line"));
    }
    let status = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Err(bad("truncated response headers"));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().map_err(|_| bad("bad content-length"))?;
            }
            headers.push((name, value));
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| bad("non-utf8 body"))?;
    Ok((status, headers, body))
}

/// Parse one response from a buffered stream into `(status, body)`.
pub fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, String)> {
    read_response_full(reader).map(|(status, _headers, body)| (status, body))
}

/// A keep-alive client connection: strictly serial requests over one TCP
/// stream. This is the test/bench driver, not a general HTTP client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { writer, reader: BufReader::new(stream) })
    }

    fn send(&mut self, method: &str, path: &str, body: &str) -> std::io::Result<()> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: ssnal-en\r\ncontent-type: application/json\r\n\
             content-length: {}\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes())?;
        self.writer.write_all(body.as_bytes())?;
        self.writer.flush()
    }

    /// Send one request and block for its response: `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<(u16, String)> {
        self.send(method, path, body)?;
        read_response(&mut self.reader)
    }

    /// [`Client::request`] keeping the response headers:
    /// `(status, headers, body)` with header names lowercased — for tests
    /// that assert on `Retry-After` and friends.
    pub fn request_full(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<(u16, Vec<(String, String)>, String)> {
        self.send(method, path, body)?;
        read_response_full(&mut self.reader)
    }

    /// Send raw bytes down the stream and read one response — for tests that
    /// exercise the server's handling of malformed requests.
    pub fn request_raw(&mut self, raw: &[u8]) -> std::io::Result<(u16, String)> {
        self.writer.write_all(raw)?;
        self.writer.flush()?;
        read_response(&mut self.reader)
    }

    /// Send raw bytes without reading a response (for deadline tests that
    /// dribble a partial request).
    pub fn send_raw(&mut self, raw: &[u8]) -> std::io::Result<()> {
        self.writer.write_all(raw)?;
        self.writer.flush()
    }

    /// Block for one response without sending anything (pairs with
    /// [`Client::send_raw`]).
    pub fn read_reply(&mut self) -> std::io::Result<(u16, String)> {
        read_response(&mut self.reader)
    }
}

/// One-shot convenience: connect, send a single `Connection: close` request,
/// return `(status, body)`.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: ssnal-en\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}
