//! Design registry and warm-session cache behind the serve front end.
//!
//! Two stores, both content-addressed off the design:
//!
//! * **Designs** — immutable `(A, b)` pairs keyed by a FNV-1a fingerprint of
//!   their exact bit content. Registration is idempotent: posting the same
//!   matrix twice yields the same `design_id` and stores one copy.
//! * **Sessions** — warm solver state ([`Session`]: Newton workspace +
//!   Gram/Cholesky cache + lazily-loaded PJRT engine) keyed by
//!   `design_id : model-spec`. An LRU bound (default 16) caps resident
//!   workspace memory; eviction drops only the registry's handle, so requests
//!   already running on an evicted session finish unharmed on their own
//!   `Arc` clone.
//!
//! Sessions mirror [`crate::api::Fit`] exactly — same `checked_lambdas` →
//! `solve_once` call sequence against the same workspace contract — so a
//! server response is byte-identical to the equivalent direct `api::` call
//! (`tests/serve_integration.rs` pins this).

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex, MutexGuard, TryLockError};

use crate::api::fit::{solve_json, PathFit};
use crate::api::{Design, EnetError, EnetModel, StatsSnapshot};
use crate::linalg::{DesignRef, DesignStorage, NewtonWorkspace};
use crate::runtime::PjrtEngine;
use crate::serve::metrics::ServeMetrics;
use crate::solver::types::SolveResult;
use crate::util::json::Json;

/// Lock a mutex, recovering from poisoning instead of propagating a panic
/// into every subsequent request.
///
/// Recovery is sound here because the guarded structures are valid at rest:
/// the registry maps hold only fully-constructed entries, and a workspace
/// abandoned mid-solve is indistinguishable from a warm one by contract (a
/// fresh and a warm workspace produce bitwise-identical results).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// FNV-1a (64-bit) — tiny, allocation-free, and stable across platforms;
/// collision risk is irrelevant at registry scale (dozens of designs).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }
}

/// Content fingerprint of a design: storage kind, shape, the exact value
/// bits, and (for CSC) the sparsity pattern — plus the response vector, so
/// the same matrix with a different stored `b` is a different design.
/// Out-of-core designs hash the file header instead of the payload (the
/// header's `content_hash` was computed over the full encoded payload at
/// convert time — no body re-scan on registration).
pub(crate) fn fingerprint(design: &Design<'_>) -> String {
    let mut h = Fnv::new();
    let a = design.design_ref();
    match a {
        DesignRef::Sparse(csc) => {
            h.write(b"csc");
            h.write_u64(csc.rows() as u64);
            h.write_u64(csc.cols() as u64);
            for &p in csc.col_ptr() {
                h.write_u64(p as u64);
            }
            for &i in csc.row_idx() {
                h.write_u64(i as u64);
            }
            for &v in csc.values() {
                h.write_u64(v.to_bits());
            }
        }
        DesignRef::Dense(_) => {
            h.write(b"dense");
            h.write_u64(a.rows() as u64);
            h.write_u64(a.cols() as u64);
            for &v in a.values_slice().expect("dense designs carry stored values") {
                h.write_u64(v.to_bits());
            }
        }
        DesignRef::OutOfCore(ooc) => {
            h.write(b"ooc");
            h.write_u64(ooc.rows() as u64);
            h.write_u64(ooc.cols() as u64);
            h.write_u64(ooc.header().fingerprint());
        }
    }
    for &v in design.b() {
        h.write_u64(v.to_bits());
    }
    format!("d{:016x}", h.0)
}

/// A registered design: the owned `(A, b)` pair plus its registry id.
pub struct StoredDesign {
    /// Content fingerprint, handed to clients as `design_id`.
    pub id: String,
    /// The validated, owned design.
    pub design: Design<'static>,
}

/// One solve's outcome with its resolved penalties — what a session carries
/// between requests (a serve-side analogue of [`crate::api::Fit`]'s
/// `(lam1, lam2, result)` triple).
#[derive(Clone)]
pub struct Solved {
    /// Resolved ℓ1 penalty.
    pub lam1: f64,
    /// Resolved ℓ2 penalty.
    pub lam2: f64,
    /// The full solver result.
    pub result: SolveResult,
}

/// A warm solver session bound to one registered design and one model spec.
///
/// Holds the same state as [`crate::api::Fit`] — Newton workspace, cached
/// PJRT engine, latest solve — but owns its design through an `Arc` so it can
/// outlive registry eviction while a request is mid-flight.
pub struct Session {
    design: Arc<StoredDesign>,
    model: EnetModel,
    ws: NewtonWorkspace,
    engine: Option<PjrtEngine>,
    solved: Option<Solved>,
    /// Solves this session has run (cold fits + refits) — diagnostics for
    /// `GET /v1/stats`.
    solves: u64,
}

impl Session {
    /// Validate the model against the design and create an empty (unsolved)
    /// session.
    pub fn new(design: Arc<StoredDesign>, model: EnetModel) -> Result<Session, EnetError> {
        model.validate_common(&design.design)?;
        Ok(Session {
            design,
            model,
            ws: NewtonWorkspace::new(),
            engine: None,
            solved: None,
            solves: 0,
        })
    }

    /// The design this session is bound to.
    pub fn design(&self) -> &Arc<StoredDesign> {
        &self.design
    }

    /// Solves this session has run (cold fits + refits).
    pub fn solves(&self) -> u64 {
        self.solves
    }

    /// Workspace reuse counters as the typed public snapshot — the same
    /// struct [`crate::api::Fit::workspace_stats`] returns. Out-of-core
    /// block-cache counters live on the shared design handle and are
    /// overlaid here (design-level totals, shared by every session bound to
    /// the same registered design).
    pub fn workspace_snapshot(&self) -> StatsSnapshot {
        let mut stats = self.ws.stats;
        stats.overlay_ooc(self.design.design.design_ref());
        StatsSnapshot::from(&stats)
    }

    /// One solve against the warm workspace — the same `checked_lambdas` →
    /// `solve_once` sequence as [`crate::api::Fit::refit`].
    fn solve(&mut self, b: &[f64]) -> Result<(), EnetError> {
        let design = Arc::clone(&self.design);
        design.design.check_response(b)?;
        let (lam1, lam2) = self.model.checked_lambdas(design.design.design_ref(), b)?;
        let (result, _trace) = self.model.solve_once(
            design.design.design_ref(),
            b,
            lam1,
            lam2,
            None,
            &mut self.engine,
            &mut self.ws,
        )?;
        self.solved = Some(Solved { lam1, lam2, result });
        self.solves += 1;
        Ok(())
    }

    /// Solve on the design's stored response if no solve exists yet; a
    /// repeated call returns the cached state untouched (same bits — it *is*
    /// the stored result).
    pub fn ensure_solved(&mut self) -> Result<(), EnetError> {
        if self.solved.is_none() {
            let design = Arc::clone(&self.design);
            self.solve(design.design.b())?;
        }
        Ok(())
    }

    /// Re-solve on a new response, reusing the warm workspace.
    pub fn refit(&mut self, b: &[f64]) -> Result<(), EnetError> {
        self.solve(b)
    }

    /// [`Session::refit`] returning the solve itself — the per-request unit
    /// the coalescer hands back to each caller.
    pub fn refit_solved(&mut self, b: &[f64]) -> Result<Solved, EnetError> {
        self.solve(b)?;
        match self.solved.clone() {
            Some(s) => Ok(s),
            None => Err(EnetError::Backend("solve completed without state".to_string())),
        }
    }

    /// Batch refit mirroring [`crate::api::Fit::refit_many`]: all responses
    /// validated up front, λmax resolution fused into one pass over the
    /// design's columns, solves run sequentially through the warm workspace.
    /// Returns every solve (with its resolved penalties); the session is left
    /// at the last one.
    pub fn refit_many<B: AsRef<[f64]>>(&mut self, bs: &[B]) -> Result<Vec<Solved>, EnetError> {
        let design = Arc::clone(&self.design);
        for b in bs {
            design.design.check_response(b.as_ref())?;
        }
        let lambdas = self.model.checked_lambdas_many(design.design.design_ref(), bs)?;
        let mut out = Vec::with_capacity(bs.len());
        for (b, &(lam1, lam2)) in bs.iter().zip(&lambdas) {
            let (result, _trace) = self.model.solve_once(
                design.design.design_ref(),
                b.as_ref(),
                lam1,
                lam2,
                None,
                &mut self.engine,
                &mut self.ws,
            )?;
            let solved = Solved { lam1, lam2, result };
            self.solved = Some(solved.clone());
            self.solves += 1;
            out.push(solved);
        }
        Ok(out)
    }

    /// JSON of the latest solve (fitting lazily on the stored response if
    /// needed) — byte-identical to [`crate::api::Fit::to_json`] for the same
    /// solve, because both render through the same `solve_json`.
    pub fn solved_json(&mut self) -> Result<Json, EnetError> {
        self.ensure_solved()?;
        let (m, n) = (self.design.design.m(), self.design.design.n());
        match self.solved.as_ref() {
            Some(s) => Ok(solve_json(m, n, s.lam1, s.lam2, &s.result)),
            // Unreachable after ensure_solved, but a typed error beats an
            // unwrap reachable from a request handler.
            None => Err(EnetError::Backend("solve completed without state".to_string())),
        }
    }

    /// Predict on new observations, fitting lazily on the stored response if
    /// no solve exists yet. Same shape check and active-set mat-vec as
    /// [`crate::api::Fit::predict`].
    pub fn predict(&mut self, a_new: DesignRef<'_>) -> Result<Vec<f64>, EnetError> {
        let n = self.design.design.n();
        if a_new.cols() != n {
            return Err(EnetError::PredictShape { expected: n, got: a_new.cols() });
        }
        self.ensure_solved()?;
        let s = match self.solved.as_ref() {
            Some(s) => s,
            None => return Err(EnetError::Backend("solve completed without state".to_string())),
        };
        let mut out = vec![0.0; a_new.rows()];
        a_new.mul_vec_support_into(&s.result.x, &s.result.active_set, &mut out);
        Ok(out)
    }

    /// A λ-path over the model's grid on the stored response (stateless with
    /// respect to the warm workspace — the path engine owns its own state).
    pub fn path(&self) -> Result<PathFit, EnetError> {
        self.model.fit_path(&self.design.design)
    }
}

/// One single-`b` refit waiting for a coalescing leader.
struct PendingRefit {
    b: Vec<f64>,
    tx: mpsc::Sender<Result<Solved, EnetError>>,
}

/// A warm session plus its cross-request refit coalescer — what the registry
/// actually hands out.
///
/// The coalescer is a combining lock: a single-`b` `/v1/refit` enqueues its
/// response on `pending` and then contends for the session mutex. Whoever
/// wins the lock becomes the leader, drains *everything* pending at that
/// moment, and serves the whole batch through one
/// [`Session::refit_many`] call (one fused λmax pass over the design instead
/// of one per request); followers find their own solve waiting on their
/// channel. Correctness leans entirely on the pinned bitwise contract:
/// `refit_many` == sequential `refit` bit for bit, so a coalesced response is
/// byte-identical to the uncoalesced one.
///
/// No entry can be stranded: every enqueuer contends for the session lock
/// *after* pushing, so the first winner after any push drains it — at
/// worst the enqueuer itself. If a leader dies mid-batch, dropping the batch
/// disconnects every follower's channel, which surfaces as a typed 5xx
/// rather than a hang.
pub struct SessionSlot {
    /// The slot's design, readable without touching the session lock.
    design: Arc<StoredDesign>,
    session: Mutex<Session>,
    pending: Mutex<Vec<PendingRefit>>,
}

impl SessionSlot {
    fn new(session: Session) -> SessionSlot {
        let design = Arc::clone(session.design());
        SessionSlot { design, session: Mutex::new(session), pending: Mutex::new(Vec::new()) }
    }

    /// The design this slot's session is bound to (lock-free).
    pub fn design(&self) -> &Arc<StoredDesign> {
        &self.design
    }

    /// Lock the session for a non-coalescing request (fit, predict, path,
    /// batch refit).
    pub fn session(&self) -> MutexGuard<'_, Session> {
        lock(&self.session)
    }

    /// Try to peek at the session without blocking — `None` while a solve is
    /// in flight. For `/v1/stats`, which must never queue behind a solve.
    pub fn try_session(&self) -> Option<MutexGuard<'_, Session>> {
        match self.session.try_lock() {
            Ok(guard) => Some(guard),
            Err(TryLockError::Poisoned(poisoned)) => Some(poisoned.into_inner()),
            Err(TryLockError::WouldBlock) => None,
        }
    }

    /// One single-response refit through the coalescer (see the type docs for
    /// the protocol). `metrics` records the realized batch sizes.
    pub fn refit_coalesced(
        &self,
        b: Vec<f64>,
        metrics: &ServeMetrics,
    ) -> Result<Solved, EnetError> {
        let (tx, rx) = mpsc::channel();
        lock(&self.pending).push(PendingRefit { b, tx });
        {
            let mut session = lock(&self.session);
            let batch: Vec<PendingRefit> = std::mem::take(&mut *lock(&self.pending));
            if !batch.is_empty() {
                metrics.record_batch(batch.len());
                let bs: Vec<&[f64]> = batch.iter().map(|p| p.b.as_slice()).collect();
                match session.refit_many(&bs) {
                    Ok(solved) => {
                        for (p, s) in batch.iter().zip(solved) {
                            let _ = p.tx.send(Ok(s));
                        }
                    }
                    Err(_) if batch.len() > 1 => {
                        // A batch-level failure (refit_many validates every
                        // response up front) must not fail innocent
                        // bystanders: fall back to per-request refits so each
                        // entry gets its own verdict, exactly as without
                        // coalescing.
                        for p in &batch {
                            let _ = p.tx.send(session.refit_solved(&p.b));
                        }
                    }
                    Err(e) => {
                        let _ = batch[0].tx.send(Err(e));
                    }
                }
            }
        }
        match rx.recv() {
            Ok(result) => result,
            // Disconnected sender: the leader died mid-batch.
            Err(_) => Err(EnetError::Backend("coalescing leader failed mid-batch".to_string())),
        }
    }
}

/// The server's shared stores: registered designs plus the warm-session LRU.
pub struct Registry {
    max_sessions: usize,
    designs: Mutex<HashMap<String, Arc<StoredDesign>>>,
    /// LRU order, least-recently-used first. A `Vec` is the right structure
    /// at this scale (default cap 16): the O(len) reorder is noise next to
    /// the solve the session exists to serve.
    sessions: Mutex<Vec<(String, Arc<SessionSlot>)>>,
}

impl Registry {
    /// An empty registry holding at most `max_sessions` warm sessions
    /// (floored at 1).
    pub fn new(max_sessions: usize) -> Registry {
        Registry {
            max_sessions: max_sessions.max(1),
            designs: Mutex::new(HashMap::new()),
            sessions: Mutex::new(Vec::new()),
        }
    }

    /// Validate and store a design, returning its fingerprint id. Idempotent:
    /// re-registering identical content returns the existing id.
    pub fn register(&self, a: DesignStorage, b: Vec<f64>) -> Result<Arc<StoredDesign>, EnetError> {
        let design = Design::from_storage(a, b)?;
        let id = fingerprint(&design);
        let mut designs = lock(&self.designs);
        let entry = designs
            .entry(id.clone())
            .or_insert_with(|| Arc::new(StoredDesign { id, design }));
        Ok(Arc::clone(entry))
    }

    /// Look up a registered design by id.
    pub fn design(&self, id: &str) -> Option<Arc<StoredDesign>> {
        lock(&self.designs).get(id).cloned()
    }

    /// Number of registered designs.
    pub fn design_count(&self) -> usize {
        lock(&self.designs).len()
    }

    /// Number of resident warm sessions.
    pub fn session_count(&self) -> usize {
        lock(&self.sessions).len()
    }

    /// Fetch or create the warm session for `(design, model)`, marking it
    /// most-recently-used. `model_key` must be the canonical serialization of
    /// the model spec (`Json::Obj` is a `BTreeMap`, so equivalent specs
    /// serialize identically); creating a session past the cap evicts the
    /// least-recently-used one — dropping only the registry's `Arc`, never a
    /// clone held by an in-flight request.
    pub fn session(
        &self,
        design: &Arc<StoredDesign>,
        model: &EnetModel,
        model_key: &str,
    ) -> Result<Arc<SessionSlot>, EnetError> {
        let key = format!("{}:{}", design.id, model_key);
        let mut sessions = lock(&self.sessions);
        if let Some(pos) = sessions.iter().position(|(k, _)| *k == key) {
            let entry = sessions.remove(pos);
            let found = Arc::clone(&entry.1);
            sessions.push(entry);
            return Ok(found);
        }
        let slot = Arc::new(SessionSlot::new(Session::new(Arc::clone(design), model.clone())?));
        if sessions.len() >= self.max_sessions {
            sessions.remove(0);
        }
        sessions.push((key, Arc::clone(&slot)));
        Ok(slot)
    }

    /// A point-in-time copy of the resident sessions (key + slot handle), in
    /// LRU order — the `/v1/stats` walk, done on a clone so the session
    /// mutexes are probed without holding the registry lock.
    pub fn sessions_snapshot(&self) -> Vec<(String, Arc<SessionSlot>)> {
        lock(&self.sessions).iter().map(|(k, s)| (k.clone(), Arc::clone(s))).collect()
    }
}
