//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [--key=value] ...`.
//! Typed accessors parse on demand and report readable errors.

use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand plus `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` pairs; bare flags map to `"true"`.
    opts: BTreeMap<String, String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut tokens = iter.into_iter().peekable();
        while let Some(tok) = tokens.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // value is next token unless it looks like another flag
                    match tokens.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = tokens.next().unwrap();
                            out.opts.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.opts.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Self, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag: present (or `--key true`) ⇒ true.
    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed numeric option with default; errors on malformed values.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("option --{key}: cannot parse {v:?}")),
        }
    }

    /// `usize` convenience.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        // accept scientific notation like 1e5 for experiment sizes
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                if let Ok(u) = v.parse::<usize>() {
                    return Ok(u);
                }
                v.parse::<f64>()
                    .ok()
                    .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                    .map(|f| f as usize)
                    .ok_or_else(|| format!("option --{key}: cannot parse {v:?} as usize"))
            }
        }
    }

    /// `f64` convenience.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        self.get_parse(key, default)
    }

    /// Comma-separated list of f64.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .map_err(|_| format!("option --{key}: bad element {s:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of usize (scientific notation allowed).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    let s = s.trim();
                    if let Ok(u) = s.parse::<usize>() {
                        return Ok(u);
                    }
                    s.parse::<f64>()
                        .ok()
                        .filter(|f| *f >= 0.0)
                        .map(|f| f as usize)
                        .ok_or_else(|| format!("option --{key}: bad element {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["solve", "--n", "1000", "--alpha=0.9", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("solve"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 1000);
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.9);
        assert!(a.get_flag("verbose"));
        assert!(!a.get_flag("quiet"));
    }

    #[test]
    fn scientific_notation_sizes() {
        let a = parse(&["bench", "--n", "1e5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 100_000);
        let a = parse(&["bench", "--ns", "1e4,1e5,5e5"]);
        assert_eq!(a.get_usize_list("ns", &[]).unwrap(), vec![10_000, 100_000, 500_000]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.get_str("mode", "native"), "native");
        assert_eq!(a.get_f64("tol", 1e-6).unwrap(), 1e-6);
    }

    #[test]
    fn f64_list() {
        let a = parse(&["x", "--alphas", "0.9, 0.8,0.6"]);
        assert_eq!(a.get_f64_list("alphas", &[]).unwrap(), vec![0.9, 0.8, 0.6]);
    }

    #[test]
    fn malformed_value_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn positional_after_command() {
        let a = parse(&["solve", "file1", "file2", "--k", "3"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
        assert_eq!(a.get_usize("k", 0).unwrap(), 3);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--n", "5"]);
        assert!(a.get_flag("fast"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
    }
}
