//! Utility substrates built in-repo (the offline environment has no clap, serde,
//! criterion or proptest): a mini CLI argument parser, wall-clock timers, table
//! and CSV/JSON emitters, and a tiny property-testing helper.

pub mod alloc_count;
pub mod cli;
pub mod csv;
pub mod error;
pub mod json;
pub mod quickcheck;
pub mod table;
pub mod timer;

pub use cli::Args;
pub use table::Table;
pub use timer::Stopwatch;
