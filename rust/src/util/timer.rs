//! Wall-clock measurement utilities for the benchmark harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed as a `Duration`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::new();
    let out = f();
    (out, sw.elapsed_s())
}

/// Summary statistics over repeated measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub mean: f64,
    /// Standard error of the mean (0 for a single measurement).
    pub se: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

/// Compute mean / standard error / range of a sample.
pub fn stats(samples: &[f64]) -> Stats {
    assert!(!samples.is_empty(), "stats of empty sample");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Stats {
        mean,
        se: (var / n as f64).sqrt(),
        min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_positive_time() {
        let sw = Stopwatch::new();
        let mut acc = 0u64;
        for i in 0..10_000 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
        assert!(sw.elapsed_s() >= 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, t) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn stats_single_sample() {
        let s = stats(&[2.5]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.se, 0.0);
        assert_eq!(s.n, 1);
    }

    #[test]
    fn stats_known_values() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-15);
        // sample var = 5/3, se = sqrt(5/12)
        assert!((s.se - (5.0f64 / 12.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::new();
        let a = sw.lap();
        let b = sw.elapsed_s();
        assert!(a >= 0.0 && b >= 0.0);
    }
}
