//! Plain-text table formatter — prints benchmark results with the same row/column
//! structure as the paper's tables.

/// Column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    /// Attach a title line printed above the table.
    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cell, width = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as CSV (for EXPERIMENTS.md ingestion).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with 3 decimals (paper tables' convention).
pub fn fmt_secs(s: f64) -> String {
    format!("{s:.3}")
}

/// Format `seconds(iterations)` like the paper's SsNAL-EN columns.
pub fn fmt_secs_iters(s: f64, iters: usize) -> String {
    format!("{s:.3}({iters})")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["n", "time"]);
        t.row(vec!["100".into(), "0.5".into()]);
        t.row(vec!["1000000".into(), "12.345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].ends_with("0.5"));
        assert!(lines[3].contains("1000000"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(0.12345), "0.123");
        assert_eq!(fmt_secs_iters(1.5, 4), "1.500(4)");
    }

    #[test]
    fn title_and_len() {
        let mut t = Table::new(&["x"]).with_title("Table 1");
        assert!(t.is_empty());
        t.row(vec!["v".into()]);
        assert_eq!(t.len(), 1);
        assert!(t.render().starts_with("Table 1\n"));
    }
}
