//! Minimal error type with context chaining (anyhow is unavailable offline).
//!
//! Mirrors the small slice of `anyhow` the crate needs: an opaque [`Error`]
//! built from any `Display` message or `std::error::Error`, a [`Result`]
//! alias, and a [`Context`] extension trait that prepends human-readable
//! context as errors bubble up (`"loading artifacts from X: cannot read ..."`).

use std::fmt;

/// Opaque error carrying a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Prepend a layer of context.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Plain and alternate ({:#}) both render the full chain — keeping the
        // cause visible is more useful than anyhow's outer-only default.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

// Like anyhow, `Error` intentionally does NOT implement `std::error::Error`,
// which is what makes this blanket conversion from source errors coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Result alias used across the runtime/coordinator layers.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for results (the `anyhow::Context` subset).
pub trait Context<T> {
    /// Attach lazily-built context to the error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
    /// Attach static context to the error.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }

    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_and_context_chain() {
        let e = Error::msg("root cause").context("while loading");
        assert_eq!(format!("{e}"), "while loading: root cause");
        assert_eq!(format!("{e:#}"), "while loading: root cause");
        assert_eq!(format!("{e:?}"), "while loading: root cause");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
        let ok: std::result::Result<u8, String> = Ok(7);
        assert_eq!(ok.context("unused").unwrap(), 7);
    }

    #[test]
    fn from_std_error() {
        fn io_fail() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        let e = io_fail().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }
}
