//! Tiny CSV writer/reader — enough for dumping benchmark series (Figure 1/2 data)
//! and reading them back in tests. No quoting of embedded commas is needed for
//! our numeric tables; fields containing commas are rejected at write time.

use std::io::Write;
use std::path::Path;

/// Write a CSV file with a header row and numeric-ish string rows.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    validate(header.iter().copied())?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        assert_eq!(row.len(), header.len(), "csv row arity mismatch");
        validate(row.iter().map(|s| s.as_str()))?;
        writeln!(f, "{}", row.join(","))?;
    }
    f.flush()
}

fn validate<'a>(cells: impl Iterator<Item = &'a str>) -> std::io::Result<()> {
    for c in cells {
        if c.contains(',') || c.contains('\n') {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("csv cell contains separator: {c:?}"),
            ));
        }
    }
    Ok(())
}

/// Read a CSV file back: `(header, rows)`.
pub fn read_csv(path: &Path) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "empty csv"))?
        .split(',')
        .map(|s| s.to_string())
        .collect::<Vec<_>>();
    let mut rows = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        rows.push(line.split(',').map(|s| s.to_string()).collect());
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ssnal_csv_test");
        let path = dir.join("t.csv");
        let rows = vec![vec!["1".to_string(), "2.5".to_string()]];
        write_csv(&path, &["a", "b"], &rows).unwrap();
        let (h, r) = read_csv(&path).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(r, rows);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_embedded_comma() {
        let dir = std::env::temp_dir().join("ssnal_csv_test2");
        let path = dir.join("t.csv");
        let rows = vec![vec!["1,2".to_string()]];
        assert!(write_csv(&path, &["a"], &rows).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
