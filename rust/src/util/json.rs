//! Minimal JSON support (serde is unavailable offline).
//!
//! A writer for structured experiment/results output and a small recursive-descent
//! parser sufficient for reading `artifacts/manifest.json` produced by
//! `python/compile/aot.py` (objects, arrays, strings, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer content (numbers with no fractional part).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array content, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        match self.bump() {
            Some(c) if c == b => Ok(()),
            other => Err(format!("expected {:?} at byte {}, got {other:?}", b as char, self.pos)),
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("truncated \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or("bad \\u escape")? as u32;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| "bad utf8".to_string())?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("dual_prox_grad".into())),
            ("m", Json::Num(500.0)),
            ("shapes", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "artifacts": [
            {"name": "dual_prox_grad", "m": 200, "n": 4000, "file": "dual_prox_grad_200x4000.hlo.txt"}
          ],
          "dtype": "f32"
        }"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("m").unwrap().as_usize(), Some(200));
        let file = arts[0].get("file").unwrap().as_str().unwrap();
        assert_eq!(file, "dual_prox_grad_200x4000.hlo.txt");
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert!(Json::parse("01abc").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
        let back = Json::Str("a\nb\"".into()).to_string();
        assert_eq!(Json::parse(&back).unwrap().as_str().unwrap(), "a\nb\"");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"λ₁ σ\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "λ₁ σ");
    }
}
