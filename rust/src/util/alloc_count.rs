//! A counting wrapper around the system allocator.
//!
//! Installed as the `#[global_allocator]` of the `ssnal-en` binary and of the
//! `alloc_newton` integration test; the library itself never installs it, so
//! embedding crates keep their own allocator. When installed, every
//! `alloc`/`realloc` bumps a relaxed atomic counter that
//! [`allocations`] exposes — the instrument behind the zero-allocation
//! Newton-hot-path pin (`tests/alloc_newton.rs`) and the `allocs/iter` column
//! of `bench-parallel --newton-*`. When *not* installed the counter simply
//! never moves, so callers must treat a zero delta as "no allocations
//! observed", not proof of absence — the dedicated test binary and the CLI
//! both install it, which is where the guarantee is enforced.
//!
//! The overhead is one relaxed fetch-add per allocation: irrelevant next to
//! the allocation itself, so shipping it in the production binary is free
//! and keeps the bench and the binary measuring the same thing.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Pass-through [`System`] allocator that counts `alloc`/`realloc` calls.
pub struct CountingAllocator;

// Safety: defers every operation to `System`; the counter has no effect on
// allocation behavior.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total heap allocations (+ reallocations) observed process-wide since
/// start, when [`CountingAllocator`] is installed; constant 0 otherwise.
/// Diff two reads around a region to count its allocations — single-threaded
/// regions only, since the counter is process-global.
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}
