//! Tiny property-based testing helper (proptest is unavailable offline).
//!
//! [`run_prop`] draws `cases` random inputs from a generator closure, runs the
//! property, and on failure performs a simple halving-style shrink over the
//! generator's seed stream, reporting the smallest failing case it found.
//! It deliberately keeps the proptest *spirit* — randomized coverage with
//! reproducible seeds — with a fraction of the machinery.

use crate::rng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; every case derives its own stream.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run a property over random inputs.
///
/// * `gen` draws an input from an RNG.
/// * `prop` returns `Ok(())` or a failure description.
///
/// Panics (with the case seed, for reproduction) if any case fails.
pub fn run_prop<T: std::fmt::Debug>(
    config: PropConfig,
    mut gen: impl FnMut(&mut Xoshiro256pp) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256pp::seed_from_u64(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}, seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Draw a random problem size in `[lo, hi]` with log-uniform spread (sizes that
/// matter for solvers span orders of magnitude).
pub fn log_uniform_usize(rng: &mut Xoshiro256pp, lo: usize, hi: usize) -> usize {
    assert!(lo >= 1 && hi >= lo);
    let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
    let v = (llo + rng.next_f64() * (lhi - llo)).exp();
    (v.round() as usize).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop(
            PropConfig { cases: 32, seed: 1 },
            |r| r.next_f64(),
            |x| {
                count += 1;
                if (0.0..1.0).contains(x) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run_prop(
            PropConfig { cases: 64, seed: 2 },
            |r| r.next_f64(),
            |x| if *x < 0.5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn log_uniform_in_bounds_and_spread() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut small = 0;
        for _ in 0..2000 {
            let v = log_uniform_usize(&mut rng, 10, 10_000);
            assert!((10..=10_000).contains(&v));
            if v < 100 {
                small += 1;
            }
        }
        // log-uniform gives ≈1/3 of mass to [10,100); uniform would give <1%.
        assert!(small > 300, "small={small}");
    }
}
