//! Parallel execution engine: across-grid chains and within-solve shards.
//! (Reached from user code via the facade — [`crate::api::EnetModel::fit_path`]
//! configures [`ParallelPathOptions`] from the builder's validated fields.)
//!
//! The subsystem has **two parallelism layers**, both dependency-free
//! (`std::thread` + channels + mutexed deques):
//!
//! 1. **Across the λ-grid** — λ-paths and K-fold CV are embarrassingly
//!    parallel *between* warm-start chains: [`chain`] cuts the grid into
//!    contiguous chains, [`solve_path_parallel`] solves them concurrently on
//!    the pool, [`shared`] coordinates max-active truncation.
//! 2. **Within one solve** — [`shard`] splits the column dimension of the
//!    solver's O(mn)/O(mr) sweeps (the `Aᵀy` dual sweep, the active-set
//!    `A_J u` accumulation, the Woodbury Gram build, the CG mat-vec) into
//!    shards fanned over the same pool. The engine hands each chain worker
//!    its share of spare cores (`threads / chains`), so the two layers
//!    compose without oversubscribing.
//!
//! Execution plumbing shared by both layers:
//!
//! * [`pool`] — a **persistent worker pool**: `available_threads() − 1`
//!   long-lived `std::thread` workers spawned once per process on first
//!   dispatch, parked on a condvar while idle and woken per kernel call.
//!   Each call publishes a batch of indexed jobs drawn from work-stealing
//!   deques ([`steal`]) with order-preserving result collection; the caller
//!   always participates, so a busy pool degrades to the serial loop rather
//!   than blocking. Dispatch costs a wake, not a spawn — which is what makes
//!   sharding profitable below O(mn) kernel granularity (`bench-parallel
//!   --pool-*` measures the per-call overhead against the retained
//!   scoped-spawn baseline). See [`pool`]'s docs for lifecycle, parking,
//!   the batch protocol and the `SSNAL_THREADS` budget interaction.
//! * [`run_tasks`] — the one scheduling primitive everything routes through:
//!   λ-chains, within-solve shards, and the CV/tuning criteria fan-out.
//!
//! **Determinism contract (both layers).** Scheduling never touches floats.
//! Layer 1: every per-point float depends only on chain-local state and
//! results are assembled by grid index, so for a **fixed chunking**
//! ([`Chunking::Chains`] / [`Chunking::PointsPerChain`]) the output is
//! bitwise-identical across thread counts — including when the stealing pool
//! migrates a chain to an idle worker, and however warm the persistent pool
//! is — and a one-chain run is
//! bitwise-identical to `path::solve_path`. [`Chunking::Auto`] instead ties
//! the chain count to the resolved thread count for maximum parallelism —
//! different thread requests then take different warm-start chains and agree
//! only to solver tolerance. Layer 2: every shard split is a pure function
//! of the problem shape and shard partials are combined in a fixed-order
//! reduction tree, so each kernel's bits are invariant to its thread budget
//! (see [`shard`]'s module docs). Cross-worker sharing (the scoreboard) only
//! prunes work that provably cannot appear in the final path.
//!
//! **Screening.** With [`ParallelPathOptions::screening`] on, each
//! warm-started point first runs the Gap-Safe sphere test (paper D.3) at the
//! *current* λ against the chain's previous solution and solves the reduced
//! design. The rule is safe — discarded features are provably zero at this
//! λ — so solutions match the unscreened path to solver tolerance while the
//! per-point cost drops from O(mn) to O(m·|survivors|) sweeps.

pub mod chain;
pub mod pool;
pub mod shard;
pub mod shared;
pub mod steal;

pub use chain::{Chain, Chunking};
pub use pool::{available_threads, resolve_threads, run_tasks};
pub use shared::SharedScreen;

/// Chain count the coordinator uses: fixed (not tied to the thread count) so
/// coordinator results are identical for every `num_threads` setting.
pub const DEFAULT_CHAINS: usize = 8;

use crate::linalg::{blas, DesignRef};
use crate::path::{
    assert_descending_grid, solve_point, PathOptions, PathPoint, PathResult, WarmState,
};
use crate::solver::screening::AugmentedView;
use crate::solver::types::{EnetProblem, SolveResult};
use crate::util::timer::Stopwatch;

/// Options for a parallel path run.
#[derive(Clone, Debug)]
pub struct ParallelPathOptions {
    /// The underlying path options (grid, α, cap, tolerance, algorithm).
    pub base: PathOptions,
    /// Worker threads (`0` = all available cores).
    pub num_threads: usize,
    /// How the grid is cut into warm-start chains.
    pub chunking: Chunking,
    /// Restrict each warm-started solve to its Gap-Safe survivors.
    pub screening: bool,
}

impl Default for ParallelPathOptions {
    fn default() -> Self {
        Self {
            base: PathOptions::default(),
            num_threads: 0,
            chunking: Chunking::Auto,
            screening: true,
        }
    }
}

impl ParallelPathOptions {
    /// Single-chain, unscreened configuration: semantics (and bits) identical
    /// to [`crate::path::solve_path`], just executed through the engine.
    pub fn sequential(base: PathOptions) -> Self {
        Self { base, num_threads: 1, chunking: Chunking::Chains(1), screening: false }
    }
}

/// Per-chain diagnostics.
#[derive(Clone, Debug)]
pub struct ChainReport {
    /// The grid segment this chain covered.
    pub chain: Chain,
    /// Points actually solved (may stop early on cap hit / frontier skip).
    pub solved: usize,
    /// Wall-clock seconds spent in the chain.
    pub seconds: f64,
    /// Mean fraction of features surviving the Gap-Safe screen (1.0 when
    /// screening is off or never bit).
    pub survivor_fraction: f64,
}

/// Result of a parallel path run: the assembled path plus engine diagnostics.
#[derive(Clone, Debug)]
pub struct ParallelPathResult {
    /// The path, identical in shape to the sequential driver's output.
    pub path: PathResult,
    /// Per-chain diagnostics, in grid order.
    pub chains: Vec<ChainReport>,
    /// Worker threads the engine ran with.
    pub threads: usize,
}

/// Run the warm-started λ-path with chains distributed over a worker pool.
pub fn solve_path_parallel<'a>(
    a: impl Into<DesignRef<'a>>,
    b: &[f64],
    opts: &ParallelPathOptions,
) -> ParallelPathResult {
    let mut sessions = Vec::new();
    solve_path_parallel_warm(a, b, opts, &mut sessions)
}

/// Warm-session variant of [`solve_path_parallel`]: `sessions` carries one
/// [`WarmState`] per chain across runs, mirroring the serving layer's
/// session reuse. Each run is numerically cold — `x`/`sigma` are cleared
/// here, so no run reads the previous run's solution — but the Newton
/// workspaces stay warm, which is bitwise-invisible (cache hits reproduce a
/// cold build's bits) and skips the Gram/factor rebuild cost when a refit
/// revisits similar active sets. The chain split is a pure function of
/// (grid length, chunking, thread request), so sessions re-associate with
/// the same grid segments on every run; if the split changes, the sessions
/// are discarded and rebuilt fresh.
pub fn solve_path_parallel_warm<'a>(
    a: impl Into<DesignRef<'a>>,
    b: &[f64],
    opts: &ParallelPathOptions,
    sessions: &mut Vec<WarmState>,
) -> ParallelPathResult {
    let a = a.into();
    assert_descending_grid(&opts.base.c_grid);
    let grid_len = opts.base.c_grid.len();
    let lambda_max = EnetProblem::lambda_max(a, b, opts.base.alpha);
    let chains = chain::split_chains(grid_len, &opts.chunking, opts.num_threads);
    if sessions.len() != chains.len() {
        sessions.clear();
        sessions.resize_with(chains.len(), WarmState::default);
    }
    // Cold numerics, warm memory: clear the carried solution and σ so the
    // run's outputs cannot depend on the previous run's numerics.
    for s in sessions.iter_mut() {
        s.x = None;
        s.sigma = None;
    }
    let board = SharedScreen::new();
    let threads = resolve_threads(opts.num_threads).min(chains.len().max(1));
    // Spare cores not consumed by chain-level parallelism go to within-solve
    // sharding (e.g. 8 threads over 2 chains → each solve shards 4-way).
    // Shard results are thread-budget-invariant, so this choice never
    // changes the output — only the schedule.
    let shard_budget = (resolve_threads(opts.num_threads) / chains.len().max(1)).max(1);

    let jobs: Vec<_> = chains
        .iter()
        .zip(sessions.drain(..))
        .map(|(&seg, warm)| {
            let board = &board;
            let base = &opts.base;
            let screening = opts.screening;
            move || {
                shard::with_threads(shard_budget, || {
                    run_chain(a, b, lambda_max, seg, base, screening, board, warm)
                })
            }
        })
        .collect();
    let outputs = run_tasks(opts.num_threads, jobs);

    // Deterministic assembly: place every solved point at its grid index, then
    // walk ascending until the grid ends, a cap hit truncates the path, or an
    // unsolved index marks the pruned tail. Sessions return in chain order
    // (`run_tasks` preserves job order).
    let mut per_index: Vec<Option<PathPoint>> = (0..grid_len).map(|_| None).collect();
    let mut reports = Vec::with_capacity(outputs.len());
    for (report, points, warm) in outputs {
        reports.push(report);
        sessions.push(warm);
        for (index, point) in points {
            per_index[index] = Some(point);
        }
    }
    let cap = opts.base.max_active;
    let mut points = Vec::with_capacity(grid_len);
    let mut truncated = false;
    for slot in per_index {
        match slot {
            Some(point) => {
                let r = point.result.active_set.len();
                points.push(point);
                if cap > 0 && r >= cap {
                    truncated = true;
                    break;
                }
            }
            None => break,
        }
    }
    let runs = points.len();
    ParallelPathResult {
        path: PathResult { points, lambda_max, runs, truncated },
        chains: reports,
        threads,
    }
}

/// Solve one chain sequentially with warm starts, publishing to the board.
/// Takes the chain's warm session by value and hands it back so the caller
/// can carry it into the next run.
#[allow(clippy::too_many_arguments)]
fn run_chain(
    a: DesignRef<'_>,
    b: &[f64],
    lambda_max: f64,
    seg: Chain,
    base: &PathOptions,
    screening: bool,
    board: &SharedScreen,
    mut warm: WarmState,
) -> (ChainReport, Vec<(usize, PathPoint)>, WarmState) {
    let sw = Stopwatch::new();
    let n = a.cols();
    let mut out: Vec<(usize, PathPoint)> = Vec::with_capacity(seg.len());
    let mut survivor_sum = 0usize;
    for index in seg.start..seg.end {
        if board.should_skip(index) {
            // The frontier only moves down, so every later index is also out.
            break;
        }
        let c = base.c_grid[index];
        let (point, survivors) = if screening {
            let prev = warm.x.clone();
            solve_point_screened(a, b, lambda_max, c, base, &mut warm, prev.as_deref())
        } else {
            retarget_to_full(a, &mut warm);
            (solve_point(a, b, lambda_max, c, base, &mut warm), n)
        };
        let r = point.result.active_set.len();
        let cap_hit = base.max_active > 0 && r >= base.max_active;
        if cap_hit {
            board.note_cap_hit(index);
        }
        survivor_sum += survivors;
        out.push((index, point));
        if cap_hit {
            break;
        }
    }
    let solved = out.len();
    let survivor_fraction = if solved == 0 || n == 0 {
        1.0
    } else {
        survivor_sum as f64 / (solved * n) as f64
    };
    (ChainReport { chain: seg, solved, seconds: sw.elapsed_s(), survivor_fraction }, out, warm)
}

/// Re-bind a chain's warm workspace to the full design when it is currently
/// bound to a gathered survivor subset, translating each sub-design column
/// back to its full-design index. Every sub-design column exists in the full
/// design, so the whole cached Gram — and the factorization — carries over.
fn retarget_to_full(a: DesignRef<'_>, warm: &mut WarmState) {
    if let Some(cols) = warm.ws_cols.take() {
        warm.newton_ws.retarget_columns(a, |k| cols.get(k).copied());
    }
}

/// Re-bind a chain's warm workspace onto this point's gathered survivor
/// sub-design. Gathered columns are bitwise copies of full-design columns,
/// so cached Gram entries stay valid under translation; active columns the
/// screen just dropped become a structural downdate inside
/// [`crate::linalg::NewtonWorkspace::retarget_columns`].
fn retarget_to_sub(a_sub: DesignRef<'_>, survivors: &[usize], warm: &mut WarmState) {
    match warm.ws_cols.take() {
        // previously bound to the full design: full index → survivor position
        None => warm.newton_ws.retarget_columns(a_sub, |j| survivors.binary_search(&j).ok()),
        // sub → sub: previous survivor position → full index → new position
        Some(prev) => {
            warm.newton_ws.retarget_columns(a_sub, |k| {
                prev.get(k).and_then(|&j| survivors.binary_search(&j).ok())
            });
            let mut cols = prev;
            cols.clear();
            cols.extend_from_slice(survivors);
            warm.ws_cols = Some(cols);
            return;
        }
    }
    warm.ws_cols = Some(survivors.to_vec());
}

/// Warm-started solve restricted to the Gap-Safe survivors of `prev_x`.
///
/// The screen runs at the *current* (λ1, λ2) — valid for any reference primal
/// point — so discarded features are provably zero at this grid point and the
/// reduced solve recovers the full solution exactly (to solver tolerance).
fn solve_point_screened(
    a: DesignRef<'_>,
    b: &[f64],
    lambda_max: f64,
    c: f64,
    base: &PathOptions,
    warm: &mut WarmState,
    prev_x: Option<&[f64]>,
) -> (PathPoint, usize) {
    let n = a.cols();
    let Some(prev) = prev_x else {
        // Chain head: no reference point, the sphere has infinite radius.
        retarget_to_full(a, &mut *warm);
        return (solve_point(a, b, lambda_max, c, base, &mut *warm), n);
    };
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(base.alpha, c, lambda_max);
    let survivors = {
        let p = EnetProblem::new(a, b, lam1, lam2);
        AugmentedView::new(&p).gap_safe_survivors(prev)
    };
    if survivors.is_empty() {
        // Everything screened out: the solution at this λ is exactly zero.
        let result = SolveResult {
            x: vec![0.0; n],
            y: b.iter().map(|v| -v).collect(),
            active_set: Vec::new(),
            screen_survivors: Some(0),
            objective: 0.5 * blas::nrm2_sq(b),
            iterations: 0,
            inner_iterations: 0,
            residual: 0.0,
            converged: true,
            algorithm: base.algorithm,
        };
        warm.x = Some(result.x.clone());
        return (PathPoint { c_lambda: c, lam1, lam2, result }, 0);
    }
    if survivors.len() * 2 > n {
        // Screen barely bites: the gather copy would outweigh the savings.
        retarget_to_full(a, &mut *warm);
        return (solve_point(a, b, lambda_max, c, base, &mut *warm), n);
    }

    let kept = survivors.len();
    // `gather_cols` preserves the storage kind, so a sparse design solves its
    // screened subproblems on a sparse sub-design too.
    let a_sub = a.gather_cols(&survivors);
    // Carry the chain's warm workspace onto the sub-design: gathered columns
    // are bitwise copies of full-design columns, so the cached Gram/factor
    // (keyed by column identity) translates through the survivor index map
    // instead of being rebuilt per λ point.
    let mut warm_sub = WarmState {
        x: warm.x.as_ref().map(|x| survivors.iter().map(|&j| x[j]).collect()),
        sigma: warm.sigma,
        newton_ws: std::mem::take(&mut warm.newton_ws),
        ws_cols: warm.ws_cols.take(),
    };
    retarget_to_sub((&a_sub).into(), &survivors, &mut warm_sub);
    let sub = solve_point(&a_sub, b, lambda_max, c, base, &mut warm_sub);

    // Scatter the reduced solution back into full coordinates.
    let mut x_full = vec![0.0; n];
    for (k, &j) in survivors.iter().enumerate() {
        x_full[j] = sub.result.x[k];
    }
    let active_set: Vec<usize> = sub.result.active_set.iter().map(|&k| survivors[k]).collect();
    warm.x = Some(x_full.clone());
    warm.sigma = warm_sub.sigma;
    warm.newton_ws = warm_sub.newton_ws;
    warm.ws_cols = warm_sub.ws_cols;
    let result =
        SolveResult { x: x_full, active_set, screen_survivors: Some(kept), ..sub.result };
    (PathPoint { c_lambda: c, lam1, lam2, result }, kept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::path::{c_lambda_grid, solve_path};
    use crate::solver::types::Algorithm;

    fn problem() -> crate::data::SyntheticProblem {
        generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 200,
            n0: 8,
            x_star: 5.0,
            snr: 10.0,
            seed: 42,
        })
    }

    fn base_opts() -> PathOptions {
        PathOptions {
            alpha: 0.8,
            c_grid: c_lambda_grid(0.95, 0.1, 16),
            max_active: 0,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        }
    }

    #[test]
    fn single_chain_engine_is_bitwise_sequential() {
        let prob = problem();
        let seq = solve_path(&prob.a, &prob.b, &base_opts());
        let eng = solve_path_parallel(
            &prob.a,
            &prob.b,
            &ParallelPathOptions::sequential(base_opts()),
        );
        assert_eq!(eng.path.runs, seq.runs);
        assert_eq!(eng.path.truncated, seq.truncated);
        for (p, q) in eng.path.points.iter().zip(seq.points.iter()) {
            assert_eq!(p.result.x, q.result.x, "c={}", p.c_lambda);
            assert_eq!(p.result.active_set, q.result.active_set);
        }
    }

    #[test]
    fn output_is_thread_count_invariant() {
        let prob = problem();
        let mk = |threads| ParallelPathOptions {
            base: base_opts(),
            num_threads: threads,
            chunking: Chunking::Chains(4),
            screening: true,
        };
        let one = solve_path_parallel(&prob.a, &prob.b, &mk(1));
        let four = solve_path_parallel(&prob.a, &prob.b, &mk(4));
        assert_eq!(one.path.runs, four.path.runs);
        for (p, q) in one.path.points.iter().zip(four.path.points.iter()) {
            assert_eq!(p.result.x, q.result.x, "c={}", p.c_lambda);
        }
    }

    #[test]
    fn chunked_path_agrees_with_sequential_to_tolerance() {
        let prob = problem();
        let seq = solve_path(&prob.a, &prob.b, &base_opts());
        for screening in [false, true] {
            let eng = solve_path_parallel(
                &prob.a,
                &prob.b,
                &ParallelPathOptions {
                    base: base_opts(),
                    num_threads: 4,
                    chunking: Chunking::Chains(4),
                    screening,
                },
            );
            assert_eq!(eng.path.runs, seq.runs);
            for (p, q) in eng.path.points.iter().zip(seq.points.iter()) {
                let dist = blas::dist2(&p.result.x, &q.result.x);
                let scale = blas::nrm2(&q.result.x) + 1.0;
                assert!(
                    dist / scale < 1e-3,
                    "screening={screening} c={}: {dist}",
                    p.c_lambda
                );
            }
        }
    }

    #[test]
    fn truncation_matches_sequential_semantics() {
        let prob = problem();
        let mut base = base_opts();
        base.c_grid = c_lambda_grid(0.95, 0.05, 40);
        base.max_active = 8;
        let eng = solve_path_parallel(
            &prob.a,
            &prob.b,
            &ParallelPathOptions {
                base: base.clone(),
                num_threads: 4,
                chunking: Chunking::Chains(5),
                screening: false,
            },
        );
        assert!(eng.path.truncated);
        assert!(eng.path.runs < 40);
        let last = eng.path.points.last().unwrap();
        assert!(last.result.active_set.len() >= 8);
        for p in &eng.path.points[..eng.path.runs - 1] {
            assert!(p.result.active_set.len() < 8, "only the last point hits the cap");
        }
    }

    #[test]
    fn screened_chain_carries_warm_workspace() {
        let prob = problem();
        let opts = ParallelPathOptions {
            base: base_opts(),
            num_threads: 1,
            chunking: Chunking::Chains(1),
            screening: true,
        };
        let cold = solve_path_parallel(&prob.a, &prob.b, &opts);
        let mut sessions = Vec::new();
        let first = solve_path_parallel_warm(&prob.a, &prob.b, &opts, &mut sessions);
        assert_eq!(sessions.len(), 1);
        let stats_first = sessions[0].newton_ws.stats;
        // the carried workspace must actually engage across screened points:
        // either structural edits or incremental Gram updates fire (a fresh
        // workspace per point — the old behavior — would leave both at the
        // per-point level only, with every point paying a rebuild)
        assert!(
            stats_first.rank1_updates + stats_first.gram_incremental > 0,
            "screened chain never reused warm state: {stats_first:?}"
        );
        // warm sessions are bitwise-invisible: session path == fresh path,
        // and a rerun on the same inputs reproduces itself exactly
        assert_eq!(cold.path.runs, first.path.runs);
        for (p, q) in cold.path.points.iter().zip(first.path.points.iter()) {
            assert_eq!(p.result.x, q.result.x, "c={}", p.c_lambda);
        }
        let second = solve_path_parallel_warm(&prob.a, &prob.b, &opts, &mut sessions);
        let stats_second = sessions[0].newton_ws.stats;
        assert_eq!(first.path.runs, second.path.runs);
        for (p, q) in first.path.points.iter().zip(second.path.points.iter()) {
            assert_eq!(p.result.x, q.result.x, "warm rerun must be bitwise-identical");
        }
        assert!(
            stats_second.factor_hits > stats_first.factor_hits,
            "rerun must hit the carried caches: {stats_first:?} vs {stats_second:?}"
        );
    }

    #[test]
    fn screening_reports_reduced_survivors() {
        let prob = problem();
        let eng = solve_path_parallel(
            &prob.a,
            &prob.b,
            &ParallelPathOptions {
                base: base_opts(),
                num_threads: 2,
                chunking: Chunking::Chains(2),
                screening: true,
            },
        );
        // warm-started points deep in each chain should screen out features
        let min_frac = eng
            .chains
            .iter()
            .map(|c| c.survivor_fraction)
            .fold(f64::INFINITY, f64::min);
        assert!(min_frac < 1.0, "screen never bit: {:?}", eng.chains);
    }
}
