//! Dependency-free work-scheduling pool: scoped `std::thread` workers drawing
//! indexed jobs from per-worker work-stealing deques ([`StealQueues`]) and
//! pushing results back on a channel.
//!
//! Results are collected by job index, so the output order — and therefore
//! every downstream float — is independent of worker scheduling: a job that
//! ran because it was *stolen* produces exactly the bits it would have
//! produced under the static split. A panicking job propagates out of
//! [`run_tasks`] when the thread scope joins, exactly like the sequential
//! loop it replaces.

use crate::parallel::steal::StealQueues;
use std::sync::mpsc;

/// Threads the host exposes (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user thread request: `0` means "all available".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Execute `jobs` on up to `num_threads` workers (`0` = all available cores),
/// returning the outputs in job order.
pub fn run_tasks<T, F>(num_threads: usize, jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_threads(num_threads).min(n);
    if workers <= 1 {
        // Single-threaded fallback: no deques, no locks, same output.
        return jobs.into_iter().map(|job| job()).collect();
    }

    let queues = StealQueues::new(jobs, workers);
    let (out_tx, out_rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let out_tx = out_tx.clone();
            let queues = &queues;
            scope.spawn(move || {
                // Own block first, then steal from the back of busy peers.
                while let Some((index, job)) = queues.pop(w) {
                    let value = job();
                    let _ = out_tx.send((index, value));
                }
            });
        }
    });
    drop(out_tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (index, value) in out_rx {
        slots[index] = Some(value);
    }
    slots
        .into_iter()
        .map(|slot| slot.expect("every job reports exactly one result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<_> = (0..64).map(|i| move || i * 3).collect();
        let out = run_tasks(4, jobs);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_matches_parallel() {
        let mk = || (0..40).map(|i| move || (i as f64).sqrt().sin()).collect::<Vec<_>>();
        assert_eq!(run_tasks(1, mk()), run_tasks(4, mk()));
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let count = &count;
                move || count.fetch_add(1, Ordering::Relaxed)
            })
            .collect();
        let out = run_tasks(0, jobs);
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_oversubscribed() {
        let none: Vec<fn() -> u8> = Vec::new();
        assert!(run_tasks(8, none).is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_tasks(64, jobs), vec![0, 1, 2]);
    }

    #[test]
    fn imbalanced_jobs_finish_and_keep_order() {
        // One deliberately heavy job in worker 0's block: the stealing pool
        // must still return every result at its own index.
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || {
                    let mut acc = 0u64;
                    let reps = if i == 0 { 200_000 } else { 200 };
                    for k in 0..reps {
                        acc = acc.wrapping_add(k).rotate_left(1);
                    }
                    (i, acc)
                }
            })
            .collect();
        let out = run_tasks(4, jobs);
        for (slot, (i, _)) in out.iter().enumerate() {
            assert_eq!(slot, *i);
        }
    }

    #[test]
    fn resolve_semantics() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }
}
