//! Dependency-free work-scheduling pool: scoped `std::thread` workers pulling
//! indexed jobs from an `mpsc` channel and pushing results back on another.
//!
//! Results are collected by job index, so the output order — and therefore
//! every downstream float — is independent of worker scheduling. A panicking
//! job propagates out of [`run_tasks`] when the thread scope joins, exactly
//! like the sequential loop it replaces.

use std::sync::{mpsc, Mutex};

/// Threads the host exposes (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user thread request: `0` means "all available".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Execute `jobs` on up to `num_threads` workers (`0` = all available cores),
/// returning the outputs in job order.
pub fn run_tasks<T, F>(num_threads: usize, jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_threads(num_threads).min(n);
    if workers <= 1 {
        // Single-threaded fallback: no channels, no locks, same output.
        return jobs.into_iter().map(|job| job()).collect();
    }

    // Job queue: one sender fills it up-front, workers share the receiver.
    let (job_tx, job_rx) = mpsc::channel::<(usize, F)>();
    for indexed in jobs.into_iter().enumerate() {
        job_tx.send(indexed).expect("job queue open");
    }
    drop(job_tx); // workers drain until the channel reports disconnect
    let job_rx = Mutex::new(job_rx);

    let (out_tx, out_rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let out_tx = out_tx.clone();
            let job_rx = &job_rx;
            scope.spawn(move || loop {
                // Take the lock only to pop the next job — the guard must drop
                // before the job runs, or the pool would serialize.
                let next = job_rx.lock().expect("job queue lock").recv();
                let Ok((index, job)) = next else {
                    break; // queue drained
                };
                let value = job();
                let _ = out_tx.send((index, value));
            });
        }
    });
    drop(out_tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (index, value) in out_rx {
        slots[index] = Some(value);
    }
    slots
        .into_iter()
        .map(|slot| slot.expect("every job reports exactly one result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<_> = (0..64).map(|i| move || i * 3).collect();
        let out = run_tasks(4, jobs);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_matches_parallel() {
        let mk = || (0..40).map(|i| move || (i as f64).sqrt().sin()).collect::<Vec<_>>();
        assert_eq!(run_tasks(1, mk()), run_tasks(4, mk()));
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let count = &count;
                move || count.fetch_add(1, Ordering::Relaxed)
            })
            .collect();
        let out = run_tasks(0, jobs);
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_oversubscribed() {
        let none: Vec<fn() -> u8> = Vec::new();
        assert!(run_tasks(8, none).is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_tasks(64, jobs), vec![0, 1, 2]);
    }

    #[test]
    fn resolve_semantics() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }
}
