//! Persistent worker pool: the process-wide scheduling substrate both
//! parallelism layers (λ-chains and within-solve shards) dispatch through.
//!
//! # Lifecycle
//!
//! The pool is spawned lazily on the first multi-threaded [`run_tasks`] call:
//! `available_threads() − 1` long-lived `std::thread` workers (the calling
//! thread is always the remaining participant). Workers **park** on a condvar
//! while no batch is in flight and are woken per kernel call, so dispatch
//! costs a mutex hand-off and a wake — not a thread spawn — and sharding
//! stays profitable well below O(mn) kernel granularity. The pool lives for
//! the rest of the process; there is no shutdown protocol (workers hold no
//! resources beyond a parked thread and its scratch arena, and the OS
//! reclaims them at exit).
//!
//! Because workers are long-lived, each one's thread-local
//! [`crate::linalg::workspace::ShardScratch`] arena persists across batches:
//! a worker that publishes *nested* shard kernels (a chain worker sharding
//! its own sweeps) reuses its own partial buffers call after call instead of
//! allocating per wake. The committed per-wake dispatch cost is exported as
//! [`SEED_DISPATCH_SECONDS`] and seeds the shard-size floor in
//! [`crate::parallel::shard`].
//!
//! # Batch protocol
//!
//! Each [`run_tasks`] call publishes a *batch*: indexed jobs pre-split into
//! per-slot work-stealing deques ([`StealQueues`]), one result slot per job,
//! and a participant cap equal to the call's resolved thread budget. The
//! caller is always participant 0 and drains jobs itself — a fully busy pool
//! degrades a call to the serial loop, it never blocks it — while parked
//! workers join as participants 1..cap. Batches from concurrent or nested
//! calls (a chain worker sharding its own kernels) coexist in the publish
//! list; workers serve whichever batch has a free slot. The caller returns
//! only after unlisting its batch *and* observing that every joined
//! participant has left it, which is what makes handing workers raw pointers
//! to the caller's stack sound.
//!
//! # Thread budgets
//!
//! `num_threads` is resolved per call ([`resolve_threads`]; `0` = all cores)
//! and caps how many participants may join that batch — the chain engine
//! hands each chain worker `threads / chains` spare cores for its
//! within-solve shards (`SSNAL_THREADS`, see [`crate::parallel::shard`]), and
//! because chain participants occupy pool workers, exactly the spare workers
//! remain parked for the nested shard batches: the two layers compose without
//! oversubscribing.
//!
//! # Determinism
//!
//! Results are filed by job index, so the output order — and therefore every
//! downstream float — is independent of which participant ran a job, whether
//! it was stolen, and how warm the pool is: a batch on a warm pool produces
//! exactly the bits of a fresh-pool or scoped-spawn run. A panicking job
//! propagates out of [`run_tasks`] on the calling thread, exactly like the
//! sequential loop it replaces.

use crate::parallel::steal::StealQueues;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex, Once, OnceLock};

/// Seeded per-wake dispatch cost (seconds per `run_tasks` call on a warm
/// pool): the worst `pool_seconds_per_call` row of the committed
/// `rust/benches/baselines/BENCH_pool_dispatch.json`. This is a *committed
/// measurement*, not a runtime probe — [`crate::parallel::shard`] derives its
/// default shard-size floor from it, and deriving from a live measurement
/// would make shard plans (and reduction bits) vary run to run. Refresh it
/// together with the baseline JSON when the dispatch path changes materially.
pub const SEED_DISPATCH_SECONDS: f64 = 1.8e-5;

/// Threads the host exposes (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user thread request: `0` means "all available".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// One job's result cell. Each index is produced by exactly one participant
/// (a [`StealQueues`] pop yields it exactly once), so the cell has at most
/// one writer, and the publisher only reads it after the batch retires.
struct ResultSlot<T>(UnsafeCell<Option<T>>);

/// One in-flight `run_tasks` call, allocated on the publisher's stack and
/// shared with workers through a type-erased [`BatchHandle`].
struct Batch<T, F> {
    /// Indexed jobs, pre-split into one deque per participant slot.
    queues: StealQueues<F>,
    /// One result cell per job, filed by job index.
    results: Vec<ResultSlot<T>>,
    /// Participants currently inside [`run_batch`] (joins are registered
    /// under the pool lock; the publisher waits for this to reach zero).
    active: AtomicUsize,
    /// First panic payload caught from a job, re-raised by the publisher.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Type-erased view of a [`Batch`] stored in the publish list.
///
/// Safety invariant (upheld by [`run_tasks`]): the pointed-to batch outlives
/// its listing — the publisher removes the handle and then blocks until
/// `active == 0` before its stack frame (and the batch) goes away.
#[derive(Clone, Copy)]
struct BatchHandle {
    batch: *const (),
    run: unsafe fn(*const (), usize),
    active: *const AtomicUsize,
    /// Total participant slots (the publisher holds slot 0).
    cap: usize,
    /// Next slot to hand to a joining worker (guarded by the pool lock).
    next_slot: usize,
    id: u64,
}

// Safety: the raw pointers reference a Batch that the publisher keeps alive
// until every participant has left it (see the retire sequence in
// `run_tasks`); the Batch's shared state is the Sync StealQueues, the atomic
// counter, the panic mutex, and result cells with disjoint single writers.
unsafe impl Send for BatchHandle {}

struct PoolState {
    batches: Vec<BatchHandle>,
    next_id: u64,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Parks idle workers; notified when a batch is published.
    work_cv: Condvar,
    /// Parks publishers waiting for their batch's participants to drain.
    done_cv: Condvar,
}

fn shared() -> &'static Shared {
    static SHARED: OnceLock<Shared> = OnceLock::new();
    SHARED.get_or_init(|| Shared {
        state: Mutex::new(PoolState { batches: Vec::new(), next_id: 0 }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
    })
}

/// Spawn the persistent workers exactly once, on first parallel dispatch.
fn ensure_workers() {
    static SPAWN: Once = Once::new();
    SPAWN.call_once(|| {
        for w in 0..available_threads().saturating_sub(1) {
            let _ = std::thread::Builder::new()
                .name(format!("ssnal-pool-{w}"))
                .spawn(worker_loop);
        }
    });
}

/// The body of one persistent worker: park until a batch has a free slot,
/// join it, drain jobs, report back, park again.
fn worker_loop() {
    let sh = shared();
    let mut st = sh.state.lock().expect("pool state lock");
    loop {
        if let Some(entry) = st.batches.iter_mut().find(|b| b.next_slot < b.cap) {
            let slot = entry.next_slot;
            entry.next_slot += 1;
            let handle = *entry;
            // Register under the lock: the publisher's retire sequence
            // (unlist, then wait for active == 0) can then never miss us.
            unsafe { (*handle.active).fetch_add(1, Ordering::Relaxed) };
            drop(st);
            unsafe { (handle.run)(handle.batch, slot) };
            let last = unsafe { (*handle.active).fetch_sub(1, Ordering::AcqRel) } == 1;
            st = sh.state.lock().expect("pool state lock");
            if last {
                // Notify under the lock so a publisher between its counter
                // check and its condvar wait cannot miss the wake.
                sh.done_cv.notify_all();
            }
        } else {
            st = sh.work_cv.wait(st).expect("pool state lock");
        }
    }
}

/// Drain jobs from `slot`'s deque (stealing once it is empty) and file each
/// result at its job index. Job panics are caught and parked in the batch;
/// the publisher re-raises the first one after the batch retires.
///
/// Safety: `batch` must point to a live `Batch<T, F>` whose publisher does
/// not return before every participant has left this function, and `slot`
/// must be below the batch's deque count.
unsafe fn run_batch<T, F>(batch: *const (), slot: usize)
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let batch = &*(batch as *const Batch<T, F>);
    while let Some((index, job)) = batch.queues.pop(slot) {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
            // Safety: StealQueues yields each index exactly once, so this
            // cell has no other writer.
            Ok(value) => *batch.results[index].0.get() = Some(value),
            Err(payload) => {
                let mut first = batch.panic.lock().expect("pool panic slot");
                if first.is_none() {
                    *first = Some(payload);
                }
            }
        }
    }
}

/// Execute `jobs` on up to `num_threads` participants (`0` = all available
/// cores), returning the outputs in job order. Dispatches through the
/// persistent pool; the caller always participates, so progress never
/// depends on a worker being free.
pub fn run_tasks<T, F>(num_threads: usize, jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_threads(num_threads).min(n);
    if workers <= 1 {
        // Single-threaded fallback: no pool traffic, no locks, same output.
        return jobs.into_iter().map(|job| job()).collect();
    }
    ensure_workers();

    let batch = Batch {
        queues: StealQueues::new(jobs, workers),
        results: (0..n).map(|_| ResultSlot(UnsafeCell::new(None))).collect(),
        active: AtomicUsize::new(0),
        panic: Mutex::new(None),
    };
    let erased = &batch as *const Batch<T, F> as *const ();
    let sh = shared();
    let id = {
        let mut st = sh.state.lock().expect("pool state lock");
        let id = st.next_id;
        st.next_id += 1;
        st.batches.push(BatchHandle {
            batch: erased,
            run: run_batch::<T, F>,
            active: &batch.active,
            cap: workers,
            next_slot: 1,
            id,
        });
        id
    };
    // Wake one parked worker per free slot — notify_all would stampede every
    // parked worker (and its mutex reacquisition) on each kernel call, the
    // exact overhead the persistent pool exists to avoid. Busy workers need
    // no notification: they re-scan the batch list before re-parking.
    for _ in 1..workers {
        sh.work_cv.notify_one();
    }

    // The publisher is participant 0: it drains its own deque and then
    // steals, so with every pool worker busy elsewhere the call degrades to
    // the serial loop instead of waiting.
    unsafe { run_batch::<T, F>(erased, 0) };

    // Retire: unlist the batch so no new worker joins, then wait until every
    // joined participant has left the (stack-allocated) batch. The Acquire
    // load pairs with the workers' AcqRel decrements, making their result
    // writes visible below.
    {
        let mut st = sh.state.lock().expect("pool state lock");
        st.batches.retain(|b| b.id != id);
        while batch.active.load(Ordering::Acquire) != 0 {
            st = sh.done_cv.wait(st).expect("pool state lock");
        }
    }

    if let Some(payload) = batch.panic.into_inner().expect("pool panic slot") {
        // Preserve the scoped-spawn contract: a panicking job propagates out
        // of run_tasks on the calling thread.
        std::panic::resume_unwind(payload);
    }
    let results = batch.results;
    results
        .into_iter()
        .map(|slot| slot.0.into_inner().expect("every job reports exactly one result"))
        .collect()
}

/// The pre-pool execution model: spawn scoped workers per call and collect
/// results over a channel. Semantically identical to [`run_tasks`] (same
/// deques, same index-ordered output, same bits); kept as the measured
/// baseline for the `bench-parallel --pool-*` dispatch-overhead comparison.
pub fn run_tasks_scoped<T, F>(num_threads: usize, jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_threads(num_threads).min(n);
    if workers <= 1 {
        return jobs.into_iter().map(|job| job()).collect();
    }

    let queues = StealQueues::new(jobs, workers);
    let (out_tx, out_rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let out_tx = out_tx.clone();
            let queues = &queues;
            scope.spawn(move || {
                // Own block first, then steal from the back of busy peers.
                while let Some((index, job)) = queues.pop(w) {
                    let value = job();
                    let _ = out_tx.send((index, value));
                }
            });
        }
    });
    drop(out_tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (index, value) in out_rx {
        slots[index] = Some(value);
    }
    slots
        .into_iter()
        .map(|slot| slot.expect("every job reports exactly one result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<_> = (0..64).map(|i| move || i * 3).collect();
        let out = run_tasks(4, jobs);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_matches_parallel() {
        let mk = || (0..40).map(|i| move || (i as f64).sqrt().sin()).collect::<Vec<_>>();
        assert_eq!(run_tasks(1, mk()), run_tasks(4, mk()));
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let count = &count;
                move || count.fetch_add(1, Ordering::Relaxed)
            })
            .collect();
        let out = run_tasks(0, jobs);
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_oversubscribed() {
        let none: Vec<fn() -> u8> = Vec::new();
        assert!(run_tasks(8, none).is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_tasks(64, jobs), vec![0, 1, 2]);
    }

    #[test]
    fn imbalanced_jobs_finish_and_keep_order() {
        // One deliberately heavy job in slot 0's block: the stealing pool
        // must still return every result at its own index.
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || {
                    let mut acc = 0u64;
                    let reps = if i == 0 { 200_000 } else { 200 };
                    for k in 0..reps {
                        acc = acc.wrapping_add(k).rotate_left(1);
                    }
                    (i, acc)
                }
            })
            .collect();
        let out = run_tasks(4, jobs);
        for (slot, (i, _)) in out.iter().enumerate() {
            assert_eq!(slot, *i);
        }
    }

    #[test]
    fn resolve_semantics() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn warm_pool_repeats_identically() {
        // Repeated batches on the warm pool are bitwise-identical to each
        // other and to the scoped-spawn baseline.
        let mk = || (0..32).map(|i| move || ((i * 37) as f64).sqrt().sin()).collect::<Vec<_>>();
        let first = run_tasks(4, mk());
        for _ in 0..10 {
            assert_eq!(run_tasks(4, mk()), first);
        }
        assert_eq!(run_tasks_scoped(4, mk()), first);
    }

    #[test]
    fn nested_batches_complete() {
        // A pool-worker participant publishing its own inner batch (the
        // chain-engine → shard nesting) must not deadlock the pool.
        let jobs: Vec<_> = (0..4)
            .map(|outer: usize| {
                move || {
                    let inner: Vec<_> = (0..8).map(|i| move || outer * 100 + i).collect();
                    run_tasks(2, inner)
                }
            })
            .collect();
        let out = run_tasks(4, jobs);
        for (outer, inner) in out.iter().enumerate() {
            assert_eq!(inner, &(0..8).map(|i| outer * 100 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn job_panics_propagate_to_the_publisher() {
        let jobs: Vec<_> = (0..8)
            .map(|i: usize| {
                move || {
                    if i == 3 {
                        panic!("pool job panic");
                    }
                    i
                }
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_tasks(4, jobs)));
        assert!(result.is_err(), "panic must propagate out of run_tasks");
        // The pool survives a panicking batch.
        let jobs: Vec<_> = (0..8).map(|i: usize| move || i + 1).collect();
        assert_eq!(run_tasks(4, jobs), (1..=8).collect::<Vec<_>>());
    }
}
