//! Work-stealing job queues for the worker pool.
//!
//! The pool used to drain one `Mutex<mpsc::Receiver>`: correct, but every pop
//! contends on a single lock, and the FIFO order means a worker that lands on
//! a long job ties up the jobs queued behind it until someone else happens to
//! reach the channel. [`StealQueues`] gives each participant slot of a batch
//! (the publisher plus the persistent-pool workers that join it) its own
//! deque, seeded with the contiguous block of jobs a static split would have
//! assigned to it.
//! A worker pops from the *front* of its own deque (preserving the
//! cache-friendly static order) and, once empty, steals from the *back* of a
//! victim's deque — the job farthest from the victim's current position, so
//! owner and thief never want the same end.
//!
//! Stealing only changes *which worker* runs a job, never the job itself or
//! the index its result is filed under, so [`crate::parallel::run_tasks`]
//! output — and every float downstream — is identical to the static split.
//! This is what lets imbalanced λ-grids (low-c tail chains cost several times
//! their head-chain peers) keep all workers busy without touching numerics.

use std::collections::VecDeque;
use std::sync::Mutex;

/// One worker's deque of `(job index, job)` pairs.
type Deque<F> = Mutex<VecDeque<(usize, F)>>;

/// Per-worker job deques with back-stealing.
pub struct StealQueues<F> {
    queues: Vec<Deque<F>>,
}

impl<F> StealQueues<F> {
    /// Distribute `jobs` over `workers` deques in contiguous index blocks —
    /// the same assignment a static split would make, so a run with no steals
    /// (e.g. perfectly balanced work) visits jobs in the static order.
    pub fn new(jobs: Vec<F>, workers: usize) -> Self {
        assert!(workers >= 1, "need at least one worker");
        let n = jobs.len();
        let mut queues: Vec<VecDeque<(usize, F)>> =
            (0..workers).map(|_| VecDeque::new()).collect();
        for (index, job) in jobs.into_iter().enumerate() {
            // block owner: worker w gets indices [w·n/W, (w+1)·n/W)
            let owner = index * workers / n.max(1);
            queues[owner.min(workers - 1)].push_back((index, job));
        }
        Self { queues: queues.into_iter().map(Mutex::new).collect() }
    }

    /// Number of worker deques.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Next job for `worker`: front of its own deque, else steal from the back
    /// of the first non-empty victim (scanning round-robin from `worker + 1`).
    /// `None` means every deque was empty at the time of the scan.
    pub fn pop(&self, worker: usize) -> Option<(usize, F)> {
        debug_assert!(worker < self.queues.len());
        if let Some(job) = self.queues[worker].lock().expect("steal queue lock").pop_front() {
            return Some(job);
        }
        let w = self.queues.len();
        for k in 1..w {
            let victim = (worker + k) % w;
            if let Some(job) =
                self.queues[victim].lock().expect("steal queue lock").pop_back()
            {
                return Some(job);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_indices<F>(q: &StealQueues<F>, worker: usize) -> Vec<usize> {
        let mut out = Vec::new();
        while let Some((i, _)) = q.pop(worker) {
            out.push(i);
        }
        out
    }

    #[test]
    fn blocks_mirror_the_static_split() {
        let q = StealQueues::new((0..8).collect::<Vec<_>>(), 4);
        assert_eq!(q.workers(), 4);
        // worker 0 drains its own block first (front order), then steals the
        // remaining blocks from the other deques' backs.
        let order = drain_indices(&q, 0);
        assert_eq!(order.len(), 8);
        assert_eq!(&order[..2], &[0, 1], "own block first, in order");
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn steals_from_the_back_of_victims() {
        let q = StealQueues::new((0..6).collect::<Vec<_>>(), 2);
        // worker 1 owns [3, 4, 5]; worker 0's first steal takes victim's back.
        assert_eq!(q.pop(0).unwrap().0, 0);
        assert_eq!(q.pop(0).unwrap().0, 1);
        assert_eq!(q.pop(0).unwrap().0, 2);
        assert_eq!(q.pop(0).unwrap().0, 5, "steal takes the victim's coldest job");
        assert_eq!(q.pop(1).unwrap().0, 3, "owner still pops its front");
        assert_eq!(q.pop(1).unwrap().0, 4);
        assert!(q.pop(0).is_none());
        assert!(q.pop(1).is_none());
    }

    #[test]
    fn uneven_job_counts_cover_everything() {
        for (jobs, workers) in [(1usize, 4usize), (5, 3), (7, 2), (16, 5)] {
            let q = StealQueues::new((0..jobs).collect::<Vec<_>>(), workers);
            let mut seen = Vec::new();
            // drain from every worker alternately to exercise the scan order
            'outer: loop {
                let mut any = false;
                for w in 0..workers {
                    if let Some((i, _)) = q.pop(w) {
                        seen.push(i);
                        any = true;
                    }
                }
                if !any {
                    break 'outer;
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..jobs).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn threaded_drain_runs_each_job_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..200)
            .map(|_| {
                let count = &count;
                move || count.fetch_add(1, Ordering::Relaxed)
            })
            .collect();
        let q = StealQueues::new(jobs, 4);
        std::thread::scope(|scope| {
            for w in 0..4 {
                let q = &q;
                scope.spawn(move || {
                    while let Some((_, job)) = q.pop(w) {
                        job();
                    }
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }
}
