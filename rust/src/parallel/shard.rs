//! Within-solve sharded linalg: the second parallelism layer.
//!
//! [`crate::parallel`]'s chain engine parallelizes *across* λ-grid points;
//! this module parallelizes *inside* one solve, where the paper's cost
//! anatomy puts the remaining O(mn) and O(mr) sweeps: the `Aᵀy` dual sweep,
//! the active-set `A_J u` accumulation, the `A_JᵀA_J` Gram build behind the
//! Woodbury strategy, the matrix-free CG mat-vec, the direct-Newton rank-1
//! triangle build, and the Gap-Safe `dual_point`/survivor scoring sweeps.
//! Each kernel splits its column dimension into **shards** and fans the
//! shards out through the pool's scheduling primitive
//! ([`crate::parallel::run_tasks`], work-stealing deques). The pool is
//! **persistent** — parked `std::thread` workers woken per kernel call (see
//! [`crate::parallel::pool`]'s module docs for lifecycle and parking) — so
//! dispatch costs a condvar wake, not a thread spawn, and sharding pays off
//! below O(mn) kernel granularity.
//!
//! # Determinism contract
//!
//! Every kernel's floating-point result is a pure function of its inputs and
//! its [`Plan`] — never of the thread count or of scheduling:
//!
//! * the shard split is a pure function of the problem shape
//!   ([`Plan::for_work`] derives it from element count × flops per element);
//! * element-wise kernels (`Aᵀy`, per-column dots, the Gram entries) compute
//!   each output element exactly as the serial loop does, so they are bitwise
//!   identical to the serial path *regardless* of sharding;
//! * reduction kernels (sharded `dot`, `A_J u` accumulation) combine shard
//!   partials with a **fixed-order pairwise tree** executed on the calling
//!   thread, so a 1-thread and an 8-thread run add the same numbers in the
//!   same order.
//!
//! Thread count only decides whether shards run on pool workers or in a loop
//! on the calling thread; both schedules produce the same bits. For shapes
//! that resolve to a single shard (every small problem), the kernels reduce
//! to exactly the pre-shard serial code paths.
//!
//! # Thread configuration
//!
//! The shard thread budget is ambient, not threaded through every call site:
//! a process-global default (initialized from the `SSNAL_THREADS` environment
//! variable, else 1; see [`set_threads`]) plus a thread-local override
//! ([`with_threads`]) that the chain engine uses to hand each worker its
//! share of spare cores — chains × within-solve shards never oversubscribe.

use crate::linalg::{blas, Mat};
use crate::parallel::pool;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Flops a single shard should amortize; below this, splitting costs more in
/// partial-buffer traffic than it buys in parallelism.
pub const TARGET_SHARD_FLOPS: usize = 1 << 21;

/// Cap on shards per kernel call (the reduction tree stays tiny).
pub const MAX_SHARDS: usize = 64;

/// Process-global shard thread budget (0 = not yet initialized).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override (0 = inherit the global budget).
    static LOCAL_THREADS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn global_threads() -> usize {
    let cur = GLOBAL_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let init = std::env::var("SSNAL_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or(1);
    // Racing initializers read the same fixed environment, so they agree.
    GLOBAL_THREADS.store(init, Ordering::Relaxed);
    init
}

/// Set the process-global shard thread budget (≥ 1; overrides `SSNAL_THREADS`).
pub fn set_threads(t: usize) {
    GLOBAL_THREADS.store(t.max(1), Ordering::Relaxed);
}

/// The shard thread budget in effect on this thread.
pub fn threads() -> usize {
    let local = LOCAL_THREADS.with(|c| c.get());
    if local != 0 {
        local
    } else {
        global_threads()
    }
}

/// Run `f` with the shard thread budget pinned to `t` on this thread
/// (restored afterwards, panic-safe). Worker threads spawned by the pool do
/// **not** inherit the override — each chain worker gets its own.
pub fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(t.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// A shard split: how many shards a kernel call uses. Pure data, pure
/// function of the problem shape — never of the thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Plan {
    /// Number of shards (≥ 1).
    pub shards: usize,
}

impl Plan {
    /// One shard: the serial code path, bit for bit.
    pub fn single() -> Plan {
        Plan { shards: 1 }
    }

    /// Force an explicit shard count (tests and the bench harness).
    pub fn with_shards(shards: usize) -> Plan {
        Plan { shards: shards.max(1) }
    }

    /// Derive the shard count from `units` work items costing roughly
    /// `flops_per_unit` each: one shard per [`TARGET_SHARD_FLOPS`] block,
    /// capped at [`MAX_SHARDS`] and at the unit count.
    pub fn for_work(units: usize, flops_per_unit: usize) -> Plan {
        if units == 0 {
            return Plan::single();
        }
        let total = units.saturating_mul(flops_per_unit.max(1));
        Plan { shards: (total / TARGET_SHARD_FLOPS).clamp(1, MAX_SHARDS).min(units) }
    }

    /// Balanced contiguous ranges tiling `0..units` (lengths differ by ≤ 1).
    pub fn split(&self, units: usize) -> Vec<Range<usize>> {
        let count = self.shards.clamp(1, units.max(1));
        let base = units / count;
        let extra = units % count;
        let mut out = Vec::with_capacity(count);
        let mut start = 0;
        for k in 0..count {
            let len = base + usize::from(k < extra);
            out.push(start..start + len);
            start += len;
        }
        out
    }
}

/// Run one closure per range, on the pool when the thread budget and the work
/// size justify it, else inline. Outputs are returned in range order either
/// way, so callers observe identical results.
fn run_ranges<T, F>(ranges: &[Range<usize>], f: F) -> Vec<T>
where
    F: Fn(Range<usize>) -> T + Sync,
    T: Send,
{
    let t = threads();
    if t <= 1 || ranges.len() <= 1 {
        return ranges.iter().map(|r| f(r.clone())).collect();
    }
    let jobs: Vec<_> = ranges
        .iter()
        .map(|r| {
            let f = &f;
            let r = r.clone();
            move || f(r)
        })
        .collect();
    pool::run_tasks(t, jobs)
}

/// Fixed-order pairwise tree sum of shard partials: combine `parts[i]` with
/// `parts[i + ceil(w/2)]`, halve, repeat. The order depends only on the part
/// count, never on which thread produced which part.
fn tree_reduce_scalars(mut parts: Vec<f64>) -> f64 {
    debug_assert!(!parts.is_empty());
    let mut width = parts.len();
    while width > 1 {
        let half = width.div_ceil(2);
        for i in 0..(width - half) {
            parts[i] += parts[i + half];
        }
        width = half;
    }
    parts[0]
}

/// Tree sum of equal-length vector partials (same pairing as the scalar
/// reduction), executed on the calling thread.
fn tree_reduce_vecs(mut parts: Vec<Vec<f64>>) -> Vec<f64> {
    debug_assert!(!parts.is_empty());
    let mut width = parts.len();
    while width > 1 {
        let half = width.div_ceil(2);
        for i in 0..(width - half) {
            let (lo, hi) = parts.split_at_mut(half);
            let src = &hi[i];
            for (d, s) in lo[i].iter_mut().zip(src.iter()) {
                *d += *s;
            }
        }
        width = half;
    }
    parts.swap_remove(0)
}

/// Sharded dot product (tree-reduced shard partials).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_planned(Plan::for_work(a.len(), 2), a, b)
}

/// [`dot`] with an explicit plan.
pub fn dot_planned(plan: Plan, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let ranges = plan.split(a.len());
    if ranges.len() == 1 {
        return blas::dot(a, b);
    }
    let parts = run_ranges(&ranges, |r| blas::dot(&a[r.clone()], &b[r]));
    tree_reduce_scalars(parts)
}

/// Sharded `y += alpha·x`. Disjoint output ranges: bitwise identical to
/// [`blas::axpy`] at every plan and thread count.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_planned(Plan::for_work(x.len(), 2), alpha, x, y)
}

/// [`axpy`] with an explicit plan.
pub fn axpy_planned(plan: Plan, alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let ranges = plan.split(x.len());
    if threads() <= 1 || ranges.len() <= 1 {
        // Same per-element op as the sharded path: y[i] += alpha·x[i].
        blas::axpy(alpha, x, y);
        return;
    }
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut y[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let xs = &x[r.start..r.end];
        jobs.push(move || blas::axpy(alpha, xs, head));
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded `out = Aᵀy` — the O(mn) dual sweep, one contiguous dot per output
/// element over disjoint column ranges. Bitwise identical to
/// [`Mat::t_mul_vec_into`] at every plan and thread count.
pub fn t_mul_vec_into(a: &Mat, y: &[f64], out: &mut [f64]) {
    t_mul_vec_into_planned(Plan::for_work(a.cols(), 2 * a.rows()), a, y, out)
}

/// [`t_mul_vec_into`] with an explicit plan.
pub fn t_mul_vec_into_planned(plan: Plan, a: &Mat, y: &[f64], out: &mut [f64]) {
    assert_eq!(y.len(), a.rows());
    assert_eq!(out.len(), a.cols());
    let ranges = plan.split(a.cols());
    if threads() <= 1 || ranges.len() <= 1 {
        a.t_mul_vec_into(y, out);
        return;
    }
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut out[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let start = r.start;
        jobs.push(move || {
            for (k, o) in head.iter_mut().enumerate() {
                *o = blas::dot(a.col(start + k), y);
            }
        });
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded sparse mat-vec `out = Σ_{j∈support} x[j]·A[:,j]` (the gradient's
/// `A_J u_J` term). Single-shard plans run the exact pre-shard serial kernel;
/// multi-shard plans accumulate per-shard partials and tree-reduce them.
pub fn mul_vec_support_into(a: &Mat, x: &[f64], support: &[usize], out: &mut [f64]) {
    mul_vec_support_into_planned(Plan::for_work(support.len(), 2 * a.rows()), a, x, support, out)
}

/// [`mul_vec_support_into`] with an explicit plan.
pub fn mul_vec_support_into_planned(
    plan: Plan,
    a: &Mat,
    x: &[f64],
    support: &[usize],
    out: &mut [f64],
) {
    assert_eq!(out.len(), a.rows());
    let ranges = plan.split(support.len());
    if ranges.len() == 1 {
        a.mul_vec_support_into(x, support, out);
        return;
    }
    let m = a.rows();
    let parts = run_ranges(&ranges, |r| {
        let mut part = vec![0.0; m];
        for &j in &support[r] {
            let xj = x[j];
            if xj != 0.0 {
                blas::axpy(xj, a.col(j), &mut part);
            }
        }
        part
    });
    let total = tree_reduce_vecs(parts);
    out.copy_from_slice(&total);
}

/// Sharded `out += Σ_k coeffs[k]·A[:, idx[k]]` (Woodbury's `A_J w` and the CG
/// operator's accumulation half). Zero coefficients are skipped, exactly like
/// the serial axpy loop. Single-shard plans accumulate in place (the
/// pre-shard serial bits); multi-shard plans tree-reduce zero-based partials
/// and add the total once.
pub fn add_scaled_cols(a: &Mat, idx: &[usize], coeffs: &[f64], out: &mut [f64]) {
    add_scaled_cols_planned(Plan::for_work(idx.len(), 2 * a.rows()), a, idx, coeffs, out)
}

/// [`add_scaled_cols`] with an explicit plan.
pub fn add_scaled_cols_planned(
    plan: Plan,
    a: &Mat,
    idx: &[usize],
    coeffs: &[f64],
    out: &mut [f64],
) {
    assert_eq!(idx.len(), coeffs.len());
    assert_eq!(out.len(), a.rows());
    let ranges = plan.split(idx.len());
    if ranges.len() == 1 {
        for (k, &j) in idx.iter().enumerate() {
            if coeffs[k] != 0.0 {
                blas::axpy(coeffs[k], a.col(j), out);
            }
        }
        return;
    }
    let m = a.rows();
    let parts = run_ranges(&ranges, |r| {
        let mut part = vec![0.0; m];
        for k in r {
            if coeffs[k] != 0.0 {
                blas::axpy(coeffs[k], a.col(idx[k]), &mut part);
            }
        }
        part
    });
    let total = tree_reduce_vecs(parts);
    for (o, t) in out.iter_mut().zip(total.iter()) {
        *o += *t;
    }
}

/// Sharded `out[k] = scale·⟨A[:, idx[k]], v⟩` (Woodbury's `A_Jᵀ rhs` and the
/// CG operator's dot half). Per-element, disjoint outputs: bitwise identical
/// to the serial loop at every thread count.
pub fn col_dots(a: &Mat, idx: &[usize], v: &[f64], scale: f64, out: &mut [f64]) {
    assert_eq!(out.len(), idx.len());
    assert_eq!(v.len(), a.rows());
    let plan = Plan::for_work(idx.len(), 2 * a.rows());
    let ranges = plan.split(idx.len());
    if threads() <= 1 || ranges.len() <= 1 {
        for (k, &j) in idx.iter().enumerate() {
            out[k] = scale * blas::dot(a.col(j), v);
        }
        return;
    }
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut out[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let ids = &idx[r.start..r.end];
        jobs.push(move || {
            for (k, o) in head.iter_mut().enumerate() {
                *o = scale * blas::dot(a.col(ids[k]), v);
            }
        });
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded Gram build `G = A_JᵀA_J + ridge·I`, rows assigned to shards in a
/// **strided** pattern (shard k takes rows k, k+S, k+2S, …) so the shrinking
/// upper-triangle rows balance. Every entry is the same column-pair dot the
/// serial [`Mat::gram_of_cols`] computes — the result is bitwise identical at
/// every thread count.
pub fn gram_of_cols(a: &Mat, idx: &[usize], ridge: f64) -> Mat {
    let r = idx.len();
    // triangle rows cost (r − row)·2m flops; size the plan on the total
    let plan = Plan::for_work(r * (r + 1) / 2, 2 * a.rows());
    if threads() <= 1 || plan.shards <= 1 {
        return a.gram_of_cols(idx, ridge);
    }
    let shards = plan.shards.min(r.max(1));
    let jobs: Vec<_> = (0..shards)
        .map(|k| {
            move || {
                let mut rows = Vec::new();
                let mut row = k;
                while row < r {
                    let ca = a.col(idx[row]);
                    let vals: Vec<f64> = (row..r).map(|b| blas::dot(ca, a.col(idx[b]))).collect();
                    rows.push((row, vals));
                    row += shards;
                }
                rows
            }
        })
        .collect();
    let outs = pool::run_tasks(threads(), jobs);
    let mut g = Mat::zeros(r, r);
    for rows in outs {
        for (row, vals) in rows {
            for (off, v) in vals.into_iter().enumerate() {
                let b = row + off;
                g.set(row, b, v);
                g.set(b, row, v);
            }
        }
    }
    for i in 0..r {
        g.set(i, i, g.get(i, i) + ridge);
    }
    g
}

/// Run one closure per plan-derived contiguous range of `0..units`, fanned
/// over the pool, returning the per-range outputs **in range order** — the
/// general sharded map behind the feature-wise screening sweeps
/// (`dual_point` scoring, Gap-Safe survivor scans). The range split is a pure
/// function of `(units, flops_per_unit)`, so for closures whose output is a
/// pure function of their range the result is identical at every thread
/// budget.
pub fn map_ranges<T, F>(units: usize, flops_per_unit: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let ranges = Plan::for_work(units, flops_per_unit.max(1)).split(units);
    run_ranges(&ranges, f)
}

/// Map a closure over every column, sharded (feature-wise precomputes such as
/// screening column norms). Per-element: output identical to the serial map.
pub fn map_cols<T, F>(a: &Mat, flops_per_col: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&[f64]) -> T + Sync,
{
    let outs = map_ranges(a.cols(), flops_per_col, |r| {
        r.map(|j| f(a.col(j))).collect::<Vec<T>>()
    });
    outs.into_iter().flatten().collect()
}

/// Sharded rank-1 lower-triangle accumulation for the direct Newton build:
/// `v[c.., c] += κ · Σ_{j∈active} a_j[c] · a_j[c..]` for every column `c` of
/// the m×m matrix `v` — the `solve_direct` O(m²r) sweep. Shards own strided
/// column sets (shard k takes c = k, k+S, …) so the shrinking triangle rows
/// balance, mirroring [`gram_of_cols`]. Every entry folds over `j` in
/// active-set order with the serial loop's exact `s != 0` skip, so the build
/// is bitwise-invariant to the thread budget; multi-shard plans accumulate
/// zero-based partials and add each column once, which matches the serial
/// in-place loop bit for bit whenever `v`'s triangle starts at zero (as in
/// `solve_direct`).
pub fn rank1_lower_accum(a: &Mat, active: &[usize], kappa: f64, v: &mut Mat) {
    let m = a.rows();
    assert_eq!(v.rows(), m);
    assert_eq!(v.cols(), m);
    let plan = Plan::for_work(m * (m + 1) / 2, 2 * active.len().max(1));
    if threads() <= 1 || plan.shards <= 1 {
        // The exact pre-shard serial loop: j-outer rank-1 updates.
        for &j in active {
            let col = a.col(j);
            for c in 0..m {
                let s = kappa * col[c];
                if s != 0.0 {
                    let vc = v.col_mut(c);
                    for row in c..m {
                        vc[row] += s * col[row];
                    }
                }
            }
        }
        return;
    }
    // The multi-shard path tree-folds zero-based partials and adds each
    // column once; that matches the serial in-place fold bit for bit only
    // from a zeroed triangle. Enforce the precondition in release too — the
    // O(m²) scan is a 1/r fraction of the O(m²r) build it guards, and a
    // silent violation would make output bits depend on the thread budget.
    assert!(
        (0..m).all(|c| (c..m).all(|r| v.get(r, c) == 0.0)),
        "multi-shard rank1_lower_accum requires a zeroed lower triangle"
    );
    let shards = plan.shards.min(m);
    let jobs: Vec<_> = (0..shards)
        .map(|k| {
            move || {
                let mut cols = Vec::new();
                let mut c = k;
                while c < m {
                    let mut vals = vec![0.0; m - c];
                    for &j in active {
                        let col = a.col(j);
                        let s = kappa * col[c];
                        if s != 0.0 {
                            for (off, dst) in vals.iter_mut().enumerate() {
                                *dst += s * col[c + off];
                            }
                        }
                    }
                    cols.push((c, vals));
                    c += shards;
                }
                cols
            }
        })
        .collect();
    for cols in pool::run_tasks(threads(), jobs) {
        for (c, vals) in cols {
            let vc = v.col_mut(c);
            for (off, val) in vals.into_iter().enumerate() {
                vc[c + off] += val;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn thread_config_roundtrip() {
        // global default is ≥ 1 whatever the environment says
        assert!(threads() >= 1);
        let ambient = threads();
        let inside = with_threads(3, threads);
        assert_eq!(inside, 3);
        assert_eq!(threads(), ambient, "override must restore");
        let nested = with_threads(2, || with_threads(5, threads));
        assert_eq!(nested, 5);
    }

    #[test]
    fn plan_split_tiles_and_balances() {
        for units in [0usize, 1, 2, 7, 100, 1000] {
            for shards in [1usize, 2, 3, 8, 200] {
                let ranges = Plan::with_shards(shards).split(units);
                assert!(!ranges.is_empty());
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, units);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "units={units} shards={shards}: {sizes:?}");
            }
        }
    }

    #[test]
    fn plan_for_work_is_shape_only() {
        assert_eq!(Plan::for_work(0, 100).shards, 1);
        assert_eq!(Plan::for_work(10, 2).shards, 1, "tiny work stays single-shard");
        let big = Plan::for_work(1 << 20, 1 << 10);
        assert!(big.shards > 1 && big.shards <= MAX_SHARDS);
        // never more shards than units
        assert!(Plan::for_work(3, usize::MAX / 4).shards <= 3);
    }

    #[test]
    fn tree_reduction_is_fixed_order() {
        // scalar: 5 parts → ((p0+p3)+ (p1+p4)) ... verify against a direct
        // evaluation of the documented pairing
        let parts = vec![1e-16, 1.0, -1.0, 2.0, 3.0];
        let got = tree_reduce_scalars(parts.clone());
        // width 5, half 3: p0+=p3, p1+=p4 → [2+1e-16? ...]; width 3, half 2:
        // p0+=p2; width 2: p0+=p1
        let (mut p0, mut p1, p2) = (parts[0] + parts[3], parts[1] + parts[4], parts[2]);
        p0 += p2;
        p0 += p1;
        assert_eq!(got, p0);
        let vecs = vec![vec![1.0, 2.0], vec![0.5, -1.0], vec![0.25, 4.0]];
        let got = tree_reduce_vecs(vecs.clone());
        let expect = vec![
            (vecs[0][0] + vecs[2][0]) + vecs[1][0],
            (vecs[0][1] + vecs[2][1]) + vecs[1][1],
        ];
        assert_eq!(got, expect);
    }

    #[test]
    fn single_shard_kernels_match_serial_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let a = Mat::from_fn(13, 37, |_, _| rng.next_gaussian());
        let y: Vec<f64> = (0..13).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..37).map(|_| rng.next_gaussian()).collect();

        let mut out_serial = vec![0.0; 37];
        a.t_mul_vec_into(&y, &mut out_serial);
        let mut out_shard = vec![0.0; 37];
        t_mul_vec_into(&a, &y, &mut out_shard);
        assert_eq!(out_serial, out_shard);

        let support: Vec<usize> = (0..37).step_by(3).collect();
        let mut au_serial = vec![0.0; 13];
        a.mul_vec_support_into(&x, &support, &mut au_serial);
        let mut au_shard = vec![0.0; 13];
        mul_vec_support_into(&a, &x, &support, &mut au_shard);
        assert_eq!(au_serial, au_shard);

        let g_serial = a.gram_of_cols(&support, 0.3);
        let g_shard = gram_of_cols(&a, &support, 0.3);
        assert_eq!(g_serial.as_slice(), g_shard.as_slice());

        assert_eq!(dot(&x, &x), blas::dot(&x, &x));
    }

    #[test]
    fn forced_plans_are_thread_count_invariant() {
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let a: Vec<f64> = (0..4001).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f64> = (0..4001).map(|_| rng.next_gaussian()).collect();
        for shards in [1usize, 2, 3, 8] {
            let plan = Plan::with_shards(shards);
            let reference = with_threads(1, || dot_planned(plan, &a, &b));
            for t in [2usize, 4, 8] {
                let got = with_threads(t, || dot_planned(plan, &a, &b));
                assert_eq!(got.to_bits(), reference.to_bits(), "shards={shards} threads={t}");
            }
        }
    }

    #[test]
    fn map_cols_preserves_order() {
        let a = Mat::from_fn(4, 9, |i, j| (i + 10 * j) as f64);
        let sums = map_cols(&a, 4, |col| col.iter().sum::<f64>());
        let expect: Vec<f64> = (0..9).map(|j| a.col(j).iter().sum::<f64>()).collect();
        assert_eq!(sums, expect);
    }

    #[test]
    fn map_ranges_tiles_in_order() {
        // Per-range outputs come back in range order and tile 0..units.
        let outs = map_ranges(257, 1 << 20, |r| r.collect::<Vec<usize>>());
        let flat: Vec<usize> = outs.into_iter().flatten().collect();
        assert_eq!(flat, (0..257).collect::<Vec<usize>>());
        // degenerate: zero units still yields one (empty) range
        let outs = map_ranges(0, 8, |r| r.len());
        assert_eq!(outs, vec![0]);
    }

    #[test]
    fn rank1_lower_accum_matches_explicit_sum() {
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let m = 17;
        let a = Mat::from_fn(m, 40, |_, _| rng.next_gaussian());
        let active: Vec<usize> = (0..40).step_by(2).collect();
        let kappa = 0.6;
        // reference: the explicit j-outer rank-1 loop on the lower triangle
        let mut v_ref = Mat::zeros(m, m);
        for &j in &active {
            let col = a.col(j);
            for c in 0..m {
                let s = kappa * col[c];
                if s != 0.0 {
                    for row in c..m {
                        let cur = v_ref.get(row, c);
                        v_ref.set(row, c, cur + s * col[row]);
                    }
                }
            }
        }
        for t in [1usize, 4] {
            let mut v = Mat::zeros(m, m);
            with_threads(t, || rank1_lower_accum(&a, &active, kappa, &mut v));
            assert_eq!(v.as_slice(), v_ref.as_slice(), "threads={t}");
        }
    }
}
