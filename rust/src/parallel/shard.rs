//! Within-solve sharded linalg: the second parallelism layer.
//!
//! [`crate::parallel`]'s chain engine parallelizes *across* λ-grid points;
//! this module parallelizes *inside* one solve, where the paper's cost
//! anatomy puts the remaining O(mn) and O(mr) sweeps: the `Aᵀy` dual sweep,
//! the active-set `A_J u` accumulation, the `A_JᵀA_J` Gram build behind the
//! Woodbury strategy, the matrix-free CG mat-vec, the direct-Newton rank-1
//! triangle build, and the Gap-Safe `dual_point`/survivor scoring sweeps.
//! Each kernel splits its column dimension into **shards** and fans the
//! shards out through the pool's scheduling primitive
//! ([`crate::parallel::run_tasks`], work-stealing deques). The pool is
//! **persistent** — parked `std::thread` workers woken per kernel call (see
//! [`crate::parallel::pool`]'s module docs for lifecycle and parking) — so
//! dispatch costs a condvar wake, not a thread spawn, and sharding pays off
//! below O(mn) kernel granularity.
//!
//! # Determinism contract
//!
//! Every kernel's floating-point result is a pure function of its inputs and
//! its [`Plan`] — never of the thread count or of scheduling:
//!
//! * the shard split is a pure function of the problem shape and the shard
//!   flop target ([`Plan::for_work`] derives it from element count × flops
//!   per element against [`target_shard_flops`]);
//! * element-wise kernels (`Aᵀy`, per-column dots, the Gram entries) compute
//!   each output element exactly as the serial loop does, so they are bitwise
//!   identical to the serial path *regardless* of sharding;
//! * reduction kernels (sharded `dot`, `A_J u` accumulation) combine shard
//!   partials with a **fixed-order pairwise tree** executed on the calling
//!   thread, so a 1-thread and an 8-thread run add the same numbers in the
//!   same order.
//!
//! Thread count only decides whether shards run on pool workers or in a loop
//! on the calling thread; both schedules produce the same bits. For shapes
//! that resolve to a single shard (every small problem), the kernels reduce
//! to exactly the pre-shard serial code paths — and take them without
//! touching the heap, which is what keeps the workspace-backed Newton hot
//! path allocation-free (see [`crate::linalg::workspace`]).
//!
//! # Scratch reuse
//!
//! Multi-shard reduction kernels need one zero-based partial buffer per
//! shard. Those buffers are drawn as a single flat slab from the **calling
//! thread's** [`crate::linalg::workspace::ShardScratch`] arena (thread-local,
//! so chain workers and nested shard calls on pool workers each reuse their
//! own) and returned after the fixed-order reduction — steady-state kernel
//! calls stop allocating the `vec![0.0; m]`-per-shard partials entirely.
//! Shard jobs write into disjoint pre-split slices of the slab, so the
//! partials' values (and the reduction order) are exactly those of the
//! old allocate-per-shard scheme, bit for bit.
//!
//! # Thread configuration
//!
//! The shard thread budget is ambient, not threaded through every call site:
//! a process-global default (initialized from the `SSNAL_THREADS` environment
//! variable, else 1; see [`set_threads`]) plus a thread-local override
//! ([`with_threads`]) that the chain engine uses to hand each worker its
//! share of spare cores — chains × within-solve shards never oversubscribe.
//! `SSNAL_THREADS` **never** changes output bits (see the contract above).
//!
//! # Shard flop target
//!
//! How much work one shard must amortize is itself configurable:
//! [`target_shard_flops`] resolves, in order, a thread-local override
//! ([`with_target_shard_flops`], scoped experiments/tests only — it affects
//! plans computed on the calling thread alone), a process-global value
//! ([`set_target_shard_flops`] / the `SSNAL_SHARD_FLOPS` environment
//! variable, read once), and finally a default *derived from the measured
//! per-wake dispatch cost* of the persistent pool: the committed
//! `BENCH_pool_dispatch.json` baseline seeds
//! [`pool::SEED_DISPATCH_SECONDS`], a shard is required to amortize
//! [`DISPATCH_AMORTIZATION`] wakes at [`EFFECTIVE_FLOPS_PER_SEC`], and the
//! result is rounded to the nearest power of two (which lands on
//! [`TARGET_SHARD_FLOPS`] = 2²¹ for the current seeds). The derivation uses
//! committed constants — never a runtime measurement — so the default plan
//! is identical on every host and every run. Unlike `SSNAL_THREADS`,
//! `SSNAL_SHARD_FLOPS` **changes the shard split and therefore the bits of
//! the reduction kernels**: it is part of the problem-shape inputs the
//! determinism contract is conditioned on, and must be identical across runs
//! that are expected to agree bitwise.

use crate::linalg::workspace::{scratch_give, scratch_take_zeroed};
use crate::linalg::{blas, DesignRef, Mat};
use crate::parallel::pool;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The derived default of [`target_shard_flops`] for the committed dispatch
/// seeds (kept as a named anchor: tests pin the derivation to it).
pub const TARGET_SHARD_FLOPS: usize = 1 << 21;

/// Wakes one shard must amortize against the seeded per-wake dispatch cost.
pub const DISPATCH_AMORTIZATION: f64 = 64.0;

/// Effective streaming flop rate (flops/s) assumed by the derivation — a
/// deliberately conservative single-core estimate for the level-1 kernels.
pub const EFFECTIVE_FLOPS_PER_SEC: f64 = 2.0e9;

/// Clamp bounds for the shard flop target (env override included).
pub const MIN_SHARD_FLOPS: usize = 1 << 16;
/// See [`MIN_SHARD_FLOPS`].
pub const MAX_SHARD_FLOPS: usize = 1 << 26;

/// Cap on shards per kernel call (the reduction tree stays tiny).
pub const MAX_SHARDS: usize = 64;

/// Process-global shard thread budget (0 = not yet initialized).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override (0 = inherit the global budget).
    static LOCAL_THREADS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn global_threads() -> usize {
    let cur = GLOBAL_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let init = std::env::var("SSNAL_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or(1);
    // Racing initializers read the same fixed environment, so they agree.
    GLOBAL_THREADS.store(init, Ordering::Relaxed);
    init
}

/// Set the process-global shard thread budget (≥ 1; overrides `SSNAL_THREADS`).
pub fn set_threads(t: usize) {
    GLOBAL_THREADS.store(t.max(1), Ordering::Relaxed);
}

/// The shard thread budget in effect on this thread.
pub fn threads() -> usize {
    let local = LOCAL_THREADS.with(|c| c.get());
    if local != 0 {
        local
    } else {
        global_threads()
    }
}

/// Run `f` with the shard thread budget pinned to `t` on this thread
/// (restored afterwards, panic-safe). Worker threads spawned by the pool do
/// **not** inherit the override — each chain worker gets its own.
pub fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(t.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// Process-global shard flop target (0 = not yet initialized).
static GLOBAL_SHARD_FLOPS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override (0 = inherit the global target).
    static LOCAL_SHARD_FLOPS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// The flop target derived from the committed per-wake dispatch seed (see the
/// module docs' "Shard flop target" section): `seed_seconds × amortization ×
/// flops/s`, rounded to the nearest power of two in log space and clamped.
fn derived_shard_flops() -> usize {
    let raw = pool::SEED_DISPATCH_SECONDS * DISPATCH_AMORTIZATION * EFFECTIVE_FLOPS_PER_SEC;
    let exp = raw.max(1.0).log2().round() as u32;
    (1usize << exp.min(usize::BITS - 2)).clamp(MIN_SHARD_FLOPS, MAX_SHARD_FLOPS)
}

fn global_shard_flops() -> usize {
    let cur = GLOBAL_SHARD_FLOPS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let init = std::env::var("SSNAL_SHARD_FLOPS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
        .map(|t| t.clamp(MIN_SHARD_FLOPS, MAX_SHARD_FLOPS))
        .unwrap_or_else(derived_shard_flops);
    // Racing initializers read the same fixed environment, so they agree.
    GLOBAL_SHARD_FLOPS.store(init, Ordering::Relaxed);
    init
}

/// Set the process-global shard flop target (clamped; overrides
/// `SSNAL_SHARD_FLOPS`). Changing it mid-process changes subsequent plans —
/// and therefore reduction-kernel bits — so do it before any solve.
pub fn set_target_shard_flops(t: usize) {
    GLOBAL_SHARD_FLOPS.store(t.clamp(MIN_SHARD_FLOPS, MAX_SHARD_FLOPS), Ordering::Relaxed);
}

/// The shard flop target in effect on this thread.
pub fn target_shard_flops() -> usize {
    let local = LOCAL_SHARD_FLOPS.with(|c| c.get());
    if local != 0 {
        local
    } else {
        global_shard_flops()
    }
}

/// Run `f` with the shard flop target pinned to `t` **on this thread**
/// (restored afterwards, panic-safe). Scoped experiments and tests only:
/// plans computed on other threads (pool workers, chain workers) keep the
/// global target, so production configuration must go through
/// `SSNAL_SHARD_FLOPS` / [`set_target_shard_flops`] to keep every thread's
/// plans — and bits — in agreement.
pub fn with_target_shard_flops<T>(t: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_SHARD_FLOPS.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_SHARD_FLOPS.with(|c| {
        let p = c.get();
        c.set(t.clamp(MIN_SHARD_FLOPS, MAX_SHARD_FLOPS));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// A shard split: how many shards a kernel call uses. Pure data, pure
/// function of the problem shape — never of the thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Plan {
    /// Number of shards (≥ 1).
    pub shards: usize,
}

impl Plan {
    /// One shard: the serial code path, bit for bit.
    pub fn single() -> Plan {
        Plan { shards: 1 }
    }

    /// Force an explicit shard count (tests and the bench harness).
    pub fn with_shards(shards: usize) -> Plan {
        Plan { shards: shards.max(1) }
    }

    /// Derive the shard count from `units` work items costing roughly
    /// `flops_per_unit` each: one shard per [`target_shard_flops`] block,
    /// capped at [`MAX_SHARDS`] and at the unit count.
    pub fn for_work(units: usize, flops_per_unit: usize) -> Plan {
        if units == 0 {
            return Plan::single();
        }
        let total = units.saturating_mul(flops_per_unit.max(1));
        Plan { shards: (total / target_shard_flops()).clamp(1, MAX_SHARDS).min(units) }
    }

    /// Balanced contiguous ranges tiling `0..units` (lengths differ by ≤ 1).
    pub fn split(&self, units: usize) -> Vec<Range<usize>> {
        let count = self.shards.clamp(1, units.max(1));
        let base = units / count;
        let extra = units % count;
        let mut out = Vec::with_capacity(count);
        let mut start = 0;
        for k in 0..count {
            let len = base + usize::from(k < extra);
            out.push(start..start + len);
            start += len;
        }
        out
    }
}

/// Run one closure per range, on the pool when the thread budget and the work
/// size justify it, else inline. Outputs are returned in range order either
/// way, so callers observe identical results.
fn run_ranges<T, F>(ranges: &[Range<usize>], f: F) -> Vec<T>
where
    F: Fn(Range<usize>) -> T + Sync,
    T: Send,
{
    let t = threads();
    if t <= 1 || ranges.len() <= 1 {
        return ranges.iter().map(|r| f(r.clone())).collect();
    }
    let jobs: Vec<_> = ranges
        .iter()
        .map(|r| {
            let f = &f;
            let r = r.clone();
            move || f(r)
        })
        .collect();
    pool::run_tasks(t, jobs)
}

/// Fixed-order pairwise tree sum of shard partials: combine `parts[i]` with
/// `parts[i + ceil(w/2)]`, halve, repeat. The order depends only on the part
/// count, never on which thread produced which part.
fn tree_reduce_scalars(mut parts: Vec<f64>) -> f64 {
    debug_assert!(!parts.is_empty());
    let mut width = parts.len();
    while width > 1 {
        let half = width.div_ceil(2);
        for i in 0..(width - half) {
            parts[i] += parts[i + half];
        }
        width = half;
    }
    parts[0]
}

/// Tree sum of `parts` equal-`len` vector partials packed contiguously in
/// `flat` (same pairing as the scalar reduction), executed on the calling
/// thread; the total lands in `flat[..len]`. Operating on one flat slab (the
/// scratch buffer the partials were written into) instead of a
/// `Vec<Vec<f64>>` keeps the reduction allocation-free; the pairing — and
/// therefore every output bit — is unchanged.
fn tree_reduce_flat(flat: &mut [f64], parts: usize, len: usize) {
    debug_assert!(parts > 0);
    debug_assert!(flat.len() >= parts * len);
    let mut width = parts;
    while width > 1 {
        let half = width.div_ceil(2);
        for i in 0..(width - half) {
            let (lo, hi) = flat.split_at_mut(half * len);
            let dst = &mut lo[i * len..(i + 1) * len];
            let src = &hi[i * len..(i + 1) * len];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
        }
        width = half;
    }
}

/// Run `()`-returning jobs that write into caller-owned disjoint buffers: on
/// the pool when the ambient budget allows, else inline on the calling
/// thread. Both schedules execute every job exactly once over the same
/// buffers, so they are indistinguishable to the caller.
fn run_jobs<F>(jobs: Vec<F>)
where
    F: FnOnce() + Send,
{
    let t = threads();
    if t <= 1 || jobs.len() <= 1 {
        for job in jobs {
            job();
        }
    } else {
        pool::run_tasks(t, jobs);
    }
}

/// Sharded dot product (tree-reduced shard partials).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_planned(Plan::for_work(a.len(), 2), a, b)
}

/// [`dot`] with an explicit plan.
pub fn dot_planned(plan: Plan, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // A single-shard plan is the serial kernel, bit for bit — taken without
    // touching the heap (no range split is materialized).
    if plan.shards <= 1 || a.len() <= 1 {
        return blas::dot(a, b);
    }
    let ranges = plan.split(a.len());
    let parts = run_ranges(&ranges, |r| blas::dot(&a[r.clone()], &b[r]));
    tree_reduce_scalars(parts)
}

/// Sharded `y += alpha·x`. Disjoint output ranges: bitwise identical to
/// [`blas::axpy`] at every plan and thread count.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_planned(Plan::for_work(x.len(), 2), alpha, x, y)
}

/// [`axpy`] with an explicit plan.
pub fn axpy_planned(plan: Plan, alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if threads() <= 1 || plan.shards <= 1 || x.len() <= 1 {
        // Same per-element op as the sharded path: y[i] += alpha·x[i].
        blas::axpy(alpha, x, y);
        return;
    }
    let ranges = plan.split(x.len());
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut y[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let xs = &x[r.start..r.end];
        jobs.push(move || blas::axpy(alpha, xs, head));
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded `out = Aᵀy` — the O(mn) dual sweep (O(nnz) on CSC designs), one
/// column dot per output element over disjoint column ranges. Bitwise
/// identical to [`DesignRef::t_mul_vec_into`] at every plan, thread count,
/// and storage. The plan is a function of the *logical* shape (`cols × 2·rows`
/// flops), never the storage, so dense and sparse copies of one matrix shard
/// identically.
pub fn t_mul_vec_into<'a>(a: impl Into<DesignRef<'a>>, y: &[f64], out: &mut [f64]) {
    let a = a.into();
    t_mul_vec_into_planned(Plan::for_work(a.cols(), 2 * a.rows()), a, y, out)
}

/// [`t_mul_vec_into`] with an explicit plan.
pub fn t_mul_vec_into_planned<'a>(
    plan: Plan,
    a: impl Into<DesignRef<'a>>,
    y: &[f64],
    out: &mut [f64],
) {
    let a = a.into();
    assert_eq!(y.len(), a.rows());
    assert_eq!(out.len(), a.cols());
    if threads() <= 1 || plan.shards <= 1 || a.cols() <= 1 {
        a.t_mul_vec_into(y, out);
        return;
    }
    let ranges = plan.split(a.cols());
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut out[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let start = r.start;
        jobs.push(move || {
            for (k, o) in head.iter_mut().enumerate() {
                *o = a.col_dot(start + k, y);
            }
        });
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded sparse mat-vec `out = Σ_{j∈support} x[j]·A[:,j]` (the gradient's
/// `A_J u_J` term). Single-shard plans run the exact pre-shard serial kernel;
/// multi-shard plans accumulate per-shard partials and tree-reduce them.
pub fn mul_vec_support_into<'a>(
    a: impl Into<DesignRef<'a>>,
    x: &[f64],
    support: &[usize],
    out: &mut [f64],
) {
    let a = a.into();
    mul_vec_support_into_planned(Plan::for_work(support.len(), 2 * a.rows()), a, x, support, out)
}

/// [`mul_vec_support_into`] with an explicit plan.
pub fn mul_vec_support_into_planned<'a>(
    plan: Plan,
    a: impl Into<DesignRef<'a>>,
    x: &[f64],
    support: &[usize],
    out: &mut [f64],
) {
    let a = a.into();
    assert_eq!(out.len(), a.rows());
    if plan.shards <= 1 || support.len() <= 1 {
        a.mul_vec_support_into(x, support, out);
        return;
    }
    let ranges = plan.split(support.len());
    let m = a.rows();
    // One zero-based partial per shard, packed in a flat scratch slab (see
    // the module docs' "Scratch reuse" section).
    let mut flat = scratch_take_zeroed(ranges.len() * m);
    {
        let mut jobs = Vec::with_capacity(ranges.len());
        let mut rest = &mut flat[..];
        for r in &ranges {
            let (part, tail) = std::mem::take(&mut rest).split_at_mut(m);
            let ids = &support[r.start..r.end];
            jobs.push(move || {
                for &j in ids {
                    let xj = x[j];
                    if xj != 0.0 {
                        a.col_axpy(xj, j, &mut *part);
                    }
                }
            });
            rest = tail;
        }
        run_jobs(jobs);
    }
    tree_reduce_flat(&mut flat, ranges.len(), m);
    out.copy_from_slice(&flat[..m]);
    scratch_give(flat);
}

/// Sharded `out += Σ_k coeffs[k]·A[:, idx[k]]` (Woodbury's `A_J w` and the CG
/// operator's accumulation half). Zero coefficients are skipped, exactly like
/// the serial axpy loop. Single-shard plans accumulate in place (the
/// pre-shard serial bits); multi-shard plans tree-reduce zero-based partials
/// and add the total once.
pub fn add_scaled_cols<'a>(
    a: impl Into<DesignRef<'a>>,
    idx: &[usize],
    coeffs: &[f64],
    out: &mut [f64],
) {
    let a = a.into();
    add_scaled_cols_planned(Plan::for_work(idx.len(), 2 * a.rows()), a, idx, coeffs, out)
}

/// [`add_scaled_cols`] with an explicit plan.
pub fn add_scaled_cols_planned<'a>(
    plan: Plan,
    a: impl Into<DesignRef<'a>>,
    idx: &[usize],
    coeffs: &[f64],
    out: &mut [f64],
) {
    let a = a.into();
    assert_eq!(idx.len(), coeffs.len());
    assert_eq!(out.len(), a.rows());
    if plan.shards <= 1 || idx.len() <= 1 {
        for (k, &j) in idx.iter().enumerate() {
            if coeffs[k] != 0.0 {
                a.col_axpy(coeffs[k], j, out);
            }
        }
        return;
    }
    let ranges = plan.split(idx.len());
    let m = a.rows();
    let mut flat = scratch_take_zeroed(ranges.len() * m);
    {
        let mut jobs = Vec::with_capacity(ranges.len());
        let mut rest = &mut flat[..];
        for r in &ranges {
            let (part, tail) = std::mem::take(&mut rest).split_at_mut(m);
            let r = r.clone();
            jobs.push(move || {
                for k in r {
                    if coeffs[k] != 0.0 {
                        a.col_axpy(coeffs[k], idx[k], &mut *part);
                    }
                }
            });
            rest = tail;
        }
        run_jobs(jobs);
    }
    tree_reduce_flat(&mut flat, ranges.len(), m);
    for (o, t) in out.iter_mut().zip(flat[..m].iter()) {
        *o += *t;
    }
    scratch_give(flat);
}

/// Sharded `out[k] = scale·⟨A[:, idx[k]], v⟩` (Woodbury's `A_Jᵀ rhs` and the
/// CG operator's dot half). Per-element, disjoint outputs: bitwise identical
/// to the serial loop at every thread count.
pub fn col_dots<'a>(
    a: impl Into<DesignRef<'a>>,
    idx: &[usize],
    v: &[f64],
    scale: f64,
    out: &mut [f64],
) {
    let a = a.into();
    assert_eq!(out.len(), idx.len());
    assert_eq!(v.len(), a.rows());
    let plan = Plan::for_work(idx.len(), 2 * a.rows());
    if threads() <= 1 || plan.shards <= 1 || idx.len() <= 1 {
        for (k, &j) in idx.iter().enumerate() {
            out[k] = scale * a.col_dot(j, v);
        }
        return;
    }
    let ranges = plan.split(idx.len());
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = &mut out[..];
    for r in &ranges {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
        let ids = &idx[r.start..r.end];
        jobs.push(move || {
            for (k, o) in head.iter_mut().enumerate() {
                *o = scale * a.col_dot(ids[k], v);
            }
        });
        rest = tail;
    }
    pool::run_tasks(threads(), jobs);
}

/// Sharded Gram build `G = A_JᵀA_J + ridge·I`, rows assigned to shards in a
/// **strided** pattern (shard k takes rows k, k+S, k+2S, …) so the shrinking
/// upper-triangle rows balance. Every entry is the same column-pair dot the
/// serial [`Mat::gram_of_cols`] computes — the result is bitwise identical at
/// every thread count.
pub fn gram_of_cols<'a>(a: impl Into<DesignRef<'a>>, idx: &[usize], ridge: f64) -> Mat {
    let mut g = Mat::zeros(idx.len(), idx.len());
    gram_of_cols_into(a, idx, ridge, &mut g);
    g
}

/// [`gram_of_cols`] into a caller-owned (workspace) matrix, resized only when
/// its dimension changes. The strided upper-triangle rows are computed into a
/// flat slab from the calling thread's scratch arena and scattered
/// sequentially, so repeated builds allocate nothing.
pub fn gram_of_cols_into<'a>(a: impl Into<DesignRef<'a>>, idx: &[usize], ridge: f64, g: &mut Mat) {
    let a = a.into();
    let r = idx.len();
    if g.rows() != r || g.cols() != r {
        *g = Mat::zeros(r, r);
    }
    // triangle rows cost (r − row)·2m flops; size the plan on the total
    let plan = Plan::for_work(r * (r + 1) / 2, 2 * a.rows());
    if threads() <= 1 || plan.shards <= 1 {
        // the exact serial build, written into the reused buffer
        for row in 0..r {
            for b in row..r {
                let v = a.cols_dot(idx[row], idx[b]);
                g.set(row, b, v);
                g.set(b, row, v);
            }
            let d = g.get(row, row) + ridge;
            g.set(row, row, d);
        }
        return;
    }
    let shards = plan.shards.min(r.max(1));
    // Flat slab holding the packed upper-triangle rows (row `row` occupies
    // `r - row` slots); shard k owns the strided rows k, k+S, ….
    let mut flat = scratch_take_zeroed(r * (r + 1) / 2);
    {
        let mut buckets: Vec<Vec<(usize, &mut [f64])>> =
            (0..shards).map(|_| Vec::new()).collect();
        let mut rest = &mut flat[..];
        for row in 0..r {
            let (vals, tail) = std::mem::take(&mut rest).split_at_mut(r - row);
            buckets[row % shards].push((row, vals));
            rest = tail;
        }
        let jobs: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                move || {
                    for (row, vals) in bucket {
                        for (off, dst) in vals.iter_mut().enumerate() {
                            *dst = a.cols_dot(idx[row], idx[row + off]);
                        }
                    }
                }
            })
            .collect();
        run_jobs(jobs);
    }
    let mut pos = 0;
    for row in 0..r {
        for off in 0..(r - row) {
            let v = flat[pos + off];
            let b = row + off;
            g.set(row, b, v);
            g.set(b, row, v);
        }
        pos += r - row;
    }
    for i in 0..r {
        g.set(i, i, g.get(i, i) + ridge);
    }
    scratch_give(flat);
}

/// Run one closure per plan-derived contiguous range of `0..units`, fanned
/// over the pool, returning the per-range outputs **in range order** — the
/// general sharded map behind the feature-wise screening sweeps
/// (`dual_point` scoring, Gap-Safe survivor scans). The range split is a pure
/// function of `(units, flops_per_unit)`, so for closures whose output is a
/// pure function of their range the result is identical at every thread
/// budget.
pub fn map_ranges<T, F>(units: usize, flops_per_unit: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let ranges = Plan::for_work(units, flops_per_unit.max(1)).split(units);
    run_ranges(&ranges, f)
}

/// Map a closure over every column, sharded (feature-wise precomputes such as
/// screening column norms). Per-element: output identical to the serial map.
pub fn map_cols<T, F>(a: &Mat, flops_per_col: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&[f64]) -> T + Sync,
{
    let outs = map_ranges(a.cols(), flops_per_col, |r| {
        r.map(|j| f(a.col(j))).collect::<Vec<T>>()
    });
    outs.into_iter().flatten().collect()
}

/// Sharded rank-1 lower-triangle accumulation for the direct Newton build:
/// `v[c.., c] += κ · Σ_{j∈active} a_j[c] · a_j[c..]` for every column `c` of
/// the m×m matrix `v` — the `solve_direct` O(m²r) sweep. Shards own strided
/// column sets (shard k takes c = k, k+S, …) so the shrinking triangle rows
/// balance, mirroring [`gram_of_cols`]. Every entry folds over `j` in
/// active-set order with the serial loop's exact `s != 0` skip, so the build
/// is bitwise-invariant to the thread budget; multi-shard plans accumulate
/// zero-based partials and add each column once, which matches the serial
/// in-place loop bit for bit whenever `v`'s triangle starts at zero (as in
/// `solve_direct`).
pub fn rank1_lower_accum<'a>(
    a: impl Into<DesignRef<'a>>,
    active: &[usize],
    kappa: f64,
    v: &mut Mat,
) {
    let a = a.into();
    let m = a.rows();
    assert_eq!(v.rows(), m);
    assert_eq!(v.cols(), m);
    let plan = Plan::for_work(m * (m + 1) / 2, 2 * active.len().max(1));
    if threads() <= 1 || plan.shards <= 1 {
        // The exact pre-shard serial loop: j-outer rank-1 updates. The dense
        // loop's `s != 0` guard skips exactly the zero entries a CSC column
        // does not store, and the skipped inner products are ±0.0 identities
        // on a zeroed triangle — so the two arms agree bit for bit.
        match a {
            DesignRef::Dense(ad) => {
                for &j in active {
                    let col = ad.col(j);
                    for c in 0..m {
                        let s = kappa * col[c];
                        if s != 0.0 {
                            let vc = v.col_mut(c);
                            for row in c..m {
                                vc[row] += s * col[row];
                            }
                        }
                    }
                }
            }
            DesignRef::Sparse(asp) => {
                for &j in active {
                    let (rs, vs) = asp.col(j);
                    for (k, (&c, &cv)) in rs.iter().zip(vs.iter()).enumerate() {
                        let s = kappa * cv;
                        if s != 0.0 {
                            let vc = v.col_mut(c);
                            // rows are ascending, so entries ≥ c are rs[k..]
                            for (&row, &val) in rs[k..].iter().zip(vs[k..].iter()) {
                                vc[row] += s * val;
                            }
                        }
                    }
                }
            }
            DesignRef::OutOfCore(oc) => {
                // Decoded panels are exact dense columns; the loop body is
                // the Dense arm verbatim.
                for &j in active {
                    oc.with_col(j, |col| {
                        for c in 0..m {
                            let s = kappa * col[c];
                            if s != 0.0 {
                                let vc = v.col_mut(c);
                                for row in c..m {
                                    vc[row] += s * col[row];
                                }
                            }
                        }
                    });
                }
            }
        }
        return;
    }
    // The multi-shard path folds zero-based partials and adds each column
    // once; that matches the serial in-place fold bit for bit only from a
    // zeroed triangle. The precondition is discharged by the owning
    // workspace ([`crate::linalg::workspace::NewtonWorkspace`] zeroes its
    // build buffer before lending it out — the zero-or-overwrite rule), so
    // the former O(m²) release-mode scan is now a debug assertion.
    debug_assert!(
        (0..m).all(|c| (c..m).all(|r| v.get(r, c) == 0.0)),
        "multi-shard rank1_lower_accum requires a zeroed lower triangle"
    );
    let shards = plan.shards.min(m);
    // Flat slab of packed column tails (column c occupies m − c slots),
    // strided over shards like the Gram build.
    let mut flat = scratch_take_zeroed(m * (m + 1) / 2);
    {
        let mut buckets: Vec<Vec<(usize, &mut [f64])>> =
            (0..shards).map(|_| Vec::new()).collect();
        let mut rest = &mut flat[..];
        for c in 0..m {
            let (vals, tail) = std::mem::take(&mut rest).split_at_mut(m - c);
            buckets[c % shards].push((c, vals));
            rest = tail;
        }
        let jobs: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                move || {
                    for (c, vals) in bucket {
                        match a {
                            DesignRef::Dense(ad) => {
                                for &j in active {
                                    let col = ad.col(j);
                                    let s = kappa * col[c];
                                    if s != 0.0 {
                                        for (off, dst) in vals.iter_mut().enumerate() {
                                            *dst += s * col[c + off];
                                        }
                                    }
                                }
                            }
                            DesignRef::Sparse(asp) => {
                                for &j in active {
                                    let (rs, vsv) = asp.col(j);
                                    let pos = rs.partition_point(|&row| row < c);
                                    if pos < rs.len() && rs[pos] == c {
                                        let s = kappa * vsv[pos];
                                        if s != 0.0 {
                                            for (&row, &val) in
                                                rs[pos..].iter().zip(vsv[pos..].iter())
                                            {
                                                vals[row - c] += s * val;
                                            }
                                        }
                                    }
                                }
                            }
                            DesignRef::OutOfCore(oc) => {
                                // Dense arm verbatim over decoded panels; the
                                // shared panel cache serves concurrent shards
                                // (immutable Arcs, per-thread decode scratch).
                                for &j in active {
                                    oc.with_col(j, |col| {
                                        let s = kappa * col[c];
                                        if s != 0.0 {
                                            for (off, dst) in vals.iter_mut().enumerate() {
                                                *dst += s * col[c + off];
                                            }
                                        }
                                    });
                                }
                            }
                        }
                    }
                }
            })
            .collect();
        run_jobs(jobs);
    }
    let mut pos = 0;
    for c in 0..m {
        let vc = v.col_mut(c);
        for (off, val) in flat[pos..pos + (m - c)].iter().enumerate() {
            vc[c + off] += *val;
        }
        pos += m - c;
    }
    scratch_give(flat);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn thread_config_roundtrip() {
        // global default is ≥ 1 whatever the environment says
        assert!(threads() >= 1);
        let ambient = threads();
        let inside = with_threads(3, threads);
        assert_eq!(inside, 3);
        assert_eq!(threads(), ambient, "override must restore");
        let nested = with_threads(2, || with_threads(5, threads));
        assert_eq!(nested, 5);
    }

    #[test]
    fn plan_split_tiles_and_balances() {
        for units in [0usize, 1, 2, 7, 100, 1000] {
            for shards in [1usize, 2, 3, 8, 200] {
                let ranges = Plan::with_shards(shards).split(units);
                assert!(!ranges.is_empty());
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, units);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "units={units} shards={shards}: {sizes:?}");
            }
        }
    }

    #[test]
    fn plan_for_work_is_shape_only() {
        assert_eq!(Plan::for_work(0, 100).shards, 1);
        assert_eq!(Plan::for_work(10, 2).shards, 1, "tiny work stays single-shard");
        let big = Plan::for_work(1 << 20, 1 << 10);
        assert!(big.shards > 1 && big.shards <= MAX_SHARDS);
        // never more shards than units
        assert!(Plan::for_work(3, usize::MAX / 4).shards <= 3);
    }

    #[test]
    fn tree_reduction_is_fixed_order() {
        // scalar: 5 parts → ((p0+p3)+ (p1+p4)) ... verify against a direct
        // evaluation of the documented pairing
        let parts = vec![1e-16, 1.0, -1.0, 2.0, 3.0];
        let got = tree_reduce_scalars(parts.clone());
        // width 5, half 3: p0+=p3, p1+=p4 → [2+1e-16? ...]; width 3, half 2:
        // p0+=p2; width 2: p0+=p1
        let (mut p0, mut p1, p2) = (parts[0] + parts[3], parts[1] + parts[4], parts[2]);
        p0 += p2;
        p0 += p1;
        assert_eq!(got, p0);
        // flat vector partials: same pairing as the scalar tree
        let mut flat = vec![1.0, 2.0, 0.5, -1.0, 0.25, 4.0]; // 3 parts × len 2
        tree_reduce_flat(&mut flat, 3, 2);
        let expect = [(1.0 + 0.25) + 0.5, (2.0 + 4.0) + (-1.0)];
        assert_eq!(&flat[..2], &expect);
    }

    #[test]
    fn shard_flop_target_derivation_and_override() {
        // the derived default must land exactly on the documented anchor —
        // a drifting derivation would silently change reduction bits
        assert_eq!(derived_shard_flops(), TARGET_SHARD_FLOPS);
        // scoped override: lowering the target multiplies the shard count
        let base = Plan::for_work(1 << 18, 16);
        let fine = with_target_shard_flops(MIN_SHARD_FLOPS, || Plan::for_work(1 << 18, 16));
        assert!(
            fine.shards >= base.shards,
            "lower target must not shard less: {fine:?} vs {base:?}"
        );
        assert_eq!(fine.shards, MAX_SHARDS, "2^22 flops / 2^16 target caps at MAX_SHARDS");
        // the override is scoped and restored
        let restored = Plan::for_work(1 << 18, 16);
        assert_eq!(restored, base);
        // clamping
        let clamped = with_target_shard_flops(1, target_shard_flops);
        assert_eq!(clamped, MIN_SHARD_FLOPS);
    }

    #[test]
    fn reduction_bits_depend_on_plan_not_target_resolution() {
        // the same explicit plan gives the same bits whatever the ambient
        // flop target resolves to — the target only picks the plan
        let a: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.01 - 3.0).collect();
        let b: Vec<f64> = (0..1000).map(|i| 0.5 - (i as f64) * 0.003).collect();
        let plan = Plan::with_shards(4);
        let reference = dot_planned(plan, &a, &b);
        let under_override =
            with_target_shard_flops(MIN_SHARD_FLOPS, || dot_planned(plan, &a, &b));
        assert_eq!(reference.to_bits(), under_override.to_bits());
    }

    #[test]
    fn single_shard_kernels_match_serial_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let a = Mat::from_fn(13, 37, |_, _| rng.next_gaussian());
        let y: Vec<f64> = (0..13).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..37).map(|_| rng.next_gaussian()).collect();

        let mut out_serial = vec![0.0; 37];
        a.t_mul_vec_into(&y, &mut out_serial);
        let mut out_shard = vec![0.0; 37];
        t_mul_vec_into(&a, &y, &mut out_shard);
        assert_eq!(out_serial, out_shard);

        let support: Vec<usize> = (0..37).step_by(3).collect();
        let mut au_serial = vec![0.0; 13];
        a.mul_vec_support_into(&x, &support, &mut au_serial);
        let mut au_shard = vec![0.0; 13];
        mul_vec_support_into(&a, &x, &support, &mut au_shard);
        assert_eq!(au_serial, au_shard);

        let g_serial = a.gram_of_cols(&support, 0.3);
        let g_shard = gram_of_cols(&a, &support, 0.3);
        assert_eq!(g_serial.as_slice(), g_shard.as_slice());

        assert_eq!(dot(&x, &x), blas::dot(&x, &x));
    }

    #[test]
    fn forced_plans_are_thread_count_invariant() {
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let a: Vec<f64> = (0..4001).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f64> = (0..4001).map(|_| rng.next_gaussian()).collect();
        for shards in [1usize, 2, 3, 8] {
            let plan = Plan::with_shards(shards);
            let reference = with_threads(1, || dot_planned(plan, &a, &b));
            for t in [2usize, 4, 8] {
                let got = with_threads(t, || dot_planned(plan, &a, &b));
                assert_eq!(got.to_bits(), reference.to_bits(), "shards={shards} threads={t}");
            }
        }
    }

    #[test]
    fn map_cols_preserves_order() {
        let a = Mat::from_fn(4, 9, |i, j| (i + 10 * j) as f64);
        let sums = map_cols(&a, 4, |col| col.iter().sum::<f64>());
        let expect: Vec<f64> = (0..9).map(|j| a.col(j).iter().sum::<f64>()).collect();
        assert_eq!(sums, expect);
    }

    #[test]
    fn map_ranges_tiles_in_order() {
        // Per-range outputs come back in range order and tile 0..units.
        let outs = map_ranges(257, 1 << 20, |r| r.collect::<Vec<usize>>());
        let flat: Vec<usize> = outs.into_iter().flatten().collect();
        assert_eq!(flat, (0..257).collect::<Vec<usize>>());
        // degenerate: zero units still yields one (empty) range
        let outs = map_ranges(0, 8, |r| r.len());
        assert_eq!(outs, vec![0]);
    }

    #[test]
    fn sharded_kernels_are_storage_invariant_bitwise() {
        use crate::linalg::CscMat;
        let mut rng = Xoshiro256pp::seed_from_u64(123);
        let (m, n) = (60usize, 200usize);
        let a = Mat::from_fn(m, n, |_, _| {
            if rng.next_f64() < 0.8 {
                0.0
            } else {
                rng.next_gaussian()
            }
        });
        let s = CscMat::from_dense(&a);
        let y: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let support: Vec<usize> = (0..n).step_by(2).collect();
        let coeffs: Vec<f64> = support.iter().map(|&j| x[j]).collect();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        // MIN_SHARD_FLOPS forces the gram/rank-1 triangle builds multi-shard
        // at this shape; the default target exercises the serial arms.
        for target in [TARGET_SHARD_FLOPS, MIN_SHARD_FLOPS] {
            for t in [1usize, 4] {
                with_target_shard_flops(target, || {
                    with_threads(t, || {
                        let plan = Plan::with_shards(5);
                        let (mut od, mut os) = (vec![0.0; n], vec![0.0; n]);
                        t_mul_vec_into_planned(plan, &a, &y, &mut od);
                        t_mul_vec_into_planned(plan, &s, &y, &mut os);
                        assert_eq!(bits(&od), bits(&os), "t_mul_vec t={t}");
                        let (mut ud, mut us) = (vec![0.0; m], vec![0.0; m]);
                        mul_vec_support_into_planned(plan, &a, &x, &support, &mut ud);
                        mul_vec_support_into_planned(plan, &s, &x, &support, &mut us);
                        assert_eq!(bits(&ud), bits(&us), "mul_vec_support t={t}");
                        let (mut vd, mut vs) = (y.clone(), y.clone());
                        add_scaled_cols_planned(plan, &a, &support, &coeffs, &mut vd);
                        add_scaled_cols_planned(plan, &s, &support, &coeffs, &mut vs);
                        assert_eq!(bits(&vd), bits(&vs), "add_scaled_cols t={t}");
                        let (mut cd, mut cs) = (vec![0.0; support.len()], vec![0.0; support.len()]);
                        col_dots(&a, &support, &y, 0.3, &mut cd);
                        col_dots(&s, &support, &y, 0.3, &mut cs);
                        assert_eq!(bits(&cd), bits(&cs), "col_dots t={t}");
                        let gd = gram_of_cols(&a, &support, 0.7);
                        let gs = gram_of_cols(&s, &support, 0.7);
                        assert_eq!(bits(gd.as_slice()), bits(gs.as_slice()), "gram t={t}");
                        let (mut rd, mut rs) = (Mat::zeros(m, m), Mat::zeros(m, m));
                        rank1_lower_accum(&a, &support, 0.9, &mut rd);
                        rank1_lower_accum(&s, &support, 0.9, &mut rs);
                        assert_eq!(bits(rd.as_slice()), bits(rs.as_slice()), "rank1 t={t}");
                    })
                });
            }
        }
    }

    #[test]
    fn rank1_lower_accum_matches_explicit_sum() {
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let m = 17;
        let a = Mat::from_fn(m, 40, |_, _| rng.next_gaussian());
        let active: Vec<usize> = (0..40).step_by(2).collect();
        let kappa = 0.6;
        // reference: the explicit j-outer rank-1 loop on the lower triangle
        let mut v_ref = Mat::zeros(m, m);
        for &j in &active {
            let col = a.col(j);
            for c in 0..m {
                let s = kappa * col[c];
                if s != 0.0 {
                    for row in c..m {
                        let cur = v_ref.get(row, c);
                        v_ref.set(row, c, cur + s * col[row]);
                    }
                }
            }
        }
        for t in [1usize, 4] {
            let mut v = Mat::zeros(m, m);
            with_threads(t, || rank1_lower_accum(&a, &active, kappa, &mut v));
            assert_eq!(v.as_slice(), v_ref.as_slice(), "threads={t}");
        }
    }
}
