//! State shared across path workers: the cross-chain truncation frontier.
//!
//! Sharing is *advisory only*: workers publish cap hits and consult the
//! frontier to skip grid points that can no longer appear in the final path.
//! Nothing a worker reads here ever changes the floats it produces for a
//! point it does solve — that is the invariant that keeps the engine's output
//! independent of worker scheduling. (Per-point Gap-Safe screening state stays
//! chain-local for the same reason; its summary is reported per chain via
//! [`crate::parallel::ChainReport`].)

use std::sync::atomic::{AtomicUsize, Ordering};

/// Shared truncation scoreboard for one parallel path run.
pub struct SharedScreen {
    /// Lowest grid index whose solution hit the max-active cap
    /// (`usize::MAX` = cap not hit anywhere yet).
    truncation: AtomicUsize,
}

impl SharedScreen {
    /// Fresh scoreboard.
    pub fn new() -> Self {
        Self { truncation: AtomicUsize::new(usize::MAX) }
    }

    /// Record that the solution at `grid_index` hit the max-active cap.
    pub fn note_cap_hit(&self, grid_index: usize) {
        self.truncation.fetch_min(grid_index, Ordering::SeqCst);
    }

    /// Lowest grid index known to have hit the cap, if any.
    pub fn truncated_at(&self) -> Option<usize> {
        match self.truncation.load(Ordering::SeqCst) {
            usize::MAX => None,
            t => Some(t),
        }
    }

    /// True when `grid_index` lies strictly beyond the truncation frontier and
    /// therefore cannot appear in the assembled path. Skipping is safe: the
    /// frontier only ever moves down, so a skipped index stays excluded.
    pub fn should_skip(&self, grid_index: usize) -> bool {
        grid_index > self.truncation.load(Ordering::SeqCst)
    }
}

impl Default for SharedScreen {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncation_frontier_takes_the_minimum() {
        let s = SharedScreen::new();
        assert_eq!(s.truncated_at(), None);
        assert!(!s.should_skip(9));
        s.note_cap_hit(7);
        s.note_cap_hit(3);
        assert_eq!(s.truncated_at(), Some(3));
        assert!(s.should_skip(4));
        assert!(!s.should_skip(3));
        assert!(!s.should_skip(0));
    }
}
