//! Splitting a descending c_λ grid into warm-start chains.
//!
//! Warm starts only pay off along a *contiguous* run of nearby λ values, so
//! the grid is cut into contiguous segments ("chains"); each chain is solved
//! sequentially with warm starts and the chains run concurrently. The split is
//! a pure function of `(grid length, chunking, thread count)` — never of
//! runtime timing — which is what makes the engine's output deterministic.

use crate::parallel::pool::resolve_threads;

/// How to cut the λ-grid into warm-start chains.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Chunking {
    /// One chain per worker thread.
    Auto,
    /// Exactly this many chains (clamped to the grid length; `0` acts like 1).
    Chains(usize),
    /// Chains of (at most) this many grid points.
    PointsPerChain(usize),
}

/// One contiguous chain: grid indices `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chain {
    pub start: usize,
    pub end: usize,
}

impl Chain {
    /// Number of grid points in the chain.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True for a degenerate empty chain (never produced by [`split_chains`]).
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// Split `grid_len` points into contiguous chains per the chunking policy.
/// Chains are returned in grid order and differ in length by at most one.
pub fn split_chains(grid_len: usize, chunking: &Chunking, num_threads: usize) -> Vec<Chain> {
    if grid_len == 0 {
        return Vec::new();
    }
    let count = match chunking {
        Chunking::Auto => resolve_threads(num_threads),
        Chunking::Chains(k) => (*k).max(1),
        Chunking::PointsPerChain(p) => grid_len.div_ceil((*p).max(1)),
    }
    .min(grid_len);
    let base = grid_len / count;
    let extra = grid_len % count;
    let mut chains = Vec::with_capacity(count);
    let mut start = 0;
    for k in 0..count {
        let len = base + usize::from(k < extra);
        chains.push(Chain { start, end: start + len });
        start += len;
    }
    chains
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover(chains: &[Chain], len: usize) {
        assert_eq!(chains.first().unwrap().start, 0);
        assert_eq!(chains.last().unwrap().end, len);
        for w in chains.windows(2) {
            assert_eq!(w[0].end, w[1].start, "chains must tile the grid");
        }
        for c in chains {
            assert!(!c.is_empty());
        }
    }

    #[test]
    fn chains_tile_the_grid() {
        for len in [1usize, 2, 7, 100, 101] {
            for k in [1usize, 2, 3, 8] {
                let chains = split_chains(len, &Chunking::Chains(k), 1);
                assert_eq!(chains.len(), k.min(len));
                cover(&chains, len);
                let sizes: Vec<usize> = chains.iter().map(Chain::len).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "balanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn points_per_chain() {
        let chains = split_chains(10, &Chunking::PointsPerChain(4), 1);
        assert_eq!(chains.len(), 3);
        cover(&chains, 10);
        assert!(chains.iter().all(|c| c.len() <= 4));
    }

    #[test]
    fn auto_uses_thread_count() {
        let chains = split_chains(100, &Chunking::Auto, 4);
        assert_eq!(chains.len(), 4);
        cover(&chains, 100);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(split_chains(0, &Chunking::Auto, 4).is_empty());
        assert_eq!(split_chains(3, &Chunking::Chains(0), 1).len(), 1);
        assert_eq!(split_chains(2, &Chunking::Chains(9), 1).len(), 2);
    }
}
