//! BLAS-like level-1 kernels, hand-written for the offline testbed.
//!
//! The SsNAL-EN hot loop is dominated by long contiguous dot products (`Aᵀy`,
//! `A_JᵀA_J`) and axpys (`Ax` over the active set). Each kernel uses unrolled
//! independent accumulators so LLVM auto-vectorizes them to packed SIMD ops.
//!
//! **SIMD-width audit.** The unroll width is `UNROLL = 8`: two 4-lane AVX2
//! registers (or one 8-lane AVX-512 register) of f64 accumulators in flight.
//! The previous 4-way kernels left half the throughput on the table on AVX2
//! hosts because a single 4-lane accumulator chain is latency-bound on the
//! `vaddpd` (4-cycle) dependency; eight independent accumulators cover the
//! latency×throughput product (4 cycles × 2 ports) exactly. Widths of 16 were
//! measured no faster (register pressure starts spilling) — see
//! `ssnal-en bench-parallel --shard-threads` which emits the audit table. The
//! 4-way variants are kept as `dot4`/`axpy4` so the audit stays reproducible.

/// Unroll width chosen by the SIMD-width audit (see module docs).
pub const UNROLL: usize = 8;

/// Dot product with 8 independent accumulators (auto-vectorization friendly).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut s = [0.0f64; 8];
    // Slice reborrow of exact length lets the compiler drop bounds checks.
    let (a8, at) = a.split_at(chunks * 8);
    let (b8, bt) = b.split_at(chunks * 8);
    let mut i = 0;
    while i < a8.len() {
        s[0] += a8[i] * b8[i];
        s[1] += a8[i + 1] * b8[i + 1];
        s[2] += a8[i + 2] * b8[i + 2];
        s[3] += a8[i + 3] * b8[i + 3];
        s[4] += a8[i + 4] * b8[i + 4];
        s[5] += a8[i + 5] * b8[i + 5];
        s[6] += a8[i + 6] * b8[i + 6];
        s[7] += a8[i + 7] * b8[i + 7];
        i += 8;
    }
    let mut acc = ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    for (x, y) in at.iter().zip(bt.iter()) {
        acc += x * y;
    }
    acc
}

/// Dot product with 4 accumulators — the pre-audit kernel, kept for the
/// width-audit benchmark.
#[inline]
pub fn dot4(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let (a4, at) = a.split_at(chunks * 4);
    let (b4, bt) = b.split_at(chunks * 4);
    let mut i = 0;
    while i < a4.len() {
        s0 += a4[i] * b4[i];
        s1 += a4[i + 1] * b4[i + 1];
        s2 += a4[i + 2] * b4[i + 2];
        s3 += a4[i + 3] * b4[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in at.iter().zip(bt.iter()) {
        s += x * y;
    }
    s
}

/// `y += alpha * x`, 8-way unrolled.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let (x8, xt) = x.split_at(chunks * 8);
    let (y8, yt) = y.split_at_mut(chunks * 8);
    let mut i = 0;
    while i < x8.len() {
        y8[i] += alpha * x8[i];
        y8[i + 1] += alpha * x8[i + 1];
        y8[i + 2] += alpha * x8[i + 2];
        y8[i + 3] += alpha * x8[i + 3];
        y8[i + 4] += alpha * x8[i + 4];
        y8[i + 5] += alpha * x8[i + 5];
        y8[i + 6] += alpha * x8[i + 6];
        y8[i + 7] += alpha * x8[i + 7];
        i += 8;
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// `y += alpha * x`, 4-way — the pre-audit kernel, kept for the width-audit
/// bench (`shard_linalg_rows` times it against the 8-way [`axpy`]).
#[inline]
pub fn axpy4(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 4;
    let (x4, xt) = x.split_at(chunks * 4);
    let (y4, yt) = y.split_at_mut(chunks * 4);
    let mut i = 0;
    while i < x4.len() {
        y4[i] += alpha * x4[i];
        y4[i + 1] += alpha * x4[i + 1];
        y4[i + 2] += alpha * x4[i + 2];
        y4[i + 3] += alpha * x4[i + 3];
        i += 4;
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// `y = x + beta * y` (the CG direction update `p ← r + βp`), 8-way unrolled.
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let (x8, xt) = x.split_at(chunks * 8);
    let (y8, yt) = y.split_at_mut(chunks * 8);
    let mut i = 0;
    while i < x8.len() {
        y8[i] = x8[i] + beta * y8[i];
        y8[i + 1] = x8[i + 1] + beta * y8[i + 1];
        y8[i + 2] = x8[i + 2] + beta * y8[i + 2];
        y8[i + 3] = x8[i + 3] + beta * y8[i + 3];
        y8[i + 4] = x8[i + 4] + beta * y8[i + 4];
        y8[i + 5] = x8[i + 5] + beta * y8[i + 5];
        y8[i + 6] = x8[i + 6] + beta * y8[i + 6];
        y8[i + 7] = x8[i + 7] + beta * y8[i + 7];
        i += 8;
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi = xi + beta * *yi;
    }
}

/// Euclidean norm, scaled by the max element to stay safe on extreme inputs.
///
/// Non-finite semantics follow IEEE-754 vector-norm conventions strictly:
/// any NaN element makes the norm NaN (a NaN must never be laundered into a
/// finite value or ±∞), and otherwise any infinite element makes it +∞.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    let mut mx = 0.0f64;
    let mut saw_nan = false;
    for &v in x {
        let a = v.abs();
        // f64::max ignores NaN operands, so track them explicitly.
        saw_nan |= a.is_nan();
        mx = mx.max(a);
    }
    if saw_nan {
        return f64::NAN;
    }
    if mx == 0.0 {
        return 0.0;
    }
    if mx.is_infinite() {
        return f64::INFINITY;
    }
    let inv = 1.0 / mx;
    let mut s = 0.0;
    if inv.is_finite() {
        for &v in x {
            let t = v * inv;
            s += t * t;
        }
    } else {
        // mx is subnormal: 1/mx overflows to ∞, so divide per element instead
        // of laundering a tiny vector into +∞.
        for &v in x {
            let t = v / mx;
            s += t * t;
        }
    }
    mx * s.sqrt()
}

/// Squared Euclidean norm (fast path, no scaling).
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// `out = a - b` elementwise.
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// `||a - b||₂` without allocating.
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_all_lengths() {
        for n in 0..40 {
            let a: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 3.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "n={n}");
            assert!((dot4(&a, &b) - naive).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 17, 64] {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let mut y: Vec<f64> = (0..n).map(|i| -(i as f64) * 0.25).collect();
            let mut y4 = y.clone();
            let mut y2 = y.clone();
            axpy(2.5, &x, &mut y);
            axpy4(2.5, &x, &mut y4);
            for i in 0..n {
                y2[i] += 2.5 * x[i];
            }
            assert_eq!(y, y2);
            // per-element op is a single mul-add: widths agree bitwise
            assert_eq!(y4, y2);
        }
    }

    #[test]
    fn xpby_matches_naive() {
        for n in [0usize, 1, 7, 8, 9, 33] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
            let mut y: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
            let mut y2 = y.clone();
            xpby(&x, 0.75, &mut y);
            for i in 0..n {
                y2[i] = x[i] + 0.75 * y2[i];
            }
            assert_eq!(y, y2);
        }
    }

    #[test]
    fn nrm2_basic_and_scaled() {
        assert_eq!(nrm2(&[]), 0.0);
        assert_eq!(nrm2(&[0.0, 0.0]), 0.0);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        // huge values: naive sum-of-squares would overflow
        let big = vec![1e200, 1e200];
        assert!((nrm2(&big) - 1e200 * 2f64.sqrt()).abs() / 1e200 < 1e-12);
    }

    #[test]
    fn nrm2_nonfinite_edge_cases() {
        // NaN anywhere → NaN, never a finite value or ∞
        assert!(nrm2(&[f64::NAN]).is_nan());
        assert!(nrm2(&[0.0, f64::NAN, 0.0]).is_nan());
        assert!(nrm2(&[1.0, f64::NAN]).is_nan());
        // NaN wins even in the presence of ∞
        assert!(nrm2(&[f64::INFINITY, f64::NAN]).is_nan());
        assert!(nrm2(&[f64::NAN, f64::NEG_INFINITY]).is_nan());
        // ∞ without NaN → +∞ (either sign of the element)
        assert_eq!(nrm2(&[f64::INFINITY]), f64::INFINITY);
        assert_eq!(nrm2(&[1.0, f64::NEG_INFINITY, 2.0]), f64::INFINITY);
        // smallest normal survives the scaling
        let tiny = f64::MIN_POSITIVE;
        assert!(nrm2(&[tiny, 0.0]) > 0.0);
        // true subnormals too: 1/mx overflows there, the divide path kicks in
        let sub = 1e-320f64;
        assert_eq!(nrm2(&[sub, 0.0]), sub);
        assert!(nrm2(&[sub, sub]).is_finite());
        assert!(nrm2(&[sub, sub]) >= sub);
    }

    #[test]
    fn inf_norm_and_dist() {
        assert_eq!(nrm_inf(&[-3.0, 2.0, 0.5]), 3.0);
        assert!((dist2(&[1.0, 2.0], &[4.0, 6.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn scal_and_sub() {
        let mut v = vec![1.0, -2.0, 3.0];
        scal(-2.0, &mut v);
        assert_eq!(v, vec![-2.0, 4.0, -6.0]);
        let mut out = vec![0.0; 3];
        sub_into(&[5.0, 5.0, 5.0], &v, &mut out);
        assert_eq!(out, vec![7.0, 1.0, 11.0]);
    }
}
