//! BLAS-like level-1 kernels, hand-written for the offline single-core testbed.
//!
//! The SsNAL-EN hot loop is dominated by long contiguous dot products (`Aᵀy`,
//! `A_JᵀA_J`) and axpys (`Ax` over the active set). Each kernel uses 4-way
//! unrolled independent accumulators so LLVM auto-vectorizes them to packed
//! AVX ops; see EXPERIMENTS.md §Perf for measured throughput.

/// Dot product with 4 independent accumulators (auto-vectorization friendly).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    // Slice reborrow of exact length lets the compiler drop bounds checks.
    let (a4, at) = a.split_at(chunks * 4);
    let (b4, bt) = b.split_at(chunks * 4);
    let mut i = 0;
    while i < a4.len() {
        s0 += a4[i] * b4[i];
        s1 += a4[i + 1] * b4[i + 1];
        s2 += a4[i + 2] * b4[i + 2];
        s3 += a4[i + 3] * b4[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in at.iter().zip(bt.iter()) {
        s += x * y;
    }
    s
}

/// `y += alpha * x`, unrolled.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 4;
    let (x4, xt) = x.split_at(chunks * 4);
    let (y4, yt) = y.split_at_mut(chunks * 4);
    let mut i = 0;
    while i < x4.len() {
        y4[i] += alpha * x4[i];
        y4[i + 1] += alpha * x4[i + 1];
        y4[i + 2] += alpha * x4[i + 2];
        y4[i + 3] += alpha * x4[i + 3];
        i += 4;
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm (no over/underflow guard needed at our scales, but we scale
/// by the max element to stay safe on extreme inputs).
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    let mx = x.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    if mx == 0.0 || !mx.is_finite() {
        return if mx.is_finite() { 0.0 } else { f64::INFINITY };
    }
    let inv = 1.0 / mx;
    let mut s = 0.0;
    for &v in x {
        let t = v * inv;
        s += t * t;
    }
    mx * s.sqrt()
}

/// Squared Euclidean norm (fast path, no scaling).
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// `out = a - b` elementwise.
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// `||a - b||₂` without allocating.
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_all_lengths() {
        for n in 0..40 {
            let a: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 3.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0usize, 1, 3, 4, 5, 17, 64] {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let mut y: Vec<f64> = (0..n).map(|i| -(i as f64) * 0.25).collect();
            let mut y2 = y.clone();
            axpy(2.5, &x, &mut y);
            for i in 0..n {
                y2[i] += 2.5 * x[i];
            }
            assert_eq!(y, y2);
        }
    }

    #[test]
    fn nrm2_basic_and_scaled() {
        assert_eq!(nrm2(&[]), 0.0);
        assert_eq!(nrm2(&[0.0, 0.0]), 0.0);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        // huge values: naive sum-of-squares would overflow
        let big = vec![1e200, 1e200];
        assert!((nrm2(&big) - 1e200 * 2f64.sqrt()).abs() / 1e200 < 1e-12);
    }

    #[test]
    fn inf_norm_and_dist() {
        assert_eq!(nrm_inf(&[-3.0, 2.0, 0.5]), 3.0);
        assert!((dist2(&[1.0, 2.0], &[4.0, 6.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn scal_and_sub() {
        let mut v = vec![1.0, -2.0, 3.0];
        scal(-2.0, &mut v);
        assert_eq!(v, vec![-2.0, 4.0, -6.0]);
        let mut out = vec![0.0; 3];
        sub_into(&[5.0, 5.0, 5.0], &v, &mut out);
        assert_eq!(out, vec![7.0, 1.0, 11.0]);
    }
}
