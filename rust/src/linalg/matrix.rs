//! Column-major dense matrix.
//!
//! The design matrix `A` (m × n, n ≫ m) is stored **column-major** because every hot
//! operation in SsNAL-EN streams over columns:
//!
//! * `Aᵀy` — one contiguous dot product per column,
//! * `Ax` with sparse `x` — an axpy per *active* column only,
//! * `A_J` — gathering active columns is a contiguous copy,
//! * `A_JᵀA_J` — dots of column pairs.
//!
//! The methods here are the *serial reference kernels*. The solver hot paths
//! call the sharded counterparts in [`crate::parallel::shard`], which split
//! the column dimension over the worker pool. Element-wise kernels (`Aᵀy`,
//! Gram entries) reproduce these loops bit for bit at any shard count;
//! reduction kernels (`Ax` accumulation) match them bit for bit only at
//! single-shard plans and are otherwise *thread-count-invariant* under a
//! fixed-order reduction tree (`tests/linalg_parallel.rs` pins both down).

use crate::linalg::blas;

/// Dense column-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    /// Column-major storage: element (i, j) lives at `data[j * rows + i]`.
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.data[j * rows + i] = f(i, j);
            }
        }
        m
    }

    /// Wrap existing column-major storage.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "storage length mismatch");
        Self { rows, cols, data }
    }

    /// Build from row-major data (e.g. parsed text files).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "storage length mismatch");
        Self::from_fn(rows, cols, |i, j| data[i * cols + j])
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Immutable view of column `j` (length `rows`, contiguous).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Element access (row, col).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    /// Element assignment (row, col).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Raw column-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable column-major storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// `out = Aᵀ y` — the O(mn) dual sweep; one contiguous dot per column.
    pub fn t_mul_vec_into(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for j in 0..self.cols {
            out[j] = blas::dot(self.col(j), y);
        }
    }

    /// `Aᵀ y`, allocating.
    pub fn t_mul_vec(&self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.t_mul_vec_into(y, &mut out);
        out
    }

    /// `out = A x` — accumulated column-wise; skips exact zeros in `x`, which makes
    /// this O(m·nnz(x)) on the sparse primal iterates SsNAL produces.
    pub fn mul_vec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        out.iter_mut().for_each(|o| *o = 0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                blas::axpy(xj, self.col(j), out);
            }
        }
    }

    /// `A x`, allocating.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.mul_vec_into(x, &mut out);
        out
    }

    /// `A x` restricted to a support set: `out = Σ_{j∈support} x[j]·A[:,j]`.
    pub fn mul_vec_support_into(&self, x: &[f64], support: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        out.iter_mut().for_each(|o| *o = 0.0);
        for &j in support {
            let xj = x[j];
            if xj != 0.0 {
                blas::axpy(xj, self.col(j), out);
            }
        }
    }

    /// Gather columns `idx` into a dense m × |idx| matrix (contiguous copies).
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for (k, &j) in idx.iter().enumerate() {
            out.col_mut(k).copy_from_slice(self.col(j));
        }
        out
    }

    /// Gram matrix of a column subset: `G = A_JᵀA_J + ridge·I` (|J| × |J|, row-major
    /// packed into a `Mat` — symmetric so the layout question is moot).
    pub fn gram_of_cols(&self, idx: &[usize], ridge: f64) -> Mat {
        let r = idx.len();
        let mut g = Mat::zeros(r, r);
        for a in 0..r {
            let ca = self.col(idx[a]);
            for b in a..r {
                let v = blas::dot(ca, self.col(idx[b]));
                g.set(a, b, v);
                g.set(b, a, v);
            }
            let d = g.get(a, a) + ridge;
            g.set(a, a, d);
        }
        g
    }

    /// In-place structural remap of a **square** matrix: resize to
    /// `new_n × new_n`, where new entry `(i, j)` takes the old entry
    /// `(old_map[i], old_map[j])` and rows/columns with `old_map[k] ==
    /// usize::MAX` are *inserted* (zero-filled). `old_map` must be strictly
    /// increasing over its mapped entries — the shape of an active-set edit
    /// (columns removed and inserted at sorted positions), which is what the
    /// Woodbury Gram cache uses this for. Kept entries move bit-for-bit;
    /// no arithmetic is performed.
    ///
    /// Runs in place over the existing storage in two passes (compact the
    /// survivors forward, then expand with holes backward), so the only
    /// possible allocation is growing the backing buffer beyond its retained
    /// capacity.
    pub(crate) fn remap_square(&mut self, new_n: usize, old_map: &[usize]) {
        assert_eq!(self.rows, self.cols, "remap_square requires a square matrix");
        assert_eq!(old_map.len(), new_n, "old_map must have one entry per new index");
        let n_old = self.rows;
        let s = old_map.iter().filter(|&&m| m != usize::MAX).count();
        debug_assert!(s <= n_old, "more survivors than old rows");
        debug_assert!(
            old_map
                .iter()
                .filter(|&&m| m != usize::MAX)
                .zip(old_map.iter().filter(|&&m| m != usize::MAX).skip(1))
                .all(|(a, b)| a < b),
            "old_map must be strictly increasing over mapped entries"
        );
        // Pass 1 — compact the surviving rows/columns into a leading s×s
        // block (stride s), ascending destination order. The t-th mapped
        // entry has old index ≥ t and n_old ≥ s, so every source index is
        // ≥ its destination: forward copies never read an overwritten slot.
        {
            let data = &mut self.data;
            let mut tj = 0usize;
            for &oj in old_map.iter().filter(|&&m| m != usize::MAX) {
                let mut ti = 0usize;
                for &oi in old_map.iter().filter(|&&m| m != usize::MAX) {
                    debug_assert!(oi < n_old && oj < n_old, "old_map index out of range");
                    data[tj * s + ti] = data[oj * n_old + oi];
                    ti += 1;
                }
                tj += 1;
            }
        }
        self.data.resize(new_n * new_n, 0.0);
        // Pass 2 — expand from stride s to stride new_n, descending
        // destination order, zero-filling inserted rows/columns. Survivor
        // ranks satisfy t ≤ its new index and s ≤ new_n, so every source
        // index is ≤ its destination: backward copies are safe.
        {
            let data = &mut self.data;
            let mut tj = s;
            for j in (0..new_n).rev() {
                let oj_mapped = old_map[j] != usize::MAX;
                if oj_mapped {
                    tj -= 1;
                }
                let mut ti = s;
                for i in (0..new_n).rev() {
                    let oi_mapped = old_map[i] != usize::MAX;
                    if oi_mapped {
                        ti -= 1;
                    }
                    data[j * new_n + i] =
                        if oj_mapped && oi_mapped { data[tj * s + ti] } else { 0.0 };
                }
            }
        }
        self.rows = new_n;
        self.cols = new_n;
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        blas::nrm2(&self.data)
    }

    /// Transpose (used only in small/test contexts — the solver never transposes A).
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// Dense matrix–matrix product (small matrices: tuning, tests).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for j in 0..other.cols {
            let bcol = other.col(j);
            let ocol = out.col_mut(j);
            for (k, &bkj) in bcol.iter().enumerate() {
                if bkj != 0.0 {
                    blas::axpy(bkj, self.col(k), ocol);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mat {
        // [[1, 2, 3],
        //  [4, 5, 6]]
        Mat::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn storage_is_column_major() {
        let a = small();
        assert_eq!(a.col(0), &[1.0, 4.0]);
        assert_eq!(a.col(2), &[3.0, 6.0]);
        assert_eq!(a.get(1, 1), 5.0);
    }

    #[test]
    fn t_mul_vec_correct() {
        let a = small();
        let y = [1.0, -1.0];
        assert_eq!(a.t_mul_vec(&y), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn mul_vec_correct_and_skips_zeros() {
        let a = small();
        let x = [1.0, 0.0, 2.0];
        assert_eq!(a.mul_vec(&x), vec![7.0, 16.0]);
    }

    #[test]
    fn mul_vec_support_matches_dense() {
        let a = small();
        let x = [1.0, -2.0, 2.0];
        let support = [0usize, 1, 2];
        let mut out = vec![0.0; 2];
        a.mul_vec_support_into(&x, &support, &mut out);
        assert_eq!(out, a.mul_vec(&x));
    }

    #[test]
    fn gather_and_gram() {
        let a = small();
        let g = a.gather_cols(&[0, 2]);
        assert_eq!(g.rows(), 2);
        assert_eq!(g.cols(), 2);
        assert_eq!(g.col(1), &[3.0, 6.0]);
        let gram = a.gram_of_cols(&[0, 2], 0.5);
        // col0·col0 = 17, col0·col2 = 27, col2·col2 = 45
        assert_eq!(gram.get(0, 0), 17.5);
        assert_eq!(gram.get(0, 1), 27.0);
        assert_eq!(gram.get(1, 0), 27.0);
        assert_eq!(gram.get(1, 1), 45.5);
    }

    #[test]
    fn matmul_against_hand_computed() {
        let a = small(); // 2x3
        let b = Mat::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.rows(), 2);
        assert_eq!(c.cols(), 2);
        // [[1+3, 2+3],[4+6, 5+6]]
        assert_eq!(c.get(0, 0), 4.0);
        assert_eq!(c.get(0, 1), 5.0);
        assert_eq!(c.get(1, 0), 10.0);
        assert_eq!(c.get(1, 1), 11.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn eye_matmul_identity() {
        let a = small();
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    /// Reference for `remap_square`: rebuild from scratch with the same map.
    fn remap_reference(src: &Mat, new_n: usize, old_map: &[usize]) -> Mat {
        Mat::from_fn(new_n, new_n, |i, j| {
            if old_map[i] == usize::MAX || old_map[j] == usize::MAX {
                0.0
            } else {
                src.get(old_map[i], old_map[j])
            }
        })
    }

    #[test]
    fn remap_square_matches_reference() {
        const INS: usize = usize::MAX;
        let base = Mat::from_fn(6, 6, |i, j| (i * 17 + j * 3 + 1) as f64 * 0.25);
        let cases: Vec<Vec<usize>> = vec![
            vec![0, 1, 2, 3, 4, 5],      // identity
            vec![0, 1, 2, 3],            // pure suffix truncation
            vec![0, 2, 3, 5],            // interior removals (shrink)
            vec![0, 1, INS, 2, 3, 4, 5], // interior insertion (grow)
            vec![INS, 0, 2, INS, 4, 5],  // mixed insert + remove, same size
            vec![1, INS, 3, INS, 5, INS, INS], // grow past the old size
            vec![INS, INS],              // everything replaced
            vec![],                      // collapse to empty
        ];
        for map in cases {
            let mut got = base.clone();
            got.remap_square(map.len(), &map);
            let want = remap_reference(&base, map.len(), &map);
            assert_eq!(got.rows(), want.rows());
            assert_eq!(got.cols(), want.cols());
            assert_eq!(got.as_slice(), want.as_slice(), "map {map:?}");
        }
    }

    #[test]
    fn remap_square_chains_without_reallocating_on_shrink() {
        let mut m = Mat::from_fn(8, 8, |i, j| (i + 10 * j) as f64);
        let snapshot = m.clone();
        let cap = {
            m.remap_square(5, &[0, 2, 3, 6, 7]);
            m.data.capacity()
        };
        // growing back within retained capacity must not reallocate
        m.remap_square(7, &[usize::MAX, 0, 1, 2, usize::MAX, 3, 4]);
        assert_eq!(m.data.capacity(), cap);
        let step1 = remap_reference(&snapshot, 5, &[0, 2, 3, 6, 7]);
        let want = remap_reference(&step1, 7, &[usize::MAX, 0, 1, 2, usize::MAX, 3, 4]);
        assert_eq!(m.as_slice(), want.as_slice());
    }
}
