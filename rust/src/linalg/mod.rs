//! Linear-algebra substrate (built from scratch — the offline environment
//! ships no BLAS/LAPACK bindings).
//!
//! Everything SsNAL-EN and its baselines need: a column-major [`matrix::Mat`],
//! a CSC sparse matrix with bitwise-dense-equal kernels ([`sparse::CscMat`]),
//! an out-of-core block-streamed design tier with a bounded panel cache
//! ([`ooc::OocDesign`]), and the storage-polymorphic
//! [`design::DesignRef`]/[`design::DesignStorage`]
//! views the solvers dispatch over, level-1 kernels tuned for the solver's
//! streaming access patterns ([`blas`]), [`chol::Cholesky`] for the
//! direct/Woodbury Newton strategies, matrix-free [`cg`] for the
//! large-active-set regime, small least-squares/dof solves for tuning
//! ([`lstsq`]), and the solver-wide buffer arena + active-set-aware
//! factorization cache behind the zero-allocation Newton hot path
//! ([`workspace`]).

pub mod blas;
pub mod cg;
pub mod chol;
pub mod design;
pub mod lstsq;
pub mod matrix;
pub mod ooc;
pub mod sparse;
pub mod workspace;

pub use cg::{solve_cg, solve_cg_with, CgResult};
pub use chol::{Cholesky, NotPositiveDefinite};
pub use design::{DesignRef, DesignStorage};
pub use matrix::Mat;
pub use ooc::{OocCounters, OocDesign, OocEncoding, OocHeader, OocWriter};
pub use sparse::CscMat;
pub use workspace::{
    design_fingerprint, DesignFingerprint, NewtonWorkspace, ShardScratch, WorkspaceStats,
};
