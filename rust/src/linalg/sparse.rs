//! Compressed-sparse-column storage for GWAS-scale designs.
//!
//! SNP minor-allele dosage matrices are ~95 % exact zeros, and every hot
//! kernel in the solve stack (`Aᵀy`, active-set `A_J u`, Woodbury Gram, CG
//! mat-vecs, Gap-Safe column sweeps) streams over columns — so a CSC layout
//! (`col_ptr` / `row_idx` / `values`) turns each O(m) column pass into an
//! O(nnz_j) pass without touching the solver's control flow.
//!
//! ## The bitwise contract
//!
//! Sparse kernels here are not merely "numerically close" to the dense ones in
//! [`crate::linalg::matrix`] — they reproduce them **bit for bit**, which is
//! what lets [`crate::linalg::DesignRef`] dispatch storage under the solvers
//! without changing a single fit. Two facts make this possible:
//!
//! 1. **Skipping a stored zero never changes bits.** Every accumulator in the
//!    dense kernels starts at `+0.0` and only ever adds products; under
//!    IEEE-754 round-to-nearest a sum can only become `-0.0` when *both*
//!    addends are `-0.0`, so no accumulator ever holds `-0.0`. Adding
//!    `±0.0` (the product a zero design entry contributes) to any non-`-0.0`
//!    value is an identity, hence dropping exact-zero entries is invisible.
//!    (This relies on the finite-input validation the [`crate::api`] layer
//!    performs: a NaN/∞ response would make `0.0 · y[i]` NaN.)
//! 2. **The dense reduction order is reproducible from nonzeros alone.**
//!    [`crate::linalg::blas::dot`] accumulates index `i < 8·⌊m/8⌋` into lane
//!    `i % 8`, combines the eight lanes in a fixed tree, then folds the tail
//!    sequentially. [`sparse_dot_dense`] replays exactly that: each stored
//!    nonzero feeds lane `row % 8` (rows are ascending, so per-lane order
//!    matches), the lane-combine tree is identical, and tail rows fold in
//!    ascending order. Per-element kernels (`axpy` scatters) need no
//!    emulation — element updates are independent.
//!
//! `tests` below pin `to_bits()` equality against the dense kernels across
//! lengths straddling the 8-lane boundary; `tests/linalg_parallel.rs` extends
//! the pin to whole fits at every thread budget.

use crate::linalg::blas;
use crate::linalg::matrix::Mat;

/// Sparse column-major (CSC) matrix of `f64`.
///
/// Invariants (checked in [`CscMat::new`]):
/// * `col_ptr` has length `cols + 1`, starts at 0, ends at `nnz`, and is
///   non-decreasing,
/// * `row_idx[col_ptr[j]..col_ptr[j+1]]` is strictly ascending and in
///   `0..rows` for every column `j`,
/// * `values.len() == row_idx.len()`.
///
/// Stored values may include explicit zeros (they are harmless — see the
/// module docs); [`CscMat::from_dense`] never stores them.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMat {
    rows: usize,
    cols: usize,
    /// Column start offsets into `row_idx`/`values` (length `cols + 1`).
    col_ptr: Vec<usize>,
    /// Row index of each stored entry, strictly ascending per column.
    row_idx: Vec<usize>,
    /// Stored entry values, parallel to `row_idx`.
    values: Vec<f64>,
}

impl CscMat {
    /// Build from raw CSC arrays, validating the structural invariants.
    /// Panics on invalid input — in-crate constructors have already
    /// established the invariants; untrusted data (e.g. a serving request)
    /// goes through [`CscMat::try_new`] instead.
    pub fn new(
        rows: usize,
        cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        match Self::try_new(rows, cols, col_ptr, row_idx, values) {
            Ok(m) => m,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`CscMat::new`]: validate the structural invariants and
    /// return a description of the first violation instead of panicking —
    /// the entry point for CSC arrays arriving from untrusted callers
    /// (`ssnal-en serve` request bodies).
    pub fn try_new(
        rows: usize,
        cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Result<Self, String> {
        if col_ptr.len() != cols + 1 {
            return Err(format!(
                "col_ptr must have cols + 1 entries (got {} for {cols} columns)",
                col_ptr.len()
            ));
        }
        if col_ptr[0] != 0 {
            return Err("col_ptr must start at 0".to_string());
        }
        if col_ptr[cols] != row_idx.len() {
            return Err(format!(
                "col_ptr must end at nnz ({} vs {})",
                col_ptr[cols],
                row_idx.len()
            ));
        }
        if row_idx.len() != values.len() {
            return Err(format!(
                "row_idx and values must be parallel ({} vs {})",
                row_idx.len(),
                values.len()
            ));
        }
        for j in 0..cols {
            if col_ptr[j] > col_ptr[j + 1] {
                return Err(format!("col_ptr must be non-decreasing (column {j})"));
            }
            let rs = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            for w in rs.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "row indices must be strictly ascending per column (column {j})"
                    ));
                }
            }
            if let Some(&last) = rs.last() {
                if last >= rows {
                    return Err(format!("row index {last} out of bounds for {rows} rows"));
                }
            }
        }
        Ok(Self { rows, cols, col_ptr, row_idx, values })
    }

    /// Convert a dense matrix, dropping exact zeros (`±0.0`).
    pub fn from_dense(a: &Mat) -> Self {
        let (rows, cols) = (a.rows(), a.cols());
        let mut col_ptr = Vec::with_capacity(cols + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..cols {
            for (i, &v) in a.col(j).iter().enumerate() {
                if v != 0.0 {
                    row_idx.push(i);
                    values.push(v);
                }
            }
            col_ptr.push(row_idx.len());
        }
        Self { rows, cols, col_ptr, row_idx, values }
    }

    /// Expand back to a dense matrix (tests / small fallbacks only).
    pub fn to_dense(&self) -> Mat {
        let mut a = Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (rs, vs) = self.col(j);
            let col = a.col_mut(j);
            for (&i, &v) in rs.iter().zip(vs) {
                col[i] = v;
            }
        }
        a
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    /// Fraction of entries stored (`nnz / (rows·cols)`; 0 for empty shapes).
    pub fn density(&self) -> f64 {
        let cells = self.rows * self.cols;
        if cells == 0 {
            0.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    /// The nonzero pattern of column `j`: `(row_indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        debug_assert!(j < self.cols);
        let (lo, hi) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[lo..hi], &self.values[lo..hi])
    }

    /// Stored entries in column `j`.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// The raw stored-value slice (workspace fingerprinting).
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// The raw column-offset slice, length `cols + 1` (design
    /// fingerprinting / serialization).
    #[inline]
    pub fn col_ptr(&self) -> &[usize] {
        &self.col_ptr
    }

    /// The raw row-index slice, parallel to [`CscMat::values`] (design
    /// fingerprinting / serialization).
    #[inline]
    pub fn row_idx(&self) -> &[usize] {
        &self.row_idx
    }

    /// Element access (row, col) — O(log nnz_j); tuning/tests only.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let (rs, vs) = self.col(j);
        match rs.binary_search(&i) {
            Ok(k) => vs[k],
            Err(_) => 0.0,
        }
    }

    /// `A[:,j]ᵀ y`, bitwise-identical to `blas::dot(dense_col_j, y)`.
    #[inline]
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        debug_assert_eq!(y.len(), self.rows);
        let (rs, vs) = self.col(j);
        sparse_dot_dense(rs, vs, y, self.rows)
    }

    /// `A[:,a]ᵀ A[:,b]`, bitwise-identical to the dense column dot.
    pub fn cols_dot(&self, a: usize, b: usize) -> f64 {
        let (ra, va) = self.col(a);
        let (rb, vb) = self.col(b);
        sparse_dot_sparse(ra, va, rb, vb, self.rows)
    }

    /// `‖A[:,j]‖²`, bitwise-identical to `blas::nrm2_sq(dense_col_j)`.
    #[inline]
    pub fn col_nrm2_sq(&self, j: usize) -> f64 {
        self.cols_dot(j, j)
    }

    /// `out += alpha · A[:,j]` — a per-element scatter, bitwise-identical to
    /// `blas::axpy(alpha, dense_col_j, out)` (see the module docs).
    #[inline]
    pub fn col_axpy(&self, alpha: f64, j: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows);
        let (rs, vs) = self.col(j);
        for (&i, &v) in rs.iter().zip(vs) {
            out[i] += alpha * v;
        }
    }

    /// `out = Aᵀ y` — one sparse dot per column (O(nnz) total).
    pub fn t_mul_vec_into(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for j in 0..self.cols {
            out[j] = self.col_dot(j, y);
        }
    }

    /// `Aᵀ y`, allocating.
    pub fn t_mul_vec(&self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.t_mul_vec_into(y, &mut out);
        out
    }

    /// `out = A x`, skipping exact zeros in `x` like the dense kernel.
    pub fn mul_vec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        out.iter_mut().for_each(|o| *o = 0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                self.col_axpy(xj, j, out);
            }
        }
    }

    /// `A x`, allocating.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.mul_vec_into(x, &mut out);
        out
    }

    /// `A x` restricted to a support set.
    pub fn mul_vec_support_into(&self, x: &[f64], support: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        out.iter_mut().for_each(|o| *o = 0.0);
        for &j in support {
            let xj = x[j];
            if xj != 0.0 {
                self.col_axpy(xj, j, out);
            }
        }
    }

    /// Gather columns `idx` into a new CSC matrix (contiguous copies of the
    /// per-column runs; the sparse counterpart of [`Mat::gather_cols`]).
    pub fn gather_cols(&self, idx: &[usize]) -> CscMat {
        let nnz: usize = idx.iter().map(|&j| self.col_nnz(j)).sum();
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        let mut row_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for &j in idx {
            let (rs, vs) = self.col(j);
            row_idx.extend_from_slice(rs);
            values.extend_from_slice(vs);
            col_ptr.push(row_idx.len());
        }
        CscMat { rows: self.rows, cols: idx.len(), col_ptr, row_idx, values }
    }
}

/// Sparse·dense dot replaying `blas::dot`'s exact reduction order: nonzeros
/// below the 8-lane boundary feed lane `row % 8` (ascending row order keeps
/// per-lane order identical), the lanes combine in the same fixed tree, and
/// tail rows fold sequentially.
#[inline]
pub fn sparse_dot_dense(rows: &[usize], vals: &[f64], y: &[f64], m: usize) -> f64 {
    let boundary = (m / 8) * 8;
    let split = rows.partition_point(|&r| r < boundary);
    let mut s = [0.0f64; 8];
    for k in 0..split {
        s[rows[k] % 8] += vals[k] * y[rows[k]];
    }
    let mut acc = ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    for k in split..rows.len() {
        acc += vals[k] * y[rows[k]];
    }
    acc
}

/// Sparse·sparse dot (sorted-merge over the row intersection) with the same
/// dense reduction order as [`sparse_dot_dense`].
pub fn sparse_dot_sparse(
    ra: &[usize],
    va: &[f64],
    rb: &[usize],
    vb: &[f64],
    m: usize,
) -> f64 {
    let boundary = (m / 8) * 8;
    let sa = ra.partition_point(|&r| r < boundary);
    let sb = rb.partition_point(|&r| r < boundary);
    let mut s = [0.0f64; 8];
    let (mut ia, mut ib) = (0, 0);
    while ia < sa && ib < sb {
        match ra[ia].cmp(&rb[ib]) {
            std::cmp::Ordering::Less => ia += 1,
            std::cmp::Ordering::Greater => ib += 1,
            std::cmp::Ordering::Equal => {
                s[ra[ia] % 8] += va[ia] * vb[ib];
                ia += 1;
                ib += 1;
            }
        }
    }
    let mut acc = ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    let (mut ia, mut ib) = (sa, sb);
    while ia < ra.len() && ib < rb.len() {
        match ra[ia].cmp(&rb[ib]) {
            std::cmp::Ordering::Less => ia += 1,
            std::cmp::Ordering::Greater => ib += 1,
            std::cmp::Ordering::Equal => {
                acc += va[ia] * vb[ib];
                ia += 1;
                ib += 1;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    /// Pseudo-random dense matrix with roughly `1 - sparsity` nonzero mass.
    fn random_sparse_dense(m: usize, n: usize, sparsity: f64, seed: u64) -> Mat {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Mat::from_fn(m, n, |_, _| {
            if rng.next_f64() < sparsity {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
    }

    #[test]
    fn roundtrip_and_counts() {
        let a = random_sparse_dense(13, 7, 0.8, 1);
        let s = CscMat::from_dense(&a);
        assert_eq!(s.to_dense(), a);
        assert!(s.density() <= 0.5, "density {}", s.density());
        let total: usize = (0..7).map(|j| s.col_nnz(j)).sum();
        assert_eq!(total, s.nnz());
    }

    #[test]
    fn get_matches_dense() {
        let a = random_sparse_dense(9, 5, 0.7, 2);
        let s = CscMat::from_dense(&a);
        for j in 0..5 {
            for i in 0..9 {
                assert_eq!(s.get(i, j), a.get(i, j));
            }
        }
    }

    #[test]
    fn col_dot_is_bitwise_dense_across_lane_boundary() {
        // lengths straddling multiples of the 8-lane unroll boundary
        for m in (1..=40).chain([63, 64, 65, 127, 128, 129]) {
            let a = random_sparse_dense(m, 6, 0.85, m as u64);
            let s = CscMat::from_dense(&a);
            let mut rng = Xoshiro256pp::seed_from_u64(999 + m as u64);
            let y: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
            for j in 0..6 {
                let dense = blas::dot(a.col(j), &y);
                let sparse = s.col_dot(j, &y);
                assert_eq!(dense.to_bits(), sparse.to_bits(), "m={m} j={j}");
            }
        }
    }

    #[test]
    fn cols_dot_is_bitwise_dense() {
        for m in [5usize, 8, 9, 16, 17, 33, 64, 100] {
            let a = random_sparse_dense(m, 8, 0.8, 77 + m as u64);
            let s = CscMat::from_dense(&a);
            for i in 0..8 {
                for j in 0..8 {
                    let dense = blas::dot(a.col(i), a.col(j));
                    let sparse = s.cols_dot(i, j);
                    assert_eq!(dense.to_bits(), sparse.to_bits(), "m={m} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn col_nrm2_sq_is_bitwise_dense() {
        let a = random_sparse_dense(37, 10, 0.9, 5);
        let s = CscMat::from_dense(&a);
        for j in 0..10 {
            assert_eq!(
                blas::nrm2_sq(a.col(j)).to_bits(),
                s.col_nrm2_sq(j).to_bits(),
                "j={j}"
            );
        }
    }

    #[test]
    fn axpy_and_mat_vecs_are_bitwise_dense() {
        let m = 29;
        let a = random_sparse_dense(m, 12, 0.85, 11);
        let s = CscMat::from_dense(&a);
        let mut rng = Xoshiro256pp::seed_from_u64(4242);
        let y: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
        let mut x: Vec<f64> = (0..12).map(|_| rng.next_gaussian()).collect();
        x[3] = 0.0;
        x[7] = 0.0;

        let mut dense_out = vec![0.0; m];
        let mut sparse_out = vec![0.0; m];
        blas::axpy(0.37, a.col(2), &mut dense_out);
        s.col_axpy(0.37, 2, &mut sparse_out);
        assert_eq!(dense_out, sparse_out);

        assert_eq!(a.mul_vec(&x), s.mul_vec(&x));
        assert_eq!(a.t_mul_vec(&y), s.t_mul_vec(&y));
        let support = [0usize, 3, 5, 9];
        let mut d = vec![0.0; m];
        let mut sp = vec![0.0; m];
        a.mul_vec_support_into(&x, &support, &mut d);
        s.mul_vec_support_into(&x, &support, &mut sp);
        assert_eq!(d, sp);
    }

    #[test]
    fn csc_edge_cases() {
        // empty column, all-dense column, single-nonzero rows
        let a = Mat::from_fn(10, 3, |i, j| match j {
            0 => 0.0,                       // empty column
            1 => (i as f64) + 1.0,          // fully dense column
            _ => if i == 4 { 2.5 } else { 0.0 }, // single nonzero
        });
        let s = CscMat::from_dense(&a);
        assert_eq!(s.col_nnz(0), 0);
        assert_eq!(s.col_nnz(1), 10);
        assert_eq!(s.col_nnz(2), 1);
        let y: Vec<f64> = (0..10).map(|i| (i as f64) * 0.5 - 2.0).collect();
        for j in 0..3 {
            assert_eq!(
                blas::dot(a.col(j), &y).to_bits(),
                s.col_dot(j, &y).to_bits(),
                "j={j}"
            );
        }
        assert_eq!(s.to_dense(), a);
        // gather preserves the pattern
        let g = s.gather_cols(&[2, 0]);
        assert_eq!(g.cols(), 2);
        assert_eq!(g.get(4, 0), 2.5);
        assert_eq!(g.col_nnz(1), 0);
    }

    #[test]
    fn zero_matrix_and_empty_shapes() {
        let z = CscMat::from_dense(&Mat::zeros(6, 4));
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.density(), 0.0);
        assert_eq!(z.mul_vec(&[1.0; 4]), vec![0.0; 6]);
        assert_eq!(z.t_mul_vec(&[1.0; 6]), vec![0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn unsorted_rows_rejected() {
        CscMat::new(4, 1, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_row_rejected() {
        CscMat::new(3, 1, vec![0, 1], vec![3], vec![1.0]);
    }

    #[test]
    #[should_panic(expected = "col_ptr must end at nnz")]
    fn inconsistent_col_ptr_rejected() {
        CscMat::new(3, 1, vec![0, 2], vec![1], vec![1.0]);
    }
}
