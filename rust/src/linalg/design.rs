//! Storage-polymorphic design matrices: one dispatch point for dense, CSC,
//! and out-of-core storage.
//!
//! [`DesignRef`] is a `Copy` borrowed view over a dense [`Mat`], a sparse
//! [`CscMat`], or an on-disk [`OocDesign`], exposing the unified serial
//! kernel surface every solver consumes (`Aᵀy`, `A x`, support-restricted
//! gathers, column dots/axpys, Gram blocks). [`DesignStorage`] is the owned
//! counterpart that [`crate::api::Design`] and the screening path's column
//! gathers hold.
//!
//! Dense arms delegate verbatim to the [`Mat`] reference kernels; sparse arms
//! delegate to [`CscMat`]'s dense-bit-emulating kernels (see
//! [`crate::linalg::sparse`]'s module docs for why the two storages produce
//! **bitwise-identical** results). Out-of-core arms decode the touched
//! columns to exact dense `f64` panels and run the *same* dense [`blas`]
//! kernels as the dense arms (see [`crate::linalg::ooc`]), which extends the
//! bitwise guarantee to streamed designs at any cache budget. The sharded
//! counterparts in [`crate::parallel::shard`] dispatch over `DesignRef` too,
//! with shard plans that are pure functions of the *logical* shape (rows ×
//! cols), never of the storage — so all three storages of the same matrix
//! shard identically, which is what extends the bitwise guarantee to
//! multi-thread fits.
//!
//! One deliberate asymmetry: [`DesignRef::gather_cols`] on an out-of-core
//! design materializes the gathered sub-design **in core** (dense). Gathers
//! are active-set-sized by construction, and an in-core survivor sub-design
//! is what keeps the warm-workspace machinery (rank-1 factor edits,
//! screened-chain retargeting) working unchanged on streamed cohorts.

use std::sync::Arc;

use crate::linalg::blas;
use crate::linalg::matrix::Mat;
use crate::linalg::ooc::OocDesign;
use crate::linalg::sparse::CscMat;

/// Borrowed storage-polymorphic view of a design matrix.
#[derive(Clone, Copy, Debug)]
pub enum DesignRef<'a> {
    /// Dense column-major storage.
    Dense(&'a Mat),
    /// Compressed-sparse-column storage.
    Sparse(&'a CscMat),
    /// On-disk block-streamed storage with a bounded decoded-panel cache.
    OutOfCore(&'a OocDesign),
}

impl<'a> From<&'a Mat> for DesignRef<'a> {
    fn from(a: &'a Mat) -> Self {
        DesignRef::Dense(a)
    }
}

impl<'a> From<&'a CscMat> for DesignRef<'a> {
    fn from(a: &'a CscMat) -> Self {
        DesignRef::Sparse(a)
    }
}

impl<'a> From<&'a OocDesign> for DesignRef<'a> {
    fn from(a: &'a OocDesign) -> Self {
        DesignRef::OutOfCore(a)
    }
}

impl<'a> From<&'a DesignStorage> for DesignRef<'a> {
    fn from(a: &'a DesignStorage) -> Self {
        a.as_ref()
    }
}

impl<'a> DesignRef<'a> {
    #[inline]
    pub fn rows(self) -> usize {
        match self {
            DesignRef::Dense(a) => a.rows(),
            DesignRef::Sparse(a) => a.rows(),
            DesignRef::OutOfCore(a) => a.rows(),
        }
    }

    #[inline]
    pub fn cols(self) -> usize {
        match self {
            DesignRef::Dense(a) => a.cols(),
            DesignRef::Sparse(a) => a.cols(),
            DesignRef::OutOfCore(a) => a.cols(),
        }
    }

    /// Whether the underlying storage is CSC.
    #[inline]
    pub fn is_sparse(self) -> bool {
        matches!(self, DesignRef::Sparse(_))
    }

    /// Whether the underlying storage streams from disk.
    #[inline]
    pub fn is_out_of_core(self) -> bool {
        matches!(self, DesignRef::OutOfCore(_))
    }

    /// The dense matrix behind this view, if dense-backed.
    #[inline]
    pub fn as_dense(self) -> Option<&'a Mat> {
        match self {
            DesignRef::Dense(a) => Some(a),
            DesignRef::Sparse(_) | DesignRef::OutOfCore(_) => None,
        }
    }

    /// The CSC matrix behind this view, if sparse-backed.
    #[inline]
    pub fn as_sparse(self) -> Option<&'a CscMat> {
        match self {
            DesignRef::Sparse(a) => Some(a),
            DesignRef::Dense(_) | DesignRef::OutOfCore(_) => None,
        }
    }

    /// The out-of-core handle behind this view, if disk-backed.
    #[inline]
    pub fn as_ooc(self) -> Option<&'a OocDesign> {
        match self {
            DesignRef::OutOfCore(a) => Some(a),
            DesignRef::Dense(_) | DesignRef::Sparse(_) => None,
        }
    }

    /// The raw stored-value slice (dense: column-major data; sparse: stored
    /// nonzeros; `None` for out-of-core storage, whose values live on disk).
    /// Used for workspace fingerprinting and whole-design scans.
    #[inline]
    pub fn values_slice(self) -> Option<&'a [f64]> {
        match self {
            DesignRef::Dense(a) => Some(a.as_slice()),
            DesignRef::Sparse(a) => Some(a.values()),
            DesignRef::OutOfCore(_) => None,
        }
    }

    /// Element access (row, col). O(1) dense, O(log nnz_j) sparse, one panel
    /// fetch out-of-core — tuning and tests only, never a solver hot path.
    #[inline]
    pub fn get(self, i: usize, j: usize) -> f64 {
        match self {
            DesignRef::Dense(a) => a.get(i, j),
            DesignRef::Sparse(a) => a.get(i, j),
            DesignRef::OutOfCore(a) => a.with_col(j, |c| c[i]),
        }
    }

    /// `A[:,j]ᵀ y` — bitwise-identical across storages.
    #[inline]
    pub fn col_dot(self, j: usize, y: &[f64]) -> f64 {
        match self {
            DesignRef::Dense(a) => blas::dot(a.col(j), y),
            DesignRef::Sparse(a) => a.col_dot(j, y),
            DesignRef::OutOfCore(a) => a.with_col(j, |c| blas::dot(c, y)),
        }
    }

    /// `A[:,a]ᵀ A[:,b]` — the Gram entry kernel (both the cold build and the
    /// workspace's incremental tail updates route through this, so cache hits
    /// stay bitwise-cold-equal on every storage).
    #[inline]
    pub fn cols_dot(self, a: usize, b: usize) -> f64 {
        match self {
            DesignRef::Dense(m) => blas::dot(m.col(a), m.col(b)),
            DesignRef::Sparse(m) => m.cols_dot(a, b),
            DesignRef::OutOfCore(m) => {
                // Fetch both panels up front (Arc-held, no lock while
                // dotting); a and b may live in the same panel.
                let (pa, at_a) = m.col_panel(a);
                let (pb, at_b) = m.col_panel(b);
                let rows = m.rows();
                blas::dot(&pa[at_a..at_a + rows], &pb[at_b..at_b + rows])
            }
        }
    }

    /// `‖A[:,j]‖²` — bitwise-identical across storages.
    #[inline]
    pub fn col_nrm2_sq(self, j: usize) -> f64 {
        match self {
            DesignRef::Dense(a) => blas::nrm2_sq(a.col(j)),
            DesignRef::Sparse(a) => a.col_nrm2_sq(j),
            DesignRef::OutOfCore(a) => a.with_col(j, blas::nrm2_sq),
        }
    }

    /// `out += alpha · A[:,j]` — bitwise-identical across storages.
    #[inline]
    pub fn col_axpy(self, alpha: f64, j: usize, out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => blas::axpy(alpha, a.col(j), out),
            DesignRef::Sparse(a) => a.col_axpy(alpha, j, out),
            DesignRef::OutOfCore(a) => a.with_col(j, |c| blas::axpy(alpha, c, out)),
        }
    }

    /// Iterate column `j` in ascending row order. The dense and out-of-core
    /// arms yield every entry (zeros included); the sparse arm yields stored
    /// nonzeros only — consumers that skip exact zeros (every current
    /// caller) see identical streams.
    #[inline]
    pub fn col_iter(self, j: usize) -> ColIter<'a> {
        match self {
            DesignRef::Dense(a) => ColIter::Dense(a.col(j).iter().enumerate()),
            DesignRef::Sparse(a) => {
                let (rs, vs) = a.col(j);
                ColIter::Sparse(rs.iter().zip(vs.iter()))
            }
            DesignRef::OutOfCore(a) => {
                let (panel, at) = a.col_panel(j);
                ColIter::Ooc { panel, at, rows: a.rows(), next: 0 }
            }
        }
    }

    /// `out = Aᵀ y` (serial reference; the solvers use the sharded variant).
    pub fn t_mul_vec_into(self, y: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.t_mul_vec_into(y, out),
            DesignRef::Sparse(a) => a.t_mul_vec_into(y, out),
            DesignRef::OutOfCore(a) => {
                assert_eq!(y.len(), a.rows());
                assert_eq!(out.len(), a.cols());
                for (j, slot) in out.iter_mut().enumerate() {
                    *slot = a.with_col(j, |c| blas::dot(c, y));
                }
            }
        }
    }

    /// `Aᵀ y`, allocating.
    pub fn t_mul_vec(self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols()];
        self.t_mul_vec_into(y, &mut out);
        out
    }

    /// `out = A x`, skipping exact zeros in `x`.
    pub fn mul_vec_into(self, x: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.mul_vec_into(x, out),
            DesignRef::Sparse(a) => a.mul_vec_into(x, out),
            DesignRef::OutOfCore(a) => {
                assert_eq!(x.len(), a.cols());
                assert_eq!(out.len(), a.rows());
                out.iter_mut().for_each(|o| *o = 0.0);
                for (j, &xj) in x.iter().enumerate() {
                    if xj != 0.0 {
                        a.with_col(j, |c| blas::axpy(xj, c, out));
                    }
                }
            }
        }
    }

    /// `A x`, allocating.
    pub fn mul_vec(self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows()];
        self.mul_vec_into(x, &mut out);
        out
    }

    /// `A x` restricted to a support set.
    pub fn mul_vec_support_into(self, x: &[f64], support: &[usize], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.mul_vec_support_into(x, support, out),
            DesignRef::Sparse(a) => a.mul_vec_support_into(x, support, out),
            DesignRef::OutOfCore(a) => {
                assert_eq!(out.len(), a.rows());
                out.iter_mut().for_each(|o| *o = 0.0);
                for &j in support {
                    let xj = x[j];
                    if xj != 0.0 {
                        a.with_col(j, |c| blas::axpy(xj, c, out));
                    }
                }
            }
        }
    }

    /// Gram matrix of a column subset: `G = A_JᵀA_J + ridge·I`, entry-wise
    /// bitwise-identical to [`Mat::gram_of_cols`] on any storage.
    pub fn gram_of_cols(self, idx: &[usize], ridge: f64) -> Mat {
        match self {
            DesignRef::Dense(a) => a.gram_of_cols(idx, ridge),
            DesignRef::Sparse(_) | DesignRef::OutOfCore(_) => {
                let r = idx.len();
                let mut g = Mat::zeros(r, r);
                for a in 0..r {
                    for b in a..r {
                        let v = self.cols_dot(idx[a], idx[b]);
                        g.set(a, b, v);
                        g.set(b, a, v);
                    }
                    let d = g.get(a, a) + ridge;
                    g.set(a, a, d);
                }
                g
            }
        }
    }

    /// Gather columns `idx` into an owned design. Dense and sparse sources
    /// preserve their storage kind; out-of-core sources materialize a
    /// **dense in-core** sub-design (gathers are active-set-sized, and an
    /// in-core copy keeps rank-1 workspace edits working on streamed
    /// cohorts).
    pub fn gather_cols(self, idx: &[usize]) -> DesignStorage {
        match self {
            DesignRef::Dense(a) => DesignStorage::Dense(a.gather_cols(idx)),
            DesignRef::Sparse(a) => DesignStorage::Sparse(a.gather_cols(idx)),
            DesignRef::OutOfCore(a) => {
                let m = a.rows();
                let mut out = Mat::zeros(m, idx.len());
                for (k, &j) in idx.iter().enumerate() {
                    a.with_col(j, |c| out.col_mut(k).copy_from_slice(c));
                }
                DesignStorage::Dense(out)
            }
        }
    }
}

/// Ascending-row column iterator over any storage (see
/// [`DesignRef::col_iter`]).
pub enum ColIter<'a> {
    /// Dense: every row, zeros included.
    Dense(std::iter::Enumerate<std::slice::Iter<'a, f64>>),
    /// Sparse: stored nonzeros only.
    Sparse(std::iter::Zip<std::slice::Iter<'a, usize>, std::slice::Iter<'a, f64>>),
    /// Out-of-core: every row of a decoded panel, zeros included. Owns the
    /// panel `Arc` so the column stays alive for the iterator's lifetime.
    Ooc {
        /// Decoded panel holding the column.
        panel: Arc<Vec<f64>>,
        /// Offset of the column within the panel.
        at: usize,
        /// Logical row count.
        rows: usize,
        /// Next row to yield.
        next: usize,
    },
}

impl<'a> Iterator for ColIter<'a> {
    type Item = (usize, f64);

    #[inline]
    fn next(&mut self) -> Option<(usize, f64)> {
        match self {
            ColIter::Dense(it) => it.next().map(|(i, &v)| (i, v)),
            ColIter::Sparse(it) => it.next().map(|(&i, &v)| (i, v)),
            ColIter::Ooc { panel, at, rows, next } => {
                if *next >= *rows {
                    return None;
                }
                let i = *next;
                *next += 1;
                Some((i, panel[*at + i]))
            }
        }
    }
}

/// Owned storage-polymorphic design matrix: what [`crate::api::Design`]
/// carries and what [`DesignRef::gather_cols`] produces.
#[derive(Clone, Debug)]
pub enum DesignStorage {
    /// Dense column-major storage.
    Dense(Mat),
    /// Compressed-sparse-column storage.
    Sparse(CscMat),
    /// On-disk block-streamed storage (a cheap shared handle; clones share
    /// the panel cache and streaming counters).
    OutOfCore(OocDesign),
}

impl DesignStorage {
    /// Borrow as a dispatchable view.
    #[inline]
    pub fn as_ref(&self) -> DesignRef<'_> {
        match self {
            DesignStorage::Dense(a) => DesignRef::Dense(a),
            DesignStorage::Sparse(a) => DesignRef::Sparse(a),
            DesignStorage::OutOfCore(a) => DesignRef::OutOfCore(a),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.as_ref().rows()
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.as_ref().cols()
    }

    /// Whether the storage is CSC.
    #[inline]
    pub fn is_sparse(&self) -> bool {
        matches!(self, DesignStorage::Sparse(_))
    }

    /// Whether the storage streams from disk.
    #[inline]
    pub fn is_out_of_core(&self) -> bool {
        matches!(self, DesignStorage::OutOfCore(_))
    }
}

impl From<Mat> for DesignStorage {
    fn from(a: Mat) -> Self {
        DesignStorage::Dense(a)
    }
}

impl From<CscMat> for DesignStorage {
    fn from(a: CscMat) -> Self {
        DesignStorage::Sparse(a)
    }
}

impl From<OocDesign> for DesignStorage {
    fn from(a: OocDesign) -> Self {
        DesignStorage::OutOfCore(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ooc;
    use crate::rng::Xoshiro256pp;

    fn pair(m: usize, n: usize, seed: u64) -> (Mat, CscMat) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let a = Mat::from_fn(m, n, |_, _| {
            if rng.next_f64() < 0.85 {
                0.0
            } else {
                rng.next_gaussian()
            }
        });
        let s = CscMat::from_dense(&a);
        (a, s)
    }

    fn ooc_copy(a: &Mat, tag: &str, block_cols: usize, cache_bytes: usize) -> OocDesign {
        let mut path = std::env::temp_dir();
        path.push(format!("ssnal_design_test_{tag}_{}.ooc", std::process::id()));
        ooc::write_design_f64(&path, DesignRef::from(a), block_cols).expect("write ooc");
        let d = OocDesign::open_with_cache(&path, cache_bytes).expect("open ooc");
        std::fs::remove_file(&path).ok();
        d
    }

    #[test]
    fn dispatch_matches_across_storages_bitwise() {
        let (a, s) = pair(27, 9, 3);
        let o = ooc_copy(&a, "dispatch", 4, 1 << 20);
        let (da, ds, do_) = (DesignRef::from(&a), DesignRef::from(&s), DesignRef::from(&o));
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let y: Vec<f64> = (0..27).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..9).map(|_| rng.next_gaussian()).collect();

        assert_eq!(da.t_mul_vec(&y), ds.t_mul_vec(&y));
        assert_eq!(da.t_mul_vec(&y), do_.t_mul_vec(&y));
        assert_eq!(da.mul_vec(&x), ds.mul_vec(&x));
        assert_eq!(da.mul_vec(&x), do_.mul_vec(&x));
        for j in 0..9 {
            assert_eq!(da.col_dot(j, &y).to_bits(), ds.col_dot(j, &y).to_bits());
            assert_eq!(da.col_dot(j, &y).to_bits(), do_.col_dot(j, &y).to_bits());
            assert_eq!(da.col_nrm2_sq(j).to_bits(), ds.col_nrm2_sq(j).to_bits());
            assert_eq!(da.col_nrm2_sq(j).to_bits(), do_.col_nrm2_sq(j).to_bits());
        }
        let idx = [1usize, 4, 6];
        let ga = da.gram_of_cols(&idx, 0.25);
        let gs = ds.gram_of_cols(&idx, 0.25);
        let go = do_.gram_of_cols(&idx, 0.25);
        assert_eq!(ga.as_slice(), gs.as_slice());
        assert_eq!(ga.as_slice(), go.as_slice());
    }

    #[test]
    fn ooc_dispatch_survives_eviction_pressure() {
        // A cache that holds a single 27x2 panel forces constant re-reads;
        // results must not change by a bit.
        let (a, _) = pair(27, 9, 3);
        let o = ooc_copy(&a, "evict", 2, 27 * 2 * 8);
        let (da, do_) = (DesignRef::from(&a), DesignRef::from(&o));
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let y: Vec<f64> = (0..27).map(|_| rng.next_gaussian()).collect();
        for _ in 0..3 {
            assert_eq!(da.t_mul_vec(&y), do_.t_mul_vec(&y));
            assert!(o.resident_bytes() <= o.cache_budget());
        }
        assert!(o.counters().cache_misses > o.header().blocks() as u64);
    }

    #[test]
    fn col_iter_agrees_on_nonzeros() {
        let (a, s) = pair(15, 4, 9);
        let o = ooc_copy(&a, "col_iter", 2, 1 << 20);
        for j in 0..4 {
            let dense: Vec<(usize, f64)> = DesignRef::from(&a)
                .col_iter(j)
                .filter(|(_, v)| *v != 0.0)
                .collect();
            let sparse: Vec<(usize, f64)> = DesignRef::from(&s).col_iter(j).collect();
            let ooc: Vec<(usize, f64)> = DesignRef::from(&o)
                .col_iter(j)
                .filter(|(_, v)| *v != 0.0)
                .collect();
            assert_eq!(dense, sparse, "j={j}");
            assert_eq!(dense, ooc, "j={j}");
        }
    }

    #[test]
    fn gather_preserves_storage_kind() {
        let (a, s) = pair(12, 6, 21);
        let o = ooc_copy(&a, "gather", 3, 1 << 20);
        let idx = [5usize, 0, 3];
        let ga = DesignRef::from(&a).gather_cols(&idx);
        let gs = DesignRef::from(&s).gather_cols(&idx);
        let go = DesignRef::from(&o).gather_cols(&idx);
        assert!(!ga.is_sparse());
        assert!(gs.is_sparse());
        // Out-of-core gathers materialize dense in-core sub-designs.
        assert!(!go.is_sparse() && !go.is_out_of_core());
        for (k, &j) in idx.iter().enumerate() {
            for i in 0..12 {
                assert_eq!(ga.as_ref().get(i, k), a.get(i, j));
                assert_eq!(gs.as_ref().get(i, k), a.get(i, j));
                assert_eq!(go.as_ref().get(i, k), a.get(i, j));
            }
        }
    }
}
