//! Storage-polymorphic design matrices: one dispatch point for dense and CSC.
//!
//! [`DesignRef`] is a `Copy` borrowed view over either a dense [`Mat`] or a
//! sparse [`CscMat`], exposing the unified serial kernel surface every solver
//! consumes (`Aᵀy`, `A x`, support-restricted gathers, column dots/axpys,
//! Gram blocks). [`DesignStorage`] is the owned counterpart that
//! [`crate::api::Design`] and the screening path's column gathers hold.
//!
//! Dense arms delegate verbatim to the [`Mat`] reference kernels; sparse arms
//! delegate to [`CscMat`]'s dense-bit-emulating kernels (see
//! [`crate::linalg::sparse`]'s module docs for why the two storages produce
//! **bitwise-identical** results). The sharded counterparts in
//! [`crate::parallel::shard`] dispatch over `DesignRef` too, with shard plans
//! that are pure functions of the *logical* shape (rows × cols), never of the
//! storage — so a sparse and a dense copy of the same matrix also shard
//! identically, which is what extends the bitwise guarantee to multi-thread
//! fits.

use crate::linalg::blas;
use crate::linalg::matrix::Mat;
use crate::linalg::sparse::CscMat;

/// Borrowed storage-polymorphic view of a design matrix.
#[derive(Clone, Copy, Debug)]
pub enum DesignRef<'a> {
    /// Dense column-major storage.
    Dense(&'a Mat),
    /// Compressed-sparse-column storage.
    Sparse(&'a CscMat),
}

impl<'a> From<&'a Mat> for DesignRef<'a> {
    fn from(a: &'a Mat) -> Self {
        DesignRef::Dense(a)
    }
}

impl<'a> From<&'a CscMat> for DesignRef<'a> {
    fn from(a: &'a CscMat) -> Self {
        DesignRef::Sparse(a)
    }
}

impl<'a> From<&'a DesignStorage> for DesignRef<'a> {
    fn from(a: &'a DesignStorage) -> Self {
        a.as_ref()
    }
}

impl<'a> DesignRef<'a> {
    #[inline]
    pub fn rows(self) -> usize {
        match self {
            DesignRef::Dense(a) => a.rows(),
            DesignRef::Sparse(a) => a.rows(),
        }
    }

    #[inline]
    pub fn cols(self) -> usize {
        match self {
            DesignRef::Dense(a) => a.cols(),
            DesignRef::Sparse(a) => a.cols(),
        }
    }

    /// Whether the underlying storage is CSC.
    #[inline]
    pub fn is_sparse(self) -> bool {
        matches!(self, DesignRef::Sparse(_))
    }

    /// The dense matrix behind this view, if dense-backed.
    #[inline]
    pub fn as_dense(self) -> Option<&'a Mat> {
        match self {
            DesignRef::Dense(a) => Some(a),
            DesignRef::Sparse(_) => None,
        }
    }

    /// The CSC matrix behind this view, if sparse-backed.
    #[inline]
    pub fn as_sparse(self) -> Option<&'a CscMat> {
        match self {
            DesignRef::Dense(_) => None,
            DesignRef::Sparse(a) => Some(a),
        }
    }

    /// The raw stored-value slice (dense: column-major data; sparse: stored
    /// nonzeros). Used for workspace fingerprinting.
    #[inline]
    pub fn values_slice(self) -> &'a [f64] {
        match self {
            DesignRef::Dense(a) => a.as_slice(),
            DesignRef::Sparse(a) => a.values(),
        }
    }

    /// Element access (row, col). O(1) dense, O(log nnz_j) sparse — tuning
    /// and tests only, never a solver hot path.
    #[inline]
    pub fn get(self, i: usize, j: usize) -> f64 {
        match self {
            DesignRef::Dense(a) => a.get(i, j),
            DesignRef::Sparse(a) => a.get(i, j),
        }
    }

    /// `A[:,j]ᵀ y` — bitwise-identical across storages.
    #[inline]
    pub fn col_dot(self, j: usize, y: &[f64]) -> f64 {
        match self {
            DesignRef::Dense(a) => blas::dot(a.col(j), y),
            DesignRef::Sparse(a) => a.col_dot(j, y),
        }
    }

    /// `A[:,a]ᵀ A[:,b]` — the Gram entry kernel (both the cold build and the
    /// workspace's incremental tail updates route through this, so cache hits
    /// stay bitwise-cold-equal on every storage).
    #[inline]
    pub fn cols_dot(self, a: usize, b: usize) -> f64 {
        match self {
            DesignRef::Dense(m) => blas::dot(m.col(a), m.col(b)),
            DesignRef::Sparse(m) => m.cols_dot(a, b),
        }
    }

    /// `‖A[:,j]‖²` — bitwise-identical across storages.
    #[inline]
    pub fn col_nrm2_sq(self, j: usize) -> f64 {
        match self {
            DesignRef::Dense(a) => blas::nrm2_sq(a.col(j)),
            DesignRef::Sparse(a) => a.col_nrm2_sq(j),
        }
    }

    /// `out += alpha · A[:,j]` — bitwise-identical across storages.
    #[inline]
    pub fn col_axpy(self, alpha: f64, j: usize, out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => blas::axpy(alpha, a.col(j), out),
            DesignRef::Sparse(a) => a.col_axpy(alpha, j, out),
        }
    }

    /// Iterate column `j` in ascending row order. The dense arm yields every
    /// entry (zeros included); the sparse arm yields stored nonzeros only —
    /// consumers that skip exact zeros (every current caller) see identical
    /// streams.
    #[inline]
    pub fn col_iter(self, j: usize) -> ColIter<'a> {
        match self {
            DesignRef::Dense(a) => ColIter::Dense(a.col(j).iter().enumerate()),
            DesignRef::Sparse(a) => {
                let (rs, vs) = a.col(j);
                ColIter::Sparse(rs.iter().zip(vs.iter()))
            }
        }
    }

    /// `out = Aᵀ y` (serial reference; the solvers use the sharded variant).
    pub fn t_mul_vec_into(self, y: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.t_mul_vec_into(y, out),
            DesignRef::Sparse(a) => a.t_mul_vec_into(y, out),
        }
    }

    /// `Aᵀ y`, allocating.
    pub fn t_mul_vec(self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols()];
        self.t_mul_vec_into(y, &mut out);
        out
    }

    /// `out = A x`, skipping exact zeros in `x`.
    pub fn mul_vec_into(self, x: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.mul_vec_into(x, out),
            DesignRef::Sparse(a) => a.mul_vec_into(x, out),
        }
    }

    /// `A x`, allocating.
    pub fn mul_vec(self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows()];
        self.mul_vec_into(x, &mut out);
        out
    }

    /// `A x` restricted to a support set.
    pub fn mul_vec_support_into(self, x: &[f64], support: &[usize], out: &mut [f64]) {
        match self {
            DesignRef::Dense(a) => a.mul_vec_support_into(x, support, out),
            DesignRef::Sparse(a) => a.mul_vec_support_into(x, support, out),
        }
    }

    /// Gram matrix of a column subset: `G = A_JᵀA_J + ridge·I`, entry-wise
    /// bitwise-identical to [`Mat::gram_of_cols`] on any storage.
    pub fn gram_of_cols(self, idx: &[usize], ridge: f64) -> Mat {
        match self {
            DesignRef::Dense(a) => a.gram_of_cols(idx, ridge),
            DesignRef::Sparse(_) => {
                let r = idx.len();
                let mut g = Mat::zeros(r, r);
                for a in 0..r {
                    for b in a..r {
                        let v = self.cols_dot(idx[a], idx[b]);
                        g.set(a, b, v);
                        g.set(b, a, v);
                    }
                    let d = g.get(a, a) + ridge;
                    g.set(a, a, d);
                }
                g
            }
        }
    }

    /// Gather columns `idx` into an owned design of the same storage kind.
    pub fn gather_cols(self, idx: &[usize]) -> DesignStorage {
        match self {
            DesignRef::Dense(a) => DesignStorage::Dense(a.gather_cols(idx)),
            DesignRef::Sparse(a) => DesignStorage::Sparse(a.gather_cols(idx)),
        }
    }
}

/// Ascending-row column iterator over either storage (see
/// [`DesignRef::col_iter`]).
pub enum ColIter<'a> {
    /// Dense: every row, zeros included.
    Dense(std::iter::Enumerate<std::slice::Iter<'a, f64>>),
    /// Sparse: stored nonzeros only.
    Sparse(std::iter::Zip<std::slice::Iter<'a, usize>, std::slice::Iter<'a, f64>>),
}

impl<'a> Iterator for ColIter<'a> {
    type Item = (usize, f64);

    #[inline]
    fn next(&mut self) -> Option<(usize, f64)> {
        match self {
            ColIter::Dense(it) => it.next().map(|(i, &v)| (i, v)),
            ColIter::Sparse(it) => it.next().map(|(&i, &v)| (i, v)),
        }
    }
}

/// Owned storage-polymorphic design matrix: what [`crate::api::Design`]
/// carries and what [`DesignRef::gather_cols`] produces.
#[derive(Clone, Debug)]
pub enum DesignStorage {
    /// Dense column-major storage.
    Dense(Mat),
    /// Compressed-sparse-column storage.
    Sparse(CscMat),
}

impl DesignStorage {
    /// Borrow as a dispatchable view.
    #[inline]
    pub fn as_ref(&self) -> DesignRef<'_> {
        match self {
            DesignStorage::Dense(a) => DesignRef::Dense(a),
            DesignStorage::Sparse(a) => DesignRef::Sparse(a),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.as_ref().rows()
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.as_ref().cols()
    }

    /// Whether the storage is CSC.
    #[inline]
    pub fn is_sparse(&self) -> bool {
        matches!(self, DesignStorage::Sparse(_))
    }
}

impl From<Mat> for DesignStorage {
    fn from(a: Mat) -> Self {
        DesignStorage::Dense(a)
    }
}

impl From<CscMat> for DesignStorage {
    fn from(a: CscMat) -> Self {
        DesignStorage::Sparse(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn pair(m: usize, n: usize, seed: u64) -> (Mat, CscMat) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let a = Mat::from_fn(m, n, |_, _| {
            if rng.next_f64() < 0.85 {
                0.0
            } else {
                rng.next_gaussian()
            }
        });
        let s = CscMat::from_dense(&a);
        (a, s)
    }

    #[test]
    fn dispatch_matches_across_storages_bitwise() {
        let (a, s) = pair(27, 9, 3);
        let (da, ds) = (DesignRef::from(&a), DesignRef::from(&s));
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let y: Vec<f64> = (0..27).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..9).map(|_| rng.next_gaussian()).collect();

        assert_eq!(da.t_mul_vec(&y), ds.t_mul_vec(&y));
        assert_eq!(da.mul_vec(&x), ds.mul_vec(&x));
        for j in 0..9 {
            assert_eq!(da.col_dot(j, &y).to_bits(), ds.col_dot(j, &y).to_bits());
            assert_eq!(da.col_nrm2_sq(j).to_bits(), ds.col_nrm2_sq(j).to_bits());
        }
        let idx = [1usize, 4, 6];
        let ga = da.gram_of_cols(&idx, 0.25);
        let gs = ds.gram_of_cols(&idx, 0.25);
        assert_eq!(ga.as_slice(), gs.as_slice());
    }

    #[test]
    fn col_iter_agrees_on_nonzeros() {
        let (a, s) = pair(15, 4, 9);
        for j in 0..4 {
            let dense: Vec<(usize, f64)> = DesignRef::from(&a)
                .col_iter(j)
                .filter(|(_, v)| *v != 0.0)
                .collect();
            let sparse: Vec<(usize, f64)> = DesignRef::from(&s).col_iter(j).collect();
            assert_eq!(dense, sparse, "j={j}");
        }
    }

    #[test]
    fn gather_preserves_storage_kind() {
        let (a, s) = pair(12, 6, 21);
        let idx = [5usize, 0, 3];
        let ga = DesignRef::from(&a).gather_cols(&idx);
        let gs = DesignRef::from(&s).gather_cols(&idx);
        assert!(!ga.is_sparse());
        assert!(gs.is_sparse());
        for (k, &j) in idx.iter().enumerate() {
            for i in 0..12 {
                assert_eq!(ga.as_ref().get(i, k), a.get(i, j));
                assert_eq!(gs.as_ref().get(i, k), a.get(i, j));
            }
        }
    }
}
