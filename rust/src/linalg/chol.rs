//! Dense Cholesky factorization and solves.
//!
//! Used for the two *direct* Newton-system strategies of SsNAL-EN (paper §3.2):
//!
//! * m×m factorization of `V = I_m + κ A_J A_Jᵀ` — cost O(m³),
//! * r×r factorization of `κ⁻¹I_r + A_JᵀA_J` inside the Sherman–Morrison–Woodbury
//!   identity (Eq. 19) — cost O(r³), the paper's key saving when r < m,
//!
//! and for the ridge/least-squares systems in parameter tuning.

use crate::linalg::matrix::Mat;

/// Cholesky factor `L` (lower triangular) with `M = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

/// Error for non-positive-definite inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite (pivot {})", self.pivot)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix (only the lower triangle of
    /// `m` is read). Right-looking, column-oriented to match `Mat`'s layout.
    pub fn factor(m: &Mat) -> Result<Self, NotPositiveDefinite> {
        let mut ch = Cholesky::empty();
        ch.refactor(m, 0.0, 0)?;
        Ok(ch)
    }

    /// A 0×0 placeholder for workspaces that [`Cholesky::refactor`] later
    /// fills in place (a solve on an empty factor is a no-op).
    pub fn empty() -> Self {
        Self { l: Mat::zeros(0, 0) }
    }

    /// (Re)factor `src + ridge·I` into this factor **in place**, reusing the
    /// factor of the leading `start×start` block — the workspace-facing
    /// entry point behind the active-set-aware factorization cache
    /// ([`crate::linalg::workspace`]).
    ///
    /// Only `src`'s lower triangle is read; `ridge` is added to each diagonal
    /// entry as it is consumed (bitwise-identical to factoring a matrix that
    /// already carries the ridge, since both perform the same single add).
    ///
    /// Caller contract for `start > 0`: the current factor must be a valid
    /// Cholesky factor of a matrix whose **leading `start×start` block**
    /// equals that of `src + ridge·I`. Everything outside that block may have
    /// changed: rows `start..` of the leading columns are re-derived by
    /// forward substitution against the (unchanged) leading factor, and
    /// pivots `start..` are then rebuilt — each refreshed entry is computed
    /// by exactly the expression the full factorization uses, on equal
    /// inputs, so a partial refactor reproduces the bits of a full cold
    /// factorization exactly. Any dimension change forces a full rebuild
    /// (`start` is ignored) and reallocates the factor buffer; matching
    /// dimensions reuse it.
    ///
    /// On error the factor is left invalid (columns `< pivot` refreshed,
    /// the rest stale); callers must not solve with it until a later
    /// `refactor` succeeds.
    pub fn refactor(
        &mut self,
        src: &Mat,
        ridge: f64,
        start: usize,
    ) -> Result<(), NotPositiveDefinite> {
        assert_eq!(src.rows(), src.cols(), "cholesky requires square input");
        let n = src.rows();
        let mut start = start.min(n);
        if self.l.rows() != n || self.l.cols() != n {
            self.l = Mat::zeros(n, n);
            start = 0;
        }
        let l = &mut self.l;
        // Refresh rows `start..` of the kept leading columns by forward
        // substitution: L[i,j] = (src[i,j] − Σ_{k<j} L[i,k]·L[j,k]) / L[j,j],
        // j ascending so L[i,k] (k < j) is already refreshed. This is the
        // exact expression (and inner-loop order) the full factorization
        // uses for these entries.
        for j in 0..start {
            let inv = 1.0 / l.get(j, j);
            for i in start..n {
                let mut s = src.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s * inv);
            }
        }
        self.rebuild_tail(src, ridge, start)
    }

    /// Rebuild pivots `start..` from `src + ridge·I`, assuming columns
    /// `< start` of the factor (all rows) are already current. This is the
    /// trailing half of the full factorization, shared verbatim by
    /// [`Cholesky::refactor`] and [`Cholesky::refactor_edited`] so every
    /// rebuilt entry uses the cold factorization's exact expression.
    fn rebuild_tail(
        &mut self,
        src: &Mat,
        ridge: f64,
        start: usize,
    ) -> Result<(), NotPositiveDefinite> {
        let n = src.rows();
        let l = &mut self.l;
        // refresh the rebuilt columns: lower triangle from src, upper zeroed
        for j in start..n {
            for i in 0..j {
                l.set(i, j, 0.0);
            }
            for i in j..n {
                l.set(i, j, src.get(i, j));
            }
        }
        for j in start..n {
            // d = (src[j,j] + ridge) - Σ_{k<j} L[j,k]²
            let mut d = l.get(j, j) + ridge;
            for k in 0..j {
                let ljk = l.get(j, k);
                d -= ljk * ljk;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(NotPositiveDefinite { pivot: j });
            }
            let djj = d.sqrt();
            l.set(j, j, djj);
            let inv = 1.0 / djj;
            for i in (j + 1)..n {
                let mut s = l.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s * inv);
            }
        }
        Ok(())
    }

    /// Structural rank-k up/down-date: refactor `src + ridge·I` **reusing the
    /// current factor across a row/column edit script** — columns removed
    /// and/or inserted at sorted positions, the shape of an active-set change
    /// in the Woodbury cache. `old_map[i]` names the old index of new
    /// row/column `i` (`usize::MAX` = inserted), strictly increasing over
    /// mapped entries and the identity below `start` (the first edited
    /// position).
    ///
    /// Caller contract: the current factor is a valid Cholesky factor of an
    /// old matrix such that `src[i, j] == old[old_map[i], old_map[j]]`
    /// bit-for-bit for every pair of mapped indices with `j < start` (kept
    /// entries are shifted values, not recomputed ones — the Gram cache
    /// guarantees this because entries are keyed by column identity), with
    /// the same `ridge`.
    ///
    /// Why this reproduces a cold factorization bit for bit: the leading
    /// `start×start` block of `src` is untouched, so its factor block is
    /// byte-identical. For a surviving row `i ≥ start`, the cold expression
    /// for `L[i, k]`, `k < start`, is forward substitution through the
    /// unchanged leading factor on unchanged inputs — exactly the bits the
    /// old factor already stores at `(old_map[i], k)`, so a shift suffices.
    /// Inserted rows get that same forward substitution computed fresh (the
    /// cold expression on cold inputs), and pivots `start..` rebuild through
    /// `Cholesky::rebuild_tail` — the cold trailing loop. Every entry is
    /// therefore either a bitwise-preserved cold value or a freshly computed
    /// one; none is approximated, which is what keeps the repo's
    /// warm-equals-cold contract intact (a classical hyperbolic-rotation
    /// downdate would not).
    ///
    /// A pure suffix truncation (`start == src.rows()`) costs a shift and no
    /// arithmetic. On error the factor is left invalid, exactly like
    /// [`Cholesky::refactor`]; a retry must restart from scratch.
    pub fn refactor_edited(
        &mut self,
        src: &Mat,
        ridge: f64,
        start: usize,
        old_map: &[usize],
    ) -> Result<(), NotPositiveDefinite> {
        assert_eq!(src.rows(), src.cols(), "cholesky requires square input");
        let n = src.rows();
        assert_eq!(old_map.len(), n, "old_map must have one entry per new index");
        let start = start.min(n);
        debug_assert!(
            old_map.iter().take(start).enumerate().all(|(i, &m)| m == i),
            "old_map must be the identity below start"
        );
        self.l.remap_square(n, old_map);
        // Forward-substitute the inserted rows' leading entries:
        // L[i,k] = (src[i,k] − Σ_{t<k} L[i,t]·L[k,t]) / L[k,k] — the exact
        // expression the full factorization uses for these entries. Survivor
        // rows were shifted bitwise by the remap and need no arithmetic.
        for i in start..n {
            if old_map[i] != usize::MAX {
                continue;
            }
            for k in 0..start {
                let mut s = src.get(i, k);
                for t in 0..k {
                    s -= self.l.get(i, t) * self.l.get(k, t);
                }
                let v = s * (1.0 / self.l.get(k, k));
                self.l.set(i, k, v);
            }
        }
        self.rebuild_tail(src, ridge, start)
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// Access to the lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `M x = rhs` in place via forward + backward substitution.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.dim();
        assert_eq!(x.len(), n);
        // forward: L w = rhs
        for i in 0..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.l.get(i, k) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
        // backward: Lᵀ x = w
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.l.get(k, i) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
    }

    /// Solve returning a fresh vector.
    pub fn solve(&self, rhs: &[f64]) -> Vec<f64> {
        let mut x = rhs.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// log-determinant of `M` (used by diagnostics): `2 Σ log L[i,i]`.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn spd_random(n: usize, seed: u64) -> Mat {
        // B random, M = BᵀB + n·I is SPD.
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let b = Mat::from_fn(n, n, |_, _| r.next_gaussian());
        let mut m = b.transpose().matmul(&b);
        for i in 0..n {
            m.set(i, i, m.get(i, i) + n as f64);
        }
        m
    }

    #[test]
    fn factor_solve_roundtrip() {
        for n in [1usize, 2, 5, 20] {
            let m = spd_random(n, 42 + n as u64);
            let ch = Cholesky::factor(&m).unwrap();
            let mut r = Xoshiro256pp::seed_from_u64(7);
            let rhs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            let x = ch.solve(&rhs);
            let back = m.mul_vec(&x);
            for i in 0..n {
                assert!((back[i] - rhs[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn l_times_lt_reconstructs() {
        let m = spd_random(6, 3);
        let ch = Cholesky::factor(&m).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose());
        for i in 0..6 {
            for j in 0..6 {
                assert!((rec.get(i, j) - m.get(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let m = Mat::from_row_major(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&m).is_err());
    }

    #[test]
    fn rejects_semidefinite() {
        let m = Mat::from_row_major(2, 2, &[1.0, 1.0, 1.0, 1.0]); // rank 1
        let e = Cholesky::factor(&m).unwrap_err();
        assert_eq!(e.pivot, 1);
    }

    #[test]
    fn partial_refactor_matches_full_bitwise() {
        // Change everything *outside* the leading p×p block — trailing block
        // AND the rows p.. of the leading columns, exactly what an
        // active-set tail change does to a Gram matrix. Refactoring from
        // pivot p must reproduce a full cold factorization bit for bit.
        let n = 12;
        let mut m1 = spd_random(n, 9);
        let full1 = Cholesky::factor(&m1).unwrap();
        let mut ch = Cholesky::factor(&m1).unwrap();
        assert_eq!(ch.l().as_slice(), full1.l().as_slice());

        let p = 7;
        for i in p..n {
            for j in 0..=i {
                let bump = 0.3 + ((i + j) as f64) * 0.01;
                m1.set(i, j, m1.get(i, j) + bump);
                if i != j {
                    m1.set(j, i, m1.get(j, i) + bump);
                }
            }
            m1.set(i, i, m1.get(i, i) + 10.0); // keep it SPD (Gershgorin slack)
        }
        ch.refactor(&m1, 0.0, p).unwrap();
        let full2 = Cholesky::factor(&m1).unwrap();
        assert_eq!(ch.l().as_slice(), full2.l().as_slice());

        // ridge is applied as the factor consumes the diagonal: factoring
        // (M, ridge) equals factoring M+ridge·I computed entrywise
        let mut with_ridge = Cholesky::empty();
        with_ridge.refactor(&m1, 2.5, 0).unwrap();
        let mut m_ridged = m1.clone();
        for i in 0..n {
            m_ridged.set(i, i, m_ridged.get(i, i) + 2.5);
        }
        let cold = Cholesky::factor(&m_ridged).unwrap();
        assert_eq!(with_ridge.l().as_slice(), cold.l().as_slice());

        // dimension change forces a clean full rebuild
        let m_small = spd_random(5, 4);
        ch.refactor(&m_small, 0.0, 3).unwrap();
        let full_small = Cholesky::factor(&m_small).unwrap();
        assert_eq!(ch.l().as_slice(), full_small.l().as_slice());
    }

    #[test]
    fn identity_logdet_zero() {
        let ch = Cholesky::factor(&Mat::eye(4)).unwrap();
        assert!(ch.log_det().abs() < 1e-14);
        assert_eq!(ch.solve(&[1.0, 2.0, 3.0, 4.0]), vec![1.0, 2.0, 3.0, 4.0]);
    }

    /// Build the edited matrix a Gram cache would produce: kept entries are
    /// *shifted* from the old matrix (bitwise), inserted rows/columns filled
    /// from a donor SPD matrix large enough to stay positive definite.
    fn edited_matrix(old: &Mat, donor: &Mat, old_map: &[usize]) -> Mat {
        let n = old_map.len();
        Mat::from_fn(n, n, |i, j| match (old_map[i], old_map[j]) {
            (usize::MAX, _) | (_, usize::MAX) => donor.get(i, j),
            (oi, oj) => old.get(oi, oj),
        })
    }

    #[test]
    fn refactor_edited_matches_cold_bitwise() {
        const INS: usize = usize::MAX;
        let n = 12;
        let old = spd_random(n, 21);
        // edit scripts: (old_map, first edited position)
        let cases: Vec<(Vec<usize>, usize)> = vec![
            (vec![0, 1, 2, 3, 4, 5, 6, 7], 8),                   // pure suffix truncation
            ((0..n).collect(), n),                               // no-op edit
            (vec![0, 1, 2, 4, 5, 6, 7, 8, 9, 10, 11], 3),        // interior removal
            (vec![0, 1, 2, 3, INS, 4, 5, 6, 7, 8, 9, 10, 11], 4), // interior insertion
            (vec![0, 1, INS, 3, 5, INS, 7, 8, 11], 2),           // mixed, multi-edit
            (vec![INS, 1, 2, 3], 0),                             // edit at the front
        ];
        for (map, start) in cases {
            // a donor with a heavy diagonal keeps every edited matrix SPD
            let donor = spd_random(map.len(), 77 + map.len() as u64);
            let edited = edited_matrix(&old, &donor, &map);
            let cold = Cholesky::factor(&edited).unwrap();
            let mut warm = Cholesky::factor(&old).unwrap();
            warm.refactor_edited(&edited, 0.0, start, &map).unwrap();
            assert_eq!(warm.l().as_slice(), cold.l().as_slice(), "map {map:?}");
        }
    }

    #[test]
    fn refactor_edited_applies_ridge_like_cold() {
        let n = 9;
        let old = spd_random(n, 31);
        let mut warm = Cholesky::empty();
        warm.refactor(&old, 1.5, 0).unwrap();
        let map: Vec<usize> = vec![0, 1, 2, 3, 5, 6, 8]; // drop rows 4 and 7
        let edited = edited_matrix(&old, &old, &map);
        warm.refactor_edited(&edited, 1.5, 4, &map).unwrap();
        let mut cold = Cholesky::empty();
        cold.refactor(&edited, 1.5, 0).unwrap();
        assert_eq!(warm.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn refactor_edited_reports_lost_positive_definiteness() {
        // A negative ridge the old set survives, but a near-duplicate
        // inserted column drives an eigenvalue below |ridge|: the edited
        // refactor must fail at a trailing pivot exactly like a cold
        // factorization would — never return an approximate factor.
        let n = 6;
        let old = spd_random(n, 41);
        let ridge = -0.5;
        let mut warm = Cholesky::empty();
        warm.refactor(&old, ridge, 0).unwrap();
        // insert a copy of row/column 2 right after it (the Gram of a
        // duplicated column): the edited matrix is singular, so adding the
        // negative ridge cannot stay positive definite
        let map: Vec<usize> = vec![0, 1, 2, usize::MAX, 3, 4, 5];
        let mut edited = edited_matrix(&old, &old, &map);
        for k in 0..edited.rows() {
            let v = if k == 3 { edited.get(2, 2) } else { edited.get(k, 2) };
            edited.set(k, 3, v);
            edited.set(3, k, v);
        }
        let err = warm.refactor_edited(&edited, ridge, 3, &map).unwrap_err();
        // cold with the same ridge fails at the same pivot
        let mut cold = Cholesky::empty();
        let cold_err = cold.refactor(&edited, ridge, 0).unwrap_err();
        assert_eq!(err.pivot, cold_err.pivot);
        // and the factor recovers on a sane retry from scratch
        warm.refactor(&old, 0.0, 0).unwrap();
        let fresh = Cholesky::factor(&old).unwrap();
        assert_eq!(warm.l().as_slice(), fresh.l().as_slice());
    }
}
