//! Small regularized least-squares solves.
//!
//! Used by the tuning module (paper §3.3): least-squares **de-biasing** on the
//! selected features, and the Elastic Net degrees of freedom
//! `ν = tr(A_J (A_JᵀA_J + λ2 I_r)⁻¹ A_Jᵀ)`. The active set is small
//! (r ≲ a few hundred), so normal equations + Cholesky are appropriate.

use crate::linalg::chol::{Cholesky, NotPositiveDefinite};
use crate::linalg::matrix::Mat;
use crate::linalg::DesignRef;

/// Solve `min_w ‖A_J w − b‖² + ridge·‖w‖²` via normal equations on the gathered
/// columns `idx` of `a`. With `ridge = 0` a tiny jitter is added if the Gram
/// matrix is numerically singular (collinear selected columns).
pub fn ridge_on_support<'a>(
    a: impl Into<DesignRef<'a>>,
    idx: &[usize],
    b: &[f64],
    ridge: f64,
) -> Vec<f64> {
    let a = a.into();
    assert_eq!(a.rows(), b.len());
    if idx.is_empty() {
        return Vec::new();
    }
    let mut reg = ridge;
    let rhs: Vec<f64> = idx.iter().map(|&j| a.col_dot(j, b)).collect();
    // escalate jitter until the (PSD + reg I) system factors
    for _attempt in 0..6 {
        let gram = a.gram_of_cols(idx, reg);
        match Cholesky::factor(&gram) {
            Ok(ch) => return ch.solve(&rhs),
            Err(NotPositiveDefinite { .. }) => {
                let scale = gram_diag_max(&gram).max(1.0);
                reg = if reg == 0.0 { 1e-10 * scale } else { reg * 100.0 };
            }
        }
    }
    panic!("ridge_on_support: system did not factor even with jitter");
}

fn gram_diag_max(g: &Mat) -> f64 {
    (0..g.rows()).fold(0.0f64, |m, i| m.max(g.get(i, i)))
}

/// Elastic Net degrees of freedom (Tibshirani et al. 2012, paper Eq. after 21):
/// `ν = tr(A_J (A_JᵀA_J + λ2 I_r)⁻¹ A_Jᵀ) = tr((G + λ2 I)⁻¹ G)` with `G = A_JᵀA_J`.
pub fn enet_degrees_of_freedom<'a>(a: impl Into<DesignRef<'a>>, idx: &[usize], lam2: f64) -> f64 {
    let a = a.into();
    if idx.is_empty() {
        return 0.0;
    }
    let r = idx.len();
    let g = a.gram_of_cols(idx, 0.0);
    let greg = a.gram_of_cols(idx, lam2.max(1e-12));
    let ch = match Cholesky::factor(&greg) {
        Ok(c) => c,
        Err(_) => {
            // collinear active set with λ2≈0: escalate jitter
            let jit = gram_diag_max(&g).max(1.0) * 1e-8;
            Cholesky::factor(&a.gram_of_cols(idx, lam2 + jit))
                .expect("dof gram should factor with jitter")
        }
    };
    // tr((G+λ2I)⁻¹G) = Σ_k eₖᵀ(G+λ2I)⁻¹ G eₖ — r solves of an r×r system.
    let mut trace = 0.0;
    for k in 0..r {
        let col: Vec<f64> = (0..r).map(|i| g.get(i, k)).collect();
        let s = ch.solve(&col);
        trace += s[k];
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn random_design(m: usize, n: usize, seed: u64) -> Mat {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        Mat::from_fn(m, n, |_, _| r.next_gaussian())
    }

    #[test]
    fn ols_recovers_exact_coefficients() {
        let m = 50;
        let a = random_design(m, 5, 1);
        let w_true = [2.0, -1.0, 0.5, 3.0, -0.25];
        let b = a.mul_vec(&w_true);
        let w = ridge_on_support(&a, &[0, 1, 2, 3, 4], &b, 0.0);
        for i in 0..5 {
            assert!((w[i] - w_true[i]).abs() < 1e-8, "{w:?}");
        }
    }

    #[test]
    fn ridge_shrinks_toward_zero() {
        let a = random_design(30, 3, 2);
        let b = a.mul_vec(&[1.0, 1.0, 1.0]);
        let w0 = ridge_on_support(&a, &[0, 1, 2], &b, 0.0);
        let w1 = ridge_on_support(&a, &[0, 1, 2], &b, 100.0);
        let n0: f64 = w0.iter().map(|v| v * v).sum();
        let n1: f64 = w1.iter().map(|v| v * v).sum();
        assert!(n1 < n0);
    }

    #[test]
    fn handles_duplicate_columns_with_jitter() {
        let m = 20;
        let base = random_design(m, 1, 3);
        // two identical columns → singular Gram; jitter must kick in
        let a = Mat::from_fn(m, 2, |i, _| base.get(i, 0));
        let b: Vec<f64> = (0..m).map(|i| base.get(i, 0) * 2.0).collect();
        let w = ridge_on_support(&a, &[0, 1], &b, 0.0);
        assert_eq!(w.len(), 2);
        // predictions should still be near-perfect
        let pred = a.mul_vec(&w);
        for i in 0..m {
            assert!((pred[i] - b[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_support_returns_empty() {
        let a = random_design(5, 2, 4);
        assert!(ridge_on_support(&a, &[], &[0.0; 5], 0.0).is_empty());
        assert_eq!(enet_degrees_of_freedom(&a, &[], 1.0), 0.0);
    }

    #[test]
    fn dof_limits() {
        // λ2 → 0: ν → r (OLS dof). λ2 → ∞: ν → 0.
        let a = random_design(40, 6, 5);
        let idx: Vec<usize> = (0..6).collect();
        let nu0 = enet_degrees_of_freedom(&a, &idx, 1e-10);
        assert!((nu0 - 6.0).abs() < 1e-4, "nu0={nu0}");
        let nu_inf = enet_degrees_of_freedom(&a, &idx, 1e9);
        assert!(nu_inf < 1e-3, "nu_inf={nu_inf}");
        // monotone decreasing in λ2
        let nu_a = enet_degrees_of_freedom(&a, &idx, 0.1);
        let nu_b = enet_degrees_of_freedom(&a, &idx, 10.0);
        assert!(nu_a > nu_b);
    }
}
