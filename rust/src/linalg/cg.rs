//! Conjugate gradient for symmetric positive-definite operators.
//!
//! The third Newton-system strategy of SsNAL-EN (paper §3.2): when both m and r are
//! large, `V d = −∇ψ` is solved approximately and **matrix-free** — each CG iteration
//! needs only `v ↦ v + κ A_J (A_Jᵀ v)`, two streaming passes over the active columns.

use crate::linalg::blas;

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    /// Number of iterations performed.
    pub iters: usize,
    /// Final residual norm `‖b − Mx‖₂`.
    pub residual: f64,
    /// Whether the tolerance was met before `max_iters`.
    pub converged: bool,
}

/// Solve `M x = b` for SPD operator `M` given as a mat-vec closure.
///
/// * `matvec(v, out)` must write `M v` into `out`.
/// * `x` holds the initial guess on entry and the solution on exit.
/// * Stops when `‖r‖ ≤ tol·max(1, ‖b‖)`.
///
/// Allocates the three working vectors per call; hot paths hold them in a
/// [`crate::linalg::workspace::NewtonWorkspace`] and call [`solve_cg_with`].
pub fn solve_cg(
    matvec: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iters: usize,
) -> CgResult {
    let (mut r, mut p, mut ap) = (Vec::new(), Vec::new(), Vec::new());
    solve_cg_with(matvec, b, x, tol, max_iters, &mut r, &mut p, &mut ap)
}

/// [`solve_cg`] with caller-provided working vectors `r`/`p`/`ap` (resized to
/// `b.len()` and fully overwritten — no bits of their previous contents
/// survive into the iteration). With capacities already grown, a call
/// performs zero heap allocations; the result is bitwise-identical to
/// [`solve_cg`] either way.
pub fn solve_cg_with(
    mut matvec: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iters: usize,
    r: &mut Vec<f64>,
    p: &mut Vec<f64>,
    ap: &mut Vec<f64>,
) -> CgResult {
    let n = b.len();
    assert_eq!(x.len(), n);
    r.resize(n, 0.0);
    p.resize(n, 0.0);
    ap.resize(n, 0.0);

    // r = b - M x
    matvec(x, ap);
    for i in 0..n {
        r[i] = b[i] - ap[i];
    }
    let bnorm = blas::nrm2(b).max(1.0);
    let stop = tol * bnorm;

    let mut rsold = blas::nrm2_sq(r);
    if rsold.sqrt() <= stop {
        return CgResult { iters: 0, residual: rsold.sqrt(), converged: true };
    }
    p.copy_from_slice(r);

    for it in 1..=max_iters {
        matvec(p, ap);
        let pap = blas::dot(p, ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator not SPD (numerically) — bail with what we have
            return CgResult { iters: it - 1, residual: rsold.sqrt(), converged: false };
        }
        let alpha = rsold / pap;
        blas::axpy(alpha, p, x);
        blas::axpy(-alpha, ap, r);
        let rsnew = blas::nrm2_sq(r);
        if rsnew.sqrt() <= stop {
            return CgResult { iters: it, residual: rsnew.sqrt(), converged: true };
        }
        let beta = rsnew / rsold;
        blas::xpby(r, beta, p);
        rsold = rsnew;
    }
    CgResult { iters: max_iters, residual: rsold.sqrt(), converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Mat;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn solves_identity_instantly() {
        let b = [1.0, -2.0, 3.0];
        let mut x = [0.0; 3];
        let res = solve_cg(|v, out| out.copy_from_slice(v), &b, &mut x, 1e-12, 10);
        assert!(res.converged);
        assert!(res.iters <= 2);
        for i in 0..3 {
            assert!((x[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn matches_direct_solve_on_spd() {
        let n = 30;
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let bmat = Mat::from_fn(n, n, |_, _| r.next_gaussian());
        let mut m = bmat.transpose().matmul(&bmat);
        for i in 0..n {
            m.set(i, i, m.get(i, i) + n as f64);
        }
        let rhs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mut x = vec![0.0; n];
        let res = solve_cg(|v, out| m.mul_vec_into(v, out), &rhs, &mut x, 1e-12, 500);
        assert!(res.converged, "residual {}", res.residual);
        let direct = crate::linalg::chol::Cholesky::factor(&m).unwrap().solve(&rhs);
        for i in 0..n {
            assert!((x[i] - direct[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn exact_in_n_iterations() {
        // CG converges in at most n iterations in exact arithmetic.
        let m = Mat::from_row_major(2, 2, &[4.0, 1.0, 1.0, 3.0]);
        let b = [1.0, 2.0];
        let mut x = [2.0, 1.0]; // nonzero start
        let res = solve_cg(|v, out| m.mul_vec_into(v, out), &b, &mut x, 1e-14, 3);
        assert!(res.converged);
        assert!(res.iters <= 2);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let n = 40;
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let bmat = Mat::from_fn(n, n, |_, _| r.next_gaussian());
        let mut m = bmat.transpose().matmul(&bmat);
        for i in 0..n {
            m.set(i, i, m.get(i, i) + 2.0 * n as f64);
        }
        let rhs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mut cold = vec![0.0; n];
        let rc = solve_cg(|v, out| m.mul_vec_into(v, out), &rhs, &mut cold, 1e-10, 500);
        // start from the solution: should converge in 0 iterations
        let mut warm = cold.clone();
        let rw = solve_cg(|v, out| m.mul_vec_into(v, out), &rhs, &mut warm, 1e-10, 500);
        assert!(rw.iters <= rc.iters);
        assert_eq!(rw.iters, 0);
    }

    #[test]
    fn reports_nonconvergence() {
        let m = Mat::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1e8]);
        let b = [1.0, 1.0];
        let mut x = [0.0, 0.0];
        let res = solve_cg(|v, out| m.mul_vec_into(v, out), &b, &mut x, 1e-16, 1);
        assert!(!res.converged);
        assert_eq!(res.iters, 1);
    }
}
