//! Out-of-core design storage: block-streamed column I/O with a bounded
//! panel cache.
//!
//! [`OocDesign`] is the third storage tier behind
//! [`crate::linalg::DesignRef`]: a design matrix that lives on disk in a
//! fixed binary layout (64-byte header + column-major payload) and is
//! streamed through a bounded LRU cache of *decoded column panels*. Two
//! payload encodings are supported:
//!
//! * **f64** — each column is `rows` little-endian `f64`s, byte-for-byte the
//!   column-major layout of [`Mat`];
//! * **2-bit PLINK codes** — each column is `ceil(rows/4)` bytes of PLINK
//!   1.9 genotype codes (LSB-first, sample `s` in byte `s/4` at bit
//!   `2·(s%4)`), decoded on read to `{0.0, 1.0, 2.0}` dosages (code `01` =
//!   missing maps to the header's `missing_fill`).
//!
//! # Bitwise contract
//!
//! The in-core sparse tier earns bitwise equality with dense by *emulating*
//! the dense reduction order (see [`crate::linalg::sparse`]). The out-of-core
//! tier earns it more directly: every kernel decodes the touched columns to
//! exact dense `f64` slices and then runs the *identical* dense [`blas`]
//! kernels the `Dense` arm runs. Decoding is deterministic (pure function of
//! the on-disk bytes), caching only changes *when* a panel is decoded, never
//! *what* it decodes to, and shard plans remain pure functions of the logical
//! shape — so streamed results are bitwise-identical to in-core results at
//! every `SSNAL_THREADS` budget and every cache budget, including under
//! eviction pressure.
//!
//! # Cache contract
//!
//! The panel cache is an LRU keyed by block index with a hard byte budget:
//! `resident_bytes() <= cache_budget()` at all times. A panel whose decoded
//! size alone exceeds the budget is served but never inserted (pure
//! streaming); otherwise LRU panels are evicted until the newcomer fits.
//! Hit/miss/bytes-read counters are process-wide atomics on the shared
//! handle, surfaced through `WorkspaceStats` → `StatsSnapshot` →
//! `GET /v1/stats`.
//!
//! Handles are cheap to clone (an `Arc`); clones share the cache and the
//! counters, which is what you want — they describe the same on-disk design.

use std::cell::RefCell;
use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::linalg::matrix::Mat;

/// Magic bytes opening every SSNAL out-of-core design file.
pub const OOC_MAGIC: [u8; 8] = *b"SSNALOC1";
/// Current format version.
pub const OOC_VERSION: u32 = 1;
/// Header size in bytes; the payload starts at this offset.
pub const OOC_HEADER_BYTES: u64 = 64;
/// Default decoded-panel cache budget (bytes) when none is configured.
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;
/// Default columns per cached panel when none is configured at write time.
pub const DEFAULT_BLOCK_COLS: usize = 256;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Payload encoding of an out-of-core design file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OocEncoding {
    /// Little-endian `f64` column-major payload.
    F64,
    /// 2-bit PLINK 1.9 genotype codes, decoded to `{0,1,2}` dosages.
    Plink2Bit,
}

impl OocEncoding {
    fn tag(self) -> u32 {
        match self {
            OocEncoding::F64 => 0,
            OocEncoding::Plink2Bit => 1,
        }
    }

    fn from_tag(tag: u32) -> io::Result<OocEncoding> {
        match tag {
            0 => Ok(OocEncoding::F64),
            1 => Ok(OocEncoding::Plink2Bit),
            t => Err(bad_format(format!("unknown encoding tag {t}"))),
        }
    }
}

/// Parsed 64-byte header of an out-of-core design file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OocHeader {
    /// Payload encoding.
    pub encoding: OocEncoding,
    /// Logical row count (samples).
    pub rows: usize,
    /// Logical column count (features / variants).
    pub cols: usize,
    /// Columns per cached panel (cache granularity, not a layout parameter).
    pub block_cols: usize,
    /// Dosage substituted for PLINK missing genotypes at decode time.
    pub missing_fill: f64,
    /// FNV-1a hash of the encoded payload, computed at write time; the
    /// content half of header-based fingerprints (no body re-scan needed).
    pub content_hash: u64,
}

impl OocHeader {
    /// Encoded bytes per column for this header's encoding.
    pub fn bytes_per_col(&self) -> usize {
        match self.encoding {
            OocEncoding::F64 => self.rows * 8,
            OocEncoding::Plink2Bit => self.rows.div_ceil(4),
        }
    }

    /// Number of column blocks (`ceil(cols / block_cols)`).
    pub fn blocks(&self) -> usize {
        self.cols.div_ceil(self.block_cols)
    }

    /// FNV-1a fold of every header field — the design-identity half of
    /// workspace and serve fingerprints.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        let mut fold = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(FNV_PRIME);
        };
        fold(u64::from(self.encoding.tag()));
        fold(self.rows as u64);
        fold(self.cols as u64);
        fold(self.block_cols as u64);
        fold(self.missing_fill.to_bits());
        fold(self.content_hash);
        h
    }

    fn to_bytes(self) -> [u8; OOC_HEADER_BYTES as usize] {
        let mut out = [0u8; OOC_HEADER_BYTES as usize];
        out[0..8].copy_from_slice(&OOC_MAGIC);
        out[8..12].copy_from_slice(&OOC_VERSION.to_le_bytes());
        out[12..16].copy_from_slice(&self.encoding.tag().to_le_bytes());
        out[16..24].copy_from_slice(&(self.rows as u64).to_le_bytes());
        out[24..32].copy_from_slice(&(self.cols as u64).to_le_bytes());
        out[32..40].copy_from_slice(&(self.block_cols as u64).to_le_bytes());
        out[40..48].copy_from_slice(&self.missing_fill.to_bits().to_le_bytes());
        out[48..56].copy_from_slice(&self.content_hash.to_le_bytes());
        // bytes 56..64 reserved, zero
        out
    }

    fn from_bytes(raw: &[u8; OOC_HEADER_BYTES as usize]) -> io::Result<OocHeader> {
        if raw[0..8] != OOC_MAGIC {
            return Err(bad_format("bad magic (not an SSNAL OOC design file)".into()));
        }
        let version = u32::from_le_bytes(raw[8..12].try_into().unwrap());
        if version != OOC_VERSION {
            return Err(bad_format(format!("unsupported format version {version}")));
        }
        let encoding = OocEncoding::from_tag(u32::from_le_bytes(raw[12..16].try_into().unwrap()))?;
        let rows = u64::from_le_bytes(raw[16..24].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(raw[24..32].try_into().unwrap()) as usize;
        let block_cols = u64::from_le_bytes(raw[32..40].try_into().unwrap()) as usize;
        let missing_fill = f64::from_bits(u64::from_le_bytes(raw[40..48].try_into().unwrap()));
        let content_hash = u64::from_le_bytes(raw[48..56].try_into().unwrap());
        if rows == 0 || cols == 0 {
            return Err(bad_format(format!("degenerate shape {rows}x{cols}")));
        }
        if block_cols == 0 {
            return Err(bad_format("block_cols must be positive".into()));
        }
        Ok(OocHeader { encoding, rows, cols, block_cols, missing_fill, content_hash })
    }
}

fn bad_format(reason: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("ooc design: {reason}"))
}

/// Decode one packed 2-bit PLINK column into `{0,1,2}` / `missing_fill`
/// dosages. Code mapping (PLINK 1.9 `.bed`): `00` = hom A1 → 2.0, `01` =
/// missing → `missing_fill`, `10` = het → 1.0, `11` = hom A2 → 0.0.
pub fn decode_plink_col(codes: &[u8], rows: usize, missing_fill: f64, out: &mut [f64]) {
    debug_assert!(codes.len() >= rows.div_ceil(4));
    debug_assert!(out.len() >= rows);
    for (i, slot) in out.iter_mut().enumerate().take(rows) {
        let code = (codes[i / 4] >> (2 * (i % 4))) & 0b11;
        *slot = match code {
            0b00 => 2.0,
            0b01 => missing_fill,
            0b10 => 1.0,
            _ => 0.0,
        };
    }
}

/// Pack one column of `{0,1,2}` dosages into 2-bit PLINK codes (the inverse
/// of [`decode_plink_col`] for non-missing data). Returns an error string on
/// any value outside `{0,1,2}` — the 2-bit encoding is for raw dosage
/// matrices only.
pub fn encode_plink_col(col: &[f64], out: &mut Vec<u8>) -> Result<(), String> {
    out.clear();
    out.resize(col.len().div_ceil(4), 0u8);
    for (i, &v) in col.iter().enumerate() {
        let code: u8 = if v == 2.0 {
            0b00
        } else if v == 1.0 {
            0b10
        } else if v == 0.0 {
            0b11
        } else {
            return Err(format!("value {v} at row {i} is not a {{0,1,2}} dosage"));
        };
        out[i / 4] |= code << (2 * (i % 4));
    }
    Ok(())
}

/// Streaming writer for the on-disk block format: create, push columns in
/// order, `finish()` (which stamps the header, content hash included).
pub struct OocWriter {
    file: BufWriter<File>,
    header: OocHeader,
    cols_written: usize,
    hash: u64,
    scratch: Vec<u8>,
}

impl OocWriter {
    /// Create `path` (truncating) for a `rows × cols` design.
    pub fn create(
        path: &Path,
        rows: usize,
        cols: usize,
        block_cols: usize,
        encoding: OocEncoding,
        missing_fill: f64,
    ) -> io::Result<OocWriter> {
        if rows == 0 || cols == 0 {
            return Err(bad_format(format!("degenerate shape {rows}x{cols}")));
        }
        let mut file = BufWriter::new(
            OpenOptions::new().write(true).create(true).truncate(true).open(path)?,
        );
        file.seek(SeekFrom::Start(OOC_HEADER_BYTES))?;
        Ok(OocWriter {
            file,
            header: OocHeader {
                encoding,
                rows,
                cols,
                block_cols: block_cols.max(1),
                missing_fill,
                content_hash: 0,
            },
            cols_written: 0,
            hash: FNV_OFFSET,
            scratch: Vec::new(),
        })
    }

    fn push_bytes(&mut self, raw: &[u8]) -> io::Result<()> {
        if self.cols_written >= self.header.cols {
            return Err(bad_format("more columns pushed than declared".into()));
        }
        for &b in raw {
            self.hash ^= u64::from(b);
            self.hash = self.hash.wrapping_mul(FNV_PRIME);
        }
        self.file.write_all(raw)?;
        self.cols_written += 1;
        Ok(())
    }

    /// Append one dense column (f64 encoding only).
    pub fn push_col_f64(&mut self, col: &[f64]) -> io::Result<()> {
        if self.header.encoding != OocEncoding::F64 {
            return Err(bad_format("push_col_f64 on a non-f64 file".into()));
        }
        if col.len() != self.header.rows {
            return Err(bad_format(format!(
                "column length {} != rows {}",
                col.len(),
                self.header.rows
            )));
        }
        self.scratch.clear();
        self.scratch.reserve(col.len() * 8);
        for &v in col {
            self.scratch.extend_from_slice(&v.to_le_bytes());
        }
        let raw = std::mem::take(&mut self.scratch);
        let res = self.push_bytes(&raw);
        self.scratch = raw;
        res
    }

    /// Append one packed 2-bit column (`ceil(rows/4)` bytes, PLINK codes).
    pub fn push_col_codes(&mut self, codes: &[u8]) -> io::Result<()> {
        if self.header.encoding != OocEncoding::Plink2Bit {
            return Err(bad_format("push_col_codes on a non-2bit file".into()));
        }
        if codes.len() != self.header.rows.div_ceil(4) {
            return Err(bad_format(format!(
                "packed column length {} != ceil(rows/4) = {}",
                codes.len(),
                self.header.rows.div_ceil(4)
            )));
        }
        let raw = codes.to_vec();
        self.push_bytes(&raw)
    }

    /// Flush the payload and stamp the header. Errors if fewer columns were
    /// pushed than declared.
    pub fn finish(mut self) -> io::Result<OocHeader> {
        if self.cols_written != self.header.cols {
            return Err(bad_format(format!(
                "{} columns pushed, {} declared",
                self.cols_written, self.header.cols
            )));
        }
        self.header.content_hash = self.hash;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&self.header.to_bytes())?;
        self.file.flush()?;
        Ok(self.header)
    }
}

/// Write any in-core design to `path` with the f64 encoding. Columns are
/// densified through the storage-polymorphic column iterator, so dense and
/// CSC sources produce byte-identical files for equal logical matrices.
pub fn write_design_f64(
    path: &Path,
    a: crate::linalg::DesignRef<'_>,
    block_cols: usize,
) -> io::Result<OocHeader> {
    let (m, n) = (a.rows(), a.cols());
    let mut w = OocWriter::create(path, m, n, block_cols, OocEncoding::F64, 0.0)?;
    let mut col = vec![0.0; m];
    for j in 0..n {
        col.iter_mut().for_each(|v| *v = 0.0);
        for (i, v) in a.col_iter(j) {
            col[i] = v;
        }
        w.push_col_f64(&col)?;
    }
    w.finish()
}

/// Write a `{0,1,2}`-valued in-core design (raw dosages) to `path` with the
/// 2-bit PLINK encoding. Errors on any value outside `{0,1,2}`.
pub fn write_design_plink2bit(
    path: &Path,
    a: crate::linalg::DesignRef<'_>,
    block_cols: usize,
    missing_fill: f64,
) -> io::Result<OocHeader> {
    let (m, n) = (a.rows(), a.cols());
    let mut w = OocWriter::create(path, m, n, block_cols, OocEncoding::Plink2Bit, missing_fill)?;
    let mut col = vec![0.0; m];
    let mut packed = Vec::new();
    for j in 0..n {
        col.iter_mut().for_each(|v| *v = 0.0);
        for (i, v) in a.col_iter(j) {
            col[i] = v;
        }
        encode_plink_col(&col, &mut packed)
            .map_err(|e| bad_format(format!("column {j}: {e}")))?;
        w.push_col_codes(&packed)?;
    }
    w.finish()
}

/// Point-in-time copy of the shared streaming counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OocCounters {
    /// Panel lookups served from the resident cache.
    pub cache_hits: u64,
    /// Panel lookups that went to disk (read + decode).
    pub cache_misses: u64,
    /// Encoded bytes read from the file (payload only, header excluded).
    pub bytes_read: u64,
}

struct Lru {
    /// `(block index, decoded panel)` in LRU order — front oldest, back MRU.
    panels: Vec<(usize, Arc<Vec<f64>>)>,
    resident_bytes: usize,
}

struct Inner {
    file: File,
    path: PathBuf,
    header: OocHeader,
    budget: usize,
    cache: Mutex<Lru>,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_read: AtomicU64,
}

impl std::fmt::Debug for Inner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OocDesign")
            .field("path", &self.path)
            .field("header", &self.header)
            .field("budget", &self.budget)
            .finish()
    }
}

/// Shared handle to an on-disk design: parsed header, positioned-read file
/// handle, bounded LRU panel cache, streaming counters. See the module docs
/// for the bitwise and cache contracts.
#[derive(Clone, Debug)]
pub struct OocDesign {
    inner: Arc<Inner>,
}

thread_local! {
    /// Per-thread encoded-read scratch so concurrent shard jobs never share
    /// a decode buffer (decoded panels themselves are immutable `Arc`s).
    static READ_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Positioned exact read (shared with the PLINK `.bed` reader in
/// [`crate::data::snp`]).
pub(crate) fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset)
    }
    #[cfg(not(unix))]
    {
        // Fallback for non-unix targets: a cloned handle shares the cursor,
        // so serialize through a fresh seek each call (correct, slower).
        let mut f = file.try_clone()?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

impl OocDesign {
    /// Open `path` with the default cache budget.
    pub fn open(path: &Path) -> io::Result<OocDesign> {
        OocDesign::open_with_cache(path, DEFAULT_CACHE_BYTES)
    }

    /// Open `path` with an explicit decoded-panel cache budget in bytes.
    pub fn open_with_cache(path: &Path, cache_bytes: usize) -> io::Result<OocDesign> {
        let file = File::open(path)?;
        let mut raw = [0u8; OOC_HEADER_BYTES as usize];
        read_exact_at(&file, &mut raw, 0)?;
        let header = OocHeader::from_bytes(&raw)?;
        let expect = OOC_HEADER_BYTES + (header.cols * header.bytes_per_col()) as u64;
        let actual = file.metadata()?.len();
        if actual != expect {
            return Err(bad_format(format!(
                "file length {actual} != expected {expect} for {}x{} payload",
                header.rows, header.cols
            )));
        }
        Ok(OocDesign {
            inner: Arc::new(Inner {
                file,
                path: path.to_path_buf(),
                header,
                budget: cache_bytes,
                cache: Mutex::new(Lru { panels: Vec::new(), resident_bytes: 0 }),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                bytes_read: AtomicU64::new(0),
            }),
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.inner.header.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.inner.header.cols
    }

    /// The parsed file header.
    pub fn header(&self) -> &OocHeader {
        &self.inner.header
    }

    /// Path this design was opened from.
    pub fn path(&self) -> &Path {
        &self.inner.path
    }

    /// Configured decoded-panel cache budget in bytes.
    pub fn cache_budget(&self) -> usize {
        self.inner.budget
    }

    /// Identity pointer for workspace fingerprinting: stable across clones
    /// of the same handle (they share one `Inner`).
    pub fn identity_ptr(&self) -> usize {
        Arc::as_ptr(&self.inner) as usize
    }

    /// Current copy of the shared streaming counters.
    pub fn counters(&self) -> OocCounters {
        OocCounters {
            cache_hits: self.inner.hits.load(Ordering::Relaxed),
            cache_misses: self.inner.misses.load(Ordering::Relaxed),
            bytes_read: self.inner.bytes_read.load(Ordering::Relaxed),
        }
    }

    /// Zero the shared streaming counters (bench cold/warm phases).
    pub fn reset_counters(&self) {
        self.inner.hits.store(0, Ordering::Relaxed);
        self.inner.misses.store(0, Ordering::Relaxed);
        self.inner.bytes_read.store(0, Ordering::Relaxed);
    }

    /// Bytes of decoded panels currently resident. Invariant:
    /// `resident_bytes() <= cache_budget()` at all times.
    pub fn resident_bytes(&self) -> usize {
        lock_cache(&self.inner.cache).resident_bytes
    }

    /// Drop every resident panel (bench cold phases on a shared handle).
    pub fn evict_all(&self) {
        let mut lru = lock_cache(&self.inner.cache);
        lru.panels.clear();
        lru.resident_bytes = 0;
    }

    fn lazy_panel(&self, blk: usize) -> Arc<Vec<f64>> {
        // Probe under the lock; never hold it across I/O or decode.
        {
            let mut lru = lock_cache(&self.inner.cache);
            if let Some(pos) = lru.panels.iter().position(|(b, _)| *b == blk) {
                let entry = lru.panels.remove(pos);
                let panel = Arc::clone(&entry.1);
                lru.panels.push(entry);
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                return panel;
            }
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        let panel = Arc::new(self.read_decode_block(blk));
        let panel_bytes = panel.len() * 8;
        let mut lru = lock_cache(&self.inner.cache);
        // A racing thread may have inserted the same block while we read;
        // keep theirs. A panel larger than the whole budget is served but
        // never cached, preserving the resident <= budget invariant.
        if panel_bytes <= self.inner.budget && !lru.panels.iter().any(|(b, _)| *b == blk) {
            while lru.resident_bytes + panel_bytes > self.inner.budget {
                let (_, old) = lru.panels.remove(0);
                lru.resident_bytes -= old.len() * 8;
            }
            lru.resident_bytes += panel_bytes;
            lru.panels.push((blk, Arc::clone(&panel)));
        }
        panel
    }

    fn read_decode_block(&self, blk: usize) -> Vec<f64> {
        let h = &self.inner.header;
        let start = blk * h.block_cols;
        let bcols = h.block_cols.min(h.cols - start);
        let bpc = h.bytes_per_col();
        let offset = OOC_HEADER_BYTES + (start * bpc) as u64;
        let nbytes = bcols * bpc;
        READ_SCRATCH.with(|scratch| {
            let mut buf = scratch.borrow_mut();
            buf.clear();
            buf.resize(nbytes, 0u8);
            // Reads can only fail on truncation-after-open or hardware
            // faults; lengths were validated at open, so treat failure as
            // fatal rather than threading io::Result through every kernel.
            read_exact_at(&self.inner.file, &mut buf, offset).unwrap_or_else(|e| {
                panic!("ooc design read failed at block {blk} ({}): {e}", self.inner.path.display())
            });
            self.inner.bytes_read.fetch_add(nbytes as u64, Ordering::Relaxed);
            let mut panel = vec![0.0; h.rows * bcols];
            match h.encoding {
                OocEncoding::F64 => {
                    for (dst, src) in panel.iter_mut().zip(buf.chunks_exact(8)) {
                        *dst = f64::from_le_bytes(src.try_into().unwrap());
                    }
                }
                OocEncoding::Plink2Bit => {
                    for c in 0..bcols {
                        decode_plink_col(
                            &buf[c * bpc..(c + 1) * bpc],
                            h.rows,
                            h.missing_fill,
                            &mut panel[c * h.rows..(c + 1) * h.rows],
                        );
                    }
                }
            }
            panel
        })
    }

    /// Fetch the decoded panel holding column `j` and return `(panel, offset
    /// of column j within it)`. The panel stays alive as long as the `Arc`.
    pub fn col_panel(&self, j: usize) -> (Arc<Vec<f64>>, usize) {
        debug_assert!(j < self.cols());
        let blk = j / self.inner.header.block_cols;
        let panel = self.lazy_panel(blk);
        let within = j - blk * self.inner.header.block_cols;
        (panel, within * self.inner.header.rows)
    }

    /// Run `f` over the decoded dense column `j`. All storage-polymorphic
    /// kernels route through this, then run the same dense `blas` kernels as
    /// the `Dense` arm — the bitwise contract in one place.
    #[inline]
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[f64]) -> R) -> R {
        let (panel, at) = self.col_panel(j);
        f(&panel[at..at + self.rows()])
    }

    /// Materialize the full design in core (tests and small sub-designs).
    pub fn to_dense(&self) -> Mat {
        let (m, n) = (self.rows(), self.cols());
        let mut data = vec![0.0; m * n];
        for j in 0..n {
            self.with_col(j, |c| data[j * m..(j + 1) * m].copy_from_slice(c));
        }
        Mat::from_col_major(m, n, data)
    }
}

fn lock_cache(m: &Mutex<Lru>) -> std::sync::MutexGuard<'_, Lru> {
    // The cache holds immutable decoded panels and byte accounting only; a
    // panic mid-update cannot leave torn panels, so recover from poison.
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DesignRef;
    use crate::rng::Xoshiro256pp;

    fn tmp_path(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ssnal_ooc_test_{tag}_{}.ooc", std::process::id()));
        p
    }

    fn random_mat(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Mat::from_fn(m, n, |_, _| rng.next_gaussian())
    }

    #[test]
    fn header_round_trips_through_bytes() {
        let h = OocHeader {
            encoding: OocEncoding::Plink2Bit,
            rows: 1234,
            cols: 77,
            block_cols: 16,
            missing_fill: 0.5,
            content_hash: 0xdead_beef_cafe_f00d,
        };
        let parsed = OocHeader::from_bytes(&h.to_bytes()).expect("parses");
        assert_eq!(parsed, h);
        assert_eq!(parsed.bytes_per_col(), 1234usize.div_ceil(4));
        assert_eq!(parsed.blocks(), 77usize.div_ceil(16));

        let mut bad = h.to_bytes();
        bad[0] = b'X';
        assert!(OocHeader::from_bytes(&bad).is_err());
    }

    #[test]
    fn f64_file_round_trips_bitwise() {
        let a = random_mat(23, 11, 42);
        let path = tmp_path("f64_round_trip");
        write_design_f64(&path, DesignRef::from(&a), 4).expect("write");
        let ooc = OocDesign::open(&path).expect("open");
        assert_eq!((ooc.rows(), ooc.cols()), (23, 11));
        let back = ooc.to_dense();
        assert_eq!(a.as_slice(), back.as_slice());
        for j in 0..11 {
            ooc.with_col(j, |c| assert_eq!(c, a.col(j), "j={j}"));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plink2bit_encode_decode_round_trips() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let a = Mat::from_fn(17, 9, |_, _| f64::from((rng.next_f64() * 3.0) as u32));
        let path = tmp_path("plink_round_trip");
        write_design_plink2bit(&path, DesignRef::from(&a), 3, 0.0).expect("write");
        let ooc = OocDesign::open(&path).expect("open");
        let back = ooc.to_dense();
        assert_eq!(a.as_slice(), back.as_slice());
        std::fs::remove_file(&path).ok();

        // Non-dosage values must be rejected.
        let bad = Mat::from_fn(4, 2, |_, _| 0.5);
        let path = tmp_path("plink_reject");
        assert!(write_design_plink2bit(&path, DesignRef::from(&bad), 2, 0.0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_code_decodes_to_fill() {
        // One column of 5 samples: codes [2, missing, 0, 1, 2] packed LSB
        // first. dosage(code): 00->2, 01->fill, 10->1, 11->0.
        let codes = [
            0b01_11_01_00u8, // samples 0..4: code 0, 1, 3, 1
            0b00_00_00_00u8, // sample 4: code 0
        ];
        let mut out = [0.0; 5];
        decode_plink_col(&codes, 5, -1.0, &mut out);
        assert_eq!(out, [2.0, -1.0, 0.0, -1.0, 2.0]);
    }

    #[test]
    fn cache_respects_budget_and_counts_hits() {
        let a = random_mat(16, 12, 9);
        let path = tmp_path("cache_budget");
        write_design_f64(&path, DesignRef::from(&a), 2).expect("write");
        // One panel = 16 rows x 2 cols x 8 bytes = 256 bytes; budget fits 2.
        let ooc = OocDesign::open_with_cache(&path, 512).expect("open");
        for j in 0..12 {
            ooc.with_col(j, |_| ());
        }
        assert!(ooc.resident_bytes() <= 512);
        let cold = ooc.counters();
        assert_eq!(cold.cache_misses, 6); // 6 blocks, each read once
        assert_eq!(cold.bytes_read, 6 * 256);

        // Re-sweeping re-reads evicted blocks but stays within budget,
        // and the decoded values are identical either way.
        for j in 0..12 {
            ooc.with_col(j, |c| assert_eq!(c, a.col(j)));
        }
        assert!(ooc.resident_bytes() <= 512);
        assert!(ooc.counters().cache_misses > cold.cache_misses);

        // A budget holding everything turns the second sweep into pure hits.
        let warm = OocDesign::open_with_cache(&path, 1 << 20).expect("open");
        for j in 0..12 {
            warm.with_col(j, |_| ());
        }
        let after_cold = warm.counters();
        for j in 0..12 {
            warm.with_col(j, |c| assert_eq!(c, a.col(j)));
        }
        let after_warm = warm.counters();
        assert_eq!(after_warm.cache_misses, after_cold.cache_misses);
        assert_eq!(after_warm.bytes_read, after_cold.bytes_read);
        assert_eq!(after_warm.cache_hits, after_cold.cache_hits + 12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn oversized_panel_streams_without_caching() {
        let a = random_mat(32, 6, 11);
        let path = tmp_path("oversized");
        write_design_f64(&path, DesignRef::from(&a), 3).expect("write");
        // One panel = 32 x 3 x 8 = 768 bytes > 100-byte budget.
        let ooc = OocDesign::open_with_cache(&path, 100).expect("open");
        for j in 0..6 {
            ooc.with_col(j, |c| assert_eq!(c, a.col(j)));
        }
        assert_eq!(ooc.resident_bytes(), 0);
        assert_eq!(ooc.counters().cache_hits, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_rejected_at_open() {
        let a = random_mat(8, 4, 5);
        let path = tmp_path("truncated");
        write_design_f64(&path, DesignRef::from(&a), 2).expect("write");
        let full = std::fs::read(&path).expect("read back");
        std::fs::write(&path, &full[..full.len() - 8]).expect("truncate");
        assert!(OocDesign::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn content_hash_distinguishes_payloads() {
        let a = random_mat(10, 5, 1);
        let b = random_mat(10, 5, 2);
        let (pa, pb) = (tmp_path("hash_a"), tmp_path("hash_b"));
        let ha = write_design_f64(&pa, DesignRef::from(&a), 2).expect("write a");
        let hb = write_design_f64(&pb, DesignRef::from(&b), 2).expect("write b");
        assert_ne!(ha.content_hash, hb.content_hash);
        assert_ne!(ha.fingerprint(), hb.fingerprint());
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn clones_share_cache_and_counters() {
        let a = random_mat(12, 8, 3);
        let path = tmp_path("clone_share");
        write_design_f64(&path, DesignRef::from(&a), 4).expect("write");
        let ooc = OocDesign::open(&path).expect("open");
        let other = ooc.clone();
        for j in 0..8 {
            ooc.with_col(j, |_| ());
        }
        for j in 0..8 {
            other.with_col(j, |_| ());
        }
        // Second sweep through the clone hits the shared cache.
        assert_eq!(other.counters().cache_hits, 8);
        assert_eq!(ooc.identity_ptr(), other.identity_ptr());
        std::fs::remove_file(&path).ok();
    }
}
