//! Solver-wide workspace arena: reused buffers and the active-set-aware
//! factorization cache behind the zero-allocation Newton hot path.
//!
//! Two pieces live here:
//!
//! * [`NewtonWorkspace`] — owned by one solve driver (`ssnal::solve_warm_ws`
//!   allocates one per solve; the λ-path's [`crate::path::WarmState`] carries
//!   one per warm-start chain so it also persists *across* warm-started
//!   λ-steps). It holds every buffer the Newton-system strategies need — the
//!   direct strategy's m×m build matrix, the Woodbury Gram and its `w`
//!   vector, CG's `r`/`p`/`ap`/`coeffs` — plus the factorization cache below.
//! * [`ShardScratch`] — a per-thread keyed arena of `f64` buffers
//!   (thread-local, so every long-lived thread — the caller, chain workers,
//!   and the persistent pool workers of [`crate::parallel::pool`] — reuses
//!   its own). [`crate::parallel::shard`]'s reduction kernels draw their
//!   per-shard partial buffers from the *calling* thread's arena instead of
//!   allocating `vec![0.0; m]` per shard per call.
//!
//! # Buffer lifecycle and the zero-or-overwrite rule
//!
//! Every reused buffer is either **fully overwritten** before it is read
//! (CG's `r`/`ap`, the Woodbury `w`, recomputed Gram entries) or **explicitly
//! zeroed** when the consumer folds into it (the direct strategy's m×m build
//! matrix is `fill(0.0)`-ed before `rank1_lower_accum`, and
//! [`ShardScratch::take_zeroed`] hands out zero-filled partials). No bit of a
//! previous iteration's contents can therefore leak into a later one, which
//! is what makes the warm paths bitwise-identical to cold ones. The
//! zeroed-lower-triangle precondition of
//! [`crate::parallel::shard::rank1_lower_accum`] is discharged here (the
//! workspace zeroes the build buffer) rather than by an O(m²) runtime scan in
//! the kernel.
//!
//! # Factorization cache and invalidation
//!
//! Per Newton step the dominant cost is building and factoring either
//! `V = I + κ A_J A_Jᵀ` (direct, O(m²r + m³)) or `κ⁻¹I + A_JᵀA_J` (Woodbury,
//! O(r²m + r³)). Consecutive SsN iterations — and consecutive warm-started
//! λ-steps — usually keep the active set `J` (and, within one outer AL
//! iteration, κ) unchanged, so the cache keys on `(J, κ)`:
//!
//! * **J and κ unchanged** — reuse the Cholesky outright (both strategies).
//! * **J unchanged, κ changed** (a new outer iteration bumped σ) — the
//!   Woodbury cache reuses the *raw* Gram `A_JᵀA_J` (stored without the
//!   κ-dependent ridge: zero new column dots) and refactors with the new
//!   ridge.
//! * **J changed by ≤ [`RANK1_MAX_EDITS`] single columns** (insertions and/or
//!   removals at arbitrary sorted positions — the shape of an active-set
//!   step) — the structural rank-1 up/down-date tier: a sorted edit script
//!   maps surviving rows/columns to their new positions, the Gram is
//!   remapped **in place** (kept entries are keyed by column identity, so
//!   they shift bit-for-bit; only inserted rows/columns pay fresh dots), and
//!   the factor is edited through [`Cholesky::refactor_edited`] — shifted
//!   survivor entries plus cold-expression fills, never an approximate
//!   hyperbolic-rotation downdate, so the edited factor reproduces a cold
//!   factorization's bits. A downdate that loses positive definiteness
//!   (impossible for the solver's positive ridges; reachable with
//!   pathological κ) is counted in `downdate_fallbacks` and retried as a
//!   cold full refactor, which fails only where cold would.
//! * **J changed by a longer tail** (relative to the cached set) — the
//!   Woodbury Gram updates incrementally: the leading common-prefix block is
//!   kept bit-for-bit, only rows/columns from the first changed pivot are
//!   recomputed, and the Cholesky refactors from that pivot
//!   ([`Cholesky::refactor`] re-forward-substitutes the changed rows through
//!   the kept leading columns, then rebuilds the trailing pivots — every
//!   refreshed entry uses the full factorization's exact expression on equal
//!   inputs, so the partial refactor reproduces a cold factorization
//!   exactly).
//! * **J changed wholesale** (or the prefix is short) — full sharded rebuild
//!   into the same buffers.
//!
//! The direct strategy's `V` has no exploitable prefix structure (every
//! `a_j a_jᵀ` is dense in the m×m matrix), so its cache is
//! hit-or-append-or-rebuild: a set growing by a suffix of ≤
//! [`RANK1_MAX_EDITS`] columns folds just the appended rank-1 terms into the
//! cached raw accumulation (serial single-column folds — each lands exactly
//! where the cold accumulation order puts it) and refactors; anything else
//! rebuilds.
//!
//! Screened λ-chains move a workspace *between* designs:
//! [`NewtonWorkspace::retarget_columns`] translates the cached state onto a
//! gathered survivor sub-design (gathered columns are bitwise copies, so
//! Gram entries keyed by column identity stay valid) instead of resetting —
//! dropped columns become a structural downdate, and when every cached
//! column survives the factorization itself is carried over untouched.
//!
//! Every cached quantity was produced by exactly the computation the cold
//! path runs (same kernels, same operand order), so **cache hits return the
//! cold path's bits** — the warm solve is bitwise-identical to a cold solve
//! at every `SSNAL_THREADS` budget (pinned by `tests/linalg_parallel.rs`).
//!
//! A workspace is bound to one design matrix: caches key on the column
//! *indices* of `A`, not its values. [`NewtonWorkspace`] records a
//! `(data pointer, shape, sampled-entry bits)` fingerprint and self-resets
//! when handed a different `A` — the sampled bits defend against ABA
//! allocation reuse (a same-shape design rebuilt into the just-freed block).
//! This is probabilistic hardening for driver bugs, not a versioning scheme:
//! reuse a workspace across designs only via the solve drivers (which keep
//! one per chain), and call [`NewtonWorkspace::reset`] when retargeting one
//! by hand.

use crate::linalg::chol::{Cholesky, NotPositiveDefinite};
use crate::linalg::DesignRef;
use crate::linalg::Mat;
use crate::parallel::shard;
use std::cell::RefCell;

/// Absolute tail-length up to which a Woodbury Gram update is always
/// incremental; beyond it, incremental is chosen only while its serial tail
/// recompute undercuts the sharded full rebuild's per-thread dot share (see
/// `woodbury_factor`).
const INCREMENTAL_MAX_COLS: usize = 8;

/// Largest edit-script size (insertions + removals, counted per column) the
/// structural rank-1 up/down-date tier handles; larger perturbations fall
/// through to the prefix-incremental / full-rebuild tiers.
pub const RANK1_MAX_EDITS: usize = 8;

/// Cache/reuse counters (diagnostics for tests and `bench-parallel
/// --newton-*`; never consulted by the numerics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Woodbury solves that reused Gram *and* Cholesky outright.
    pub factor_hits: usize,
    /// Woodbury solves that reused the raw Gram but refactored (κ changed).
    pub gram_hits: usize,
    /// Woodbury Gram updates that recomputed only tail rows/columns.
    pub gram_incremental: usize,
    /// Woodbury Grams rebuilt from scratch (sharded).
    pub gram_rebuilds: usize,
    /// Cholesky refactors restarted at a pivot > 0.
    pub partial_refactors: usize,
    /// Columns folded into a cached quantity by a structural rank-1 update
    /// (Woodbury edit-script insertions; direct-strategy suffix appends).
    pub rank1_updates: usize,
    /// Columns removed from a cached Gram/factor by a structural downdate
    /// (Woodbury edit-script removals, including screened-chain retargets).
    pub rank1_downdates: usize,
    /// Structural factor edits that lost positive definiteness and fell back
    /// to a cold full refactor (bits identical to cold either way).
    pub downdate_fallbacks: usize,
    /// Direct solves that reused the cached m×m factor.
    pub direct_hits: usize,
    /// Direct solves that rebuilt V and refactored.
    pub direct_rebuilds: usize,
    /// Newton solves that fell back to CG after a factorization failure.
    pub cg_fallbacks: usize,
    /// Out-of-core panel lookups served from the resident cache (zero for
    /// in-core designs; overlaid from the design's shared atomics).
    pub ooc_cache_hits: usize,
    /// Out-of-core panel lookups that went to disk (read + decode).
    pub ooc_cache_misses: usize,
    /// Encoded bytes streamed from out-of-core design files.
    pub ooc_bytes_read: usize,
}

impl WorkspaceStats {
    /// Fold another workspace's counters into `self` — used to aggregate the
    /// per-chain warm sessions of a path solve into one snapshot.
    pub fn merge(&mut self, other: &WorkspaceStats) {
        self.factor_hits += other.factor_hits;
        self.gram_hits += other.gram_hits;
        self.gram_incremental += other.gram_incremental;
        self.gram_rebuilds += other.gram_rebuilds;
        self.partial_refactors += other.partial_refactors;
        self.rank1_updates += other.rank1_updates;
        self.rank1_downdates += other.rank1_downdates;
        self.downdate_fallbacks += other.downdate_fallbacks;
        self.direct_hits += other.direct_hits;
        self.direct_rebuilds += other.direct_rebuilds;
        self.cg_fallbacks += other.cg_fallbacks;
        self.ooc_cache_hits += other.ooc_cache_hits;
        self.ooc_cache_misses += other.ooc_cache_misses;
        self.ooc_bytes_read += other.ooc_bytes_read;
    }

    /// Overlay the shared streaming counters of an out-of-core design into
    /// this snapshot (the design, not the workspace, owns those atomics; for
    /// in-core designs this is a no-op). Counters are cumulative per design
    /// handle, so sessions sharing a handle see design-level totals.
    pub fn overlay_ooc(&mut self, a: DesignRef<'_>) {
        if let Some(ooc) = a.as_ooc() {
            let c = ooc.counters();
            self.ooc_cache_hits = c.cache_hits as usize;
            self.ooc_cache_misses = c.cache_misses as usize;
            self.ooc_bytes_read = c.bytes_read as usize;
        }
    }
}

/// Per-solve buffer arena + factorization cache (see the module docs).
#[derive(Clone, Debug)]
pub struct NewtonWorkspace {
    // fingerprint of the bound design (see `rebind` / `design_fingerprint`)
    a_fp: DesignFingerprint,
    /// Enables the structural rank-1 up/down-date tier (the bench harness
    /// disables it to measure the pivot-refactor tier in isolation; the
    /// numerics are bitwise-identical either way).
    pub rank1_enabled: bool,
    // edit-script scratch: old position per new row/column (usize::MAX =
    // inserted); reused across calls so steady-state edits allocate nothing
    edit_map: Vec<usize>,
    // Woodbury: raw Gram A_JᵀA_J (no ridge) + factor of (Gram + κ⁻¹I)
    gram_active: Vec<usize>,
    gram: Mat,
    gram_valid: bool,
    gram_kappa: f64,
    gram_chol: Cholesky,
    factor_valid: bool,
    pub(crate) w: Vec<f64>,
    // Direct: m×m build buffer + factor of I + κ A_J A_Jᵀ
    direct_active: Vec<usize>,
    direct_kappa: f64,
    direct_v: Mat,
    direct_chol: Cholesky,
    direct_valid: bool,
    // CG working vectors
    pub(crate) cg_r: Vec<f64>,
    pub(crate) cg_p: Vec<f64>,
    pub(crate) cg_ap: Vec<f64>,
    pub(crate) coeffs: Vec<f64>,
    /// Cache/reuse counters.
    pub stats: WorkspaceStats,
}

impl Default for NewtonWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl NewtonWorkspace {
    /// An empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self {
            a_fp: DesignFingerprint::default(),
            rank1_enabled: true,
            edit_map: Vec::new(),
            gram_active: Vec::new(),
            gram: Mat::zeros(0, 0),
            gram_valid: false,
            gram_kappa: 0.0,
            gram_chol: Cholesky::empty(),
            factor_valid: false,
            w: Vec::new(),
            direct_active: Vec::new(),
            direct_kappa: 0.0,
            direct_v: Mat::zeros(0, 0),
            direct_chol: Cholesky::empty(),
            direct_valid: false,
            cg_r: Vec::new(),
            cg_p: Vec::new(),
            cg_ap: Vec::new(),
            coeffs: Vec::new(),
            stats: WorkspaceStats::default(),
        }
    }

    /// Invalidate every cached factorization (buffer capacity is kept).
    pub fn reset(&mut self) {
        self.gram_valid = false;
        self.factor_valid = false;
        self.direct_valid = false;
    }

    /// Self-reset when handed a different design than the cached one (see
    /// [`design_fingerprint`]): pointer + shape alone would be defeated by
    /// ABA reuse — a same-shape matrix rebuilt into the just-freed
    /// allocation — so a handful of entry bit patterns are folded in, which
    /// distinguishes any realistically rebuilt design. This remains
    /// probabilistic hardening, not a versioning scheme: a workspace is
    /// still *contractually* bound to one design (call
    /// [`NewtonWorkspace::reset`] when retargeting it by hand, or
    /// [`NewtonWorkspace::retarget_columns`] to carry warm state across a
    /// column re-indexing).
    fn rebind(&mut self, a: DesignRef<'_>) {
        let fp = design_fingerprint(a);
        if fp != self.a_fp {
            self.reset();
            self.a_fp = fp;
        }
    }

    /// Retarget this workspace onto a different design whose columns are a
    /// bitwise-identical re-indexing of the current one's — the screened
    /// λ-chain case, where consecutive points gather different survivor
    /// subsets of one full design. `translate` maps a column index of the
    /// currently bound design to its index in `new_a` (`None` = the column
    /// is absent there) and must be strictly monotone over surviving
    /// columns.
    ///
    /// Cached state survives because Gram entries are keyed by column
    /// *identity* and gathered columns are bitwise copies: surviving active
    /// columns keep their dots; when every cached column survives, the
    /// factorization itself carries over untouched (its input bits are
    /// unchanged), and dropped columns become a structural downdate (Gram
    /// remap + [`Cholesky::refactor_edited`]). The direct cache survives
    /// only when every cached column does (its m×m accumulation folds all
    /// of them). The fingerprint is rewritten **without** a reset — this is
    /// the one sanctioned way to move a warm workspace between designs, and
    /// the caller vouches for the bitwise-copy contract (true for
    /// `gather_cols` survivor subsets of one full design).
    pub fn retarget_columns(
        &mut self,
        new_a: DesignRef<'_>,
        mut translate: impl FnMut(usize) -> Option<usize>,
    ) {
        self.a_fp = design_fingerprint(new_a);
        if self.gram_valid {
            let r_old = self.gram_active.len();
            self.edit_map.clear();
            let mut kept = 0usize;
            for i in 0..r_old {
                if let Some(nj) = translate(self.gram_active[i]) {
                    self.gram_active[kept] = nj;
                    self.edit_map.push(i);
                    kept += 1;
                }
            }
            let dropped = r_old - kept;
            if dropped > 0 {
                self.gram_active.truncate(kept);
                self.gram.remap_square(kept, &self.edit_map);
                self.stats.rank1_downdates += dropped;
                let had_factor = self.factor_valid && self.gram_chol.dim() == r_old;
                self.factor_valid = false;
                if had_factor {
                    let start = self
                        .edit_map
                        .iter()
                        .enumerate()
                        .find(|&(t, &o)| o != t)
                        .map(|(t, _)| t)
                        .unwrap_or(kept);
                    let ridge = 1.0 / self.gram_kappa;
                    match self.gram_chol.refactor_edited(&self.gram, ridge, start, &self.edit_map)
                    {
                        Ok(()) => self.factor_valid = true,
                        Err(_) => {
                            self.stats.downdate_fallbacks += 1;
                            if self.gram_chol.refactor(&self.gram, ridge, 0).is_ok() {
                                self.factor_valid = true;
                            }
                        }
                    }
                }
            }
            debug_assert!(
                self.gram_active.windows(2).all(|p| p[0] < p[1]),
                "retarget translation must stay strictly ascending"
            );
        }
        if self.direct_valid {
            for v in self.direct_active.iter_mut() {
                match translate(*v) {
                    Some(nj) => *v = nj,
                    None => {
                        self.direct_valid = false;
                        break;
                    }
                }
            }
        }
    }

    /// Ensure the cached Cholesky of `κ⁻¹I_r + A_JᵀA_J` is current for
    /// `(active, kappa)`, reusing/incrementing the raw Gram per the module
    /// docs. On error the factor is invalid (the raw Gram stays usable) and
    /// the caller should fall back to CG.
    pub fn woodbury_factor<'a>(
        &mut self,
        a: impl Into<DesignRef<'a>>,
        active: &[usize],
        kappa: f64,
    ) -> Result<(), NotPositiveDefinite> {
        let a = a.into();
        self.rebind(a);
        let r = active.len();
        let ridge = 1.0 / kappa;
        let same_set = self.gram_valid && self.gram_active.as_slice() == active;
        let same_kappa = self.gram_kappa.to_bits() == kappa.to_bits();
        if same_set && self.factor_valid && same_kappa {
            self.stats.factor_hits += 1;
            return Ok(());
        }

        // Structural rank-k edit (≤ RANK1_MAX_EDITS single-column
        // insertions/removals at sorted positions): remap the Gram in place —
        // kept entries are keyed by column identity, so they shift bitwise —
        // pay column dots only for inserted rows/columns, and up/down-date
        // the factor through `Cholesky::refactor_edited`.
        if !same_set && self.gram_valid && self.rank1_enabled {
            let script =
                sorted_edit_script(&self.gram_active, active, RANK1_MAX_EDITS, &mut self.edit_map);
            if let Some(ed) = script {
                return self.woodbury_factor_edited(a, active, kappa, same_kappa, ed);
            }
        }

        // Bring the raw Gram up to date; `fresh_from` is the first row/column
        // that was recomputed this call (r = nothing recomputed).
        let fresh_from = if same_set {
            self.stats.gram_hits += 1;
            r
        } else {
            let p = if self.gram_valid { common_prefix(&self.gram_active, active) } else { 0 };
            // Incremental (serial tail recompute) vs full sharded rebuild:
            // always incremental for tiny absolute tails, else only while
            // the serial tail dots undercut the rebuild's *per-thread* share
            // — the tail runs on the calling thread alone, the rebuild fans
            // out. Either path computes every entry as the same column-pair
            // dot, so this wall-clock policy can consult the ambient thread
            // budget without affecting output bits.
            let tail_dots = (r * (r + 1) - p * (p + 1)) / 2;
            let rebuild_dots_per_thread = r * (r + 1) / 2 / shard::threads().max(1);
            let incremental =
                p > 0 && (r - p <= INCREMENTAL_MAX_COLS || tail_dots <= rebuild_dots_per_thread);
            if incremental {
                self.gram_update_tail(a, active, p);
                self.stats.gram_incremental += 1;
                p
            } else {
                shard::gram_of_cols_into(a, active, 0.0, &mut self.gram);
                self.stats.gram_rebuilds += 1;
                0
            }
        };
        if !same_set {
            self.gram_active.clear();
            self.gram_active.extend_from_slice(active);
        }
        self.gram_valid = true;

        // Refactor from the first changed pivot — 0 unless the previous
        // factor used the same ridge (κ) at the same dimension, in which case
        // its leading `fresh_from` columns are exactly what a cold
        // factorization of the updated Gram would produce.
        let start = if self.factor_valid && same_kappa && self.gram_chol.dim() == r {
            fresh_from
        } else {
            0
        };
        if start > 0 && start < r {
            self.stats.partial_refactors += 1;
        }
        self.factor_valid = false;
        self.gram_chol.refactor(&self.gram, ridge, start)?;
        self.gram_kappa = kappa;
        self.factor_valid = true;
        Ok(())
    }

    /// The structural-edit arm of [`NewtonWorkspace::woodbury_factor`]:
    /// `self.edit_map` holds the old-position-per-new-row map produced by
    /// [`sorted_edit_script`]. Counted as one incremental Gram event plus
    /// per-column `rank1_updates`/`rank1_downdates`; an edited refactor that
    /// loses positive definiteness is counted in `downdate_fallbacks` and
    /// retried as a cold full refactor, which fails only where a cold
    /// factorization of the same Gram would.
    fn woodbury_factor_edited(
        &mut self,
        a: DesignRef<'_>,
        active: &[usize],
        kappa: f64,
        same_kappa: bool,
        ed: EditScript,
    ) -> Result<(), NotPositiveDefinite> {
        let r = active.len();
        let ridge = 1.0 / kappa;
        let r_old = self.gram_active.len();
        self.gram.remap_square(r, &self.edit_map);
        // Fill the inserted rows/columns — the only entries that pay dots.
        // Same operand order as the cold build: entry (i, j) with i ≤ j is
        // ⟨A[:, J[i]], A[:, J[j]]⟩.
        for q in 0..r {
            if self.edit_map[q] != usize::MAX {
                continue;
            }
            for i in 0..r {
                let v = if i <= q {
                    a.cols_dot(active[i], active[q])
                } else {
                    a.cols_dot(active[q], active[i])
                };
                self.gram.set(i, q, v);
                self.gram.set(q, i, v);
            }
        }
        self.stats.gram_incremental += 1;
        self.stats.rank1_updates += ed.inserts;
        self.stats.rank1_downdates += ed.removes;
        self.gram_active.clear();
        self.gram_active.extend_from_slice(active);
        self.gram_valid = true;

        let can_edit_factor = self.factor_valid && same_kappa && self.gram_chol.dim() == r_old;
        self.factor_valid = false;
        if can_edit_factor {
            if ed.start > 0 && ed.start < r {
                self.stats.partial_refactors += 1;
            }
            if self
                .gram_chol
                .refactor_edited(&self.gram, ridge, ed.start, &self.edit_map)
                .is_err()
            {
                // The edit lost positive definiteness (unreachable for the
                // solver's positive ridges — removing columns keeps a PD
                // principal block PD — but reachable with pathological κ):
                // retry cold; if that also fails, the Gram itself is bad and
                // the caller degrades to CG.
                self.stats.downdate_fallbacks += 1;
                self.gram_chol.refactor(&self.gram, ridge, 0)?;
            }
        } else {
            self.gram_chol.refactor(&self.gram, ridge, 0)?;
        }
        self.gram_kappa = kappa;
        self.factor_valid = true;
        Ok(())
    }

    /// Recompute Gram rows/columns `p..` against the new active set, keeping
    /// the leading `p×p` block bit-for-bit (its column indices are unchanged).
    fn gram_update_tail(&mut self, a: DesignRef<'_>, active: &[usize], p: usize) {
        let r = active.len();
        if self.gram.rows() != r || self.gram.cols() != r {
            let mut next = Mat::zeros(r, r);
            let keep = p.min(self.gram.rows());
            for j in 0..keep {
                for i in 0..keep {
                    next.set(i, j, self.gram.get(i, j));
                }
            }
            self.gram = next;
        }
        // Same entry computation (and operand order) as the cold build:
        // entry (i, j), i ≤ j, is ⟨A[:, J[i]], A[:, J[j]]⟩.
        for j in p..r {
            for i in 0..=j {
                let v = a.cols_dot(active[i], active[j]);
                self.gram.set(i, j, v);
                self.gram.set(j, i, v);
            }
        }
    }

    /// Split borrow for the Woodbury solve: the (current) factor plus the
    /// reusable `w = A_Jᵀrhs` buffer.
    pub(crate) fn woodbury_parts(&mut self) -> (&Cholesky, &mut Vec<f64>) {
        debug_assert!(self.factor_valid, "woodbury_parts before a successful woodbury_factor");
        (&self.gram_chol, &mut self.w)
    }

    /// Ensure the cached Cholesky of `V = I + κ A_J A_Jᵀ` is current for
    /// `(active, kappa)` — hit, suffix-append rank-1 update, or rebuild.
    ///
    /// The m×m build buffer caches the **raw** κ-scaled accumulation (no
    /// `+I`; the unit ridge is applied by `refactor` as it consumes the
    /// diagonal — one single add per entry either way, so the two forms are
    /// bitwise-identical). A set that *grows by a suffix* of ≤
    /// [`RANK1_MAX_EDITS`] columns is therefore a true rank-1 update: each
    /// appended column folds into the cached accumulation as a serial
    /// single-column pass — exactly where the cold accumulation order puts
    /// its terms, so the appended buffer carries a cold build's bits (the
    /// multi-shard kernel is not used here: it requires a zeroed triangle,
    /// and a multi-column batch would reassociate the per-entry sums). Any
    /// other change rebuilds — `V` has no exploitable prefix structure
    /// (every `a_j a_jᵀ` is dense in the m×m matrix), and removals would
    /// need subtraction, which is not bitwise-reversible. On error the
    /// factor is invalid and the caller should fall back to CG.
    pub fn direct_factor<'a>(
        &mut self,
        a: impl Into<DesignRef<'a>>,
        active: &[usize],
        kappa: f64,
    ) -> Result<&Cholesky, NotPositiveDefinite> {
        let a = a.into();
        self.rebind(a);
        let m = a.rows();
        if self.direct_valid
            && self.direct_kappa.to_bits() == kappa.to_bits()
            && self.direct_chol.dim() == m
            && self.direct_active.as_slice() == active
        {
            self.stats.direct_hits += 1;
            return Ok(&self.direct_chol);
        }
        let old_len = self.direct_active.len();
        let appended = self.rank1_enabled
            && self.direct_valid
            && self.direct_kappa.to_bits() == kappa.to_bits()
            && self.direct_v.rows() == m
            && self.direct_v.cols() == m
            && active.len() > old_len
            && active.len() - old_len <= RANK1_MAX_EDITS
            && active.starts_with(&self.direct_active);
        self.direct_valid = false;
        if appended {
            let v = &mut self.direct_v;
            shard::with_threads(1, || {
                for i in old_len..active.len() {
                    shard::rank1_lower_accum(a, &active[i..=i], kappa, v);
                }
            });
            self.stats.rank1_updates += active.len() - old_len;
        } else {
            if self.direct_v.rows() != m || self.direct_v.cols() != m {
                self.direct_v = Mat::zeros(m, m);
            } else {
                // zero-or-overwrite: rank1_lower_accum folds into the buffer,
                // so the workspace discharges its zeroed-triangle
                // precondition here.
                self.direct_v.as_mut_slice().fill(0.0);
            }
            shard::rank1_lower_accum(a, active, kappa, &mut self.direct_v);
            self.stats.direct_rebuilds += 1;
        }
        self.direct_chol.refactor(&self.direct_v, 1.0, 0)?;
        self.direct_active.clear();
        self.direct_active.extend_from_slice(active);
        self.direct_kappa = kappa;
        self.direct_valid = true;
        Ok(&self.direct_chol)
    }

    /// Split borrow for the CG strategy: `(coeffs, r, p, ap)`.
    pub(crate) fn cg_parts(
        &mut self,
    ) -> (&mut Vec<f64>, &mut Vec<f64>, &mut Vec<f64>, &mut Vec<f64>) {
        (&mut self.coeffs, &mut self.cg_r, &mut self.cg_p, &mut self.cg_ap)
    }
}

/// Longest common prefix of two index lists.
fn common_prefix(a: &[usize], b: &[usize]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// A sorted single-column edit script between two ascending active sets
/// (see [`sorted_edit_script`]).
#[derive(Clone, Copy, Debug)]
struct EditScript {
    /// First new position whose mapping is not the identity (the new length
    /// when the edit is a pure suffix truncation).
    start: usize,
    /// Columns entering the set (mapped to `usize::MAX`).
    inserts: usize,
    /// Columns leaving the set.
    removes: usize,
}

/// Diff two ascending index lists into a row/column edit script: fills `map`
/// with, per new position, the old position holding the same column index
/// (`usize::MAX` for an inserted column) and counts the single-column edits.
/// Returns `None` — leaving `map` unspecified — when more than `max_edits`
/// edits would be needed.
fn sorted_edit_script(
    old: &[usize],
    new: &[usize],
    max_edits: usize,
    map: &mut Vec<usize>,
) -> Option<EditScript> {
    map.clear();
    let mut oi = 0usize;
    let mut inserts = 0usize;
    for &col in new {
        while oi < old.len() && old[oi] < col {
            oi += 1; // `old[oi]` left the set
        }
        if oi < old.len() && old[oi] == col {
            map.push(oi);
            oi += 1;
        } else {
            map.push(usize::MAX);
            inserts += 1;
        }
    }
    let survivors = new.len() - inserts;
    let removes = old.len() - survivors;
    if inserts + removes > max_edits {
        return None;
    }
    let start = map
        .iter()
        .enumerate()
        .find(|&(i, &m)| m != i)
        .map(|(i, _)| i)
        .unwrap_or(new.len());
    Some(EditScript { start, inserts, removes })
}

/// Cheap identity fingerprint of a design: data pointer + shape + the bit
/// patterns of 8 evenly spaced stored entries (FNV-style fold — column-major
/// data for dense designs, the stored-nonzero slice for CSC ones). This is
/// the probabilistic identity [`NewtonWorkspace`] binds its caches to;
/// path-level warm sessions use it to detect "not the design you warmed on".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DesignFingerprint {
    ptr: usize,
    rows: usize,
    cols: usize,
    sample: u64,
}

/// Fingerprint a design (see [`DesignFingerprint`]). Out-of-core designs
/// have no in-memory value slice; their identity is the shared handle
/// pointer (stable across clones) plus the header fingerprint, whose
/// `content_hash` covers the full encoded payload.
pub fn design_fingerprint(a: DesignRef<'_>) -> DesignFingerprint {
    if let Some(ooc) = a.as_ooc() {
        return DesignFingerprint {
            ptr: ooc.identity_ptr(),
            rows: a.rows(),
            cols: a.cols(),
            sample: ooc.header().fingerprint(),
        };
    }
    let data = a.values_slice().expect("in-core designs carry stored values");
    let sample = if data.is_empty() {
        0
    } else {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for k in 0..8usize {
            let idx = k * (data.len() - 1) / 7;
            h ^= data[idx].to_bits();
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    };
    DesignFingerprint { ptr: data.as_ptr() as usize, rows: a.rows(), cols: a.cols(), sample }
}

// ---------------------------------------------------------------------------
// Per-thread shard scratch
// ---------------------------------------------------------------------------

/// A small keyed arena of `f64` buffers, one per thread (see [`scratch_take_zeroed`]).
///
/// `take_zeroed` hands out the best-fitting retained buffer (smallest
/// sufficient capacity; the largest one when none suffices, so it grows once
/// and is then keyed for that size class), zero-filled to the requested
/// length; `give` returns a buffer to the arena. At most
/// [`ShardScratch::MAX_BUFFERS`] buffers are retained — enough for the
/// solver's nesting depth (a reduction kernel holds one flat partial buffer
/// at a time; nested chain→shard calls run on different threads and
/// therefore different arenas), while bounding per-thread residency.
#[derive(Debug, Default)]
pub struct ShardScratch {
    buffers: Vec<Vec<f64>>,
}

impl ShardScratch {
    /// Retention cap per thread.
    pub const MAX_BUFFERS: usize = 8;

    /// Fresh, empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a zero-filled buffer of exactly `len` (reusing capacity when a
    /// retained buffer fits; the zero-fill is the arena's half of the
    /// zero-or-overwrite rule).
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f64> {
        let mut best: Option<usize> = None;
        for (i, b) in self.buffers.iter().enumerate() {
            let better = match best {
                None => true,
                Some(j) => {
                    let (bc, jc) = (b.capacity(), self.buffers[j].capacity());
                    if jc >= len {
                        bc >= len && bc < jc
                    } else {
                        bc > jc
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        let mut buf = match best {
            Some(i) => self.buffers.swap_remove(i),
            None => Vec::new(),
        };
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the arena (dropped once the retention cap is hit).
    pub fn give(&mut self, buf: Vec<f64>) {
        if self.buffers.len() < Self::MAX_BUFFERS {
            self.buffers.push(buf);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<ShardScratch> = RefCell::new(ShardScratch::new());
}

/// Take a zero-filled buffer from the calling thread's [`ShardScratch`].
pub fn scratch_take_zeroed(len: usize) -> Vec<f64> {
    SCRATCH.with(|s| s.borrow_mut().take_zeroed(len))
}

/// Return a buffer to the calling thread's [`ShardScratch`].
pub fn scratch_give(buf: Vec<f64>) {
    SCRATCH.with(|s| s.borrow_mut().give(buf));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn random_case(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Mat::from_fn(m, n, |_, _| rng.next_gaussian())
    }

    fn cold_woodbury_factor(a: &Mat, active: &[usize], kappa: f64) -> Cholesky {
        let g = a.gram_of_cols(active, 1.0 / kappa);
        Cholesky::factor(&g).unwrap()
    }

    #[test]
    fn factor_hit_skips_all_work_and_matches_cold() {
        let a = random_case(30, 80, 1);
        let active: Vec<usize> = (0..20).map(|k| 4 * k).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.7).unwrap();
        let rebuilds = ws.stats.gram_rebuilds;
        ws.woodbury_factor(&a, &active, 0.7).unwrap();
        assert_eq!(ws.stats.factor_hits, 1);
        assert_eq!(ws.stats.gram_rebuilds, rebuilds, "hit must not rebuild");
        let cold = cold_woodbury_factor(&a, &active, 0.7);
        let (warm, _) = ws.woodbury_parts();
        assert_eq!(warm.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn kappa_change_reuses_gram_and_matches_cold() {
        let a = random_case(25, 60, 2);
        let active: Vec<usize> = (0..15).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.5).unwrap();
        ws.woodbury_factor(&a, &active, 2.0).unwrap();
        assert_eq!(ws.stats.gram_hits, 1, "κ change must reuse the raw Gram");
        assert_eq!(ws.stats.gram_rebuilds, 1, "only the first build pays the dots");
        let cold = cold_woodbury_factor(&a, &active, 2.0);
        let (warm, _) = ws.woodbury_parts();
        assert_eq!(warm.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn tail_change_is_incremental_and_bitwise_cold() {
        let a = random_case(40, 120, 3);
        let base: Vec<usize> = (0..30).map(|k| 2 * k).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &base, 0.9).unwrap();

        // same-size tail swap: incremental Gram update + partial refactor
        // from the first changed pivot (the Gram dimension is unchanged)
        let mut swapped = base.clone();
        swapped[28] = 95;
        swapped[29] = 97;
        ws.woodbury_factor(&a, &swapped, 0.9).unwrap();
        assert_eq!(ws.stats.gram_incremental, 1, "{:?}", ws.stats);
        assert_eq!(ws.stats.partial_refactors, 1, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&a, &swapped, 0.9);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());

        // grow by 2 tail columns, then shrink by 3 — incremental Gram
        // updates; the dimension change forces a full (but dot-free on the
        // kept block) refactor
        let mut grown = swapped.clone();
        grown.push(101);
        grown.push(103);
        ws.woodbury_factor(&a, &grown, 0.9).unwrap();
        assert_eq!(ws.stats.gram_incremental, 2, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&a, &grown, 0.9);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());

        let shrunk: Vec<usize> = grown[..grown.len() - 3].to_vec();
        ws.woodbury_factor(&a, &shrunk, 0.9).unwrap();
        assert_eq!(ws.stats.gram_incremental, 3, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&a, &shrunk, 0.9);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn structural_edit_is_rank1_and_bitwise_cold() {
        let a = random_case(40, 120, 11);
        let base: Vec<usize> = (0..30).map(|k| 3 * k).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &base, 0.9).unwrap();

        // interior edit: drop column 9 (position 3), insert column 50
        let mut edited = base.clone();
        edited.remove(3);
        let pos = edited.binary_search(&50).unwrap_err();
        edited.insert(pos, 50);
        ws.woodbury_factor(&a, &edited, 0.9).unwrap();
        assert_eq!(ws.stats.rank1_updates, 1, "{:?}", ws.stats);
        assert_eq!(ws.stats.rank1_downdates, 1, "{:?}", ws.stats);
        assert_eq!(ws.stats.gram_rebuilds, 1, "the edit must not rebuild: {:?}", ws.stats);
        assert_eq!(ws.stats.downdate_fallbacks, 0, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&a, &edited, 0.9);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());

        // with the tier disabled, the same step takes the prefix path and
        // still matches cold (the tiers differ in cost only, never in bits)
        let mut ws2 = NewtonWorkspace::new();
        ws2.rank1_enabled = false;
        ws2.woodbury_factor(&a, &base, 0.9).unwrap();
        ws2.woodbury_factor(&a, &edited, 0.9).unwrap();
        assert_eq!(ws2.stats.rank1_updates, 0, "{:?}", ws2.stats);
        assert_eq!(ws2.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn retarget_keeps_factor_when_all_columns_survive() {
        let a = random_case(30, 80, 12);
        let survivors: Vec<usize> = (0..80).filter(|j| j % 2 == 0).collect();
        let sub = a.gather_cols(&survivors);
        let active: Vec<usize> = vec![4, 10, 16, 22, 40];
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.7).unwrap();
        ws.retarget_columns((&sub).into(), |j| survivors.binary_search(&j).ok());
        let sub_active: Vec<usize> =
            active.iter().map(|j| survivors.binary_search(j).unwrap()).collect();
        ws.woodbury_factor(&sub, &sub_active, 0.7).unwrap();
        assert_eq!(ws.stats.factor_hits, 1, "retarget must carry the factor: {:?}", ws.stats);
        assert_eq!(ws.stats.rank1_downdates, 0, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&sub, &sub_active, 0.7);
        let (warm, _) = ws.woodbury_parts();
        assert_eq!(warm.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn retarget_downdates_dropped_columns_bitwise() {
        let a = random_case(30, 80, 13);
        let active: Vec<usize> = vec![4, 10, 16, 22, 40, 55];
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.7).unwrap();
        // the screened sub-design loses active columns 16 and 55
        let survivors: Vec<usize> = (0..80).filter(|&j| j != 16 && j != 55).collect();
        let sub = a.gather_cols(&survivors);
        ws.retarget_columns((&sub).into(), |j| survivors.binary_search(&j).ok());
        assert_eq!(ws.stats.rank1_downdates, 2, "{:?}", ws.stats);
        let sub_active: Vec<usize> =
            [4usize, 10, 22, 40].iter().map(|j| survivors.binary_search(j).unwrap()).collect();
        ws.woodbury_factor(&sub, &sub_active, 0.7).unwrap();
        assert_eq!(ws.stats.factor_hits, 1, "the downdated factor must hit: {:?}", ws.stats);
        let cold = cold_woodbury_factor(&sub, &sub_active, 0.7);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn direct_suffix_append_is_rank1_and_bitwise_cold() {
        let a = random_case(20, 50, 14);
        let base: Vec<usize> = (0..30).collect();
        let mut ws = NewtonWorkspace::new();
        ws.direct_factor(&a, &base, 1.3).unwrap();
        let mut grown = base.clone();
        grown.extend_from_slice(&[31, 34, 37]);
        ws.direct_factor(&a, &grown, 1.3).unwrap();
        assert_eq!(ws.stats.rank1_updates, 3, "{:?}", ws.stats);
        assert_eq!(ws.stats.direct_rebuilds, 1, "append must not rebuild: {:?}", ws.stats);

        let m = a.rows();
        let mut v = Mat::zeros(m, m);
        shard::rank1_lower_accum(&a, &grown, 1.3, &mut v);
        for i in 0..m {
            v.set(i, i, v.get(i, i) + 1.0);
        }
        let cold = Cholesky::factor(&v).unwrap();
        for j in 0..m {
            for i in j..m {
                assert_eq!(
                    ws.direct_chol.l().get(i, j).to_bits(),
                    cold.l().get(i, j).to_bits(),
                    "L[{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn sorted_edit_script_maps_and_counts() {
        let mut map = Vec::new();
        // {0,2,4,6} → {0,3,4,6,9}: remove 2, insert 3 and 9
        let ed = sorted_edit_script(&[0, 2, 4, 6], &[0, 3, 4, 6, 9], 8, &mut map).unwrap();
        assert_eq!(map, vec![0, usize::MAX, 2, 3, usize::MAX]);
        assert_eq!((ed.start, ed.inserts, ed.removes), (1, 2, 1));
        // pure suffix truncation maps to the identity with start = new length
        let ed = sorted_edit_script(&[0, 2, 4, 6], &[0, 2], 8, &mut map).unwrap();
        assert_eq!(map, vec![0, 1]);
        assert_eq!((ed.start, ed.inserts, ed.removes), (2, 0, 2));
        // over budget → None
        assert!(sorted_edit_script(&[0, 1, 2, 3, 4], &[10, 11, 12], 7, &mut map).is_none());
    }

    #[test]
    fn wholesale_change_rebuilds_and_matches_cold() {
        let a = random_case(30, 100, 4);
        let first: Vec<usize> = (0..20).collect();
        let second: Vec<usize> = (40..60).collect(); // empty common prefix
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &first, 0.6).unwrap();
        ws.woodbury_factor(&a, &second, 0.6).unwrap();
        assert_eq!(ws.stats.gram_rebuilds, 2);
        assert_eq!(ws.stats.gram_incremental, 0);
        let cold = cold_woodbury_factor(&a, &second, 0.6);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn direct_cache_hits_and_matches_cold() {
        let a = random_case(20, 50, 5);
        let active: Vec<usize> = (0..35).collect(); // r > m
        let mut ws = NewtonWorkspace::new();
        ws.direct_factor(&a, &active, 1.3).unwrap();
        ws.direct_factor(&a, &active, 1.3).unwrap();
        assert_eq!(ws.stats.direct_hits, 1);
        assert_eq!(ws.stats.direct_rebuilds, 1);

        let m = a.rows();
        let mut v = Mat::zeros(m, m);
        shard::rank1_lower_accum(&a, &active, 1.3, &mut v);
        for i in 0..m {
            v.set(i, i, v.get(i, i) + 1.0);
        }
        let cold = Cholesky::factor(&v).unwrap();
        // compare the lower triangles (the cold clone zeroes the upper too)
        for j in 0..m {
            for i in j..m {
                assert_eq!(
                    ws.direct_chol.l().get(i, j).to_bits(),
                    cold.l().get(i, j).to_bits(),
                    "L[{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn rebind_resets_on_in_place_mutation_same_allocation() {
        // ABA case: the design mutates inside the SAME allocation (pointer
        // and shape unchanged) — the sampled-content fingerprint must still
        // invalidate the cache instead of serving the stale factor.
        let mut a = random_case(12, 30, 60);
        let active: Vec<usize> = (0..8).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.8).unwrap();
        a.set(0, 0, a.get(0, 0) + 1.0);
        ws.woodbury_factor(&a, &active, 0.8).unwrap();
        assert_eq!(ws.stats.factor_hits, 0, "stale factor served after mutation");
        assert_eq!(ws.stats.gram_rebuilds, 2, "{:?}", ws.stats);
        let cold = cold_woodbury_factor(&a, &active, 0.8);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn rebind_resets_on_new_design() {
        let a = random_case(15, 40, 6);
        let b = random_case(15, 40, 7);
        let active: Vec<usize> = (0..10).collect();
        let mut ws = NewtonWorkspace::new();
        ws.woodbury_factor(&a, &active, 0.8).unwrap();
        ws.woodbury_factor(&b, &active, 0.8).unwrap();
        assert_eq!(ws.stats.factor_hits, 0, "different design must not hit");
        assert_eq!(ws.stats.gram_rebuilds, 2);
        let cold = cold_woodbury_factor(&b, &active, 0.8);
        assert_eq!(ws.gram_chol.l().as_slice(), cold.l().as_slice());
    }

    #[test]
    fn failed_factor_invalidates_and_recovers() {
        // κ⁻¹I + Gram is SPD for κ > 0, so force failure via a non-finite κ
        // ridge: κ = -1 gives ridge -1, which can break positive-definiteness.
        let a = random_case(10, 30, 8);
        // duplicate columns → singular Gram; with a negative ridge the factor
        // must fail
        let active = vec![3usize, 3, 3, 3];
        let mut ws = NewtonWorkspace::new();
        assert!(ws.woodbury_factor(&a, &active, -0.5).is_err());
        assert!(!ws.factor_valid);
        // a sane κ on a sane set recovers
        let good: Vec<usize> = (0..5).collect();
        ws.woodbury_factor(&a, &good, 0.5).unwrap();
        assert!(ws.factor_valid);
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut s = ShardScratch::new();
        let mut b = s.take_zeroed(100);
        assert!(b.iter().all(|&v| v == 0.0));
        b[0] = 7.0;
        let ptr = b.as_ptr() as usize;
        let cap = b.capacity();
        s.give(b);
        let b2 = s.take_zeroed(80);
        assert_eq!(b2.as_ptr() as usize, ptr, "must reuse the retained buffer");
        assert_eq!(b2.capacity(), cap);
        assert_eq!(b2.len(), 80);
        assert!(b2.iter().all(|&v| v == 0.0), "take_zeroed must re-zero");
    }

    #[test]
    fn scratch_best_fit_prefers_smallest_sufficient() {
        let mut s = ShardScratch::new();
        let small = s.take_zeroed(10);
        let big = s.take_zeroed(1000);
        let (psmall, pbig) = (small.as_ptr() as usize, big.as_ptr() as usize);
        s.give(big);
        s.give(small);
        let got = s.take_zeroed(8);
        assert_eq!(got.as_ptr() as usize, psmall, "small request takes the small buffer");
        let got_big = s.take_zeroed(900);
        assert_eq!(got_big.as_ptr() as usize, pbig);
    }

    #[test]
    fn scratch_retention_is_capped() {
        let mut s = ShardScratch::new();
        for _ in 0..(ShardScratch::MAX_BUFFERS + 5) {
            s.give(vec![0.0; 4]);
        }
        assert_eq!(s.buffers.len(), ShardScratch::MAX_BUFFERS);
    }
}
