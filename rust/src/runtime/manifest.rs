//! Artifact manifest — the contract between `python/compile/aot.py` (which
//! lowers the L2 JAX graphs to HLO text) and the Rust PJRT engine that loads
//! them. The manifest is plain JSON parsed with [`crate::util::json`].
//!
//! ```json
//! {
//!   "dtype": "f32",
//!   "artifacts": [
//!     {"name": "dual_prox_grad", "m": 200, "n": 4000,
//!      "file": "dual_prox_grad_200x4000.hlo.txt"}
//!   ]
//! }
//! ```

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One lowered graph at a fixed shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Graph name (`dual_prox_grad`, `hess_vec`, ...).
    pub name: String,
    /// Rows of the design matrix the graph was lowered for.
    pub m: usize,
    /// Columns of the design matrix.
    pub n: usize,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Buffer element type the graphs were lowered with (currently "f32").
    pub dtype: String,
    pub artifacts: Vec<ArtifactMeta>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self, String> {
        let j = Json::parse(text)?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing artifacts array")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let get_str = |k: &str| {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or(format!("artifact {i}: missing string {k}"))
            };
            let get_usize = |k: &str| {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or(format!("artifact {i}: missing integer {k}"))
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                m: get_usize("m")?,
                n: get_usize("n")?,
                file: get_str("file")?,
            });
        }
        Ok(Self { dtype, artifacts, dir: dir.to_path_buf() })
    }

    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Find an artifact by graph name and shape.
    pub fn find(&self, name: &str, m: usize, n: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name && a.m == m && a.n == n)
    }

    /// All distinct `(m, n)` shapes present.
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self.artifacts.iter().map(|a| (a.m, a.n)).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f32",
      "artifacts": [
        {"name": "dual_prox_grad", "m": 200, "n": 4000, "file": "dual_prox_grad_200x4000.hlo.txt"},
        {"name": "hess_vec", "m": 200, "n": 4000, "file": "hess_vec_200x4000.hlo.txt"},
        {"name": "dual_prox_grad", "m": 500, "n": 10000, "file": "dual_prox_grad_500x10000.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parse_and_find() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.dtype, "f32");
        assert_eq!(m.artifacts.len(), 3);
        let a = m.find("dual_prox_grad", 200, 4000).unwrap();
        assert_eq!(a.file, "dual_prox_grad_200x4000.hlo.txt");
        assert!(m.find("dual_prox_grad", 999, 4000).is_none());
        assert_eq!(m.shapes(), vec![(200, 4000), (500, 10000)]);
        assert_eq!(
            m.path_of(a),
            PathBuf::from("/tmp/artifacts/dual_prox_grad_200x4000.hlo.txt")
        );
    }

    #[test]
    fn parse_errors() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#, Path::new(".")).is_err());
        assert!(Manifest::parse("not json", Path::new(".")).is_err());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
