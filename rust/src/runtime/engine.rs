//! PJRT execution engine — the runtime layer of the three-layer stack.
//!
//! Loads the HLO-text artifacts produced once by `python/compile/aot.py`
//! (`make artifacts`), compiles them on the PJRT CPU client, and executes them
//! from the Rust hot path. Python never runs here.
//!
//! Conventions shared with `python/compile/model.py`:
//!
//! * the design matrix is passed **transposed** (`at`, shape `(n, m)`): our
//!   column-major `Mat` storage is exactly jax's row-major `(n, m)` layout, so
//!   the buffer crosses the boundary without a transpose copy;
//! * buffers are `f32` (the artifacts' dtype; the native path stays `f64`);
//! * every graph returns a tuple (jax lowered with `return_tuple=True`).

use crate::linalg::Mat;
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A compiled graph plus its shape metadata.
pub struct LoadedGraph {
    exe: xla::PjRtLoadedExecutable,
    /// Metadata (name, m, n, file).
    pub meta: ArtifactMeta,
}

impl LoadedGraph {
    /// Execute with the given literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing graph {}", self.meta.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.meta.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// The engine: one PJRT client + all compiled graphs keyed by (name, m, n).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    graphs: HashMap<(String, usize, usize), LoadedGraph>,
    /// The manifest the engine was built from.
    pub manifest: Manifest,
}

impl PjrtEngine {
    /// Load every artifact in `dir` and compile it.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        if manifest.dtype != "f32" {
            return Err(anyhow!("unsupported artifact dtype {}", manifest.dtype));
        }
        let client = xla::PjRtClient::cpu()?;
        let mut graphs = HashMap::new();
        for meta in manifest.artifacts.clone() {
            let path = manifest.path_of(&meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", meta.name))?;
            graphs.insert((meta.name.clone(), meta.m, meta.n), LoadedGraph { exe, meta });
        }
        Ok(Self { client, graphs, manifest })
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch a graph for a given problem shape.
    pub fn graph(&self, name: &str, m: usize, n: usize) -> Result<&LoadedGraph> {
        self.graphs.get(&(name.to_string(), m, n)).ok_or_else(|| {
            anyhow!(
                "no artifact `{name}` for shape ({m}, {n}); available shapes: {:?} — \
                 re-run `make artifacts SHAPES=...`",
                self.manifest.shapes()
            )
        })
    }

    /// Number of loaded graphs.
    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    /// True if no graphs are loaded.
    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }
}

/// Convert an f64 slice to an f32 literal of the given dimensions.
pub fn literal_from_f64(values: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    let expected: usize = dims.iter().product();
    if expected != values.len() {
        return Err(anyhow!("literal shape {:?} wants {expected} values, got {}", dims, values.len()));
    }
    let f32s: Vec<f32> = values.iter().map(|&v| v as f32).collect();
    let lit = xla::Literal::vec1(&f32s);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims_i64)?)
    }
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f64) -> xla::Literal {
    xla::Literal::scalar(v as f32)
}

/// The design matrix as the `(n, m)` transposed literal the graphs expect —
/// column-major `Mat` storage *is* row-major `(n, m)`, so this is a plain
/// cast-copy with no transpose.
pub fn literal_at(a: &Mat) -> Result<xla::Literal> {
    literal_from_f64(a.as_slice(), &[a.cols(), a.rows()])
}

/// Read an output literal back to f64.
pub fn literal_to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit.to_vec()?;
    Ok(v.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals = [1.0f64, -2.5, 3.25];
        let lit = literal_from_f64(&vals, &[3]).unwrap();
        let back = literal_to_f64(&lit).unwrap();
        assert_eq!(back, vals.to_vec());
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_from_f64(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn at_literal_matches_transposed_layout() {
        // Mat column-major (2×3): col j contiguous ⇒ row-major (3, 2) = Aᵀ
        let a = Mat::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_at(&a).unwrap();
        let flat = literal_to_f64(&lit).unwrap();
        // expected Aᵀ row-major: rows are columns of A
        assert_eq!(flat, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    // Engine loading is covered by rust/tests/pjrt_integration.rs, which
    // requires `make artifacts` to have produced the HLO files.
}
