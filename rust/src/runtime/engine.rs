//! PJRT execution engine — the runtime layer of the three-layer stack.
//!
//! The *contract* side is fully native: [`Manifest`] parsing, artifact
//! discovery, and the [`Literal`] buffer type with the f64 ⇄ f32 conversion
//! helpers shared with `python/compile/model.py`:
//!
//! * the design matrix is passed **transposed** (`at`, shape `(n, m)`): our
//!   column-major `Mat` storage is exactly jax's row-major `(n, m)` layout, so
//!   the buffer crosses the boundary without a transpose copy;
//! * buffers are `f32` (the artifacts' dtype; the native path stays `f64`);
//! * every graph returns a tuple (jax lowered with `return_tuple=True`).
//!
//! The *execution* side requires an XLA/PJRT binding, which the offline
//! toolchain does not ship. [`PjrtEngine::load_dir`] therefore validates the
//! manifest and artifact files but returns a descriptive error instead of a
//! live engine; callers (the coordinator's `Backend::Pjrt`, the
//! `artifacts-check` subcommand) degrade gracefully. The native f64 backend is
//! the performance path either way (see DESIGN notes in the crate docs).

use crate::linalg::Mat;
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::util::error::{Context, Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// A host-side tensor of `f32` values with a shape — the buffer type crossing
/// the Rust ⇄ PJRT boundary. Dimension-major (row-major over `dims`).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<usize>,
}

impl Literal {
    /// 1-D literal from f32 values.
    pub fn vec1(values: &[f32]) -> Self {
        Self { data: values.to_vec(), dims: vec![values.len()] }
    }

    /// 0-D (scalar) literal.
    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: Vec::new() }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[usize]) -> Result<Self> {
        let expected: usize = dims.iter().product();
        if expected != self.data.len() {
            return Err(Error::msg(format!(
                "reshape to {:?} wants {expected} values, literal has {}",
                dims,
                self.data.len()
            )));
        }
        Ok(Self { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Shape of the literal.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Flat element view.
    pub fn values(&self) -> &[f32] {
        &self.data
    }
}

/// A graph known to the engine plus its shape metadata.
pub struct LoadedGraph {
    /// Metadata (name, m, n, file).
    pub meta: ArtifactMeta,
}

impl LoadedGraph {
    /// Execute with the given literals; returns the decomposed output tuple.
    ///
    /// Always errors in this build: executing the HLO artifacts needs a PJRT
    /// client, which the offline toolchain does not provide.
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(Error::msg(format!(
            "cannot execute graph {}: this build has no XLA/PJRT binding \
             (offline toolchain); use the native backend",
            self.meta.name
        )))
    }
}

/// The engine: all validated graphs keyed by (name, m, n).
pub struct PjrtEngine {
    graphs: HashMap<(String, usize, usize), LoadedGraph>,
    /// The manifest the engine was built from.
    pub manifest: Manifest,
}

impl PjrtEngine {
    /// Validate an artifacts directory without compiling anything: parse the
    /// manifest, check the dtype contract, and verify every referenced HLO
    /// file exists. Succeeds on a healthy directory even in builds with no
    /// PJRT binding — this is what `ssnal-en artifacts-check` gates on.
    pub fn validate_dir(dir: &Path) -> Result<Manifest> {
        let manifest = Manifest::load(dir).map_err(Error::msg)?;
        if manifest.dtype != "f32" {
            return Err(Error::msg(format!("unsupported artifact dtype {}", manifest.dtype)));
        }
        for meta in &manifest.artifacts {
            let path = manifest.path_of(meta);
            std::fs::metadata(&path)
                .with_context(|| format!("artifact file missing: {}", path.display()))?;
        }
        Ok(manifest)
    }

    /// Load every artifact in `dir` for execution.
    ///
    /// In this offline build the directory is validated (see
    /// [`Self::validate_dir`]) and then a descriptive error is returned:
    /// compiling HLO artifacts requires an XLA/PJRT binding the toolchain
    /// does not ship.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let manifest = Self::validate_dir(dir)?;
        Err(Error::msg(format!(
            "{} artifacts validated at {}, but this build has no XLA/PJRT \
             binding to compile them (offline toolchain); use the native backend",
            manifest.artifacts.len(),
            dir.display()
        )))
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Fetch a graph for a given problem shape.
    pub fn graph(&self, name: &str, m: usize, n: usize) -> Result<&LoadedGraph> {
        self.graphs.get(&(name.to_string(), m, n)).ok_or_else(|| {
            Error::msg(format!(
                "no artifact `{name}` for shape ({m}, {n}); available shapes: {:?} — \
                 re-run `make artifacts SHAPES=...`",
                self.manifest.shapes()
            ))
        })
    }

    /// Number of loaded graphs.
    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    /// True if no graphs are loaded.
    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }
}

/// Convert an f64 slice to an f32 literal of the given dimensions.
pub fn literal_from_f64(values: &[f64], dims: &[usize]) -> Result<Literal> {
    let expected: usize = dims.iter().product();
    if expected != values.len() {
        return Err(Error::msg(format!(
            "literal shape {:?} wants {expected} values, got {}",
            dims,
            values.len()
        )));
    }
    let f32s: Vec<f32> = values.iter().map(|&v| v as f32).collect();
    let lit = Literal::vec1(&f32s);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(dims)
    }
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f64) -> Literal {
    Literal::scalar(v as f32)
}

/// The design matrix as the `(n, m)` transposed literal the graphs expect —
/// column-major `Mat` storage *is* row-major `(n, m)`, so this is a plain
/// cast-copy with no transpose.
pub fn literal_at(a: &Mat) -> Result<Literal> {
    literal_from_f64(a.as_slice(), &[a.cols(), a.rows()])
}

/// Read an output literal back to f64.
pub fn literal_to_f64(lit: &Literal) -> Result<Vec<f64>> {
    Ok(lit.values().iter().map(|&x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals = [1.0f64, -2.5, 3.25];
        let lit = literal_from_f64(&vals, &[3]).unwrap();
        let back = literal_to_f64(&lit).unwrap();
        assert_eq!(back, vals.to_vec());
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_from_f64(&[1.0, 2.0], &[3]).is_err());
        assert!(Literal::vec1(&[1.0, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn at_literal_matches_transposed_layout() {
        // Mat column-major (2×3): col j contiguous ⇒ row-major (3, 2) = Aᵀ
        let a = Mat::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_at(&a).unwrap();
        assert_eq!(lit.dims(), &[3, 2]);
        let flat = literal_to_f64(&lit).unwrap();
        // expected Aᵀ row-major: rows are columns of A
        assert_eq!(flat, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn load_dir_without_artifacts_is_a_clean_error() {
        let err = PjrtEngine::load_dir(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(format!("{err}").contains("make artifacts"), "{err}");
    }
}
