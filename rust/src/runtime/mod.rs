//! Runtime layer: loads `artifacts/*.hlo.txt` (AOT-lowered JAX + Pallas graphs)
//! and executes them on the PJRT CPU client from the Rust request path.
//!
//! See DESIGN.md §2 for the three-layer architecture and
//! `python/compile/aot.py` for the producer side of the contract.

pub mod engine;
pub mod manifest;

pub use engine::{
    literal_at, literal_from_f64, literal_scalar, literal_to_f64, Literal, LoadedGraph, PjrtEngine,
};
pub use manifest::{ArtifactMeta, Manifest};

/// Default artifacts directory (relative to the repo root); can be overridden
/// with the `SSNAL_ARTIFACTS_DIR` environment variable.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("SSNAL_ARTIFACTS_DIR") {
        return std::path::PathBuf::from(dir);
    }
    std::path::PathBuf::from("artifacts")
}
