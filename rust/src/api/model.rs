//! The estimator builder — configuration half of the facade.
//!
//! [`EnetModel`] collapses the historical option structs (`SsnalOptions`,
//! `BaselineOptions`, `PathOptions`, `ParallelPathOptions`, `TuningOptions`)
//! into one builder with per-field validation: every invalid setting surfaces
//! as a typed [`EnetError`] from the `fit*`/`tune` calls instead of an
//! `assert!` panic deep inside a solver. One model value drives all three
//! workloads — single solves ([`EnetModel::fit`]), warm-started λ-paths
//! ([`EnetModel::fit_path`]) and tuning sweeps ([`EnetModel::tune`]).

use crate::api::fit::{Fit, PathFit, TuneFit};
use crate::api::{Design, EnetError};
use crate::coordinator::pjrt_solver;
use crate::linalg::{design_fingerprint, DesignRef, NewtonWorkspace};
use crate::parallel::{
    shard, solve_path_parallel_warm, Chunking, ParallelPathOptions, DEFAULT_CHAINS,
};
use crate::path::{c_lambda_grid, PathOptions};
use crate::runtime::PjrtEngine;
use crate::solver::ssnal::{self, SsnalTrace};
use crate::solver::types::{
    Algorithm, EnetProblem, NewtonStrategy, SolveResult, SolverConfig, SsnalOptions,
};
use crate::tuning::{tune_with_threads, TuningOptions};
use std::path::PathBuf;

/// Which execution backend runs the solver's inner computations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust f64 kernels (default; fastest on this CPU testbed).
    Native,
    /// AOT-compiled JAX + Pallas graphs executed via PJRT (f32). Demonstrates
    /// the full three-layer stack; requires `make artifacts` for the problem
    /// shape.
    Pjrt,
}

impl Backend {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(format!("unknown backend {other:?} (native|pjrt)")),
        }
    }
}

/// Single-point penalty specification.
#[derive(Clone, Copy, Debug)]
enum Penalty {
    /// Explicit `(λ1, λ2)`.
    Lambda(f64, f64),
    /// The paper's parametrization `λ1 = α·c·λmax`, `λ2 = (1−α)·c·λmax`,
    /// with α taken from the model's mixing parameter.
    C(f64),
}

/// λ-grid specification for path/tuning workloads.
#[derive(Clone, Debug)]
enum GridSpec {
    /// Log-spaced `c_λ` grid from `hi` down to `lo`.
    Log { hi: f64, lo: f64, points: usize },
    /// Caller-supplied descending `c_λ` values.
    Explicit(Vec<f64>),
}

/// Builder-style Elastic Net estimator — the crate's canonical entry point.
///
/// Defaults follow the paper's §4.1 protocol (α = 0.8, tol = 1e-6, SsNAL-EN
/// with the automatic Newton strategy, 100-point log grid from 1.0 to 0.1
/// capped at 100 active features). Setters are chainable and infallible; all
/// validation happens in [`EnetModel::fit`] / [`EnetModel::fit_path`] /
/// [`EnetModel::tune`], which return typed [`EnetError`]s.
///
/// ```
/// use ssnal_en::api::{Design, EnetModel};
/// use ssnal_en::data::{generate_synthetic, SyntheticSpec};
///
/// let prob = generate_synthetic(&SyntheticSpec {
///     m: 30, n: 90, n0: 4, x_star: 5.0, snr: 8.0, seed: 7,
/// });
/// let design = Design::new(&prob.a, &prob.b)?;
/// let fit = EnetModel::new().alpha_c(0.8, 0.3).tol(1e-8).fit(&design)?;
/// assert!(fit.result().converged);
/// assert!(!fit.active_set().is_empty());
/// # Ok::<(), ssnal_en::api::EnetError>(())
/// ```
#[derive(Clone, Debug)]
pub struct EnetModel {
    alpha: f64,
    penalty: Penalty,
    grid: GridSpec,
    max_active: usize,
    algorithm: Algorithm,
    solver: SolverConfig,
    cv_folds: usize,
    cv_seed: u64,
    threads: usize,
    chunking: Chunking,
    screening: bool,
    backend: Backend,
    artifacts_dir: PathBuf,
}

impl Default for EnetModel {
    fn default() -> Self {
        Self::new()
    }
}

impl EnetModel {
    /// The paper-default configuration (see the type-level docs).
    pub fn new() -> Self {
        Self {
            alpha: 0.8,
            penalty: Penalty::C(0.5),
            grid: GridSpec::Log { hi: 1.0, lo: 0.1, points: 100 },
            max_active: 100,
            algorithm: Algorithm::SsnalEn,
            solver: SolverConfig::default(),
            cv_folds: 0,
            cv_seed: 0,
            threads: 0,
            chunking: Chunking::Chains(DEFAULT_CHAINS),
            screening: true,
            backend: Backend::Native,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }

    // ---- penalty ----------------------------------------------------------

    /// Explicit penalties `(λ1, λ2)` for single fits.
    pub fn lambda(mut self, lam1: f64, lam2: f64) -> Self {
        self.penalty = Penalty::Lambda(lam1, lam2);
        self
    }

    /// The paper's `(α, c_λ)` parametrization for single fits:
    /// `λ1 = α·c·λmax`, `λ2 = (1−α)·c·λmax` with `λmax = ‖Aᵀb‖∞/α`.
    /// Also sets the mixing α used by path/tuning grids.
    pub fn alpha_c(mut self, alpha: f64, c: f64) -> Self {
        self.alpha = alpha;
        self.penalty = Penalty::C(c);
        self
    }

    /// Mixing parameter α ∈ (0, 1] (1 = pure Lasso) without touching the
    /// single-fit penalty.
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    // ---- grid (path / tuning) --------------------------------------------

    /// Log-spaced `c_λ` grid from `hi` down to `lo` with `points` values.
    pub fn grid(mut self, hi: f64, lo: f64, points: usize) -> Self {
        self.grid = GridSpec::Log { hi, lo, points };
        self
    }

    /// Explicit descending `c_λ` grid (overrides [`EnetModel::grid`]).
    pub fn c_grid(mut self, grid: Vec<f64>) -> Self {
        self.grid = GridSpec::Explicit(grid);
        self
    }

    /// Stop exploring the path once this many features are active
    /// (`0` = no cap).
    pub fn max_active(mut self, max_active: usize) -> Self {
        self.max_active = max_active;
        self
    }

    // ---- algorithm / solver knobs ----------------------------------------

    /// Which algorithm solves each instance (default: the paper's SsNAL-EN).
    pub fn algorithm(mut self, algorithm: Algorithm) -> Self {
        self.algorithm = algorithm;
        self
    }

    /// Newton-system strategy for SsNAL-EN (default: the paper's Auto cost
    /// model).
    pub fn newton(mut self, strategy: NewtonStrategy) -> Self {
        self.solver.ssnal.strategy = strategy;
        self
    }

    /// Full SsNAL option block (σ schedule, line search, CG caps). The
    /// builder's own `tol`/`verbose`/`max_iters` still take precedence over
    /// the matching fields here.
    pub fn ssnal_options(mut self, opts: SsnalOptions) -> Self {
        self.solver.ssnal = opts;
        self
    }

    /// Stopping tolerance on the solver's own criterion (default 1e-6).
    pub fn tol(mut self, tol: f64) -> Self {
        self.solver.tol = tol;
        self
    }

    /// Cap outer iterations (AL iterations for SsNAL-EN, sweeps/epochs for
    /// the baselines).
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.solver.max_iters = Some(max_iters);
        self
    }

    /// Per-iteration diagnostics.
    pub fn verbose(mut self, verbose: bool) -> Self {
        self.solver.verbose = verbose;
        self
    }

    // ---- tuning -----------------------------------------------------------

    /// k-fold cross-validation during [`EnetModel::tune`] (`0` disables CV —
    /// it is by far the costliest criterion).
    pub fn cv(mut self, folds: usize) -> Self {
        self.cv_folds = folds;
        self
    }

    /// Seed for the CV fold assignment.
    pub fn cv_seed(mut self, seed: u64) -> Self {
        self.cv_seed = seed;
        self
    }

    // ---- execution ---------------------------------------------------------

    /// Worker threads (`0` = all available cores). Single fits use this as
    /// the within-solve shard budget; paths and tuning sweeps use it for the
    /// grid-level fan-out. Results are identical at every setting for a
    /// fixed [`EnetModel::chunking`].
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// How path grids split into warm-start chains (default: a fixed
    /// [`DEFAULT_CHAINS`]-way split, so results do not depend on the thread
    /// count; [`Chunking::Auto`] ties chains to threads for maximum
    /// parallelism at the cost of that invariance).
    pub fn chunking(mut self, chunking: Chunking) -> Self {
        self.chunking = chunking;
        self
    }

    /// Gap-Safe screening of warm-started path points (default on).
    pub fn screening(mut self, screening: bool) -> Self {
        self.screening = screening;
        self
    }

    /// Single-chain, single-thread, unscreened path execution — bitwise
    /// identical to the sequential `path::solve_path` driver. The benches use
    /// this as their baseline configuration.
    pub fn sequential(self) -> Self {
        self.threads(1).chunking(Chunking::Chains(1)).screening(false)
    }

    /// Execution backend (default native f64 kernels).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Artifacts directory for the PJRT backend.
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    // ---- workloads ---------------------------------------------------------

    /// Fit one Elastic Net instance, returning a warm [`Fit`] session whose
    /// Newton workspace (buffer arena + Gram/Cholesky cache) stays bound to
    /// `design` — [`Fit::refit`] reuses it across responses.
    pub fn fit<'d>(&self, design: &'d Design<'d>) -> Result<Fit<'d>, EnetError> {
        self.fit_warm(design, None)
    }

    /// [`EnetModel::fit`] with an explicit warm-start point (SsNAL-EN only;
    /// the PJRT demo backend ignores it).
    pub fn fit_warm<'d>(
        &self,
        design: &'d Design<'d>,
        x0: Option<&[f64]>,
    ) -> Result<Fit<'d>, EnetError> {
        self.validate_common(design)?;
        if self.backend == Backend::Pjrt && self.algorithm != Algorithm::SsnalEn {
            return Err(EnetError::Unsupported {
                what: format!("{:?} on the PJRT backend", self.algorithm),
            });
        }
        if let Some(x0) = x0 {
            if x0.len() != design.n() {
                return Err(EnetError::WarmStartShape { expected: design.n(), got: x0.len() });
            }
            if let Some(index) = x0.iter().position(|v| !v.is_finite()) {
                return Err(EnetError::NonFinite { what: "warm start", index });
            }
        }
        let (lam1, lam2) = self.checked_lambdas(design.design_ref(), design.b())?;
        let mut ws = NewtonWorkspace::new();
        let mut engine = None;
        let (result, trace) = self.solve_once(
            design.design_ref(),
            design.b(),
            lam1,
            lam2,
            x0,
            &mut engine,
            &mut ws,
        )?;
        Ok(Fit { design, model: self.clone(), lam1, lam2, result, trace, ws, engine })
    }

    /// Warm-started λ-path over the configured grid, executed on the parallel
    /// engine (SsNAL-EN or the two CD variants).
    ///
    /// Per-point solves follow the path driver's contract: `tol` is the
    /// honored stopping knob and each algorithm keeps its default iteration
    /// cap. An explicit [`EnetModel::max_iters`] is therefore rejected (not
    /// silently dropped); [`EnetModel::verbose`] applies to single fits only.
    ///
    /// Like [`EnetModel::fit`], the returned [`PathFit`] is a *warm session*:
    /// the per-chain Newton workspaces that solved the path stay alive inside
    /// it, and [`PathFit::refit_path`] re-solves a new response (or design) at
    /// cache cost with bitwise-identical results.
    pub fn fit_path(&self, design: &Design<'_>) -> Result<PathFit, EnetError> {
        self.validate_common(design)?;
        self.check_path_algorithm()?;
        let popts = ParallelPathOptions {
            base: self.path_options()?,
            num_threads: self.threads,
            chunking: self.chunking.clone(),
            screening: self.screening,
        };
        let mut sessions = Vec::new();
        let result =
            solve_path_parallel_warm(design.design_ref(), design.b(), &popts, &mut sessions);
        let design_fp = design_fingerprint(design.design_ref());
        Ok(PathFit { result, popts, sessions, design_fp })
    }

    /// Tuning sweep (paper §3.3): λ-path plus GCV / e-BIC (and k-fold CV when
    /// [`EnetModel::cv`] is set) at every explored point. Like
    /// [`EnetModel::fit_path`], per-point solves use the path driver's
    /// defaults: an explicit [`EnetModel::max_iters`] is rejected rather than
    /// silently dropped.
    pub fn tune(&self, design: &Design<'_>) -> Result<TuneFit, EnetError> {
        self.validate_common(design)?;
        self.check_path_algorithm()?;
        let m = design.m();
        if self.cv_folds != 0 && (self.cv_folds < 2 || self.cv_folds > m) {
            return Err(EnetError::InvalidFolds { folds: self.cv_folds, m });
        }
        let topts = TuningOptions {
            path: self.path_options()?,
            cv_folds: self.cv_folds,
            cv_seed: self.cv_seed,
        };
        Ok(TuneFit {
            result: tune_with_threads(design.design_ref(), design.b(), &topts, self.threads),
        })
    }

    // ---- internals ---------------------------------------------------------

    /// Field-level validation shared by every workload (also used by the
    /// serve sessions, which drive `checked_lambdas`/`solve_once` directly).
    pub(crate) fn validate_common(&self, _design: &Design<'_>) -> Result<(), EnetError> {
        crate::api::check_alpha(self.alpha)?;
        if !(self.solver.tol.is_finite() && self.solver.tol > 0.0) {
            return Err(EnetError::InvalidTolerance { tol: self.solver.tol });
        }
        if self.solver.max_iters == Some(0) {
            return Err(EnetError::InvalidIterations);
        }
        Ok(())
    }

    /// Path/tuning drivers support warm-startable algorithms on the native
    /// backend only, and cannot thread a custom iteration cap through the
    /// per-point warm-start primitive — reject rather than silently drop it.
    fn check_path_algorithm(&self) -> Result<(), EnetError> {
        if self.backend == Backend::Pjrt {
            return Err(EnetError::Unsupported {
                what: "λ-path / tuning on the PJRT backend".to_string(),
            });
        }
        if self.solver.max_iters.is_some() {
            return Err(EnetError::Unsupported {
                what: "max_iters on λ-path / tuning (per-point solves use the path \
                       driver's default caps; cap single fits instead)"
                    .to_string(),
            });
        }
        match self.algorithm {
            Algorithm::SsnalEn | Algorithm::CdNaive | Algorithm::CdCovariance => Ok(()),
            other => Err(EnetError::Unsupported {
                what: format!("λ-path driving with {other:?}"),
            }),
        }
    }

    /// Resolve and validate the single-fit penalties against `(A, b)`.
    pub(crate) fn checked_lambdas(
        &self,
        a: DesignRef<'_>,
        b: &[f64],
    ) -> Result<(f64, f64), EnetError> {
        let (lam1, lam2) = match self.penalty {
            Penalty::Lambda(l1, l2) => (l1, l2),
            Penalty::C(c) => {
                if !(c.is_finite() && c > 0.0) {
                    return Err(EnetError::InvalidCLambda { c });
                }
                let lmax = EnetProblem::lambda_max(a, b, self.alpha);
                EnetProblem::lambdas_from_alpha(self.alpha, c, lmax)
            }
        };
        check_lambda_pair(lam1, lam2)
    }

    /// [`EnetModel::checked_lambdas`] for a batch of responses, with the λmax
    /// resolution fused into one pass over the design's columns: for
    /// `(α, c_λ)` models every response's `‖Aᵀbᵢ‖∞` is a running max over the
    /// same per-column `|aⱼᵀbᵢ|` dots that [`EnetProblem::lambda_max`]
    /// reduces, folded in the same column order — so the results are
    /// bitwise-identical to per-response calls while `A` is read once instead
    /// of once per response.
    pub(crate) fn checked_lambdas_many<B: AsRef<[f64]>>(
        &self,
        a: DesignRef<'_>,
        bs: &[B],
    ) -> Result<Vec<(f64, f64)>, EnetError> {
        match self.penalty {
            Penalty::Lambda(l1, l2) => {
                let pair = check_lambda_pair(l1, l2)?;
                Ok(vec![pair; bs.len()])
            }
            Penalty::C(c) => {
                if !(c.is_finite() && c > 0.0) {
                    return Err(EnetError::InvalidCLambda { c });
                }
                let mut maxes = vec![0.0f64; bs.len()];
                for j in 0..a.cols() {
                    for (max, b) in maxes.iter_mut().zip(bs) {
                        *max = max.max(a.col_dot(j, b.as_ref()).abs());
                    }
                }
                maxes
                    .into_iter()
                    .map(|nrm| {
                        let (lam1, lam2) =
                            EnetProblem::lambdas_from_alpha(self.alpha, c, nrm / self.alpha);
                        check_lambda_pair(lam1, lam2)
                    })
                    .collect()
            }
        }
    }

    /// One solve against caller-owned session state (the PJRT engine cache
    /// and the Newton workspace) — the primitive behind both
    /// [`EnetModel::fit_warm`] and [`Fit::refit`]. A fresh and a warm `ws`
    /// produce bitwise-identical results (the workspace cache contract); the
    /// engine loads once per session, not per solve.
    pub(crate) fn solve_once(
        &self,
        a: DesignRef<'_>,
        b: &[f64],
        lam1: f64,
        lam2: f64,
        x0: Option<&[f64]>,
        engine: &mut Option<PjrtEngine>,
        ws: &mut NewtonWorkspace,
    ) -> Result<(SolveResult, Option<SsnalTrace>), EnetError> {
        match self.backend {
            Backend::Pjrt => {
                let engine = match engine {
                    Some(engine) => &*engine,
                    None => {
                        let loaded = PjrtEngine::load_dir(&self.artifacts_dir).map_err(|e| {
                            EnetError::Backend(format!(
                                "loading artifacts from {}: {e}",
                                self.artifacts_dir.display()
                            ))
                        })?;
                        &*engine.insert(loaded)
                    }
                };
                let p = EnetProblem::new(a, b, lam1, lam2);
                let res = pjrt_solver::solve_pjrt(engine, &p, &self.solver.ssnal_options())
                    .map_err(|e| EnetError::Backend(format!("{e:#}")))?;
                Ok((res, None))
            }
            Backend::Native => {
                let run = || {
                    let p = EnetProblem::new(a, b, lam1, lam2);
                    match self.algorithm {
                        Algorithm::SsnalEn => {
                            let (res, trace) =
                                ssnal::solve_warm_ws(&p, &self.solver.ssnal_options(), x0, ws);
                            Ok((res, Some(trace)))
                        }
                        other if x0.is_some() => Err(EnetError::Unsupported {
                            what: format!("explicit warm start with {other:?}"),
                        }),
                        other => {
                            Ok((crate::solver::solve_with_config(&p, other, &self.solver), None))
                        }
                    }
                };
                if self.threads > 0 {
                    shard::with_threads(self.threads, run)
                } else {
                    run()
                }
            }
        }
    }

    /// Build the validated low-level [`PathOptions`].
    fn path_options(&self) -> Result<PathOptions, EnetError> {
        let c_grid = match &self.grid {
            GridSpec::Explicit(grid) => {
                if grid.is_empty() {
                    return Err(EnetError::InvalidGrid { reason: "grid is empty".to_string() });
                }
                if let Some(bad) = grid.iter().find(|c| !(c.is_finite() && **c > 0.0)) {
                    return Err(EnetError::InvalidGrid {
                        reason: format!("grid values must be positive and finite, got {bad}"),
                    });
                }
                if grid.windows(2).any(|w| w[0] <= w[1]) {
                    return Err(EnetError::InvalidGrid {
                        reason: "grid must be strictly descending".to_string(),
                    });
                }
                grid.clone()
            }
            GridSpec::Log { hi, lo, points } => {
                if !(hi.is_finite() && lo.is_finite() && *hi > *lo && *lo > 0.0) {
                    return Err(EnetError::InvalidGrid {
                        reason: format!("need hi > lo > 0, got hi={hi} lo={lo}"),
                    });
                }
                if *points < 2 {
                    return Err(EnetError::InvalidGrid {
                        reason: format!("need at least 2 grid points, got {points}"),
                    });
                }
                c_lambda_grid(*hi, *lo, *points)
            }
        };
        Ok(PathOptions {
            alpha: self.alpha,
            c_grid,
            max_active: self.max_active,
            tol: self.solver.tol,
            algorithm: self.algorithm,
        })
    }
}

/// The λ-pair validity contract shared by [`EnetModel::checked_lambdas`] and
/// [`EnetModel::checked_lambdas_many`]: finite, nonnegative, not both zero.
fn check_lambda_pair(lam1: f64, lam2: f64) -> Result<(f64, f64), EnetError> {
    let valid = lam1.is_finite()
        && lam2.is_finite()
        && lam1 >= 0.0
        && lam2 >= 0.0
        && (lam1 > 0.0 || lam2 > 0.0);
    if !valid {
        return Err(EnetError::InvalidPenalty { lam1, lam2 });
    }
    Ok((lam1, lam2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    fn problem() -> crate::data::SyntheticProblem {
        generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 8.0,
            seed: 33,
        })
    }

    #[test]
    fn invalid_settings_surface_as_typed_errors() {
        let prob = problem();
        let design = Design::new(&prob.a, &prob.b).unwrap();
        assert!(matches!(
            EnetModel::new().alpha(1.5).fit(&design),
            Err(EnetError::InvalidAlpha { .. })
        ));
        assert!(matches!(
            EnetModel::new().lambda(-1.0, 0.5).fit(&design),
            Err(EnetError::InvalidPenalty { .. })
        ));
        assert!(matches!(
            EnetModel::new().lambda(0.0, 0.0).fit(&design),
            Err(EnetError::InvalidPenalty { .. })
        ));
        assert!(matches!(
            EnetModel::new().alpha_c(0.8, -0.3).fit(&design),
            Err(EnetError::InvalidCLambda { .. })
        ));
        assert!(matches!(
            EnetModel::new().tol(0.0).fit(&design),
            Err(EnetError::InvalidTolerance { .. })
        ));
        assert!(matches!(
            EnetModel::new().max_iters(0).fit(&design),
            Err(EnetError::InvalidIterations)
        ));
        assert!(matches!(
            EnetModel::new().grid(0.1, 0.5, 10).fit_path(&design),
            Err(EnetError::InvalidGrid { .. })
        ));
        assert!(matches!(
            EnetModel::new().c_grid(vec![0.5, 0.5]).fit_path(&design),
            Err(EnetError::InvalidGrid { .. })
        ));
        assert!(matches!(
            EnetModel::new().cv(1).tune(&design),
            Err(EnetError::InvalidFolds { .. })
        ));
        assert!(matches!(
            EnetModel::new().algorithm(Algorithm::Fista).fit_path(&design),
            Err(EnetError::Unsupported { .. })
        ));
    }

    #[test]
    fn fit_path_and_tune_run_end_to_end() {
        let prob = problem();
        let design = Design::new(&prob.a, &prob.b).unwrap();
        let model = EnetModel::new().alpha(0.9).grid(0.9, 0.2, 6).max_active(0).tol(1e-6);
        let path = model.fit_path(&design).unwrap();
        assert_eq!(path.runs(), 6);
        let tuned = model.tune(&design).unwrap();
        assert_eq!(tuned.points().len(), 6);
        assert!(tuned.best_ebic() < 6);
    }

    #[test]
    fn backend_parsing() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert!(Backend::parse("gpu").is_err());
    }

    #[test]
    fn explicit_warm_start_is_honored_for_ssnal() {
        let prob = problem();
        let design = Design::new(&prob.a, &prob.b).unwrap();
        let model = EnetModel::new().alpha_c(0.8, 0.3).tol(1e-8);
        let cold = model.fit(&design).unwrap();
        let warm = model.fit_warm(&design, Some(cold.coefficients())).unwrap();
        assert!(warm.result().converged);
        assert!(warm.result().iterations <= cold.result().iterations);
        // wrong-length warm starts are typed errors
        assert!(matches!(
            model.fit_warm(&design, Some(&[0.0; 3])),
            Err(EnetError::WarmStartShape { .. })
        ));
    }
}
