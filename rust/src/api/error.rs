//! Typed errors for the estimator facade.
//!
//! Every invalid input that used to `assert!`-panic in the low-level entry
//! points (shape mismatches, negative penalties, bad α, malformed grids)
//! surfaces from the [`crate::api`] layer as an [`EnetError`] variant, so a
//! serving process can reject one bad request instead of dying. The type
//! implements [`std::error::Error`], which lets it flow into the crate-wide
//! [`crate::util::error::Error`] chain via `?` where the old coordinator
//! signatures are preserved.

use std::fmt;

/// Typed validation / execution error produced by the [`crate::api`] facade.
#[derive(Clone, Debug, PartialEq)]
pub enum EnetError {
    /// The design's row count and the response length disagree.
    ShapeMismatch {
        /// Rows of the design matrix `A`.
        rows: usize,
        /// Length of the response `b`.
        response_len: usize,
    },
    /// The design has zero rows or zero columns.
    EmptyDesign {
        /// Rows of `A`.
        rows: usize,
        /// Columns of `A`.
        cols: usize,
    },
    /// A NaN/∞ entry where finite data is required.
    NonFinite {
        /// Which input carried it (`"design"`, `"response"`, `"warm start"`).
        what: &'static str,
        /// Flat index of the first offending entry.
        index: usize,
    },
    /// Penalty weights must be finite, nonnegative, and not both zero.
    InvalidPenalty {
        /// Resolved ℓ1 weight.
        lam1: f64,
        /// Resolved squared-ℓ2 weight.
        lam2: f64,
    },
    /// The mixing parameter must satisfy α ∈ (0, 1].
    InvalidAlpha {
        /// The rejected value.
        alpha: f64,
    },
    /// The `c_λ` scale in the (α, c_λ) parametrization must be positive and
    /// finite.
    InvalidCLambda {
        /// The rejected value.
        c: f64,
    },
    /// A malformed `c_λ` grid (empty, non-descending, non-positive, …).
    InvalidGrid {
        /// Human-readable description of the defect.
        reason: String,
    },
    /// The solver tolerance must be positive and finite.
    InvalidTolerance {
        /// The rejected value.
        tol: f64,
    },
    /// An explicit iteration cap must be at least 1.
    InvalidIterations,
    /// Cross-validation folds must be 0 (disabled) or in `2..=m`.
    InvalidFolds {
        /// Requested fold count.
        folds: usize,
        /// Observations available.
        m: usize,
    },
    /// Structurally invalid design data supplied by an untrusted caller
    /// (e.g. malformed CSC arrays or a flat dense payload of the wrong
    /// length in a serving request) — rejected before any matrix is built.
    InvalidDesign {
        /// Human-readable description of the defect.
        reason: String,
    },
    /// A prediction input with the wrong number of features.
    PredictShape {
        /// Feature count of the fitted design.
        expected: usize,
        /// Feature count of the prediction input.
        got: usize,
    },
    /// A warm-start vector with the wrong length.
    WarmStartShape {
        /// Feature count of the design.
        expected: usize,
        /// Length of the supplied warm start.
        got: usize,
    },
    /// The requested model/algorithm/backend combination is not supported.
    Unsupported {
        /// What was requested.
        what: String,
    },
    /// Backend (PJRT artifact loading / graph execution) failure.
    Backend(String),
    /// A per-request deadline expired before the work could run (serving:
    /// the request spent its whole budget queued or reading its body, so the
    /// solve was never dispatched).
    Deadline {
        /// The request's total time budget, milliseconds.
        budget_ms: u64,
    },
}

impl fmt::Display for EnetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EnetError::ShapeMismatch { rows, response_len } => write!(
                f,
                "design has {rows} rows but the response has {response_len} entries"
            ),
            EnetError::EmptyDesign { rows, cols } => {
                write!(f, "design must be non-empty, got {rows}×{cols}")
            }
            EnetError::NonFinite { what, index } => {
                write!(f, "{what} contains a non-finite entry at flat index {index}")
            }
            EnetError::InvalidPenalty { lam1, lam2 } => write!(
                f,
                "penalties must be finite, nonnegative and not both zero, \
                 got λ1={lam1} λ2={lam2}"
            ),
            EnetError::InvalidAlpha { alpha } => {
                write!(f, "mixing parameter must satisfy 0 < α ≤ 1, got {alpha}")
            }
            EnetError::InvalidCLambda { c } => {
                write!(f, "c_λ must be positive and finite, got {c}")
            }
            EnetError::InvalidGrid { reason } => write!(f, "invalid c_λ grid: {reason}"),
            EnetError::InvalidTolerance { tol } => {
                write!(f, "tolerance must be positive and finite, got {tol}")
            }
            EnetError::InvalidIterations => write!(f, "iteration cap must be at least 1"),
            EnetError::InvalidFolds { folds, m } => write!(
                f,
                "cv folds must be 0 (disabled) or between 2 and m={m}, got {folds}"
            ),
            EnetError::InvalidDesign { reason } => write!(f, "invalid design data: {reason}"),
            EnetError::PredictShape { expected, got } => write!(
                f,
                "prediction input has {got} features but the fit has {expected}"
            ),
            EnetError::WarmStartShape { expected, got } => write!(
                f,
                "warm start has length {got} but the design has {expected} features"
            ),
            EnetError::Unsupported { what } => write!(f, "unsupported request: {what}"),
            EnetError::Backend(msg) => write!(f, "backend error: {msg}"),
            EnetError::Deadline { budget_ms } => {
                write!(f, "request deadline of {budget_ms} ms exceeded before dispatch")
            }
        }
    }
}

impl std::error::Error for EnetError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_the_offending_values() {
        let e = EnetError::ShapeMismatch { rows: 3, response_len: 4 };
        assert!(format!("{e}").contains('3'));
        assert!(format!("{e}").contains('4'));
        let e = EnetError::InvalidAlpha { alpha: 1.5 };
        assert!(format!("{e}").contains("1.5"));
    }

    #[test]
    fn converts_into_the_crate_error_chain() {
        fn inner() -> crate::util::error::Result<()> {
            Err(EnetError::InvalidIterations)?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("iteration cap"));
    }
}
