//! Typed snapshot of the Newton-workspace reuse counters.
//!
//! [`crate::linalg::WorkspaceStats`] is the raw per-workspace counter block
//! the solver mutates on its hot path; [`StatsSnapshot`] is the *public*,
//! serializable view of it — one struct, one JSON schema — consumed by
//! [`crate::api::Fit::workspace_stats`], the serving layer's `GET /v1/stats`
//! (per-session cache-hit rates), and the serve bench tables. Anything that
//! reports warm-session economics goes through this type rather than poking
//! at counter fields ad hoc, so the schema can only drift in one place.

use crate::linalg::WorkspaceStats;
use crate::util::json::Json;

/// A point-in-time copy of one workspace's cache/reuse counters plus the
/// derived rates every consumer wants (diagnostics only — never consulted by
/// the numerics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Woodbury solves that reused Gram *and* Cholesky outright.
    pub factor_hits: usize,
    /// Woodbury solves that reused the raw Gram but refactored (κ changed).
    pub gram_hits: usize,
    /// Woodbury Gram updates that recomputed only tail rows/columns.
    pub gram_incremental: usize,
    /// Woodbury Grams rebuilt from scratch (sharded).
    pub gram_rebuilds: usize,
    /// Cholesky refactors restarted at a pivot > 0.
    pub partial_refactors: usize,
    /// Columns appended to a cached factor via structural rank-1 update.
    pub rank1_updates: usize,
    /// Columns removed from a cached factor via structural rank-1 downdate.
    pub rank1_downdates: usize,
    /// Edited refactors that lost positive definiteness and fell back cold.
    pub downdate_fallbacks: usize,
    /// Direct solves that reused the cached m×m factor.
    pub direct_hits: usize,
    /// Direct solves that rebuilt V and refactored.
    pub direct_rebuilds: usize,
    /// Newton solves that fell back to CG after a factorization failure.
    pub cg_fallbacks: usize,
    /// Out-of-core panel lookups served from the resident block cache
    /// (always zero for in-core designs).
    pub ooc_cache_hits: usize,
    /// Out-of-core panel lookups that went to disk (read + decode).
    pub ooc_cache_misses: usize,
    /// Encoded bytes streamed from out-of-core design files.
    pub ooc_bytes_read: usize,
}

impl StatsSnapshot {
    /// Total cache-relevant Newton-system events recorded so far.
    pub fn events(&self) -> usize {
        self.factor_hits
            + self.gram_hits
            + self.gram_incremental
            + self.gram_rebuilds
            + self.direct_hits
            + self.direct_rebuilds
    }

    /// Events that reused cached state instead of rebuilding it from scratch
    /// (outright factor hits, Gram-only hits, incremental tail updates,
    /// direct-factor hits).
    pub fn hits(&self) -> usize {
        self.factor_hits + self.gram_hits + self.gram_incremental + self.direct_hits
    }

    /// Cache-hit rate in `[0, 1]` (`0.0` before any event) — the number the
    /// warm-session economics hinge on: a warm refit beats a cold fit
    /// exactly to the extent this stays high.
    pub fn hit_rate(&self) -> f64 {
        let events = self.events();
        if events == 0 {
            0.0
        } else {
            self.hits() as f64 / events as f64
        }
    }

    /// Out-of-core block-cache hit rate in `[0, 1]` (`0.0` for in-core
    /// designs, which never touch the streaming tier).
    pub fn ooc_hit_rate(&self) -> f64 {
        let lookups = self.ooc_cache_hits + self.ooc_cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.ooc_cache_hits as f64 / lookups as f64
        }
    }

    /// The canonical JSON schema (field names mirror the struct; `events`,
    /// `hits`, and `hit_rate` are included so consumers need no arithmetic).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("factor_hits", Json::Num(self.factor_hits as f64)),
            ("gram_hits", Json::Num(self.gram_hits as f64)),
            ("gram_incremental", Json::Num(self.gram_incremental as f64)),
            ("gram_rebuilds", Json::Num(self.gram_rebuilds as f64)),
            ("partial_refactors", Json::Num(self.partial_refactors as f64)),
            ("rank1_updates", Json::Num(self.rank1_updates as f64)),
            ("rank1_downdates", Json::Num(self.rank1_downdates as f64)),
            ("downdate_fallbacks", Json::Num(self.downdate_fallbacks as f64)),
            ("direct_hits", Json::Num(self.direct_hits as f64)),
            ("direct_rebuilds", Json::Num(self.direct_rebuilds as f64)),
            ("cg_fallbacks", Json::Num(self.cg_fallbacks as f64)),
            ("ooc_cache_hits", Json::Num(self.ooc_cache_hits as f64)),
            ("ooc_cache_misses", Json::Num(self.ooc_cache_misses as f64)),
            ("ooc_bytes_read", Json::Num(self.ooc_bytes_read as f64)),
            ("ooc_hit_rate", Json::Num(self.ooc_hit_rate())),
            ("events", Json::Num(self.events() as f64)),
            ("hits", Json::Num(self.hits() as f64)),
            ("hit_rate", Json::Num(self.hit_rate())),
        ])
    }

    /// Parse the schema [`StatsSnapshot::to_json`] writes — the client half
    /// of `GET /v1/stats` (the serve bench reads per-session workspace stats
    /// back through this). Derived fields are ignored; missing or malformed
    /// counters yield `None`.
    pub fn from_json(v: &Json) -> Option<StatsSnapshot> {
        let field = |key: &str| v.get(key).and_then(Json::as_usize);
        Some(StatsSnapshot {
            factor_hits: field("factor_hits")?,
            gram_hits: field("gram_hits")?,
            gram_incremental: field("gram_incremental")?,
            gram_rebuilds: field("gram_rebuilds")?,
            partial_refactors: field("partial_refactors")?,
            rank1_updates: field("rank1_updates")?,
            rank1_downdates: field("rank1_downdates")?,
            downdate_fallbacks: field("downdate_fallbacks")?,
            direct_hits: field("direct_hits")?,
            direct_rebuilds: field("direct_rebuilds")?,
            cg_fallbacks: field("cg_fallbacks")?,
            ooc_cache_hits: field("ooc_cache_hits")?,
            ooc_cache_misses: field("ooc_cache_misses")?,
            ooc_bytes_read: field("ooc_bytes_read")?,
        })
    }
}

impl From<&WorkspaceStats> for StatsSnapshot {
    fn from(ws: &WorkspaceStats) -> Self {
        StatsSnapshot {
            factor_hits: ws.factor_hits,
            gram_hits: ws.gram_hits,
            gram_incremental: ws.gram_incremental,
            gram_rebuilds: ws.gram_rebuilds,
            partial_refactors: ws.partial_refactors,
            rank1_updates: ws.rank1_updates,
            rank1_downdates: ws.rank1_downdates,
            downdate_fallbacks: ws.downdate_fallbacks,
            direct_hits: ws.direct_hits,
            direct_rebuilds: ws.direct_rebuilds,
            cg_fallbacks: ws.cg_fallbacks,
            ooc_cache_hits: ws.ooc_cache_hits,
            ooc_cache_misses: ws.ooc_cache_misses,
            ooc_bytes_read: ws.ooc_bytes_read,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatsSnapshot {
        StatsSnapshot {
            factor_hits: 6,
            gram_hits: 2,
            gram_incremental: 1,
            gram_rebuilds: 3,
            partial_refactors: 1,
            rank1_updates: 2,
            rank1_downdates: 1,
            downdate_fallbacks: 0,
            direct_hits: 0,
            direct_rebuilds: 0,
            cg_fallbacks: 0,
            ooc_cache_hits: 3,
            ooc_cache_misses: 1,
            ooc_bytes_read: 4096,
        }
    }

    #[test]
    fn rates_and_totals() {
        let s = sample();
        assert_eq!(s.events(), 12);
        assert_eq!(s.hits(), 9);
        assert!((s.hit_rate() - 0.75).abs() < 1e-15);
        // The streaming-tier counters are a separate cache: they never feed
        // the Newton-event totals, and carry their own rate.
        assert!((s.ooc_hit_rate() - 0.75).abs() < 1e-15);
        assert_eq!(StatsSnapshot::default().hit_rate(), 0.0);
        assert_eq!(StatsSnapshot::default().ooc_hit_rate(), 0.0);
    }

    #[test]
    fn json_round_trips() {
        let s = sample();
        let j = s.to_json();
        assert_eq!(j.get("hit_rate").and_then(Json::as_f64), Some(s.hit_rate()));
        let parsed = Json::parse(&j.to_string()).expect("snapshot json parses");
        assert_eq!(StatsSnapshot::from_json(&parsed), Some(s));
        assert_eq!(StatsSnapshot::from_json(&Json::Null), None);
    }

    #[test]
    fn mirrors_workspace_counters() {
        let ws = crate::linalg::WorkspaceStats {
            factor_hits: 4,
            gram_rebuilds: 1,
            rank1_updates: 3,
            downdate_fallbacks: 1,
            ooc_cache_hits: 7,
            ooc_bytes_read: 1024,
            ..Default::default()
        };
        let s = StatsSnapshot::from(&ws);
        assert_eq!(s.factor_hits, 4);
        assert_eq!(s.gram_rebuilds, 1);
        assert_eq!(s.rank1_updates, 3);
        assert_eq!(s.downdate_fallbacks, 1);
        assert_eq!(s.ooc_cache_hits, 7);
        assert_eq!(s.ooc_bytes_read, 1024);
        assert_eq!(s.events(), 5);
    }
}
