//! Fitted sessions — the result half of the facade.
//!
//! [`Fit`] is more than a result record: it is a *warm session* bound to one
//! [`Design`]. The Newton workspace that solved the fit (buffer arena +
//! active-set-aware Gram/Cholesky cache, see [`crate::linalg::workspace`])
//! stays alive inside it, so [`Fit::refit`] on a new response reuses every
//! buffer and cached factorization instead of rebuilding them — the
//! serve-many-responses scenario (GWAS permutation tests, online re-scoring)
//! at workspace-cache cost, with results bitwise-identical to a cold fit.

use crate::api::{Design, EnetError, EnetModel, StatsSnapshot};
use crate::linalg::{
    design_fingerprint, DesignFingerprint, DesignRef, NewtonWorkspace, WorkspaceStats,
};
use crate::runtime::PjrtEngine;
use crate::parallel::{
    solve_path_parallel_warm, ChainReport, ParallelPathOptions, ParallelPathResult,
};
use crate::path::{PathPoint, PathResult, WarmState};
use crate::solver::ssnal::SsnalTrace;
use crate::solver::types::SolveResult;
use crate::tuning::{CriteriaPoint, TuningResult};
use crate::util::json::Json;

/// A fitted Elastic Net model: coefficients, diagnostics, prediction, JSON
/// export — plus the warm solver state for repeated solves on the same
/// design.
///
/// ```
/// use ssnal_en::api::{Design, EnetModel};
/// use ssnal_en::linalg::Mat;
///
/// // identity design: the Elastic Net solution is analytic soft-thresholding
/// let a = Mat::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
/// let b = [3.0, -1.0];
/// let design = Design::new(&a, &b)?;
/// let mut fit = EnetModel::new().lambda(0.5, 0.5).tol(1e-10).fit(&design)?;
/// assert!((fit.coefficients()[0] - 5.0 / 3.0).abs() < 1e-8);
///
/// // predictions and a warm refit on a new response, same design
/// let preds = fit.predict(&a)?;
/// assert_eq!(preds.len(), 2);
/// let again = fit.refit(&[1.0, 2.0])?;
/// assert!(again.converged);
/// # Ok::<(), ssnal_en::api::EnetError>(())
/// ```
pub struct Fit<'d> {
    pub(crate) design: &'d Design<'d>,
    pub(crate) model: EnetModel,
    pub(crate) lam1: f64,
    pub(crate) lam2: f64,
    pub(crate) result: SolveResult,
    pub(crate) trace: Option<SsnalTrace>,
    pub(crate) ws: NewtonWorkspace,
    /// Lazily-loaded PJRT engine, kept for the session so repeated solves on
    /// the Pjrt backend do not re-read the artifacts from disk.
    pub(crate) engine: Option<PjrtEngine>,
}

impl<'d> Fit<'d> {
    /// The full coefficient vector x̂ (length n).
    pub fn coefficients(&self) -> &[f64] {
        &self.result.x
    }

    /// Indices of the nonzero coefficients.
    pub fn active_set(&self) -> &[usize] {
        &self.result.active_set
    }

    /// The resolved penalties `(λ1, λ2)` of the latest solve.
    pub fn lambdas(&self) -> (f64, f64) {
        (self.lam1, self.lam2)
    }

    /// The full solver result of the latest solve.
    pub fn result(&self) -> &SolveResult {
        &self.result
    }

    /// Per-iteration SsNAL diagnostics (`None` for baseline algorithms and
    /// the PJRT backend).
    pub fn trace(&self) -> Option<&SsnalTrace> {
        self.trace.as_ref()
    }

    /// The design this session is bound to.
    pub fn design(&self) -> &'d Design<'d> {
        self.design
    }

    /// Workspace cache/reuse counters — how much of the Newton state the
    /// session reused so far, as the typed public snapshot shared with the
    /// serving layer's `GET /v1/stats` (diagnostics only). For out-of-core
    /// designs the block-cache counters live on the shared design handle,
    /// not the workspace, and are overlaid here.
    pub fn workspace_stats(&self) -> StatsSnapshot {
        let mut stats = self.ws.stats;
        stats.overlay_ooc(self.design.design_ref());
        StatsSnapshot::from(&stats)
    }

    /// Consume the session, keeping only the solver result.
    pub fn into_result(self) -> SolveResult {
        self.result
    }

    /// Predict responses for new observations: `ŷ = A_new · x̂` (sparse
    /// mat-vec over the active set). Accepts either storage kind — `&Mat`,
    /// `&CscMat`, or `&DesignStorage` — so a model fit on a sparse CSC
    /// cohort scores sparse held-out data without densifying it; the CSC
    /// mat-vec is bitwise-identical to the dense one.
    pub fn predict<'a>(&self, a_new: impl Into<DesignRef<'a>>) -> Result<Vec<f64>, EnetError> {
        let a_new = a_new.into();
        if a_new.cols() != self.design.n() {
            return Err(EnetError::PredictShape {
                expected: self.design.n(),
                got: a_new.cols(),
            });
        }
        let mut out = vec![0.0; a_new.rows()];
        a_new.mul_vec_support_into(&self.result.x, &self.result.active_set, &mut out);
        Ok(out)
    }

    /// Re-solve on the *same design* with a new response, reusing the
    /// session's warm Newton workspace (buffer arena + Gram/Cholesky cache —
    /// for `(α, c_λ)` models the λ's are re-resolved against the new
    /// response, exactly as a cold fit would).
    ///
    /// The solve itself starts cold (no iterate carry-over), so the result is
    /// **bitwise-identical** to `model.fit(&Design::new(a, b)?)` at every
    /// `SSNAL_THREADS` budget — only the memory behavior differs: buffers and
    /// cached factors are reused instead of reallocated/rebuilt
    /// (`tests/alloc_newton.rs` pins the allocation bound,
    /// `tests/api_facade.rs` the bitwise equality).
    pub fn refit(&mut self, b: &[f64]) -> Result<&SolveResult, EnetError> {
        self.design.check_response(b)?;
        let (lam1, lam2) = self.model.checked_lambdas(self.design.design_ref(), b)?;
        let (result, trace) = self.model.solve_once(
            self.design.design_ref(),
            b,
            lam1,
            lam2,
            None,
            &mut self.engine,
            &mut self.ws,
        )?;
        self.lam1 = lam1;
        self.lam2 = lam2;
        self.result = result;
        self.trace = trace;
        Ok(&self.result)
    }

    /// Re-solve on the same design for a *batch* of responses, amortizing the
    /// λmax resolution: for `(α, c_λ)` models all per-response `λ^max` values
    /// are computed in one fused pass over the design's columns (a running
    /// max per response), which reads `A` once instead of once per response —
    /// bitwise-identical to resolving each response separately, because both
    /// reduce the same `|aⱼᵀb|` column dots through the same in-order max
    /// fold.
    ///
    /// All responses are validated up front (one bad response fails the whole
    /// batch before any solve runs). Solves then run sequentially through the
    /// warm workspace; the session is left at the state of the *last* response
    /// in the batch, exactly as if [`Fit::refit`] had been called in a loop.
    pub fn refit_many<B: AsRef<[f64]>>(&mut self, bs: &[B]) -> Result<Vec<SolveResult>, EnetError> {
        for b in bs {
            self.design.check_response(b.as_ref())?;
        }
        let lambdas = self.model.checked_lambdas_many(self.design.design_ref(), bs)?;
        let mut results = Vec::with_capacity(bs.len());
        for (b, &(lam1, lam2)) in bs.iter().zip(&lambdas) {
            let (result, trace) = self.model.solve_once(
                self.design.design_ref(),
                b.as_ref(),
                lam1,
                lam2,
                None,
                &mut self.engine,
                &mut self.ws,
            )?;
            self.lam1 = lam1;
            self.lam2 = lam2;
            self.result = result;
            self.trace = trace;
            results.push(self.result.clone());
        }
        Ok(results)
    }

    /// Structured export of the latest solve (sparse coefficients: the
    /// `coefficients` array holds the values at `active_set`'s indices).
    pub fn to_json(&self) -> Json {
        solve_json(self.design.m(), self.design.n(), self.lam1, self.lam2, &self.result)
    }

    /// [`Fit::to_json`] rendered as a compact JSON string.
    pub fn export_json(&self) -> String {
        self.to_json().to_string()
    }
}

/// The canonical JSON shape of one solve — shared by [`Fit::to_json`] and the
/// serve handlers so a server response is byte-identical to a direct
/// `Fit::export_json()` on the same solve.
pub(crate) fn solve_json(m: usize, n: usize, lam1: f64, lam2: f64, r: &SolveResult) -> Json {
    Json::obj(vec![
        ("kind", Json::Str("ssnal_en.fit".to_string())),
        ("algorithm", Json::Str(r.algorithm.name().to_string())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("lam1", Json::Num(lam1)),
        ("lam2", Json::Num(lam2)),
        ("converged", Json::Bool(r.converged)),
        ("iterations", Json::Num(r.iterations as f64)),
        ("inner_iterations", Json::Num(r.inner_iterations as f64)),
        ("residual", Json::Num(r.residual)),
        ("objective", Json::Num(r.objective)),
        (
            "active_set",
            Json::Arr(r.active_set.iter().map(|&j| Json::Num(j as f64)).collect()),
        ),
        (
            "coefficients",
            Json::Arr(r.active_set.iter().map(|&j| Json::Num(r.x[j])).collect()),
        ),
    ])
}

/// A solved λ-path with the parallel engine's diagnostics — and, like
/// [`Fit`], a *warm session*: the per-chain Newton workspaces (buffer arenas
/// + rank-1-editable Gram/Cholesky caches) that solved the path stay alive
/// inside it. [`PathFit::refit_path`] re-solves the whole grid for a new
/// response at workspace-cache cost, bitwise-identical to a cold
/// [`EnetModel::fit_path`].
#[derive(Clone, Debug)]
pub struct PathFit {
    pub(crate) result: ParallelPathResult,
    /// The validated engine options the path ran with (reused by refits).
    pub(crate) popts: ParallelPathOptions,
    /// One warm per-chain session per λ-chain, in deterministic chain order.
    pub(crate) sessions: Vec<WarmState>,
    /// Fingerprint of the design the sessions are bound to; a refit against a
    /// different design drops the sessions instead of retargeting them.
    pub(crate) design_fp: DesignFingerprint,
}

impl PathFit {
    /// The assembled path (grid order).
    pub fn path(&self) -> &PathResult {
        &self.result.path
    }

    /// The solved points, in grid order.
    pub fn points(&self) -> &[PathPoint] {
        &self.result.path.points
    }

    /// Grid values actually explored.
    pub fn runs(&self) -> usize {
        self.result.path.runs
    }

    /// Whether the max-active cap truncated the path.
    pub fn truncated(&self) -> bool {
        self.result.path.truncated
    }

    /// `λ^max` used for the parametrization.
    pub fn lambda_max(&self) -> f64 {
        self.result.path.lambda_max
    }

    /// Per-chain engine diagnostics.
    pub fn chains(&self) -> &[ChainReport] {
        &self.result.chains
    }

    /// Worker threads the engine ran with.
    pub fn threads(&self) -> usize {
        self.result.threads
    }

    /// Aggregate workspace cache/reuse counters across every chain session —
    /// the path-scale analogue of [`Fit::workspace_stats`] (diagnostics only).
    pub fn workspace_stats(&self) -> StatsSnapshot {
        let mut total = WorkspaceStats::default();
        for s in &self.sessions {
            total.merge(&s.newton_ws.stats);
        }
        StatsSnapshot::from(&total)
    }

    /// Re-solve the full λ-grid on a (possibly new) design/response, reusing
    /// the session's warm per-chain Newton workspaces — buffer arenas, cached
    /// Grams, and rank-1-editable Cholesky factors survive across refits.
    ///
    /// Per-point numerics start cold (no iterate carry-over), so the result
    /// is **bitwise-identical** to a fresh [`EnetModel::fit_path`] with the
    /// same options at every `SSNAL_THREADS` budget; only the memory behavior
    /// differs. A refit against a design with a different fingerprint drops
    /// the warm sessions first (correct either way — the fingerprint check is
    /// a fast path, not a correctness gate).
    pub fn refit_path(&mut self, design: &Design<'_>) -> &PathResult {
        let fp = design_fingerprint(design.design_ref());
        if fp != self.design_fp {
            self.sessions.clear();
            self.design_fp = fp;
        }
        self.result = solve_path_parallel_warm(
            design.design_ref(),
            design.b(),
            &self.popts,
            &mut self.sessions,
        );
        &self.result.path
    }

    /// Consume into the raw engine result.
    pub fn into_inner(self) -> ParallelPathResult {
        self.result
    }
}

/// A completed tuning sweep (GCV / e-BIC / optional CV per path point).
#[derive(Clone, Debug)]
pub struct TuneFit {
    pub(crate) result: TuningResult,
}

impl TuneFit {
    /// Criteria at every explored grid point.
    pub fn points(&self) -> &[CriteriaPoint] {
        &self.result.points
    }

    /// Index of the GCV optimum.
    pub fn best_gcv(&self) -> usize {
        self.result.best_gcv
    }

    /// Index of the e-BIC optimum.
    pub fn best_ebic(&self) -> usize {
        self.result.best_ebic
    }

    /// Index of the CV optimum (when CV ran).
    pub fn best_cv(&self) -> Option<usize> {
        self.result.best_cv
    }

    /// The underlying path (for coefficient extraction).
    pub fn path(&self) -> &PathResult {
        &self.result.path
    }

    /// Consume into the raw tuning result.
    pub fn into_inner(self) -> TuningResult {
        self.result
    }
}
