//! The estimator facade — the crate's canonical public surface.
//!
//! Three types cover every workload the lower layers implement:
//!
//! * [`Design`] — a validated `(A, b)` pair (owned or borrowed; shape and
//!   finiteness checks return typed [`EnetError`]s instead of panicking),
//! * [`EnetModel`] — a builder collapsing the historical option structs into
//!   one coherent configuration (`.lambda(..)` / `.alpha_c(..)` /
//!   `.grid(..)` / `.algorithm(..)` / `.newton(..)` / `.cv(..)` /
//!   `.threads(..)` / `.backend(..)`),
//! * [`Fit`] — a warm fitted session: coefficients, [`Fit::predict`],
//!   active set, trace, JSON export, and [`Fit::refit`] for repeated solves
//!   on the same design that reuse the Newton workspace and Gram/Cholesky
//!   cache instead of rebuilding them per call.
//!
//! Algorithm dispatch goes through the [`crate::solver::Solver`] trait
//! registry, so all eight algorithms are reachable uniformly
//! ([`EnetModel::algorithm`]); λ-paths and tuning sweeps
//! ([`EnetModel::fit_path`], [`EnetModel::tune`]) run on the parallel engine.
//! The old `Coordinator` survives as a deprecated compatibility shim over
//! this module.
//!
//! ```
//! use ssnal_en::api::{Design, EnetModel};
//! use ssnal_en::data::{generate_synthetic, SyntheticSpec};
//!
//! let prob = generate_synthetic(&SyntheticSpec {
//!     m: 30, n: 90, n0: 4, x_star: 5.0, snr: 8.0, seed: 7,
//! });
//! let design = Design::new(&prob.a, &prob.b)?;
//! let mut fit = EnetModel::new().alpha_c(0.8, 0.4).tol(1e-8).fit(&design)?;
//! assert!(fit.result().converged);
//!
//! // warm session: re-solve the same design against a new response,
//! // reusing the fit's Newton workspace (bitwise-identical to a cold fit)
//! let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
//! let again = fit.refit(&b2)?;
//! assert!(again.converged);
//! # Ok::<(), ssnal_en::api::EnetError>(())
//! ```

pub mod design;
pub mod error;
pub mod fit;
pub mod model;
pub mod stats;

pub use design::Design;
pub use error::EnetError;
pub use fit::{Fit, PathFit, TuneFit};
pub use model::{Backend, EnetModel};
pub use stats::StatsSnapshot;

/// The one α-range rule (0 < α ≤ 1, finite), shared by
/// [`Design::lambda_max`] and the builder's validation so the two surfaces
/// can never disagree on which mixing parameters are valid.
pub(crate) fn check_alpha(alpha: f64) -> Result<(), EnetError> {
    if alpha.is_finite() && alpha > 0.0 && alpha <= 1.0 {
        Ok(())
    } else {
        Err(EnetError::InvalidAlpha { alpha })
    }
}
