//! Validated design/response pairs — the data half of the facade.
//!
//! A [`Design`] is the one object every facade operation consumes: it pins a
//! `(A, b)` pair that has already passed shape and finiteness checks, so the
//! solver layers below can keep their cheap `assert!` contracts while the
//! public surface reports typed [`EnetError`]s. It can borrow caller-owned
//! buffers (zero-copy, the common case) or own them (for designs built on
//! the fly and handed across threads/sessions).

use crate::api::EnetError;
use crate::linalg::Mat;
use crate::solver::types::EnetProblem;

/// Owned-or-borrowed design matrix.
#[derive(Clone, Debug)]
enum DesignMat<'a> {
    Borrowed(&'a Mat),
    Owned(Mat),
}

/// Owned-or-borrowed response vector.
#[derive(Clone, Debug)]
enum ResponseVec<'a> {
    Borrowed(&'a [f64]),
    Owned(Vec<f64>),
}

/// A validated Elastic Net data set: design matrix `A` (m × n, column-major)
/// plus response `b` (length m), shape- and finiteness-checked on
/// construction.
///
/// Construct once, then fit any number of [`crate::api::EnetModel`]
/// configurations against it — a fitted session ([`crate::api::Fit`]) keeps
/// its Newton workspace bound to this design, so repeated solves reuse the
/// Gram/Cholesky cache.
///
/// ```
/// use ssnal_en::api::{Design, EnetError};
/// use ssnal_en::linalg::Mat;
///
/// let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -2.0]);
/// let b = [1.0, 1.0];
/// let design = Design::new(&a, &b)?;
/// assert_eq!((design.m(), design.n()), (2, 3));
///
/// // invalid input is a typed error, not a panic
/// let short = [1.0];
/// assert!(matches!(
///     Design::new(&a, &short),
///     Err(EnetError::ShapeMismatch { .. })
/// ));
/// # Ok::<(), EnetError>(())
/// ```
#[derive(Clone, Debug)]
pub struct Design<'a> {
    a: DesignMat<'a>,
    b: ResponseVec<'a>,
}

impl<'a> Design<'a> {
    /// Borrow a caller-owned `(A, b)` pair (zero-copy).
    pub fn new(a: &'a Mat, b: &'a [f64]) -> Result<Self, EnetError> {
        Self::build(DesignMat::Borrowed(a), ResponseVec::Borrowed(b))
    }

    /// Take ownership of `(A, b)` — for designs constructed on the fly.
    pub fn from_owned(a: Mat, b: Vec<f64>) -> Result<Design<'static>, EnetError> {
        Design::build(DesignMat::Owned(a), ResponseVec::Owned(b))
    }

    fn build(a: DesignMat<'a>, b: ResponseVec<'a>) -> Result<Design<'a>, EnetError> {
        {
            let a_ref = match &a {
                DesignMat::Borrowed(m) => *m,
                DesignMat::Owned(m) => m,
            };
            let b_ref: &[f64] = match &b {
                ResponseVec::Borrowed(v) => v,
                ResponseVec::Owned(v) => v,
            };
            let (rows, cols) = (a_ref.rows(), a_ref.cols());
            if rows == 0 || cols == 0 {
                return Err(EnetError::EmptyDesign { rows, cols });
            }
            if rows != b_ref.len() {
                return Err(EnetError::ShapeMismatch { rows, response_len: b_ref.len() });
            }
            if let Some(index) = a_ref.as_slice().iter().position(|v| !v.is_finite()) {
                return Err(EnetError::NonFinite { what: "design", index });
            }
            if let Some(index) = b_ref.iter().position(|v| !v.is_finite()) {
                return Err(EnetError::NonFinite { what: "response", index });
            }
        }
        Ok(Design { a, b })
    }

    /// The design matrix.
    pub fn a(&self) -> &Mat {
        match &self.a {
            DesignMat::Borrowed(m) => m,
            DesignMat::Owned(m) => m,
        }
    }

    /// The response vector.
    pub fn b(&self) -> &[f64] {
        match &self.b {
            ResponseVec::Borrowed(v) => v,
            ResponseVec::Owned(v) => v,
        }
    }

    /// Observations m.
    pub fn m(&self) -> usize {
        self.a().rows()
    }

    /// Features n.
    pub fn n(&self) -> usize {
        self.a().cols()
    }

    /// `λ^max = ‖Aᵀb‖∞ / α` — the smallest λ scale with an all-zero solution
    /// under the paper's `(α, c_λ)` parametrization.
    pub fn lambda_max(&self, alpha: f64) -> Result<f64, EnetError> {
        crate::api::check_alpha(alpha)?;
        Ok(EnetProblem::lambda_max(self.a(), self.b(), alpha))
    }

    /// A borrowed [`EnetProblem`] view at explicit penalties — the bridge to
    /// the low-level solver entry points. Penalties are the caller's to
    /// validate here; prefer [`crate::api::EnetModel::fit`] for checked
    /// end-to-end solves.
    pub fn problem(&self, lam1: f64, lam2: f64) -> EnetProblem<'_> {
        EnetProblem::new(self.a(), self.b(), lam1, lam2)
    }

    /// Validate a replacement response against this design (shape +
    /// finiteness) — used by [`crate::api::Fit::refit`].
    pub(crate) fn check_response(&self, b: &[f64]) -> Result<(), EnetError> {
        if b.len() != self.m() {
            return Err(EnetError::ShapeMismatch { rows: self.m(), response_len: b.len() });
        }
        if let Some(index) = b.iter().position(|v| !v.is_finite()) {
            return Err(EnetError::NonFinite { what: "response", index });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrowed_and_owned_agree() {
        let a = Mat::from_row_major(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = vec![1.0, -1.0];
        let borrowed = Design::new(&a, &b).unwrap();
        let owned = Design::from_owned(a.clone(), b.clone()).unwrap();
        assert_eq!(borrowed.a().as_slice(), owned.a().as_slice());
        assert_eq!(borrowed.b(), owned.b());
        assert_eq!(borrowed.m(), 2);
        assert_eq!(borrowed.n(), 2);
    }

    #[test]
    fn rejects_bad_shapes_and_values() {
        let a = Mat::zeros(3, 2);
        assert!(matches!(
            Design::new(&a, &[0.0; 4]),
            Err(EnetError::ShapeMismatch { rows: 3, response_len: 4 })
        ));
        let empty = Mat::zeros(0, 2);
        assert!(matches!(Design::new(&empty, &[]), Err(EnetError::EmptyDesign { .. })));
        let mut bad = Mat::zeros(2, 2);
        bad.set(1, 0, f64::NAN);
        assert!(matches!(
            Design::new(&bad, &[0.0; 2]),
            Err(EnetError::NonFinite { what: "design", .. })
        ));
        let ok = Mat::zeros(2, 2);
        assert!(matches!(
            Design::new(&ok, &[0.0, f64::INFINITY]),
            Err(EnetError::NonFinite { what: "response", index: 1 })
        ));
    }

    #[test]
    fn lambda_max_validates_alpha() {
        let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -2.0]);
        let b = [1.0, 1.0];
        let d = Design::new(&a, &b).unwrap();
        assert_eq!(d.lambda_max(1.0).unwrap(), 1.0);
        assert_eq!(d.lambda_max(0.5).unwrap(), 2.0);
        assert!(matches!(d.lambda_max(0.0), Err(EnetError::InvalidAlpha { .. })));
        assert!(matches!(d.lambda_max(1.5), Err(EnetError::InvalidAlpha { .. })));
    }
}
