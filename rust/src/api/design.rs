//! Validated design/response pairs — the data half of the facade.
//!
//! A [`Design`] is the one object every facade operation consumes: it pins a
//! `(A, b)` pair that has already passed shape and finiteness checks, so the
//! solver layers below can keep their cheap `assert!` contracts while the
//! public surface reports typed [`EnetError`]s. It can borrow caller-owned
//! buffers (zero-copy, the common case) or own them (for designs built on
//! the fly and handed across threads/sessions).
//!
//! The design matrix may be **dense** ([`Mat`], column-major), **CSC
//! sparse** ([`CscMat`]), or **out-of-core** ([`OocDesign`], block-streamed
//! from disk through a bounded panel cache) — every solver in the crate
//! dispatches over [`DesignRef`] with bitwise-dense-equal kernels, so the
//! storage choice affects wall-clock time and memory, never the fitted
//! coefficients.

use std::path::Path;

use crate::api::EnetError;
use crate::linalg::{CscMat, DesignRef, DesignStorage, Mat, OocDesign};
use crate::solver::types::EnetProblem;

/// Owned-or-borrowed design matrix, over either storage kind.
#[derive(Clone, Debug)]
enum DesignMat<'a> {
    Borrowed(DesignRef<'a>),
    Owned(DesignStorage),
}

/// Owned-or-borrowed response vector.
#[derive(Clone, Debug)]
enum ResponseVec<'a> {
    Borrowed(&'a [f64]),
    Owned(Vec<f64>),
}

/// A validated Elastic Net data set: design matrix `A` (m × n, dense
/// column-major or CSC sparse) plus response `b` (length m), shape- and
/// finiteness-checked on construction.
///
/// Construct once, then fit any number of [`crate::api::EnetModel`]
/// configurations against it — a fitted session ([`crate::api::Fit`]) keeps
/// its Newton workspace bound to this design, so repeated solves reuse the
/// Gram/Cholesky cache.
///
/// ```
/// use ssnal_en::api::{Design, EnetError};
/// use ssnal_en::linalg::Mat;
///
/// let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -2.0]);
/// let b = [1.0, 1.0];
/// let design = Design::new(&a, &b)?;
/// assert_eq!((design.m(), design.n()), (2, 3));
/// assert!(!design.is_sparse());
///
/// // invalid input is a typed error, not a panic
/// let short = [1.0];
/// assert!(matches!(
///     Design::new(&a, &short),
///     Err(EnetError::ShapeMismatch { .. })
/// ));
/// # Ok::<(), EnetError>(())
/// ```
///
/// Sparse designs fit through the identical surface — same model, same bits:
///
/// ```
/// use ssnal_en::api::{Design, EnetModel};
/// use ssnal_en::linalg::{CscMat, Mat};
///
/// let dense = Mat::from_row_major(3, 2, &[1.0, 0.0, 0.0, 2.0, 3.0, 0.0]);
/// let sparse = CscMat::from_dense(&dense);
/// let b = [1.0, -1.0, 0.5];
/// let model = EnetModel::new().lambda(0.3, 0.2).tol(1e-10);
/// let xd = model.fit(&Design::new(&dense, &b)?)?.coefficients().to_vec();
/// let xs = model.fit(&Design::from_sparse(&sparse, &b)?)?.coefficients().to_vec();
/// assert_eq!(xd, xs); // bitwise-identical coefficients
/// # Ok::<(), ssnal_en::api::EnetError>(())
/// ```
#[derive(Clone, Debug)]
pub struct Design<'a> {
    a: DesignMat<'a>,
    b: ResponseVec<'a>,
}

impl<'a> Design<'a> {
    /// Borrow a caller-owned dense `(A, b)` pair (zero-copy).
    pub fn new(a: &'a Mat, b: &'a [f64]) -> Result<Self, EnetError> {
        Self::build(DesignMat::Borrowed(DesignRef::from(a)), ResponseVec::Borrowed(b))
    }

    /// Borrow a caller-owned CSC-sparse `(A, b)` pair (zero-copy). The GWAS
    /// entry point: raw genotype dosages at low minor-allele frequency are
    /// mostly zeros, and the solve stack's sparse kernels skip them.
    pub fn from_sparse(a: &'a CscMat, b: &'a [f64]) -> Result<Self, EnetError> {
        Self::build(DesignMat::Borrowed(DesignRef::from(a)), ResponseVec::Borrowed(b))
    }

    /// Take ownership of a dense `(A, b)` — for designs constructed on the fly.
    pub fn from_owned(a: Mat, b: Vec<f64>) -> Result<Design<'static>, EnetError> {
        Design::build(DesignMat::Owned(DesignStorage::Dense(a)), ResponseVec::Owned(b))
    }

    /// Take ownership of either storage kind — e.g. the automatically-chosen
    /// output of [`crate::data::snp::generate_sparse`].
    pub fn from_storage(a: DesignStorage, b: Vec<f64>) -> Result<Design<'static>, EnetError> {
        Design::build(DesignMat::Owned(a), ResponseVec::Owned(b))
    }

    /// Open an out-of-core design written by `ssnal-en convert` (or
    /// [`crate::linalg::ooc::OocWriter`]) with the default decoded-panel
    /// cache budget. `b` is still supplied in core — a `Design` couples the
    /// matrix with its response. I/O and format errors surface as
    /// [`EnetError::InvalidDesign`].
    pub fn from_ooc(path: &Path, b: Vec<f64>) -> Result<Design<'static>, EnetError> {
        Design::from_ooc_with_cache(path, b, crate::linalg::ooc::DEFAULT_CACHE_BYTES)
    }

    /// [`Design::from_ooc`] with an explicit cache budget in bytes.
    pub fn from_ooc_with_cache(
        path: &Path,
        b: Vec<f64>,
        cache_bytes: usize,
    ) -> Result<Design<'static>, EnetError> {
        let ooc = OocDesign::open_with_cache(path, cache_bytes).map_err(|e| {
            EnetError::InvalidDesign { reason: format!("{}: {e}", path.display()) }
        })?;
        Design::build(DesignMat::Owned(DesignStorage::OutOfCore(ooc)), ResponseVec::Owned(b))
    }

    fn build(a: DesignMat<'a>, b: ResponseVec<'a>) -> Result<Design<'a>, EnetError> {
        {
            let a_ref = match &a {
                DesignMat::Borrowed(r) => *r,
                DesignMat::Owned(s) => s.as_ref(),
            };
            let b_ref: &[f64] = match &b {
                ResponseVec::Borrowed(v) => v,
                ResponseVec::Owned(v) => v,
            };
            let (rows, cols) = (a_ref.rows(), a_ref.cols());
            if rows == 0 || cols == 0 {
                return Err(EnetError::EmptyDesign { rows, cols });
            }
            if rows != b_ref.len() {
                return Err(EnetError::ShapeMismatch { rows, response_len: b_ref.len() });
            }
            // For sparse storage this scans the stored nonzeros (the implicit
            // zeros are finite by definition); `index` then points into the
            // stored-values slice rather than the dense data. Out-of-core
            // designs expose no in-memory slice — their payloads are either
            // decoded 2-bit dosages (finite by construction) or f64 blocks
            // validated when `convert` densified them, so the scan is a
            // write-time responsibility there.
            if let Some(values) = a_ref.values_slice() {
                if let Some(index) = values.iter().position(|v| !v.is_finite()) {
                    return Err(EnetError::NonFinite { what: "design", index });
                }
            }
            if let Some(index) = b_ref.iter().position(|v| !v.is_finite()) {
                return Err(EnetError::NonFinite { what: "response", index });
            }
        }
        Ok(Design { a, b })
    }

    /// A borrowed view of the design matrix, over either storage kind — the
    /// value every solver entry point consumes.
    pub fn design_ref(&self) -> DesignRef<'_> {
        match &self.a {
            DesignMat::Borrowed(r) => *r,
            DesignMat::Owned(s) => s.as_ref(),
        }
    }

    /// The dense design matrix, if this design is dense.
    pub fn as_dense(&self) -> Option<&Mat> {
        self.design_ref().as_dense()
    }

    /// Whether the design is stored CSC-sparse.
    pub fn is_sparse(&self) -> bool {
        self.design_ref().is_sparse()
    }

    /// Whether the design streams from disk.
    pub fn is_out_of_core(&self) -> bool {
        self.design_ref().is_out_of_core()
    }

    /// The response vector.
    pub fn b(&self) -> &[f64] {
        match &self.b {
            ResponseVec::Borrowed(v) => v,
            ResponseVec::Owned(v) => v,
        }
    }

    /// Observations m.
    pub fn m(&self) -> usize {
        self.design_ref().rows()
    }

    /// Features n.
    pub fn n(&self) -> usize {
        self.design_ref().cols()
    }

    /// `λ^max = ‖Aᵀb‖∞ / α` — the smallest λ scale with an all-zero solution
    /// under the paper's `(α, c_λ)` parametrization.
    pub fn lambda_max(&self, alpha: f64) -> Result<f64, EnetError> {
        crate::api::check_alpha(alpha)?;
        Ok(EnetProblem::lambda_max(self.design_ref(), self.b(), alpha))
    }

    /// A borrowed [`EnetProblem`] view at explicit penalties — the bridge to
    /// the low-level solver entry points. Penalties are the caller's to
    /// validate here; prefer [`crate::api::EnetModel::fit`] for checked
    /// end-to-end solves.
    pub fn problem(&self, lam1: f64, lam2: f64) -> EnetProblem<'_> {
        EnetProblem::new(self.design_ref(), self.b(), lam1, lam2)
    }

    /// Validate a replacement response against this design (shape +
    /// finiteness) — used by [`crate::api::Fit::refit`].
    pub(crate) fn check_response(&self, b: &[f64]) -> Result<(), EnetError> {
        if b.len() != self.m() {
            return Err(EnetError::ShapeMismatch { rows: self.m(), response_len: b.len() });
        }
        if let Some(index) = b.iter().position(|v| !v.is_finite()) {
            return Err(EnetError::NonFinite { what: "response", index });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrowed_and_owned_agree() {
        let a = Mat::from_row_major(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = vec![1.0, -1.0];
        let borrowed = Design::new(&a, &b).unwrap();
        let owned = Design::from_owned(a.clone(), b.clone()).unwrap();
        assert_eq!(
            borrowed.design_ref().values_slice().unwrap(),
            owned.design_ref().values_slice().unwrap()
        );
        assert_eq!(borrowed.b(), owned.b());
        assert_eq!(borrowed.m(), 2);
        assert_eq!(borrowed.n(), 2);
        assert!(!borrowed.is_sparse());
        assert!(borrowed.as_dense().is_some());
    }

    #[test]
    fn sparse_constructors_validate_and_expose_storage() {
        let dense = Mat::from_row_major(3, 2, &[1.0, 0.0, 0.0, 2.0, 3.0, 0.0]);
        let csc = CscMat::from_dense(&dense);
        let b = vec![1.0, -1.0, 0.5];
        let d = Design::from_sparse(&csc, &b).unwrap();
        assert!(d.is_sparse());
        assert!(d.as_dense().is_none());
        assert_eq!((d.m(), d.n()), (3, 2));
        let owned = Design::from_storage(DesignStorage::Sparse(csc.clone()), b.clone()).unwrap();
        assert!(owned.is_sparse());
        // shape mismatch is a typed error on the sparse path too
        assert!(matches!(
            Design::from_sparse(&csc, &[1.0]),
            Err(EnetError::ShapeMismatch { rows: 3, response_len: 1 })
        ));
        // non-finite stored values are caught
        let bad = CscMat::new(2, 1, vec![0, 1], vec![1], vec![f64::NAN]);
        assert!(matches!(
            Design::from_sparse(&bad, &[0.0, 0.0]),
            Err(EnetError::NonFinite { what: "design", index: 0 })
        ));
    }

    #[test]
    fn rejects_bad_shapes_and_values() {
        let a = Mat::zeros(3, 2);
        assert!(matches!(
            Design::new(&a, &[0.0; 4]),
            Err(EnetError::ShapeMismatch { rows: 3, response_len: 4 })
        ));
        let empty = Mat::zeros(0, 2);
        assert!(matches!(Design::new(&empty, &[]), Err(EnetError::EmptyDesign { .. })));
        let mut bad = Mat::zeros(2, 2);
        bad.set(1, 0, f64::NAN);
        assert!(matches!(
            Design::new(&bad, &[0.0; 2]),
            Err(EnetError::NonFinite { what: "design", .. })
        ));
        let ok = Mat::zeros(2, 2);
        assert!(matches!(
            Design::new(&ok, &[0.0, f64::INFINITY]),
            Err(EnetError::NonFinite { what: "response", index: 1 })
        ));
    }

    #[test]
    fn ooc_designs_open_and_validate() {
        let dense = Mat::from_row_major(3, 2, &[1.0, 0.0, 0.0, 2.0, 3.0, 0.0]);
        let mut path = std::env::temp_dir();
        path.push(format!("ssnal_api_design_{}.ooc", std::process::id()));
        crate::linalg::ooc::write_design_f64(&path, DesignRef::from(&dense), 1)
            .expect("write ooc");
        let b = vec![1.0, -1.0, 0.5];
        let d = Design::from_ooc(&path, b.clone()).unwrap();
        assert!(d.is_out_of_core() && !d.is_sparse());
        assert!(d.as_dense().is_none());
        assert!(d.design_ref().values_slice().is_none());
        assert_eq!((d.m(), d.n()), (3, 2));
        for j in 0..2 {
            for i in 0..3 {
                assert_eq!(d.design_ref().get(i, j), dense.get(i, j));
            }
        }
        // shape mismatch is still a typed error
        assert!(matches!(
            Design::from_ooc(&path, vec![1.0]),
            Err(EnetError::ShapeMismatch { rows: 3, response_len: 1 })
        ));
        std::fs::remove_file(&path).ok();
        // a missing or malformed file maps to InvalidDesign
        assert!(matches!(
            Design::from_ooc(&path, b),
            Err(EnetError::InvalidDesign { .. })
        ));
    }

    #[test]
    fn lambda_max_validates_alpha() {
        let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -2.0]);
        let b = [1.0, 1.0];
        let d = Design::new(&a, &b).unwrap();
        assert_eq!(d.lambda_max(1.0).unwrap(), 1.0);
        assert_eq!(d.lambda_max(0.5).unwrap(), 2.0);
        assert!(matches!(d.lambda_max(0.0), Err(EnetError::InvalidAlpha { .. })));
        assert!(matches!(d.lambda_max(1.5), Err(EnetError::InvalidAlpha { .. })));
    }
}
