//! Parameter tuning (paper §3.3): k-fold CV, GCV and e-BIC over a warm-started
//! λ-path, with least-squares de-biasing on the active set.
//!
//! * `gcv(x̂) = rss(x̂)/m / (1 − ν/m)²`
//! * `e-bic(x̂) = log(rss(x̂)/m) + (ν/m)(log m + log n)`
//!
//! where `ν = tr(A_J (A_JᵀA_J + λ2 I)⁻¹ A_Jᵀ)` is the Elastic Net degrees of
//! freedom and the residual sum of squares is computed **after de-biasing**:
//! ordinary least squares refit on the selected features (Belloni et al. 2014).
//!
//! Downstream callers reach tuning through the facade —
//! [`crate::api::EnetModel::tune`] — which validates the grid, folds and
//! tolerances into typed errors before handing them to [`tune_with_threads`].

use crate::linalg::{blas, lstsq, DesignRef, Mat};
use crate::path::{solve_path, PathOptions, PathResult};
use crate::rng::Xoshiro256pp;
use crate::solver::types::{BaselineOptions, EnetProblem, SsnalOptions};
use crate::solver::{cd, ssnal};

/// Tuning criteria evaluated at one path point.
#[derive(Clone, Debug)]
pub struct CriteriaPoint {
    pub c_lambda: f64,
    pub lam1: f64,
    pub lam2: f64,
    /// Active-set size r.
    pub active: usize,
    /// k-fold cross-validation MSE (None if CV was not requested).
    pub cv: Option<f64>,
    /// Generalized cross validation.
    pub gcv: f64,
    /// Extended BIC.
    pub ebic: f64,
    /// De-biased residual sum of squares.
    pub rss: f64,
    /// Degrees of freedom ν.
    pub dof: f64,
}

/// Result of a tuning sweep.
#[derive(Clone, Debug)]
pub struct TuningResult {
    pub points: Vec<CriteriaPoint>,
    /// Index minimizing GCV.
    pub best_gcv: usize,
    /// Index minimizing e-BIC.
    pub best_ebic: usize,
    /// Index minimizing CV (if computed).
    pub best_cv: Option<usize>,
    /// The underlying path (for coefficient extraction).
    pub path: PathResult,
}

/// De-biased residual sum of squares: OLS refit on the active set `idx`.
pub fn debiased_rss<'a>(a: impl Into<DesignRef<'a>>, b: &[f64], idx: &[usize]) -> f64 {
    let a = a.into();
    let m = a.rows();
    if idx.is_empty() {
        return blas::nrm2_sq(b);
    }
    let w = lstsq::ridge_on_support(a, idx, b, 0.0);
    let mut rss = 0.0;
    for i in 0..m {
        let mut pred = 0.0;
        for (k, &j) in idx.iter().enumerate() {
            pred += a.get(i, j) * w[k];
        }
        let d = b[i] - pred;
        rss += d * d;
    }
    rss
}

/// GCV (Eq. 21 left).
pub fn gcv(rss: f64, m: usize, dof: f64) -> f64 {
    let denom = 1.0 - dof / m as f64;
    if denom <= 0.0 {
        return f64::INFINITY;
    }
    rss / m as f64 / (denom * denom)
}

/// e-BIC (Eq. 21 right).
pub fn ebic(rss: f64, m: usize, n: usize, dof: f64) -> f64 {
    let rss = rss.max(1e-300);
    (rss / m as f64).ln() + dof / m as f64 * ((m as f64).ln() + (n as f64).ln())
}

/// Assign each of `m` observations to one of `k` CV folds (shuffled, balanced).
pub fn cv_folds(m: usize, k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2 && k <= m);
    let mut idx: Vec<usize> = (0..m).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let mut fold = vec![0usize; m];
    for (pos, &i) in idx.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Options for a tuning sweep.
#[derive(Clone, Debug)]
pub struct TuningOptions {
    /// Underlying path options (grid, α, max-active cap, algorithm).
    pub path: PathOptions,
    /// Number of CV folds (0 disables CV — it is by far the costliest criterion).
    pub cv_folds: usize,
    /// Seed for fold assignment.
    pub cv_seed: u64,
}

impl Default for TuningOptions {
    fn default() -> Self {
        Self { path: PathOptions::default(), cv_folds: 0, cv_seed: 0 }
    }
}

/// Run the full tuning sweep: solve the path, evaluate GCV/e-BIC (and
/// optionally k-fold CV) at every explored point, fanning the per-point
/// criteria out over the shared persistent worker pool
/// ([`crate::parallel::run_tasks`]) on all available cores.
pub fn tune<'a>(a: impl Into<DesignRef<'a>>, b: &[f64], opts: &TuningOptions) -> TuningResult {
    tune_with_threads(a, b, opts, 0)
}

/// [`tune`] with an explicit worker-thread count (`0` = all available cores,
/// `1` = fully sequential). Criteria for different path points are
/// independent, and each point's work — de-biased RSS, degrees of freedom and
/// the K refits of cross-validation — is computed whole inside one task, so
/// the result is bitwise-identical for every thread count (the paper's CV
/// protocol, §3.3, parallelized across the λ-grid).
pub fn tune_with_threads<'a>(
    a: impl Into<DesignRef<'a>>,
    b: &[f64],
    opts: &TuningOptions,
    num_threads: usize,
) -> TuningResult {
    let a = a.into();
    let path = solve_path(a, b, &opts.path);
    let m = a.rows();
    let n = a.cols();

    // Pre-split folds once so every λ sees the same folds (paper's 10-fold cv).
    let folds =
        if opts.cv_folds >= 2 { Some(cv_folds(m, opts.cv_folds, opts.cv_seed)) } else { None };

    let jobs: Vec<_> = path
        .points
        .iter()
        .map(|pt| {
            let folds = folds.as_ref();
            move || {
                // Criteria tasks are many and small: pin within-solve
                // sharding to one thread so the grid-level fan-out owns the
                // cores (shard results don't depend on the budget anyway).
                crate::parallel::shard::with_threads(1, || {
                    let idx = &pt.result.active_set;
                    let rss = debiased_rss(a, b, idx);
                    let dof = lstsq::enet_degrees_of_freedom(a, idx, pt.lam2);
                    let cv = folds
                        .map(|f| cv_mse(a, b, f, opts.cv_folds, pt.lam1, pt.lam2, &opts.path));
                    CriteriaPoint {
                        c_lambda: pt.c_lambda,
                        lam1: pt.lam1,
                        lam2: pt.lam2,
                        active: idx.len(),
                        cv,
                        gcv: gcv(rss, m, dof),
                        ebic: ebic(rss, m, n, dof),
                        rss,
                        dof,
                    }
                })
            }
        })
        .collect();
    let points = crate::parallel::run_tasks(num_threads, jobs);

    let argmin = |f: &dyn Fn(&CriteriaPoint) -> f64| {
        points
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| f(a).partial_cmp(&f(b)).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    };
    let best_gcv = argmin(&|p: &CriteriaPoint| p.gcv);
    let best_ebic = argmin(&|p: &CriteriaPoint| p.ebic);
    let best_cv =
        folds.as_ref().map(|_| argmin(&|p: &CriteriaPoint| p.cv.unwrap_or(f64::INFINITY)));

    TuningResult { points, best_gcv, best_ebic, best_cv, path }
}

/// k-fold CV mean-squared prediction error at one (λ1, λ2).
fn cv_mse(
    a: DesignRef<'_>,
    b: &[f64],
    fold_of: &[usize],
    k: usize,
    lam1: f64,
    lam2: f64,
    popts: &PathOptions,
) -> f64 {
    let m = a.rows();
    let mut total_sq = 0.0;
    for fold in 0..k {
        let train: Vec<usize> = (0..m).filter(|&i| fold_of[i] != fold).collect();
        let test: Vec<usize> = (0..m).filter(|&i| fold_of[i] == fold).collect();
        if test.is_empty() || train.len() < 2 {
            continue;
        }
        // build the training submatrix (rows) — column-major gather by rows
        let at = Mat::from_fn(train.len(), a.cols(), |i, j| a.get(train[i], j));
        let bt: Vec<f64> = train.iter().map(|&i| b[i]).collect();
        let p = EnetProblem::new(&at, &bt, lam1, lam2);
        let x = match popts.algorithm {
            crate::solver::types::Algorithm::SsnalEn => {
                ssnal::solve(&p, &SsnalOptions { tol: popts.tol, ..Default::default() }).x
            }
            _ => cd::solve_covariance(
                &p,
                &BaselineOptions { tol: popts.tol, ..Default::default() },
            )
            .x,
        };
        for &i in &test {
            let mut pred = 0.0;
            for (j, &xj) in x.iter().enumerate() {
                if xj != 0.0 {
                    pred += a.get(i, j) * xj;
                }
            }
            let d = b[i] - pred;
            total_sq += d * d;
        }
    }
    total_sq / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::path::c_lambda_grid;

    fn problem() -> crate::data::SyntheticProblem {
        generate_synthetic(&SyntheticSpec {
            m: 60,
            n: 150,
            n0: 4,
            x_star: 5.0,
            snr: 20.0,
            seed: 17,
        })
    }

    #[test]
    fn criteria_formulas() {
        // by hand: rss=10, m=100, ν=5 → gcv = 0.1/(0.95²); ebic = ln(0.1)+0.05(ln100+ln1000)
        let g = gcv(10.0, 100, 5.0);
        assert!((g - 0.1 / (0.95 * 0.95)).abs() < 1e-12);
        let e = ebic(10.0, 100, 1000, 5.0);
        let expect = (0.1f64).ln() + 0.05 * ((100f64).ln() + (1000f64).ln());
        assert!((e - expect).abs() < 1e-12);
        // degenerate dof ≥ m → infinite gcv
        assert_eq!(gcv(1.0, 10, 10.0), f64::INFINITY);
    }

    #[test]
    fn folds_are_balanced_and_deterministic() {
        let f1 = cv_folds(103, 10, 5);
        let f2 = cv_folds(103, 10, 5);
        assert_eq!(f1, f2);
        let mut counts = [0usize; 10];
        for &f in &f1 {
            counts[f] += 1;
        }
        let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(mx - mn <= 1, "balanced folds: {counts:?}");
    }

    #[test]
    fn debiased_rss_decreases_with_more_features() {
        let prob = problem();
        let r1 = debiased_rss(&prob.a, &prob.b, &prob.support[..2]);
        let r2 = debiased_rss(&prob.a, &prob.b, &prob.support);
        assert!(r2 <= r1 + 1e-9);
        let r0 = debiased_rss(&prob.a, &prob.b, &[]);
        assert!(r1 <= r0);
    }

    #[test]
    fn tuning_selects_near_truth_support_size() {
        let prob = problem();
        let opts = TuningOptions {
            path: PathOptions {
                alpha: 0.9,
                c_grid: c_lambda_grid(0.95, 0.05, 30),
                max_active: 30,
                tol: 1e-6,
                ..Default::default()
            },
            cv_folds: 0,
            cv_seed: 0,
        };
        let tr = tune(&prob.a, &prob.b, &opts);
        // e-BIC is consistent for sparse truths: selected size near n₀=4
        let chosen = &tr.points[tr.best_ebic];
        assert!(
            (2..=8).contains(&chosen.active),
            "ebic chose active={} (expected ≈4)",
            chosen.active
        );
        // gcv also lands on a sparse model for this high-snr instance
        let g = &tr.points[tr.best_gcv];
        assert!(g.active <= 30);
    }

    #[test]
    fn cv_runs_and_selects_reasonable_model() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 60,
            n0: 3,
            x_star: 5.0,
            snr: 20.0,
            seed: 23,
        });
        let opts = TuningOptions {
            path: PathOptions {
                alpha: 0.9,
                c_grid: c_lambda_grid(0.9, 0.1, 8),
                max_active: 20,
                tol: 1e-5,
                ..Default::default()
            },
            cv_folds: 5,
            cv_seed: 1,
        };
        let tr = tune(&prob.a, &prob.b, &opts);
        let best = tr.best_cv.expect("cv requested");
        let cvs: Vec<f64> = tr.points.iter().map(|p| p.cv.unwrap()).collect();
        assert!(cvs.iter().all(|v| v.is_finite()));
        // chosen point must not have trivially-zero support if signal exists
        assert!(tr.points[best].active > 0);
    }

    #[test]
    fn dof_between_zero_and_r() {
        let prob = problem();
        let opts = TuningOptions {
            path: PathOptions {
                alpha: 0.7,
                c_grid: c_lambda_grid(0.9, 0.2, 10),
                max_active: 0,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        };
        let tr = tune(&prob.a, &prob.b, &opts);
        for p in &tr.points {
            assert!(p.dof >= -1e-9, "dof {}", p.dof);
            assert!(p.dof <= p.active as f64 + 1e-9, "dof {} > r {}", p.dof, p.active);
        }
    }
}
