//! Benchmark harness: measurement utilities plus one runner per paper
//! table/figure (see `tables`). The CLI (`ssnal-en bench-*`) runs full-size
//! versions; `cargo bench` (rust/benches/bench_main.rs) runs scaled-down ones.

pub mod check;
pub mod harness;
pub mod tables;

pub use check::{check_bench, CheckReport};
pub use harness::{measure, measure_once, MeasureConfig};
