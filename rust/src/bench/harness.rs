//! Measurement harness (criterion is unavailable offline).
//!
//! Times closures with optional warmup and repetition, reporting
//! mean ± standard error like the paper's Supplement D.1.

use crate::util::timer::{stats, Stats, Stopwatch};

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct MeasureConfig {
    /// Untimed warmup runs.
    pub warmup: usize,
    /// Timed repetitions.
    pub reps: usize,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        Self { warmup: 0, reps: 1 }
    }
}

/// Time `f` per the config; the closure's output is returned from the last run
/// (and black-boxed so the optimizer cannot elide the work).
pub fn measure<T>(config: MeasureConfig, mut f: impl FnMut() -> T) -> (Stats, T) {
    for _ in 0..config.warmup {
        std::hint::black_box(f());
    }
    assert!(config.reps >= 1);
    let mut samples = Vec::with_capacity(config.reps);
    let mut out = None;
    for _ in 0..config.reps {
        let sw = Stopwatch::new();
        let v = f();
        samples.push(sw.elapsed_s());
        out = Some(std::hint::black_box(v));
    }
    (stats(&samples), out.expect("reps >= 1"))
}

/// Time a single run.
pub fn measure_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let sw = Stopwatch::new();
    let v = f();
    (sw.elapsed_s(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_expected_times() {
        let mut calls = 0;
        let (st, v) = measure(MeasureConfig { warmup: 2, reps: 3 }, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 5);
        assert_eq!(v, 5);
        assert_eq!(st.n, 3);
        assert!(st.mean >= 0.0);
    }

    #[test]
    fn measure_once_returns_value() {
        let (t, v) = measure_once(|| 7);
        assert_eq!(v, 7);
        assert!(t >= 0.0);
    }
}
