//! One runner per table/figure of the paper (see DESIGN.md §3 for the index).
//!
//! Each runner is parameterized by problem sizes so that `cargo bench` can run
//! scaled-down versions while the CLI (`ssnal-en bench-*`) runs the full-size
//! reproductions. All runners print the same row structure as the paper's
//! tables and return the [`Table`] for capture into EXPERIMENTS.md.

use crate::api::{Design, EnetModel};
use crate::bench::harness::{measure, MeasureConfig};
use crate::data::libsvm::ReferenceSet;
use crate::data::snp::{generate as generate_snp, SnpSpec};
use crate::data::{generate_synthetic, rho_hat, standardize, SyntheticSpec};
use crate::linalg::{blas, Mat};
use crate::parallel::Chunking;
use crate::path::{c_lambda_grid, first_reaching_active};
use crate::prox;
use crate::solver::types::{Algorithm, EnetProblem, SsnalOptions};
use crate::solver::{solve_with, ssnal};
use crate::util::json::Json;
use crate::util::table::{fmt_secs, fmt_secs_iters, Table};

/// Find the largest `c_λ` whose solution has ≥ `target` active features
/// (paper: "we select the largest c_λ which gives a solution with n₀ active
/// components"), by walking a descending grid with warm starts
/// ([`EnetModel::sequential`] — bitwise-identical to the single-chain
/// driver).
pub fn c_lambda_for_active(
    a: &Mat,
    b: &[f64],
    alpha: f64,
    target: usize,
    grid_points: usize,
) -> (f64, f64, f64) {
    let design = Design::new(a, b).expect("bench design is valid");
    let path = EnetModel::new()
        .alpha(alpha)
        .grid(0.99, 0.01, grid_points)
        .max_active(target)
        .tol(1e-4) // scouting pass only
        .sequential()
        .fit_path(&design)
        .expect("bench path configuration is valid");
    let idx = first_reaching_active(path.path(), target).unwrap_or(path.points().len() - 1);
    let pt = &path.points()[idx];
    (pt.c_lambda, pt.lam1, pt.lam2)
}

/// Time one `(algorithm, λ)` cell; returns `(seconds, iterations, active)`.
fn time_solver(
    a: &Mat,
    b: &[f64],
    lam1: f64,
    lam2: f64,
    algo: Algorithm,
    tol: f64,
) -> (f64, usize, usize) {
    let p = EnetProblem::new(a, b, lam1, lam2);
    let (stats, res) = measure(MeasureConfig::default(), || solve_with(&p, algo, tol));
    (stats.mean, res.iterations, res.active_set.len())
}

// ---------------------------------------------------------------------------
// Figure 1 — penalty/conjugate/prox curves
// ---------------------------------------------------------------------------

/// Regenerate Figure 1's series on a grid over [−3, 3] with λ1 = λ2 = σ = 1.
/// Returns (header, rows) ready for CSV.
pub fn fig1_series(points: usize) -> (Vec<&'static str>, Vec<Vec<String>>) {
    let (lam1, lam2, sigma) = (1.0, 1.0, 1.0);
    let header = vec![
        "x",
        "lasso_penalty",
        "lasso_conjugate",
        "enet_penalty",
        "enet_conjugate",
        "lasso_prox",
        "lasso_prox_conj",
        "enet_prox",
        "enet_prox_conj",
    ];
    let mut rows = Vec::with_capacity(points);
    for k in 0..points {
        let x = -3.0 + 6.0 * k as f64 / (points - 1) as f64;
        let lasso_pen = lam1 * x.abs();
        let lasso_conj = if x.abs() <= lam1 { 0.0 } else { f64::INFINITY };
        let enet_pen = prox::enet_penalty(&[x], lam1, lam2);
        let enet_conj = prox::enet_conjugate(&[x], lam1, lam2);
        let lasso_prox = prox::soft_threshold(x, sigma * lam1);
        let lasso_prox_conj = if x >= sigma * lam1 {
            lam1
        } else if x <= -sigma * lam1 {
            -lam1
        } else {
            x / sigma
        };
        let enet_prox = prox::prox_enet_scalar(x, sigma, lam1, lam2);
        let enet_prox_conj = prox::prox_enet_conj_scalar(x, sigma, lam1, lam2);
        rows.push(vec![
            format!("{x:.4}"),
            format!("{lasso_pen:.6}"),
            if lasso_conj.is_finite() { format!("{lasso_conj:.6}") } else { "inf".into() },
            format!("{enet_pen:.6}"),
            format!("{enet_conj:.6}"),
            format!("{lasso_prox:.6}"),
            format!("{lasso_prox_conj:.6}"),
            format!("{enet_prox:.6}"),
            format!("{enet_prox_conj:.6}"),
        ]);
    }
    (header, rows)
}

// ---------------------------------------------------------------------------
// Table 1 — CPU time on sim1–3 across n
// ---------------------------------------------------------------------------

/// Table 1: for each scenario (sim1–3) and each n, time the CD baselines
/// (glmnet-like, sklearn-like) and SsNAL-EN at the c_λ giving n₀ active.
pub fn table1(ns: &[usize], m: usize, seed: u64, tol: f64) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "n",
        "rho_hat",
        "cd-cov(glmnet)",
        "cd-naive(sklearn)",
        "ssnal-en",
    ])
    .with_title("Table 1: CPU time (s); ssnal-en shows (outer iterations)");
    for scenario in 1..=3usize {
        let alpha = match scenario {
            1 => 0.6,
            2 => 0.75,
            _ => 0.9,
        };
        for &n in ns {
            let mut spec = SyntheticSpec::sim(scenario, n, seed + n as u64);
            spec.m = m;
            spec.n0 = spec.n0.min(n / 4).max(1);
            let prob = generate_synthetic(&spec);
            let rho = rho_hat(&prob.a, 20, 0);
            let (_c, lam1, lam2) = c_lambda_for_active(&prob.a, &prob.b, alpha, spec.n0, 25);
            let (t_cov, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdCovariance, tol);
            let (t_naive, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdNaive, tol);
            let (t_ssnal, iters, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::SsnalEn, tol);
            t.row(vec![
                format!("sim{scenario}"),
                format!("{n}"),
                format!("{rho:.1}"),
                fmt_secs(t_cov),
                fmt_secs(t_naive),
                fmt_secs_iters(t_ssnal, iters),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table 2 — polynomial-expansion reference datasets
// ---------------------------------------------------------------------------

/// Table 2: synthesized base tables → real polynomial expansion → standardize →
/// time solvers at the c_λ giving r ∈ {20, 5} active, α ∈ {0.8, 0.5}.
/// `max_n` truncates the expansion (0 = the paper's full feature count).
pub fn table2(sets: &[ReferenceSet], max_n: usize, seed: u64, tol: f64) -> Table {
    let mut t = Table::new(&[
        "dataset",
        "m",
        "n",
        "rho_hat",
        "alpha",
        "r",
        "cd-cov(glmnet)",
        "cd-naive(sklearn)",
        "ssnal-en",
    ])
    .with_title("Table 2: CPU time (s) on polynomial-expansion datasets");
    for &set in sets {
        let (name, _, _, order) = set.spec();
        let base = crate::data::libsvm::synthesize_base(set, seed);
        let (clean, _) = crate::data::polyexp::drop_constant_columns(&base.a, 1e-9);
        let (expanded, _) = crate::data::polyexp::expand(&clean, order, max_n);
        let std = standardize(&expanded);
        let (b, _) = crate::data::center(&base.b);
        let rho = rho_hat(&std.a, 20, 0);
        for &alpha in &[0.8, 0.5] {
            for &target_r in &[20usize, 5] {
                let (_c, lam1, lam2) = c_lambda_for_active(&std.a, &b, alpha, target_r, 30);
                let (t_cov, _, _) =
                    time_solver(&std.a, &b, lam1, lam2, Algorithm::CdCovariance, tol);
                let (t_naive, _, _) =
                    time_solver(&std.a, &b, lam1, lam2, Algorithm::CdNaive, tol);
                let (t_ssnal, iters, r_got) =
                    time_solver(&std.a, &b, lam1, lam2, Algorithm::SsnalEn, tol);
                t.row(vec![
                    name.to_string(),
                    format!("{}", std.a.rows()),
                    format!("{}", std.a.cols()),
                    format!("{rho:.0}"),
                    format!("{alpha}"),
                    format!("{r_got}"),
                    fmt_secs(t_cov),
                    fmt_secs(t_naive),
                    fmt_secs_iters(t_ssnal, iters),
                ]);
            }
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Figure 2 + Table 3 — INSIGHT GWAS (simulated cohorts)
// ---------------------------------------------------------------------------

/// Output of the INSIGHT-substitute experiment for one phenotype.
pub struct InsightRun {
    /// Criteria curves: (alpha, c_lambda, active, gcv, ebic, cv?) rows — Fig. 2.
    pub curves: Vec<Vec<String>>,
    /// Selected SNPs at the e-BIC optimum: (snp, de-biased coefficient) — Table 3.
    pub selected: Vec<(String, f64)>,
    /// True causal SNPs (ground truth the paper cannot have).
    pub causal: Vec<String>,
}

/// Column header for [`InsightRun::curves`].
pub const INSIGHT_CURVE_HEADER: [&str; 6] = ["alpha", "c_lambda", "active", "gcv", "ebic", "cv"];

/// Run the GWAS tuning experiment for one simulated cohort.
pub fn insight_run(
    spec: &SnpSpec,
    alphas: &[f64],
    grid_points: usize,
    cv_folds: usize,
) -> InsightRun {
    let cohort = generate_snp(spec);
    let design = Design::new(&cohort.a, &cohort.b).expect("snp design is valid");
    let mut curves = Vec::new();
    let mut best: Option<(f64, Vec<usize>)> = None; // (ebic, active set)
    for &alpha in alphas {
        let tr = EnetModel::new()
            .alpha(alpha)
            .grid(0.99, 0.05, grid_points)
            .max_active(40)
            .tol(1e-5)
            .cv(cv_folds)
            .cv_seed(spec.seed)
            .tune(&design)
            .expect("tuning configuration is valid")
            .into_inner();
        for p in &tr.points {
            curves.push(vec![
                format!("{alpha}"),
                format!("{:.4}", p.c_lambda),
                format!("{}", p.active),
                format!("{:.6}", p.gcv),
                format!("{:.6}", p.ebic),
                p.cv.map(|v| format!("{v:.6}")).unwrap_or_else(|| "NA".into()),
            ]);
        }
        let bp = &tr.points[tr.best_ebic];
        let active = tr.path.points[tr.best_ebic].result.active_set.clone();
        if best.as_ref().map(|(e, _)| bp.ebic < *e).unwrap_or(true) {
            best = Some((bp.ebic, active));
        }
    }
    let (_, active) = best.expect("at least one alpha");
    // de-biased coefficients on the selected set (paper Table 3 reports x̂)
    let coefs = crate::linalg::lstsq::ridge_on_support(&cohort.a, &active, &cohort.b, 0.0);
    let selected: Vec<(String, f64)> = active
        .iter()
        .zip(coefs.iter())
        .map(|(&j, &c)| (cohort.snp_names[j].clone(), c))
        .collect();
    let causal = cohort.causal.iter().map(|&j| cohort.snp_names[j].clone()).collect();
    InsightRun { curves, selected, causal }
}

// ---------------------------------------------------------------------------
// Table D.1 — replication standard errors
// ---------------------------------------------------------------------------

/// Table D.1: mean ± se over `reps` replications of sim1 at fixed c_λ.
pub fn table_d1(ns: &[usize], c_lambdas: &[f64], m: usize, reps: usize, tol: f64) -> Table {
    assert_eq!(ns.len(), c_lambdas.len());
    let title = format!("Table D.1: mean (se) seconds over {reps} replications of sim1");
    let mut t = Table::new(&["n", "c_lambda", "cd-cov(glmnet)", "cd-naive(sklearn)", "ssnal-en"])
        .with_title(&title);
    let alpha = 0.6;
    for (k, &n) in ns.iter().enumerate() {
        let c = c_lambdas[k];
        let mut times: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for rep in 0..reps {
            let mut spec = SyntheticSpec::sim(1, n, 1000 + rep as u64);
            spec.m = m;
            spec.n0 = spec.n0.min(n / 4).max(1);
            let prob = generate_synthetic(&spec);
            let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
            let (lam1, lam2) = EnetProblem::lambdas_from_alpha(alpha, c, lmax);
            for (i, algo) in [Algorithm::CdCovariance, Algorithm::CdNaive, Algorithm::SsnalEn]
                .iter()
                .enumerate()
            {
                let (secs, _, _) = time_solver(&prob.a, &prob.b, lam1, lam2, *algo, tol);
                times[i].push(secs);
            }
        }
        let fmt = |s: &[f64]| {
            let st = crate::util::timer::stats(s);
            format!("{:.3}({:.3})", st.mean, st.se)
        };
        t.row(vec![
            format!("{n}"),
            format!("{c}"),
            fmt(&times[0]),
            fmt(&times[1]),
            fmt(&times[2]),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table D.2 — parameter sweeps (m, snr, α, x*)
// ---------------------------------------------------------------------------

/// Table D.2: one panel per varied parameter; base (n₀=5, m=500, snr=5, α=0.9, x*=5).
pub fn table_d2(ns: &[usize], panels: &[(&str, f64)], tol: f64, seed: u64) -> Table {
    let mut t = Table::new(&["panel", "n", "cd-cov(glmnet)", "cd-naive(sklearn)", "ssnal-en"])
        .with_title("Table D.2: parameter sweeps (base: n0=5, m=500, snr=5, alpha=0.9, x*=5)");
    for &(param, value) in panels {
        for &n in ns {
            let mut m = 500usize;
            let mut snr = 5.0;
            let mut alpha = 0.9;
            let mut x_star = 5.0;
            match param {
                "m" => m = value as usize,
                "snr" => snr = value,
                "alpha" => alpha = value,
                "x*" => x_star = value,
                other => panic!("unknown panel {other}"),
            }
            let spec = SyntheticSpec { m, n, n0: 5.min(n), x_star, snr, seed: seed + n as u64 };
            let prob = generate_synthetic(&spec);
            let (_c, lam1, lam2) = c_lambda_for_active(&prob.a, &prob.b, alpha, spec.n0, 25);
            let (t_cov, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdCovariance, tol);
            let (t_naive, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdNaive, tol);
            let (t_ssnal, iters, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::SsnalEn, tol);
            t.row(vec![
                format!("{param}={value}"),
                format!("{n}"),
                fmt_secs(t_cov),
                fmt_secs(t_naive),
                fmt_secs_iters(t_ssnal, iters),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table D.3 — screening solvers comparison
// ---------------------------------------------------------------------------

/// Table D.3: scenarios × sparsity levels × all solver families, α = 0.999,
/// σ⁰ = 1 ×10 for SsNAL-EN (the paper's screening-study schedule).
pub fn table_d3(
    scenarios: &[(usize, usize, usize)],
    c_lambdas: &[f64],
    tol: f64,
    seed: u64,
) -> Table {
    let mut t = Table::new(&[
        "scenario", "c_lambda", "r", "cd-cov", "gap-safe", "cd-naive", "celer", "ssnal-en",
    ])
    .with_title("Table D.3: CPU time (s) vs screening solvers (alpha=0.999)");
    let alpha = 0.999;
    for &(n, m, n0) in scenarios {
        let spec = SyntheticSpec { m, n, n0, x_star: 5.0, snr: 5.0, seed };
        let prob = generate_synthetic(&spec);
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
        for &c in c_lambdas {
            let (lam1, lam2) = EnetProblem::lambdas_from_alpha(alpha, c, lmax);
            let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
            // SsNAL with the screening-study σ schedule
            let (st, res_ssnal) = measure(MeasureConfig::default(), || {
                ssnal::solve(&p, &SsnalOptions { tol, ..SsnalOptions::screening_sigma() })
            });
            let t_ssnal = st.mean;
            let r = res_ssnal.active_set.len();
            let (t_cov, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdCovariance, tol);
            let (t_gs, _, _) = time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdGapSafe, tol);
            let (t_naive, _, _) =
                time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::CdNaive, tol);
            let (t_celer, _, _) = time_solver(&prob.a, &prob.b, lam1, lam2, Algorithm::Celer, tol);
            t.row(vec![
                format!("n={n},m={m},n0={n0}"),
                format!("{c}"),
                format!("{r}"),
                fmt_secs(t_cov),
                fmt_secs(t_gs),
                fmt_secs(t_naive),
                fmt_secs(t_celer),
                fmt_secs(t_ssnal),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table D.4 — solution-path timing
// ---------------------------------------------------------------------------

/// Table D.4: full warm-started path (log-spaced c_λ from 1 to 0.1, truncated
/// at 100 active), for SsNAL-EN and the CD drivers; the Gap-Safe column is the
/// biglasso stand-in (screened CD per point).
pub fn table_d4(
    ns: &[usize],
    alphas: &[f64],
    m: usize,
    grid_points: usize,
    tol: f64,
    seed: u64,
) -> Table {
    let mut t = Table::new(&[
        "alpha",
        "n",
        "runs",
        "cd-cov(glmnet)",
        "cd-naive(sklearn)",
        "gap-safe(biglasso)",
        "ssnal-en",
    ])
    .with_title("Table D.4: solution-path CPU time (s), truncated at 100 active");
    for &alpha in alphas {
        for &n in ns {
            let mut spec = SyntheticSpec::sim(1, n, seed + n as u64);
            spec.m = m;
            spec.n0 = spec.n0.min(n / 4).max(1);
            let prob = generate_synthetic(&spec);
            let design = Design::new(&prob.a, &prob.b).expect("bench design is valid");
            let grid = c_lambda_grid(1.0, 0.1, grid_points);
            let max_active = 100.min(n / 2);
            // Sequential facade model — bitwise-identical to the single-chain
            // path driver, so the table measures the same work as before.
            let model = |algorithm| {
                EnetModel::new()
                    .alpha(alpha)
                    .c_grid(grid.clone())
                    .max_active(max_active)
                    .tol(tol)
                    .algorithm(algorithm)
                    .sequential()
            };
            let run = |algorithm| {
                model(algorithm).fit_path(&design).expect("bench path configuration is valid")
            };
            let (st_ssnal, path_ssnal) =
                measure(MeasureConfig::default(), || run(Algorithm::SsnalEn));
            let path_ssnal = path_ssnal.into_inner().path;
            let (st_cov, _) = measure(MeasureConfig::default(), || run(Algorithm::CdCovariance));
            let (st_naive, _) = measure(MeasureConfig::default(), || run(Algorithm::CdNaive));
            // gap-safe "path": screened CD per explored grid point (no warm
            // start across points — biglasso-style safe rules recomputed per λ)
            let (st_gs, _) = measure(MeasureConfig::default(), || {
                let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
                let mut count = 0;
                for &c in grid.iter() {
                    let (l1, l2) = EnetProblem::lambdas_from_alpha(alpha, c, lmax);
                    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
                    let r = solve_with(&p, Algorithm::CdGapSafe, tol);
                    count += 1;
                    if r.active_set.len() >= max_active || count >= path_ssnal.runs {
                        break;
                    }
                }
            });
            t.row(vec![
                format!("{alpha}"),
                format!("{n}"),
                format!("{}", path_ssnal.runs),
                fmt_secs(st_cov.mean),
                fmt_secs(st_naive.mean),
                fmt_secs(st_gs.mean),
                fmt_secs(st_ssnal.mean),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_series_shape_and_kinks() {
        let (header, rows) = fig1_series(61);
        assert_eq!(header.len(), 9);
        assert_eq!(rows.len(), 61);
        // at x=0 proxes are 0
        let mid = &rows[30];
        assert_eq!(mid[0], "0.0000");
        assert_eq!(mid[5], "0.000000"); // lasso prox
        assert_eq!(mid[7], "0.000000"); // enet prox
        // at x=3: enet prox = (3−1)/2 = 1, conj prox = (3·1+1)/2 = 2
        let last = rows.last().unwrap();
        assert_eq!(last[7], "1.000000");
        assert_eq!(last[8], "2.000000");
        // lasso conjugate is infinite outside [−1, 1]
        assert_eq!(last[2], "inf");
    }

    #[test]
    fn c_lambda_for_active_hits_target() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 60,
            n: 300,
            n0: 8,
            x_star: 5.0,
            snr: 10.0,
            seed: 5,
        });
        let (c, lam1, lam2) = c_lambda_for_active(&prob.a, &prob.b, 0.8, 8, 25);
        assert!(c > 0.0 && c < 1.0);
        let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
        let res = solve_with(&p, Algorithm::SsnalEn, 1e-6);
        assert!(res.active_set.len() >= 8, "active {}", res.active_set.len());
        assert!(res.active_set.len() <= 24, "not wildly over target");
    }

    #[test]
    fn table1_tiny_runs() {
        let t = table1(&[500], 60, 7, 1e-6);
        assert_eq!(t.len(), 3); // 3 scenarios × 1 n
    }

    #[test]
    fn table_d3_tiny_runs() {
        let t = table_d3(&[(400, 50, 20)], &[0.9, 0.5], 1e-6, 3);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn parallel_bench_rows_tiny() {
        let (t, rows, seq_secs) = parallel_path_rows(300, 40, 6, &[1, 2], 1e-5, 3, true);
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        assert!(seq_secs > 0.0);
        assert!(rows.iter().all(|r| r.runs == 6), "{rows:?}");
        assert!(rows.iter().all(|r| r.max_dist < 1e-2), "{rows:?}");
        let js = parallel_path_json(&rows, 300, 40, 6, seq_secs, true);
        assert!(js.contains("parallel_path"), "{js}");
        assert!(js.contains("rows"), "{js}");
    }

    #[test]
    fn insight_tiny_runs() {
        let spec = SnpSpec {
            m: 60,
            n_snps: 400,
            n_causal: 3,
            dominant_effect: 2.0,
            noise_sd: 0.5,
            seed: 11,
            ..Default::default()
        };
        let run = insight_run(&spec, &[0.9], 10, 0);
        assert!(!run.curves.is_empty());
        assert!(!run.selected.is_empty());
        assert_eq!(run.causal.len(), 3);
        // the dominant causal SNP should be selected
        assert!(
            run.selected.iter().any(|(name, _)| name == &run.causal[0]),
            "dominant SNP not selected: selected={:?} causal={:?}",
            run.selected,
            run.causal
        );
    }
}

// ---------------------------------------------------------------------------
// Parallel λ-path engine — threads vs wall-clock
// ---------------------------------------------------------------------------

/// One measured configuration of the parallel λ-path engine.
#[derive(Clone, Debug)]
pub struct ParallelBenchRow {
    /// Worker threads requested (chains = threads for these rows).
    pub threads: usize,
    /// Wall-clock seconds for the full path.
    pub seconds: f64,
    /// Sequential `path::solve_path` wall-clock divided by `seconds`.
    pub speedup: f64,
    /// Max ‖x_engine − x_seq‖₂ over all path points (solution agreement).
    pub max_dist: f64,
    /// Grid points explored.
    pub runs: usize,
}

/// Measure the parallel λ-path engine against the sequential driver on one
/// synthetic instance: one row per thread count (chains = threads), plus the
/// sequential baseline timing. Returns the printable table and the raw rows
/// (for the `BENCH_*.json` artifact).
pub fn parallel_path_rows(
    n: usize,
    m: usize,
    grid_points: usize,
    threads_list: &[usize],
    tol: f64,
    seed: u64,
    screening: bool,
) -> (Table, Vec<ParallelBenchRow>, f64) {
    let spec = SyntheticSpec {
        m,
        n,
        n0: (n / 100).clamp(5, 50),
        x_star: 5.0,
        snr: 5.0,
        seed,
    };
    let prob = generate_synthetic(&spec);
    let design = Design::new(&prob.a, &prob.b).expect("bench design is valid");
    let base = EnetModel::new().alpha(0.8).grid(0.95, 0.1, grid_points).max_active(0).tol(tol);
    // Sequential baseline through the facade: bitwise-identical to the
    // single-chain `path::solve_path` driver.
    let (st_seq, seq) = measure(MeasureConfig::default(), || {
        base.clone().sequential().fit_path(&design).expect("bench path configuration is valid")
    });
    let seq = seq.into_inner().path;

    let title = format!(
        "Parallel λ-path: {m}×{n}, {grid_points}-point grid, screening={screening} \
         (sequential baseline {:.3}s)",
        st_seq.mean
    );
    let mut t = Table::new(&["threads", "chains", "time(s)", "speedup", "max_dist", "runs"])
        .with_title(&title);
    let mut rows = Vec::with_capacity(threads_list.len());
    for &threads in threads_list {
        let model = base
            .clone()
            .threads(threads.max(1))
            .chunking(Chunking::Chains(threads.max(1)))
            .screening(screening);
        let (st, res) = measure(MeasureConfig::default(), || {
            model.fit_path(&design).expect("bench path configuration is valid")
        });
        let res = res.into_inner();
        let max_dist = res
            .path
            .points
            .iter()
            .zip(seq.points.iter())
            .map(|(p, q)| blas::dist2(&p.result.x, &q.result.x))
            .fold(0.0f64, f64::max);
        let row = ParallelBenchRow {
            threads: threads.max(1),
            seconds: st.mean,
            speedup: st_seq.mean / st.mean.max(1e-12),
            max_dist,
            runs: res.path.runs,
        };
        t.row(vec![
            format!("{}", row.threads),
            format!("{}", row.threads),
            fmt_secs(row.seconds),
            format!("{:.2}x", row.speedup),
            format!("{:.2e}", row.max_dist),
            format!("{}", row.runs),
        ]);
        rows.push(row);
    }
    (t, rows, st_seq.mean)
}

/// Render the parallel-path bench as the JSON payload CI uploads.
pub fn parallel_path_json(
    rows: &[ParallelBenchRow],
    n: usize,
    m: usize,
    grid_points: usize,
    sequential_seconds: f64,
    screening: bool,
) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("seconds", Json::Num(r.seconds)),
                ("speedup", Json::Num(r.speedup)),
                ("max_dist", Json::Num(r.max_dist)),
                ("runs", Json::Num(r.runs as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("parallel_path".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("grid_points", Json::Num(grid_points as f64)),
        ("screening", Json::Bool(screening)),
        ("sequential_seconds", Json::Num(sequential_seconds)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Ablations — design choices called out in DESIGN.md
// ---------------------------------------------------------------------------

/// Ablation A: Newton-system strategy (direct vs Woodbury vs CG vs the cost
/// model's Auto) across sparsity regimes. Validates the §Perf cost model.
pub fn ablation_newton(n: usize, m: usize, tol: f64, seed: u64) -> Table {
    use crate::solver::types::NewtonStrategy;
    let mut t = Table::new(&["c_lambda", "r", "direct", "woodbury", "cg", "auto"])
        .with_title("Ablation A: Newton-system strategy, CPU time (s)");
    let spec = SyntheticSpec { m, n, n0: m / 5, x_star: 5.0, snr: 5.0, seed };
    let prob = generate_synthetic(&spec);
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    for &c in &[0.9, 0.5, 0.2] {
        let (lam1, lam2) = EnetProblem::lambdas_from_alpha(0.8, c, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
        let mut row = vec![format!("{c}")];
        let mut r_seen = 0usize;
        let mut cells = Vec::new();
        for strat in [
            NewtonStrategy::Direct,
            NewtonStrategy::Woodbury,
            NewtonStrategy::ConjugateGradient,
            NewtonStrategy::Auto,
        ] {
            let opts = SsnalOptions {
                tol,
                strategy: strat,
                max_outer: 20,
                max_inner: 40,
                cg_max_iters: 200,
                ..Default::default()
            };
            let (stats, res) = measure(MeasureConfig::default(), || ssnal::solve(&p, &opts));
            r_seen = res.active_set.len();
            cells.push(fmt_secs(stats.mean));
        }
        row.push(format!("{r_seen}"));
        row.extend(cells);
        t.row(row);
    }
    t
}

/// Ablation B: σ schedule sensitivity — the paper's §4.1 remark ("smaller σ⁰
/// needs more iterations; too large σ⁰ fails to converge to the optimum").
pub fn ablation_sigma(n: usize, m: usize, tol: f64, seed: u64) -> Table {
    let mut t = Table::new(&["sigma0", "mult", "time", "outer", "inner", "converged", "obj_gap"])
        .with_title("Ablation B: sigma schedule (paper default: 5e-3, x5)");
    let spec = SyntheticSpec { m, n, n0: 20, x_star: 5.0, snr: 5.0, seed };
    let prob = generate_synthetic(&spec);
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
    // reference objective from the default schedule at tight tolerance
    let reference = ssnal::solve(&p, &SsnalOptions { tol: 1e-10, ..Default::default() });
    for &(s0, mult) in &[
        (5e-5, 5.0),
        (5e-4, 5.0),
        (5e-3, 5.0),
        (5e-2, 5.0),
        (1.0, 10.0),
        (1e2, 10.0),
    ] {
        let opts = SsnalOptions {
            tol,
            sigma0: s0,
            sigma_mult: mult,
            max_outer: 25,
            max_inner: 40,
            cg_max_iters: 200,
            ..Default::default()
        };
        let (stats, res) = measure(MeasureConfig::default(), || ssnal::solve(&p, &opts));
        t.row(vec![
            format!("{s0:.0e}"),
            format!("{mult}"),
            fmt_secs(stats.mean),
            format!("{}", res.iterations),
            format!("{}", res.inner_iterations),
            format!("{}", res.converged),
            format!("{:.2e}", (res.objective - reference.objective).abs()
                / (1.0 + reference.objective)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Within-solve sharded linalg — threads vs wall-clock + SIMD-width audit
// ---------------------------------------------------------------------------

/// One measured thread budget for the within-solve sharded kernels.
#[derive(Clone, Debug)]
pub struct ShardBenchRow {
    /// Shard thread budget ([`crate::parallel::shard::with_threads`]).
    pub threads: usize,
    /// `Aᵀy` dual sweep seconds (the dominant O(mn) kernel).
    pub aty_seconds: f64,
    /// Active-set `A_J u` accumulation seconds.
    pub accum_seconds: f64,
    /// Woodbury Gram build seconds.
    pub gram_seconds: f64,
    /// One full single-λ SSNAL solve, seconds.
    pub ssnal_seconds: f64,
    /// 1-thread SSNAL seconds divided by this row's.
    pub ssnal_speedup: f64,
    /// Whether every kernel output matched the 1-thread run bit for bit.
    pub bitwise_equal: bool,
}

/// Result of the unroll-width audit backing `blas::UNROLL`.
#[derive(Clone, Debug)]
pub struct WidthAudit {
    /// Vector length used.
    pub len: usize,
    /// Seconds for the 4-way dot (`blas::dot4`).
    pub dot4_seconds: f64,
    /// Seconds for the 8-way dot (`blas::dot`).
    pub dot8_seconds: f64,
    /// Seconds for the 4-way axpy (`blas::axpy4`).
    pub axpy4_seconds: f64,
    /// Seconds for the 8-way axpy (`blas::axpy`).
    pub axpy8_seconds: f64,
}

/// Measure the within-solve sharded kernels and a single-λ SSNAL solve at
/// each thread budget, verifying the determinism contract (bitwise equality
/// with the 1-thread run) as it goes. Also runs the SIMD-width audit that
/// justifies `blas::UNROLL = 8`.
pub fn shard_linalg_rows(
    n: usize,
    m: usize,
    threads_list: &[usize],
    tol: f64,
    seed: u64,
) -> (Table, Vec<ShardBenchRow>, WidthAudit) {
    use crate::parallel::shard;

    let spec = SyntheticSpec {
        m,
        n,
        n0: (n / 100).clamp(5, 50),
        x_star: 5.0,
        snr: 5.0,
        seed,
    };
    let prob = generate_synthetic(&spec);
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
    let sopts = SsnalOptions { tol, ..Default::default() };

    // Deterministic kernel operands: a spread-out pseudo active set and a
    // smooth dual vector, so every thread budget times identical work.
    let r = 512.min(n);
    let idx: Vec<usize> = (0..r).map(|k| k * n / r).collect();
    let coeffs: Vec<f64> = (0..r).map(|k| ((k % 7) as f64 - 3.0) * 0.25).collect();
    let y: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.01).sin()).collect();
    let kcfg = MeasureConfig { warmup: 1, reps: 3 };

    // 1-thread reference outputs for the bitwise check.
    let (ref_aty, ref_accum, ref_gram, ref_x) = shard::with_threads(1, || {
        let mut aty = vec![0.0; n];
        shard::t_mul_vec_into(&prob.a, &y, &mut aty);
        let mut accum = vec![0.0; m];
        shard::add_scaled_cols(&prob.a, &idx, &coeffs, &mut accum);
        let gram = shard::gram_of_cols(&prob.a, &idx, 0.5);
        let x = ssnal::solve(&p, &sopts).x;
        (aty, accum, gram, x)
    });

    let title = format!("Within-solve sharding: {m}×{n}, single λ (c=0.3, α=0.8), r_bench={r}");
    let mut t = Table::new(&[
        "threads",
        "aty(s)",
        "accum(s)",
        "gram(s)",
        "ssnal(s)",
        "speedup",
        "bitwise",
    ])
    .with_title(&title);
    let mut rows: Vec<ShardBenchRow> = Vec::with_capacity(threads_list.len());
    for &threads in threads_list {
        let threads = threads.max(1);
        let row = shard::with_threads(threads, || {
            let mut aty = vec![0.0; n];
            let (st_aty, _) = measure(kcfg, || shard::t_mul_vec_into(&prob.a, &y, &mut aty));
            let (st_accum, accum) = measure(kcfg, || {
                let mut accum = vec![0.0; m];
                shard::add_scaled_cols(&prob.a, &idx, &coeffs, &mut accum);
                accum
            });
            let (st_gram, gram) = measure(kcfg, || shard::gram_of_cols(&prob.a, &idx, 0.5));
            let (st_ssnal, res) = measure(MeasureConfig::default(), || ssnal::solve(&p, &sopts));
            let bitwise_equal = aty == ref_aty
                && accum == ref_accum
                && gram.as_slice() == ref_gram.as_slice()
                && res.x == ref_x;
            ShardBenchRow {
                threads,
                aty_seconds: st_aty.mean,
                accum_seconds: st_accum.mean,
                gram_seconds: st_gram.mean,
                ssnal_seconds: st_ssnal.mean,
                ssnal_speedup: 0.0,
                bitwise_equal,
            }
        });
        rows.push(row);
    }
    // Normalize against the 1-thread row wherever it sits in the list.
    let ssnal_base = rows
        .iter()
        .find(|r| r.threads == 1)
        .or_else(|| rows.first())
        .map(|r| r.ssnal_seconds)
        .unwrap_or(0.0);
    for row in rows.iter_mut() {
        row.ssnal_speedup = ssnal_base / row.ssnal_seconds.max(1e-12);
        t.row(vec![
            format!("{}", row.threads),
            fmt_secs(row.aty_seconds),
            fmt_secs(row.accum_seconds),
            fmt_secs(row.gram_seconds),
            fmt_secs(row.ssnal_seconds),
            format!("{:.2}x", row.ssnal_speedup),
            format!("{}", row.bitwise_equal),
        ]);
    }

    // SIMD-width audit: 4-way vs 8-way dot on a cache-spilling vector.
    let audit_len = 1 << 21;
    let va: Vec<f64> = (0..audit_len).map(|i| ((i % 83) as f64) * 0.03 - 1.0).collect();
    let vb: Vec<f64> = (0..audit_len).map(|i| ((i % 97) as f64) * 0.02 - 0.9).collect();
    let acfg = MeasureConfig { warmup: 2, reps: 5 };
    let (st4, _) = measure(acfg, || blas::dot4(&va, &vb));
    let (st8, _) = measure(acfg, || blas::dot(&va, &vb));
    let mut vy = vb.clone();
    let (sa4, _) = measure(acfg, || blas::axpy4(1e-9, &va, &mut vy));
    let (sa8, _) = measure(acfg, || blas::axpy(1e-9, &va, &mut vy));
    let audit = WidthAudit {
        len: audit_len,
        dot4_seconds: st4.mean,
        dot8_seconds: st8.mean,
        axpy4_seconds: sa4.mean,
        axpy8_seconds: sa8.mean,
    };

    (t, rows, audit)
}

/// Render the shard-linalg bench as the JSON payload CI uploads
/// (`BENCH_shard_linalg.json`).
pub fn shard_linalg_json(
    rows: &[ShardBenchRow],
    audit: &WidthAudit,
    n: usize,
    m: usize,
) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("aty_seconds", Json::Num(r.aty_seconds)),
                ("accum_seconds", Json::Num(r.accum_seconds)),
                ("gram_seconds", Json::Num(r.gram_seconds)),
                ("ssnal_seconds", Json::Num(r.ssnal_seconds)),
                ("ssnal_speedup", Json::Num(r.ssnal_speedup)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("shard_linalg".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        (
            "width_audit",
            Json::obj(vec![
                ("len", Json::Num(audit.len as f64)),
                ("dot4_seconds", Json::Num(audit.dot4_seconds)),
                ("dot8_seconds", Json::Num(audit.dot8_seconds)),
                ("axpy4_seconds", Json::Num(audit.axpy4_seconds)),
                ("axpy8_seconds", Json::Num(audit.axpy8_seconds)),
            ]),
        ),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Persistent-pool dispatch overhead — warm pool vs scoped spawn-per-call
// ---------------------------------------------------------------------------

/// One measured thread budget of the dispatch-overhead comparison.
#[derive(Clone, Debug)]
pub struct PoolDispatchRow {
    /// Thread budget handed to both execution strategies (≥ 2; budget 1
    /// dispatches inline under either strategy).
    pub threads: usize,
    /// Jobs per `run_tasks` call (one per participant slot).
    pub jobs: usize,
    /// Mean seconds per call dispatched through the persistent pool.
    pub pool_seconds_per_call: f64,
    /// Mean seconds per call with scoped spawn-per-call workers.
    pub scoped_seconds_per_call: f64,
    /// `scoped / pool` (> 1 means the pool dispatches cheaper).
    pub dispatch_speedup: f64,
    /// Whether a sharded kernel routed through the warm pool reproduced the
    /// 1-thread bits, on a first call and again on a repeat (warm-reuse) call.
    pub bitwise_equal: bool,
}

/// Measure per-call dispatch overhead of the persistent pool against the
/// scoped spawn-per-call baseline (`parallel::pool::run_tasks_scoped`): each
/// row times `calls` batches of `threads` trivial jobs under both strategies,
/// then verifies the warm pool's determinism on a sharded dot product.
pub fn pool_dispatch_rows(calls: usize, threads_list: &[usize]) -> (Table, Vec<PoolDispatchRow>) {
    use crate::parallel::{pool, shard};

    let calls = calls.max(1);
    // Deterministic operands for the bitwise leg: a dot big enough to fan
    // out under its forced plan.
    let va: Vec<f64> = (0..4001).map(|i| ((i % 89) as f64) * 0.021 - 0.9).collect();
    let vb: Vec<f64> = (0..4001).map(|i| ((i % 71) as f64) * 0.017 - 0.6).collect();
    let plan = shard::Plan::with_shards(8);
    let reference = shard::with_threads(1, || shard::dot_planned(plan, &va, &vb));

    let title = format!("Persistent-pool dispatch: {calls} calls/row of `threads` trivial jobs");
    let mut t = Table::new(&[
        "threads",
        "jobs/call",
        "pool(s/call)",
        "scoped(s/call)",
        "speedup",
        "bitwise",
    ])
    .with_title(&title);
    let cfg = MeasureConfig { warmup: 1, reps: 3 };
    let mut rows = Vec::with_capacity(threads_list.len());
    for &threads in threads_list {
        let threads = threads.max(2);
        let mk_jobs = || (0..threads).map(|k| move || (k as f64).sqrt()).collect::<Vec<_>>();
        let (st_pool, _) = measure(cfg, || {
            for _ in 0..calls {
                std::hint::black_box(pool::run_tasks(threads, mk_jobs()));
            }
        });
        let (st_scoped, _) = measure(cfg, || {
            for _ in 0..calls {
                std::hint::black_box(pool::run_tasks_scoped(threads, mk_jobs()));
            }
        });
        let first = shard::with_threads(threads, || shard::dot_planned(plan, &va, &vb));
        let warm = shard::with_threads(threads, || shard::dot_planned(plan, &va, &vb));
        let bitwise_equal =
            first.to_bits() == reference.to_bits() && warm.to_bits() == reference.to_bits();
        let row = PoolDispatchRow {
            threads,
            jobs: threads,
            pool_seconds_per_call: st_pool.mean / calls as f64,
            scoped_seconds_per_call: st_scoped.mean / calls as f64,
            dispatch_speedup: st_scoped.mean / st_pool.mean.max(1e-12),
            bitwise_equal,
        };
        t.row(vec![
            format!("{}", row.threads),
            format!("{}", row.jobs),
            format!("{:.2e}", row.pool_seconds_per_call),
            format!("{:.2e}", row.scoped_seconds_per_call),
            format!("{:.2}x", row.dispatch_speedup),
            format!("{}", row.bitwise_equal),
        ]);
        rows.push(row);
    }
    (t, rows)
}

/// Render the pool-dispatch bench as the JSON payload CI uploads
/// (`BENCH_pool_dispatch.json`).
pub fn pool_dispatch_json(rows: &[PoolDispatchRow], calls: usize) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("jobs", Json::Num(r.jobs as f64)),
                ("pool_seconds_per_call", Json::Num(r.pool_seconds_per_call)),
                ("scoped_seconds_per_call", Json::Num(r.scoped_seconds_per_call)),
                ("dispatch_speedup", Json::Num(r.dispatch_speedup)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pool_dispatch".to_string())),
        ("calls", Json::Num(calls as f64)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Newton workspace — cold vs warm buffers, cached vs cold factorization
// ---------------------------------------------------------------------------

/// One measured `(m, n, r, strategy)` cell of the Newton-workspace bench.
#[derive(Clone, Debug)]
pub struct NewtonBenchRow {
    /// Rows of the design (the Newton system is m×m).
    pub m: usize,
    /// Columns of the design.
    pub n: usize,
    /// Active-set size.
    pub r: usize,
    /// `"direct"`, `"woodbury"` or `"cg"`.
    pub strategy: &'static str,
    /// Seconds per solve with a fresh workspace every call (build + factor
    /// from scratch — the pre-workspace behavior).
    pub cold_seconds: f64,
    /// Seconds per solve on one warmed workspace (same active set and κ:
    /// the factorization-cache hit path).
    pub warm_seconds: f64,
    /// `cold / warm` (> 1 means the warm path is cheaper).
    pub warm_speedup: f64,
    /// Steady-state heap allocations per warm solve, measured at a 1-thread
    /// shard budget (0 when the counting allocator is installed and the
    /// zero-allocation contract holds; trivially 0 when it is not installed,
    /// e.g. in `cargo test` of the library).
    pub allocs_per_iter: f64,
    /// Whether the warm solve reproduced the cold solve bit for bit.
    pub bitwise_equal: bool,
}

/// Measure cold-vs-warm Newton solves per strategy at each `(m, n, r)` size:
/// the warm rows exercise the workspace's factorization cache (same `J` and
/// κ each call), the cold rows rebuild everything, and an allocation-counter
/// pass pins the warm path's steady-state allocations at a 1-thread budget.
pub fn newton_workspace_rows(
    sizes: &[(usize, usize, usize)],
    reps: usize,
) -> (Table, Vec<NewtonBenchRow>) {
    use crate::linalg::NewtonWorkspace;
    use crate::parallel::shard;
    use crate::rng::Xoshiro256pp;
    use crate::solver::ssn_system::solve_newton_system_ws;
    use crate::solver::types::NewtonStrategy;

    let mut t = Table::new(&[
        "m", "n", "r", "strategy", "cold(s)", "warm(s)", "speedup", "allocs/iter", "bitwise",
    ])
    .with_title("Newton workspace: cold vs warm (cached J, κ) per strategy");
    let cfg = MeasureConfig { warmup: 1, reps: reps.max(1) };
    let kappa = 0.7;
    let alloc_iters = 16u64;

    let mut rows = Vec::new();
    for &(m, n, r) in sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(2020 + (m + n + r) as u64);
        let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
        let active: Vec<usize> = (0..r.min(n)).map(|k| k * n / r.min(n).max(1)).collect();
        let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
        for (strategy, name) in [
            (NewtonStrategy::Direct, "direct"),
            (NewtonStrategy::Woodbury, "woodbury"),
            (NewtonStrategy::ConjugateGradient, "cg"),
        ] {
            let solve = |ws: &mut NewtonWorkspace, d: &mut [f64]| {
                solve_newton_system_ws(&a, &active, kappa, &rhs, d, strategy, 1e-10, 500, ws);
            };
            // Each timed sample batches several solves so µs-scale cache-hit
            // calls are not jitter-dominated.
            let batch = 4;
            // cold: fresh workspace per call (build + factor every time)
            let mut d_cold = vec![0.0; m];
            let (st_cold, _) = measure(cfg, || {
                for _ in 0..batch {
                    let mut ws = NewtonWorkspace::new();
                    solve(&mut ws, &mut d_cold);
                }
            });
            // warm: one workspace, warmed once, then cache-hit solves
            let mut ws = NewtonWorkspace::new();
            let mut d_warm = vec![0.0; m];
            solve(&mut ws, &mut d_warm);
            let (st_warm, _) = measure(cfg, || {
                for _ in 0..batch {
                    solve(&mut ws, &mut d_warm);
                }
            });
            let bitwise_equal = d_warm == d_cold;
            // steady-state allocations per warm solve at a 1-thread budget
            let allocs_per_iter = shard::with_threads(1, || {
                let mut ws1 = NewtonWorkspace::new();
                solve(&mut ws1, &mut d_warm); // warm-up: grow every buffer
                solve(&mut ws1, &mut d_warm);
                let before = crate::util::alloc_count::allocations();
                for _ in 0..alloc_iters {
                    solve(&mut ws1, &mut d_warm);
                }
                (crate::util::alloc_count::allocations() - before) as f64 / alloc_iters as f64
            });
            let row = NewtonBenchRow {
                m,
                n,
                r: active.len(),
                strategy: name,
                cold_seconds: st_cold.mean / batch as f64,
                warm_seconds: st_warm.mean / batch as f64,
                warm_speedup: st_cold.mean / st_warm.mean.max(1e-12),
                allocs_per_iter,
                bitwise_equal,
            };
            t.row(vec![
                format!("{m}"),
                format!("{n}"),
                format!("{}", row.r),
                name.to_string(),
                fmt_secs(row.cold_seconds),
                fmt_secs(row.warm_seconds),
                format!("{:.2}x", row.warm_speedup),
                format!("{:.2}", row.allocs_per_iter),
                format!("{}", row.bitwise_equal),
            ]);
            rows.push(row);
        }
    }
    (t, rows)
}

/// Render the Newton-workspace bench as the JSON payload CI uploads
/// (`BENCH_newton_workspace.json`).
pub fn newton_workspace_json(rows: &[NewtonBenchRow], reps: usize) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("m", Json::Num(r.m as f64)),
                ("n", Json::Num(r.n as f64)),
                ("r", Json::Num(r.r as f64)),
                ("strategy", Json::Str(r.strategy.to_string())),
                ("cold_seconds", Json::Num(r.cold_seconds)),
                ("warm_seconds", Json::Num(r.warm_seconds)),
                ("warm_speedup", Json::Num(r.warm_speedup)),
                ("allocs_per_iter", Json::Num(r.allocs_per_iter)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("newton_workspace".to_string())),
        ("reps", Json::Num(reps as f64)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Warm λ-chain: cold vs pivot-refactor vs rank-1 up/down-dates (ISSUE 9)
// ---------------------------------------------------------------------------

/// One strategy's measurement of the warm λ-chain comparison: the same
/// active-set schedule (suffix growth + periodic interior swaps, the shape
/// screened λ-chains actually produce) solved three ways — cold workspace
/// per point, warm workspace with the rank-1 edit tier disabled (prefix
/// incremental + pivot refactor only), and warm with the full structural
/// rank-1 up/down-date tier.
#[derive(Clone, Debug)]
pub struct WarmPathBenchRow {
    /// Rows of the design.
    pub m: usize,
    /// Columns of the design.
    pub n: usize,
    /// λ points in the chain schedule.
    pub points: usize,
    /// Active-set size at the end of the chain.
    pub r_final: usize,
    /// Newton strategy (`direct` or `woodbury`).
    pub strategy: &'static str,
    /// Whole-chain seconds, fresh workspace per point.
    pub cold_seconds: f64,
    /// Whole-chain seconds, warm workspace, rank-1 edit tier disabled.
    pub pivot_seconds: f64,
    /// Whole-chain seconds, warm workspace, rank-1 edit tier enabled.
    pub rank1_seconds: f64,
    /// `cold / rank1` (> 1 means the edit tier beats cold).
    pub rank1_vs_cold: f64,
    /// `pivot / rank1` (> 1 means the edit tier beats pivot-refactor).
    pub rank1_vs_pivot: f64,
    /// Columns appended through the rank-1 tier over one chain pass.
    pub rank1_updates: usize,
    /// Columns removed through the rank-1 tier over one chain pass.
    pub rank1_downdates: usize,
    /// Edited refactors that lost PD and fell back cold (must be 0 here).
    pub downdate_fallbacks: usize,
    /// Steady-state heap allocations per chain point at a 1-thread budget
    /// (0 when the counting allocator is installed and the contract holds).
    pub allocs_per_point: f64,
    /// Whether both warm modes reproduced the cold chain bit for bit, at
    /// thread budgets 1, 2 and 4.
    pub bitwise_equal: bool,
}

/// Build the λ-chain-like active-set schedule: mostly suffix growth (+2
/// columns per step), with every third step swapping one interior column at
/// ~3/5 of the set. Growth uses even column indices, swaps move an even
/// entry to its odd successor, so the sets stay strictly ascending.
fn warm_chain_sets(n: usize, r0: usize, points: usize) -> Vec<Vec<usize>> {
    let mut sets = Vec::with_capacity(points.max(1));
    let mut cur: Vec<usize> = (0..r0).map(|k| 2 * k).collect();
    sets.push(cur.clone());
    for step in 1..points {
        if step % 3 == 0 {
            let pos = cur.len() * 3 / 5;
            let next = cur.get(pos + 1).copied().unwrap_or(n);
            if cur[pos] % 2 == 0 && cur[pos] + 1 < next {
                cur[pos] += 1; // even → unused odd: one remove + one insert
            }
        } else {
            let last = *cur.last().unwrap();
            assert!(last + 4 < n, "chain schedule outgrew the design: raise n");
            cur.push(last + 2);
            cur.push(last + 4);
        }
        sets.push(cur.clone());
    }
    sets
}

/// Measure the warm λ-chain three ways per factor-cache strategy (see
/// [`WarmPathBenchRow`]), verifying as it goes that both warm modes
/// reproduce the cold chain bit for bit at thread budgets 1, 2 and 4, and
/// that the rank-1 warm chain allocates nothing in steady state.
pub fn warm_path_rows(
    m: usize,
    n: usize,
    r0: usize,
    points: usize,
    reps: usize,
) -> (Table, Vec<WarmPathBenchRow>) {
    use crate::linalg::NewtonWorkspace;
    use crate::parallel::shard;
    use crate::rng::Xoshiro256pp;
    use crate::solver::ssn_system::solve_newton_system_ws;
    use crate::solver::types::NewtonStrategy;

    let mut rng = Xoshiro256pp::seed_from_u64(909 + (m + n) as u64);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    let sets = warm_chain_sets(n, r0, points);
    let r_final = sets.last().map_or(0, Vec::len);
    let kappa = 0.7;
    let cfg = MeasureConfig { warmup: 1, reps: reps.max(1) };

    let mut t = Table::new(&[
        "m",
        "n",
        "points",
        "r final",
        "strategy",
        "cold(s)",
        "pivot(s)",
        "rank1(s)",
        "vs pivot",
        "vs cold",
        "up/down",
        "allocs/pt",
        "bitwise",
    ])
    .with_title("Warm λ-chain: cold vs pivot-refactor vs rank-1 up/down-dates");

    let mut rows = Vec::new();
    for (strategy, name) in
        [(NewtonStrategy::Direct, "direct"), (NewtonStrategy::Woodbury, "woodbury")]
    {
        let solve = |ws: &mut NewtonWorkspace, active: &[usize], d: &mut [f64]| {
            solve_newton_system_ws(&a, active, kappa, &rhs, d, strategy, 1e-10, 500, ws);
        };
        // One warm chain pass per mode at budgets 1/2/4, checked against the
        // cold 1-thread reference bit for bit; counters from the rank-1 pass.
        let cold_ref: Vec<Vec<f64>> = shard::with_threads(1, || {
            sets.iter()
                .map(|active| {
                    let mut ws = NewtonWorkspace::new();
                    let mut d = vec![0.0; m];
                    solve(&mut ws, active, &mut d);
                    d
                })
                .collect()
        });
        let warm_chain = |budget: usize, rank1: bool| {
            shard::with_threads(budget, || {
                let mut ws = NewtonWorkspace::new();
                ws.rank1_enabled = rank1;
                let mut out = Vec::with_capacity(sets.len());
                for active in &sets {
                    let mut d = vec![0.0; m];
                    solve(&mut ws, active, &mut d);
                    out.push(d);
                }
                (out, ws.stats)
            })
        };
        let (rank1_out, stats) = warm_chain(1, true);
        let (pivot_out, _) = warm_chain(1, false);
        let mut bitwise_equal = rank1_out == cold_ref && pivot_out == cold_ref;
        for budget in [2usize, 4] {
            bitwise_equal &= warm_chain(budget, true).0 == cold_ref;
            bitwise_equal &= warm_chain(budget, false).0 == cold_ref;
        }

        // Timings: whole-chain wall clock per mode (one reusable d buffer).
        let mut d = vec![0.0; m];
        let (st_cold, _) = measure(cfg, || {
            for active in &sets {
                let mut ws = NewtonWorkspace::new();
                solve(&mut ws, active, &mut d);
            }
        });
        let mut ws_pivot = NewtonWorkspace::new();
        ws_pivot.rank1_enabled = false;
        let (st_pivot, _) = measure(cfg, || {
            for active in &sets {
                solve(&mut ws_pivot, active, &mut d);
            }
        });
        let mut ws_rank1 = NewtonWorkspace::new();
        let (st_rank1, _) = measure(cfg, || {
            for active in &sets {
                solve(&mut ws_rank1, active, &mut d);
            }
        });

        // Steady-state allocations per chain point at a 1-thread budget: one
        // full pass ratchets every buffer, the second pass must be free.
        let allocs_per_point = shard::with_threads(1, || {
            let mut ws = NewtonWorkspace::new();
            let mut d1 = vec![0.0; m];
            for active in &sets {
                solve(&mut ws, active, &mut d1);
            }
            let before = crate::util::alloc_count::allocations();
            for active in &sets {
                solve(&mut ws, active, &mut d1);
            }
            (crate::util::alloc_count::allocations() - before) as f64 / sets.len() as f64
        });

        let row = WarmPathBenchRow {
            m,
            n,
            points: sets.len(),
            r_final,
            strategy: name,
            cold_seconds: st_cold.mean,
            pivot_seconds: st_pivot.mean,
            rank1_seconds: st_rank1.mean,
            rank1_vs_cold: st_cold.mean / st_rank1.mean.max(1e-12),
            rank1_vs_pivot: st_pivot.mean / st_rank1.mean.max(1e-12),
            rank1_updates: stats.rank1_updates,
            rank1_downdates: stats.rank1_downdates,
            downdate_fallbacks: stats.downdate_fallbacks,
            allocs_per_point,
            bitwise_equal,
        };
        t.row(vec![
            format!("{m}"),
            format!("{n}"),
            format!("{}", row.points),
            format!("{r_final}"),
            name.to_string(),
            fmt_secs(row.cold_seconds),
            fmt_secs(row.pivot_seconds),
            fmt_secs(row.rank1_seconds),
            format!("{:.2}x", row.rank1_vs_pivot),
            format!("{:.2}x", row.rank1_vs_cold),
            format!("{}/{}", row.rank1_updates, row.rank1_downdates),
            format!("{:.2}", row.allocs_per_point),
            format!("{}", row.bitwise_equal),
        ]);
        rows.push(row);
    }
    (t, rows)
}

/// Render the warm λ-chain bench as the JSON payload CI uploads
/// (`BENCH_warm_path.json`). Rows carry no `threads` key, so the baseline
/// diff matches them by index — keep the strategy order stable.
pub fn warm_path_json(rows: &[WarmPathBenchRow], reps: usize) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("m", Json::Num(r.m as f64)),
                ("n", Json::Num(r.n as f64)),
                ("points", Json::Num(r.points as f64)),
                ("r_final", Json::Num(r.r_final as f64)),
                ("strategy", Json::Str(r.strategy.to_string())),
                ("cold_seconds", Json::Num(r.cold_seconds)),
                ("pivot_seconds", Json::Num(r.pivot_seconds)),
                ("rank1_seconds", Json::Num(r.rank1_seconds)),
                ("rank1_vs_cold", Json::Num(r.rank1_vs_cold)),
                ("rank1_vs_pivot", Json::Num(r.rank1_vs_pivot)),
                ("rank1_updates", Json::Num(r.rank1_updates as f64)),
                ("rank1_downdates", Json::Num(r.rank1_downdates as f64)),
                ("downdate_fallbacks", Json::Num(r.downdate_fallbacks as f64)),
                ("allocs_per_point", Json::Num(r.allocs_per_point)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("warm_path".to_string())),
        ("reps", Json::Num(reps as f64)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Sparse CSC design storage — GWAS-style sweeps, sparse vs dense
// ---------------------------------------------------------------------------

/// One measured thread budget of the sparse-vs-dense storage comparison: the
/// same rare-variant cohort held as a dense [`Mat`] and as a
/// [`crate::linalg::CscMat`], timed through the `Aᵀy` sweep, the Gap-Safe
/// screening sweep, and a full single-λ SSNAL solve.
#[derive(Clone, Debug)]
pub struct SparseDesignRow {
    /// Within-solve shard thread budget.
    pub threads: usize,
    /// Sharded `Aᵀy` over the dense copy, seconds.
    pub dense_aty_seconds: f64,
    /// Sharded `Aᵀy` over the CSC copy, seconds.
    pub sparse_aty_seconds: f64,
    /// `dense / sparse` (> 1 means CSC is cheaper).
    pub aty_speedup: f64,
    /// Gap-Safe survivor sweep over the dense copy, seconds.
    pub dense_screen_seconds: f64,
    /// Gap-Safe survivor sweep over the CSC copy, seconds.
    pub sparse_screen_seconds: f64,
    /// `dense / sparse` for the screening sweep.
    pub screen_speedup: f64,
    /// Full single-λ SSNAL solve on the dense copy, seconds.
    pub dense_ssnal_seconds: f64,
    /// Full single-λ SSNAL solve on the CSC copy, seconds.
    pub sparse_ssnal_seconds: f64,
    /// `dense / sparse` for the full solve.
    pub ssnal_speedup: f64,
    /// Whether every sparse output (and the multi-thread dense ones)
    /// reproduced the 1-thread dense reference bit for bit.
    pub bitwise_equal: bool,
}

/// Measure the storage dispatch on a GWAS-style rare-variant cohort
/// ([`crate::data::snp::generate_sparse`], ~6% density at the default MAF
/// range): dense vs CSC `Aᵀy`, Gap-Safe screening, and a full SSNAL solve at
/// each thread budget, verifying bitwise storage- and thread-invariance
/// against the 1-thread dense run as it goes. Returns the table, the rows,
/// and the cohort's stored-entry density.
pub fn sparse_design_rows(
    n_snps: usize,
    m: usize,
    threads_list: &[usize],
    tol: f64,
    seed: u64,
) -> (Table, Vec<SparseDesignRow>, f64) {
    use crate::data::snp::{generate_sparse, SnpSpec, SparseSnpSpec};
    use crate::linalg::{CscMat, DesignStorage};
    use crate::parallel::shard;
    use crate::solver::screening::AugmentedView;

    let cohort = generate_sparse(&SparseSnpSpec {
        base: SnpSpec {
            m,
            n_snps,
            n_causal: (n_snps / 500).clamp(3, 20),
            seed,
            ..Default::default()
        },
        ..Default::default()
    });
    let density = cohort.density;
    let sp = match cohort.a {
        DesignStorage::Sparse(sp) => sp,
        DesignStorage::Dense(dm) => CscMat::from_dense(&dm),
        // The generator only produces in-core storage.
        DesignStorage::OutOfCore(_) => unreachable!("generate_sparse is in-core"),
    };
    let dense = sp.to_dense();
    let b = cohort.b;

    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);
    let pd = EnetProblem::new(&dense, &b, lam1, lam2);
    let ps = EnetProblem::new(&sp, &b, lam1, lam2);
    let sopts = SsnalOptions { tol, ..Default::default() };

    // Deterministic operands: a smooth dual vector for Aᵀy and a crude
    // keep-the-strongest-scores iterate for the screening sweep.
    let y: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.01).sin()).collect();
    let aty0 = pd.a.t_mul_vec(&b);
    let x_screen: Vec<f64> =
        aty0.iter().map(|&v| if v.abs() > 0.5 * lmax { 0.1 * v } else { 0.0 }).collect();
    let aug_d = AugmentedView::new(&pd);
    let aug_s = AugmentedView::new(&ps);
    let kcfg = MeasureConfig { warmup: 1, reps: 3 };

    // 1-thread dense reference outputs: the bitwise bar every
    // (storage, threads) combination must clear.
    let (ref_aty, ref_surv, ref_x) = shard::with_threads(1, || {
        let mut aty = vec![0.0; n_snps];
        shard::t_mul_vec_into(&dense, &y, &mut aty);
        let surv = aug_d.gap_safe_survivors(&x_screen);
        let x = ssnal::solve(&pd, &sopts).x;
        (aty, surv, x)
    });

    let title = format!(
        "CSC sparse vs dense design: {m}×{n_snps} GWAS dosages, density {:.1}%",
        density * 100.0
    );
    let mut t = Table::new(&[
        "threads",
        "aty dn(s)",
        "aty sp(s)",
        "speedup",
        "screen dn(s)",
        "screen sp(s)",
        "speedup",
        "ssnal dn(s)",
        "ssnal sp(s)",
        "speedup",
        "bitwise",
    ])
    .with_title(&title);
    let mut rows: Vec<SparseDesignRow> = Vec::with_capacity(threads_list.len());
    for &threads in threads_list {
        let threads = threads.max(1);
        let row = shard::with_threads(threads, || {
            let mut aty_d = vec![0.0; n_snps];
            let (sda, _) = measure(kcfg, || shard::t_mul_vec_into(&dense, &y, &mut aty_d));
            let mut aty_s = vec![0.0; n_snps];
            let (ssa, _) = measure(kcfg, || shard::t_mul_vec_into(&sp, &y, &mut aty_s));
            let (sds, surv_d) = measure(kcfg, || aug_d.gap_safe_survivors(&x_screen));
            let (sss, surv_s) = measure(kcfg, || aug_s.gap_safe_survivors(&x_screen));
            let (sdn, res_d) = measure(MeasureConfig::default(), || ssnal::solve(&pd, &sopts));
            let (ssn, res_s) = measure(MeasureConfig::default(), || ssnal::solve(&ps, &sopts));
            let bitwise_equal = aty_d == ref_aty
                && aty_s == ref_aty
                && surv_d == ref_surv
                && surv_s == ref_surv
                && res_d.x == ref_x
                && res_s.x == ref_x;
            SparseDesignRow {
                threads,
                dense_aty_seconds: sda.mean,
                sparse_aty_seconds: ssa.mean,
                aty_speedup: sda.mean / ssa.mean.max(1e-12),
                dense_screen_seconds: sds.mean,
                sparse_screen_seconds: sss.mean,
                screen_speedup: sds.mean / sss.mean.max(1e-12),
                dense_ssnal_seconds: sdn.mean,
                sparse_ssnal_seconds: ssn.mean,
                ssnal_speedup: sdn.mean / ssn.mean.max(1e-12),
                bitwise_equal,
            }
        });
        t.row(vec![
            format!("{}", row.threads),
            fmt_secs(row.dense_aty_seconds),
            fmt_secs(row.sparse_aty_seconds),
            format!("{:.2}x", row.aty_speedup),
            fmt_secs(row.dense_screen_seconds),
            fmt_secs(row.sparse_screen_seconds),
            format!("{:.2}x", row.screen_speedup),
            fmt_secs(row.dense_ssnal_seconds),
            fmt_secs(row.sparse_ssnal_seconds),
            format!("{:.2}x", row.ssnal_speedup),
            format!("{}", row.bitwise_equal),
        ]);
        rows.push(row);
    }
    (t, rows, density)
}

/// Render the sparse-design bench as the JSON payload CI uploads
/// (`BENCH_sparse_design.json`).
pub fn sparse_design_json(rows: &[SparseDesignRow], n: usize, m: usize, density: f64) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("dense_aty_seconds", Json::Num(r.dense_aty_seconds)),
                ("sparse_aty_seconds", Json::Num(r.sparse_aty_seconds)),
                ("aty_speedup", Json::Num(r.aty_speedup)),
                ("dense_screen_seconds", Json::Num(r.dense_screen_seconds)),
                ("sparse_screen_seconds", Json::Num(r.sparse_screen_seconds)),
                ("screen_speedup", Json::Num(r.screen_speedup)),
                ("dense_ssnal_seconds", Json::Num(r.dense_ssnal_seconds)),
                ("sparse_ssnal_seconds", Json::Num(r.sparse_ssnal_seconds)),
                ("ssnal_speedup", Json::Num(r.ssnal_speedup)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("sparse_design".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("density", Json::Num(density)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Out-of-core design storage — streamed column blocks vs in-core
// ---------------------------------------------------------------------------

/// One measured thread budget of the out-of-core storage comparison: the
/// same rare-variant cohort held as an in-core dense [`Mat`] and streamed
/// from a 2-bit [`crate::linalg::OocDesign`] file at two decoded-panel cache
/// budgets, timed through the `Aᵀy` sweep, the Gap-Safe screening sweep, and
/// a full single-λ SSNAL solve.
#[derive(Clone, Debug)]
pub struct OocDesignRow {
    /// Within-solve shard thread budget.
    pub threads: usize,
    /// Sharded `Aᵀy` over the in-core dense copy, seconds.
    pub dense_aty_seconds: f64,
    /// Sharded `Aᵀy` streamed at the large budget with an empty cache
    /// (every panel read + decoded), seconds — a single timed pass.
    pub ooc_cold_aty_seconds: f64,
    /// Sharded `Aᵀy` streamed at the large budget with the cache warm,
    /// seconds.
    pub ooc_warm_aty_seconds: f64,
    /// Cache hit rate of the small-budget cold sweep.
    pub small_hit_rate: f64,
    /// Encoded MiB read from disk by the small-budget cold sweep.
    pub small_mib_read: f64,
    /// Cache hit rate across the large-budget cold + warm sweeps.
    pub large_hit_rate: f64,
    /// Encoded MiB read from disk across the large-budget cold + warm
    /// sweeps.
    pub large_mib_read: f64,
    /// Gap-Safe survivor sweep over the dense copy, seconds.
    pub dense_screen_seconds: f64,
    /// Gap-Safe survivor sweep streamed at the small budget, seconds.
    pub ooc_screen_seconds: f64,
    /// Full single-λ SSNAL solve on the dense copy, seconds.
    pub dense_ssnal_seconds: f64,
    /// Full single-λ SSNAL solve streamed at the small budget, seconds.
    pub ooc_ssnal_seconds: f64,
    /// Whether every streamed output (both budgets, cold and warm) and the
    /// multi-thread dense ones reproduced the 1-thread dense reference bit
    /// for bit.
    pub bitwise_equal: bool,
    /// Whether `resident_bytes() <= cache_budget()` held on both handles
    /// after every sweep.
    pub cache_within_budget: bool,
    /// Whether the large-budget warm sweep was strictly cheaper than the
    /// cold pass (the margin is the whole file read + decode).
    pub warm_cheaper_than_cold: bool,
}

/// Measure the out-of-core storage tier on a GWAS-style rare-variant cohort:
/// the raw {0,1,2} dosages written once as a 2-bit block file, then streamed
/// back through the same sharded kernels as the in-core dense copy at a
/// small (heavy-eviction) and a large (fully-resident) decoded-panel cache
/// budget, verifying bitwise storage-, budget-, and thread-invariance
/// against the 1-thread dense run as it goes. Returns the table, the rows,
/// and the cohort's stored-entry density.
pub fn ooc_design_rows(
    n_snps: usize,
    m: usize,
    threads_list: &[usize],
    small_cache_bytes: usize,
    large_cache_bytes: usize,
    tol: f64,
    seed: u64,
) -> (Table, Vec<OocDesignRow>, f64) {
    use crate::data::snp::{generate_sparse, SparseSnpSpec};
    use crate::linalg::{ooc, CscMat, DesignStorage, OocDesign};
    use crate::parallel::shard;
    use crate::solver::screening::AugmentedView;
    use crate::util::timer::time_it;

    let cohort = generate_sparse(&SparseSnpSpec {
        base: SnpSpec {
            m,
            n_snps,
            n_causal: (n_snps / 500).clamp(3, 20),
            seed,
            ..Default::default()
        },
        ..Default::default()
    });
    let density = cohort.density;
    let sp = match cohort.a {
        DesignStorage::Sparse(sp) => sp,
        DesignStorage::Dense(dm) => CscMat::from_dense(&dm),
        // The generator only produces in-core storage.
        DesignStorage::OutOfCore(_) => unreachable!("generate_sparse is in-core"),
    };
    let dense = sp.to_dense();
    let b = cohort.b;

    // Write the cohort once as a 2-bit block file (raw dosages are exactly
    // 2-bit-codable), then open it at both cache budgets.
    let path = std::env::temp_dir()
        .join(format!("ssnal_bench_ooc_{}_{seed}.ooc", std::process::id()));
    ooc::write_design_plink2bit(&path, (&dense).into(), ooc::DEFAULT_BLOCK_COLS, 0.0)
        .expect("bench ooc file is writable");
    let ooc_small = OocDesign::open_with_cache(&path, small_cache_bytes)
        .expect("bench ooc file opens");
    let ooc_large = OocDesign::open_with_cache(&path, large_cache_bytes)
        .expect("bench ooc file opens");

    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);
    let pd = EnetProblem::new(&dense, &b, lam1, lam2);
    let po = EnetProblem::new(&ooc_small, &b, lam1, lam2);
    let sopts = SsnalOptions { tol, ..Default::default() };

    // Deterministic operands, shared with the sparse-design bench: a smooth
    // dual vector for Aᵀy and a crude strongest-scores iterate for the
    // screening sweep.
    let y: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.01).sin()).collect();
    let aty0 = pd.a.t_mul_vec(&b);
    let x_screen: Vec<f64> =
        aty0.iter().map(|&v| if v.abs() > 0.5 * lmax { 0.1 * v } else { 0.0 }).collect();
    let aug_d = AugmentedView::new(&pd);
    let aug_o = AugmentedView::new(&po);
    let kcfg = MeasureConfig { warmup: 1, reps: 3 };

    // 1-thread dense reference outputs: the bitwise bar every
    // (storage, budget, threads) combination must clear.
    let (ref_aty, ref_surv, ref_x) = shard::with_threads(1, || {
        let mut aty = vec![0.0; n_snps];
        shard::t_mul_vec_into(&dense, &y, &mut aty);
        let surv = aug_d.gap_safe_survivors(&x_screen);
        let x = ssnal::solve(&pd, &sopts).x;
        (aty, surv, x)
    });

    let title = format!(
        "out-of-core vs in-core design: {m}×{n_snps} GWAS dosages, 2-bit file, \
         cache {}/{} MiB",
        small_cache_bytes >> 20,
        large_cache_bytes >> 20
    );
    let mut t = Table::new(&[
        "threads",
        "aty dn(s)",
        "aty cold(s)",
        "aty warm(s)",
        "hit% sm",
        "hit% lg",
        "screen dn(s)",
        "screen ooc(s)",
        "ssnal dn(s)",
        "ssnal ooc(s)",
        "bitwise",
        "in-budget",
    ])
    .with_title(&title);
    let mut rows: Vec<OocDesignRow> = Vec::with_capacity(threads_list.len());
    for &threads in threads_list {
        let threads = threads.max(1);
        let row = shard::with_threads(threads, || {
            let mut aty_d = vec![0.0; n_snps];
            let (sda, _) = measure(kcfg, || shard::t_mul_vec_into(&dense, &y, &mut aty_d));

            // Large budget: one cold pass on an emptied cache, then the
            // warm steady state (measure()'s warmup fills the cache).
            ooc_large.evict_all();
            ooc_large.reset_counters();
            let mut aty_cold = vec![0.0; n_snps];
            let (_, cold_secs) =
                time_it(|| shard::t_mul_vec_into(&ooc_large, &y, &mut aty_cold));
            let mut aty_lg = vec![0.0; n_snps];
            let (swa, _) = measure(kcfg, || shard::t_mul_vec_into(&ooc_large, &y, &mut aty_lg));
            let lc = ooc_large.counters();
            let mut within = ooc_large.resident_bytes() <= ooc_large.cache_budget();

            // Small budget: a cold pass under heavy eviction pressure.
            ooc_small.evict_all();
            ooc_small.reset_counters();
            let mut aty_sm = vec![0.0; n_snps];
            shard::t_mul_vec_into(&ooc_small, &y, &mut aty_sm);
            let sc = ooc_small.counters();
            within &= ooc_small.resident_bytes() <= ooc_small.cache_budget();

            let (sds, surv_d) = measure(kcfg, || aug_d.gap_safe_survivors(&x_screen));
            let (sos, surv_o) = measure(kcfg, || aug_o.gap_safe_survivors(&x_screen));
            let (sdn, res_d) = measure(MeasureConfig::default(), || ssnal::solve(&pd, &sopts));
            let (son, res_o) = measure(MeasureConfig::default(), || ssnal::solve(&po, &sopts));
            within &= ooc_small.resident_bytes() <= ooc_small.cache_budget();

            let hit_rate = |c: &crate::linalg::OocCounters| {
                let total = c.cache_hits + c.cache_misses;
                if total == 0 {
                    0.0
                } else {
                    c.cache_hits as f64 / total as f64
                }
            };
            let bitwise_equal = aty_d == ref_aty
                && aty_cold == ref_aty
                && aty_lg == ref_aty
                && aty_sm == ref_aty
                && surv_d == ref_surv
                && surv_o == ref_surv
                && res_d.x == ref_x
                && res_o.x == ref_x;
            OocDesignRow {
                threads,
                dense_aty_seconds: sda.mean,
                ooc_cold_aty_seconds: cold_secs,
                ooc_warm_aty_seconds: swa.mean,
                small_hit_rate: hit_rate(&sc),
                small_mib_read: sc.bytes_read as f64 / (1 << 20) as f64,
                large_hit_rate: hit_rate(&lc),
                large_mib_read: lc.bytes_read as f64 / (1 << 20) as f64,
                dense_screen_seconds: sds.mean,
                ooc_screen_seconds: sos.mean,
                dense_ssnal_seconds: sdn.mean,
                ooc_ssnal_seconds: son.mean,
                bitwise_equal,
                cache_within_budget: within,
                warm_cheaper_than_cold: swa.mean < cold_secs,
            }
        });
        t.row(vec![
            format!("{}", row.threads),
            fmt_secs(row.dense_aty_seconds),
            fmt_secs(row.ooc_cold_aty_seconds),
            fmt_secs(row.ooc_warm_aty_seconds),
            format!("{:.0}%", row.small_hit_rate * 100.0),
            format!("{:.0}%", row.large_hit_rate * 100.0),
            fmt_secs(row.dense_screen_seconds),
            fmt_secs(row.ooc_screen_seconds),
            fmt_secs(row.dense_ssnal_seconds),
            fmt_secs(row.ooc_ssnal_seconds),
            format!("{}", row.bitwise_equal),
            format!("{}", row.cache_within_budget),
        ]);
        rows.push(row);
    }
    let _ = std::fs::remove_file(&path);
    (t, rows, density)
}

/// Render the out-of-core design bench as the JSON payload CI uploads
/// (`BENCH_ooc_design.json`).
pub fn ooc_design_json(
    rows: &[OocDesignRow],
    n: usize,
    m: usize,
    density: f64,
    small_cache_bytes: usize,
    large_cache_bytes: usize,
) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("dense_aty_seconds", Json::Num(r.dense_aty_seconds)),
                ("ooc_cold_aty_seconds", Json::Num(r.ooc_cold_aty_seconds)),
                ("ooc_warm_aty_seconds", Json::Num(r.ooc_warm_aty_seconds)),
                ("small_hit_rate", Json::Num(r.small_hit_rate)),
                ("small_mib_read", Json::Num(r.small_mib_read)),
                ("large_hit_rate", Json::Num(r.large_hit_rate)),
                ("large_mib_read", Json::Num(r.large_mib_read)),
                ("dense_screen_seconds", Json::Num(r.dense_screen_seconds)),
                ("ooc_screen_seconds", Json::Num(r.ooc_screen_seconds)),
                ("dense_ssnal_seconds", Json::Num(r.dense_ssnal_seconds)),
                ("ooc_ssnal_seconds", Json::Num(r.ooc_ssnal_seconds)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
                ("cache_within_budget", Json::Bool(r.cache_within_budget)),
                ("warm_cheaper_than_cold", Json::Bool(r.warm_cheaper_than_cold)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("ooc_design".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("density", Json::Num(density)),
        ("small_cache_bytes", Json::Num(small_cache_bytes as f64)),
        ("large_cache_bytes", Json::Num(large_cache_bytes as f64)),
        ("rows", Json::Arr(row_objs)),
    ])
    .to_string()
}

/// One concurrency level of the serve bench: N keep-alive clients hammering
/// one warm session with refit requests, each response checked byte-for-byte
/// against the direct `api::Fit` call it must equal.
#[derive(Clone, Debug)]
pub struct ServeBenchRow {
    /// Concurrent keep-alive clients.
    pub clients: usize,
    /// Total requests this row served (`clients × requests_per_client`).
    pub requests: usize,
    /// Median request latency, seconds.
    pub p50_seconds: f64,
    /// 95th-percentile request latency, seconds.
    pub p95_seconds: f64,
    /// Wall-clock for the whole row, seconds.
    pub total_seconds: f64,
    /// Whether every response (this row's and the cold/warm prelude's) was
    /// byte-identical to the direct `api::` call on the same solve.
    pub bitwise_equal: bool,
}

/// Value at quantile `q` of an ascending-sorted latency list (nearest rank).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Measure the serve front end on an in-process server (ephemeral port):
/// register one synthetic design, time a cold `/v1/fit` (session creation +
/// solve from scratch) against warm `/v1/refit`s on the same response (full
/// Gram/Cholesky-cache hits), then sweep concurrency levels where every
/// client refits on its own deterministic response and every response byte
/// is compared against a precomputed direct [`crate::api::Fit`] call.
///
/// Returns the table, the per-concurrency rows, and the
/// `(cold_fit_seconds, warm_refit_seconds)` pair the caller gates on.
pub fn serve_bench_rows(
    n: usize,
    m: usize,
    clients_list: &[usize],
    requests_per_client: usize,
    tol: f64,
    seed: u64,
) -> (Table, Vec<ServeBenchRow>, f64, f64) {
    use crate::serve::{Client, Server, ServerConfig};
    use crate::util::timer::time_it;

    let requests_per_client = requests_per_client.max(1);
    let prob = generate_synthetic(&SyntheticSpec {
        m,
        n,
        n0: (n / 100).clamp(2, 10),
        x_star: 5.0,
        snr: 5.0,
        seed,
    });
    // Response i is the base response rotated by i — deterministic, shape-
    // preserving, and i = 0 is the stored response itself (so the warm-refit
    // prelude re-solves the exact cold-fit problem through the factor cache).
    let response = |i: usize| -> Vec<f64> { (0..m).map(|k| prob.b[(k + i) % m]).collect() };

    // Direct-api reference: the byte strings every server response must equal.
    let design = Design::new(&prob.a, &prob.b).expect("serve bench design is valid");
    let model = EnetModel::new().alpha_c(0.8, 0.5).tol(tol);
    let mut reference = model.fit(&design).expect("serve bench reference fit");
    let expected_fit = reference.export_json();
    let max_requests =
        clients_list.iter().map(|&c| c.max(1)).max().unwrap_or(1) * requests_per_client;
    let mut expected = Vec::with_capacity(max_requests);
    for i in 0..max_requests {
        reference.refit(&response(i)).expect("serve bench reference refit");
        expected.push(reference.export_json());
    }

    // Request bodies. Json's number formatting round-trips f64 exactly, so
    // the server fits bit-identical inputs.
    let mut dense = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            dense.push(Json::Num(prob.a.col(j)[i]));
        }
    }
    let design_body = Json::obj(vec![
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("dense", Json::Arr(dense)),
        ("b", Json::Arr(prob.b.iter().map(|&v| Json::Num(v)).collect())),
    ])
    .to_string();
    let model_json = || Json::obj(vec![("c", Json::Num(0.5)), ("tol", Json::Num(tol))]);

    let max_clients = clients_list.iter().map(|&c| c.max(1)).max().unwrap_or(1);
    let cfg = ServerConfig {
        port: 0,
        max_inflight: 2 * max_clients + 8,
        ..ServerConfig::default()
    };
    let server = Server::bind(cfg).expect("bind ephemeral serve port");
    let handle = server.spawn().expect("spawn serve accept loop");
    let addr = handle.addr();

    let mut prelude = Client::connect(&addr).expect("connect serve bench client");
    let (status, body) =
        prelude.request("POST", "/v1/designs", &design_body).expect("register design");
    assert_eq!(status, 200, "design registration failed: {body}");
    let design_id = Json::parse(&body)
        .ok()
        .and_then(|j| j.get("design_id").and_then(|v| v.as_str().map(String::from)))
        .expect("design_id in registration response");

    let make_fit_body = || {
        Json::obj(vec![("design_id", Json::Str(design_id.clone())), ("model", model_json())])
            .to_string()
    };
    let make_refit_body = |i: usize| {
        Json::obj(vec![
            ("design_id", Json::Str(design_id.clone())),
            ("model", model_json()),
            ("b", Json::Arr(response(i).iter().map(|&v| Json::Num(v)).collect())),
        ])
        .to_string()
    };

    // Cold: the first fit creates the session and solves from scratch.
    let fit_body = make_fit_body();
    let (resp, cold_fit_seconds) = time_it(|| prelude.request("POST", "/v1/fit", &fit_body));
    let (status, body) = resp.expect("cold fit request");
    let mut prelude_bitwise = status == 200 && body == expected_fit;

    // Warm: refits on the stored response re-solve the identical problem
    // through the warm workspace (buffer arena + full factor-cache hits).
    let warm_reps = 3;
    let mut warm_total = 0.0;
    for _ in 0..warm_reps {
        let refit_body = make_refit_body(0);
        let (resp, secs) = time_it(|| prelude.request("POST", "/v1/refit", &refit_body));
        let (status, body) = resp.expect("warm refit request");
        prelude_bitwise &= status == 200 && body == expected[0];
        warm_total += secs;
    }
    let warm_refit_seconds = warm_total / warm_reps as f64;

    let mut t = Table::new(&["clients", "requests", "p50(s)", "p95(s)", "total(s)", "bitwise"])
        .with_title(&format!(
            "serve front end: {m}×{n} design, cold fit {} vs warm refit {}",
            fmt_secs(cold_fit_seconds),
            fmt_secs(warm_refit_seconds)
        ));
    let mut rows: Vec<ServeBenchRow> = Vec::with_capacity(clients_list.len());
    for &clients in clients_list {
        let clients = clients.max(1);
        let total = clients * requests_per_client;
        let addr_ref: &str = &addr;
        let expected_ref: &[String] = &expected;
        let make_refit_body = &make_refit_body;
        let ((mut lats, row_bitwise), total_seconds) = time_it(|| {
            std::thread::scope(|scope| {
                let workers: Vec<_> = (0..clients)
                    .map(|c| {
                        scope.spawn(move || {
                            let mut client =
                                Client::connect(addr_ref).expect("connect serve bench client");
                            let mut lat = Vec::with_capacity(requests_per_client);
                            let mut ok = true;
                            for r in 0..requests_per_client {
                                let i = c * requests_per_client + r;
                                let body = make_refit_body(i);
                                let (resp, secs) =
                                    time_it(|| client.request("POST", "/v1/refit", &body));
                                let (status, rbody) = resp.expect("serve bench refit");
                                ok &= status == 200 && rbody == expected_ref[i];
                                lat.push(secs);
                            }
                            (lat, ok)
                        })
                    })
                    .collect();
                let mut lats = Vec::with_capacity(total);
                let mut ok = true;
                for w in workers {
                    let (lat, o) = w.join().expect("serve bench client thread");
                    lats.extend(lat);
                    ok &= o;
                }
                (lats, ok)
            })
        });
        lats.sort_by(|a, b| a.total_cmp(b));
        let row = ServeBenchRow {
            clients,
            requests: total,
            p50_seconds: percentile(&lats, 0.50),
            p95_seconds: percentile(&lats, 0.95),
            total_seconds,
            bitwise_equal: prelude_bitwise && row_bitwise,
        };
        t.row(vec![
            format!("{}", row.clients),
            format!("{}", row.requests),
            fmt_secs(row.p50_seconds),
            fmt_secs(row.p95_seconds),
            fmt_secs(row.total_seconds),
            format!("{}", row.bitwise_equal),
        ]);
        rows.push(row);
    }
    handle.stop();
    (t, rows, cold_fit_seconds, warm_refit_seconds)
}

/// The queued-load section of the serve bench: offered load at 2× the
/// in-flight cap against one warm session, through a deliberately small
/// server. What this pins: the admission queue absorbs the whole burst
/// (zero 503s), concurrent single-`b` refits coalesce into `refit_many`
/// batches (ratio > 1), and every response stays byte-identical to the
/// uncoalesced direct-api solve — all read back through `GET /v1/stats`.
#[derive(Clone, Debug)]
pub struct ServeQueuedRow {
    /// The server's in-flight cap for this measurement.
    pub max_inflight: usize,
    /// Concurrent keep-alive clients (2× the cap).
    pub clients: usize,
    /// Total requests offered.
    pub requests: usize,
    /// Median request latency under queued load, seconds.
    pub p50_seconds: f64,
    /// 95th-percentile request latency under queued load, seconds.
    pub p95_seconds: f64,
    /// Wall-clock for the whole burst, seconds.
    pub total_seconds: f64,
    /// 503s from a full admission queue (must be 0: the queue is sized to
    /// absorb the burst).
    pub rejected_queue_full: usize,
    /// Requests that waited in the queue before executing.
    pub queued_total: usize,
    /// Coalesced-refit batches executed.
    pub coalesce_batches: usize,
    /// Single-refit requests served through those batches.
    pub coalesce_requests: usize,
    /// Requests per batch (> 1 once concurrent refits actually merged).
    pub coalesce_ratio: f64,
    /// The warm session's workspace cache-hit rate, read back from
    /// `/v1/stats` through [`crate::api::StatsSnapshot::from_json`].
    pub workspace_hit_rate: f64,
    /// Whether every response was byte-identical to the direct `api::` call.
    pub bitwise_equal: bool,
}

/// Run the queued-load measurement (see [`ServeQueuedRow`]). The server is
/// sized so the burst *must* queue (`max_inflight` 4, clients 8) and the
/// default queue depth absorbs it without rejections; all requests target
/// one warm session so concurrent refits contend on the session lock and
/// coalesce.
pub fn serve_queued_load(
    n: usize,
    m: usize,
    requests_per_client: usize,
    tol: f64,
    seed: u64,
) -> (Table, ServeQueuedRow) {
    use crate::api::StatsSnapshot;
    use crate::serve::{Client, Server, ServerConfig};
    use crate::util::timer::time_it;

    let requests_per_client = requests_per_client.max(2);
    let max_inflight = 4usize;
    let clients = 2 * max_inflight;
    let total = clients * requests_per_client;
    let prob = generate_synthetic(&SyntheticSpec {
        m,
        n,
        n0: (n / 100).clamp(2, 10),
        x_star: 5.0,
        snr: 5.0,
        seed,
    });
    let response = |i: usize| -> Vec<f64> { (0..m).map(|k| prob.b[(k + i) % m]).collect() };

    // Direct-api reference bytes, one per request index — coalesced or not,
    // the server must reproduce exactly these.
    let design = Design::new(&prob.a, &prob.b).expect("serve queued bench design is valid");
    let model = EnetModel::new().alpha_c(0.8, 0.5).tol(tol);
    let mut reference = model.fit(&design).expect("serve queued bench reference fit");
    let mut expected = Vec::with_capacity(total);
    for i in 0..total {
        reference.refit(&response(i)).expect("serve queued bench reference refit");
        expected.push(reference.export_json());
    }

    let mut dense = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            dense.push(Json::Num(prob.a.col(j)[i]));
        }
    }
    let design_body = Json::obj(vec![
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("dense", Json::Arr(dense)),
        ("b", Json::Arr(prob.b.iter().map(|&v| Json::Num(v)).collect())),
    ])
    .to_string();
    let model_json = || Json::obj(vec![("c", Json::Num(0.5)), ("tol", Json::Num(tol))]);

    let cfg = ServerConfig { port: 0, max_inflight, ..ServerConfig::default() };
    let queue_capacity = cfg.queue_depth;
    let server = Server::bind(cfg).expect("bind ephemeral serve port");
    let handle = server.spawn().expect("spawn serve accept loop");
    let addr = handle.addr();

    let mut prelude = Client::connect(&addr).expect("connect serve queued client");
    let (status, body) =
        prelude.request("POST", "/v1/designs", &design_body).expect("register design");
    assert_eq!(status, 200, "design registration failed: {body}");
    let design_id = Json::parse(&body)
        .ok()
        .and_then(|j| j.get("design_id").and_then(|v| v.as_str().map(String::from)))
        .expect("design_id in registration response");
    let make_refit_body = |i: usize| {
        Json::obj(vec![
            ("design_id", Json::Str(design_id.clone())),
            ("model", model_json()),
            ("b", Json::Arr(response(i).iter().map(|&v| Json::Num(v)).collect())),
        ])
        .to_string()
    };

    // Warm the session so the burst measures steady-state serving, not the
    // one-off session construction.
    let warmup = make_refit_body(0);
    let (status, body) = prelude.request("POST", "/v1/refit", &warmup).expect("warmup refit");
    let mut bitwise = status == 200 && body == expected[0];

    let addr_ref: &str = &addr;
    let expected_ref: &[String] = &expected;
    let make_refit_body = &make_refit_body;
    let ((mut lats, burst_bitwise), total_seconds) = time_it(|| {
        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut client =
                            Client::connect(addr_ref).expect("connect serve queued client");
                        let mut lat = Vec::with_capacity(requests_per_client);
                        let mut ok = true;
                        for r in 0..requests_per_client {
                            let i = c * requests_per_client + r;
                            let body = make_refit_body(i);
                            let (resp, secs) =
                                time_it(|| client.request("POST", "/v1/refit", &body));
                            let (status, rbody) = resp.expect("serve queued refit");
                            ok &= status == 200 && rbody == expected_ref[i];
                            lat.push(secs);
                        }
                        (lat, ok)
                    })
                })
                .collect();
            let mut lats = Vec::with_capacity(total);
            let mut ok = true;
            for w in workers {
                let (lat, o) = w.join().expect("serve queued client thread");
                lats.extend(lat);
                ok &= o;
            }
            (lats, ok)
        })
    });
    bitwise &= burst_bitwise;
    lats.sort_by(|a, b| a.total_cmp(b));

    // Read the serving counters back through the typed stats surface.
    let (status, stats_body) = prelude.request("GET", "/v1/stats", "").expect("stats request");
    assert_eq!(status, 200, "stats request failed: {stats_body}");
    let stats = Json::parse(&stats_body).expect("stats body parses");
    let counter = |obj: &str, key: &str| -> usize {
        stats.get(obj).and_then(|o| o.get(key)).and_then(Json::as_usize).unwrap_or(0)
    };
    let workspace_hit_rate = stats
        .get("sessions")
        .and_then(Json::as_arr)
        .and_then(|sessions| {
            sessions.iter().find_map(|s| {
                s.get("workspace").and_then(StatsSnapshot::from_json).map(|ws| ws.hit_rate())
            })
        })
        .unwrap_or(0.0);
    let row = ServeQueuedRow {
        max_inflight,
        clients,
        requests: total,
        p50_seconds: percentile(&lats, 0.50),
        p95_seconds: percentile(&lats, 0.95),
        total_seconds,
        rejected_queue_full: counter("queue", "rejected_full"),
        queued_total: counter("queue", "queued_total"),
        coalesce_batches: counter("coalesce", "batches"),
        coalesce_requests: counter("coalesce", "requests"),
        coalesce_ratio: stats
            .get("coalesce")
            .and_then(|c| c.get("ratio"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
        workspace_hit_rate,
        bitwise_equal: bitwise,
    };
    handle.stop();

    let mut t = Table::new(&[
        "clients", "inflight-cap", "requests", "p50(s)", "p95(s)", "503s", "queued", "coalesce",
        "bitwise",
    ])
    .with_title(&format!(
        "serve queued load: {m}×{n} design, {clients} clients vs cap {max_inflight} \
         (queue {queue_capacity})"
    ));
    t.row(vec![
        format!("{}", row.clients),
        format!("{}", row.max_inflight),
        format!("{}", row.requests),
        fmt_secs(row.p50_seconds),
        fmt_secs(row.p95_seconds),
        format!("{}", row.rejected_queue_full),
        format!("{}", row.queued_total),
        format!("{:.2}x", row.coalesce_ratio),
        format!("{}", row.bitwise_equal),
    ]);
    (t, row)
}

/// Render the serve bench as the JSON payload CI uploads
/// (`BENCH_serve.json`). Rows carry no `threads` key, so the baseline diff
/// matches them by index — keep the clients list order stable. The `queued`
/// section carries the queued-load measurement when it ran.
pub fn serve_bench_json(
    rows: &[ServeBenchRow],
    n: usize,
    m: usize,
    requests_per_client: usize,
    cold_fit_seconds: f64,
    warm_refit_seconds: f64,
    queued: Option<&ServeQueuedRow>,
) -> String {
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("clients", Json::Num(r.clients as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("p50_seconds", Json::Num(r.p50_seconds)),
                ("p95_seconds", Json::Num(r.p95_seconds)),
                ("total_seconds", Json::Num(r.total_seconds)),
                ("bitwise_equal", Json::Bool(r.bitwise_equal)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("bench", Json::Str("serve".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("requests_per_client", Json::Num(requests_per_client as f64)),
        ("cold_fit_seconds", Json::Num(cold_fit_seconds)),
        ("warm_refit_seconds", Json::Num(warm_refit_seconds)),
        ("warm_speedup", Json::Num(cold_fit_seconds / warm_refit_seconds.max(1e-12))),
        ("rows", Json::Arr(row_objs)),
    ];
    if let Some(q) = queued {
        fields.push((
            "queued",
            Json::obj(vec![
                ("max_inflight", Json::Num(q.max_inflight as f64)),
                ("clients", Json::Num(q.clients as f64)),
                ("requests", Json::Num(q.requests as f64)),
                ("p50_seconds", Json::Num(q.p50_seconds)),
                ("p95_seconds", Json::Num(q.p95_seconds)),
                ("total_seconds", Json::Num(q.total_seconds)),
                ("rejected_queue_full", Json::Num(q.rejected_queue_full as f64)),
                ("queued_total", Json::Num(q.queued_total as f64)),
                ("coalesce_batches", Json::Num(q.coalesce_batches as f64)),
                ("coalesce_requests", Json::Num(q.coalesce_requests as f64)),
                ("coalesce_ratio", Json::Num(q.coalesce_ratio)),
                ("workspace_hit_rate", Json::Num(q.workspace_hit_rate)),
                ("bitwise_equal", Json::Bool(q.bitwise_equal)),
            ]),
        ));
    }
    Json::obj(fields).to_string()
}

#[cfg(test)]
mod shard_bench_tests {
    use super::*;

    #[test]
    fn pool_dispatch_rows_tiny() {
        let (t, rows) = pool_dispatch_rows(3, &[2]);
        assert_eq!(t.len(), 1);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert!(r.bitwise_equal, "{rows:?}");
        assert!(r.pool_seconds_per_call > 0.0 && r.scoped_seconds_per_call > 0.0);
        let js = pool_dispatch_json(&rows, 3);
        assert!(js.contains("pool_dispatch"), "{js}");
        assert!(js.contains("scoped_seconds_per_call"), "{js}");
    }

    #[test]
    fn newton_workspace_rows_tiny() {
        let (t, rows) = newton_workspace_rows(&[(40, 200, 12)], 2);
        assert_eq!(t.len(), 3, "one row per strategy");
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.bitwise_equal, "warm diverged from cold: {rows:?}");
            assert!(r.cold_seconds > 0.0 && r.warm_seconds > 0.0);
            // without the counting allocator installed (library tests) the
            // counter never moves; with it, the zero-allocation contract
            // pins this to 0 — either way it must be 0 here
            assert_eq!(r.allocs_per_iter, 0.0, "{rows:?}");
        }
        // The factor-cache strategies skip the whole build+factor when warm;
        // the strict `speedup > 1` gate runs in the release bench
        // (`cmd_bench_parallel`), where the margin is several-fold — here
        // (debug, tiny sizes) only guard against gross inversions so an OS
        // scheduling spike cannot flake the unit suite.
        for r in rows.iter().filter(|r| r.strategy != "cg") {
            assert!(r.warm_speedup > 0.5, "warm grossly slower than cold: {rows:?}");
        }
        let js = newton_workspace_json(&rows, 2);
        assert!(js.contains("newton_workspace"), "{js}");
        assert!(js.contains("allocs_per_iter"), "{js}");
    }

    #[test]
    fn warm_path_rows_tiny() {
        let (t, rows) = warm_path_rows(50, 400, 10, 8, 1);
        assert_eq!(t.len(), 2, "one row per factor-cache strategy");
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.bitwise_equal, "warm chain diverged from cold: {rows:?}");
            assert!(r.cold_seconds > 0.0 && r.pivot_seconds > 0.0 && r.rank1_seconds > 0.0);
            assert_eq!(r.downdate_fallbacks, 0, "{rows:?}");
            // without the counting allocator installed (library tests) the
            // counter never moves; with it, the steady-state contract pins
            // this to 0 — either way it must be 0 here
            assert_eq!(r.allocs_per_point, 0.0, "{rows:?}");
            // the edit tier must actually engage or the bench is vacuous
            assert!(r.rank1_updates > 0, "{rows:?}");
        }
        let wb = rows.iter().find(|r| r.strategy == "woodbury").unwrap();
        assert!(wb.rank1_downdates > 0, "interior swaps never downdated: {rows:?}");
        // the strict `rank1 < pivot < cold` gates run in the release bench
        // (`cmd_bench_parallel`); here only guard against gross inversions
        for r in &rows {
            assert!(r.rank1_vs_cold > 0.5, "rank-1 grossly slower than cold: {rows:?}");
        }
        let js = warm_path_json(&rows, 1);
        assert!(js.contains("warm_path"), "{js}");
        assert!(js.contains("rank1_vs_pivot"), "{js}");
        assert!(js.contains("allocs_per_point"), "{js}");
    }

    #[test]
    fn shard_bench_rows_tiny() {
        // n·2m clears TARGET_SHARD_FLOPS so the Aᵀy and Gram kernels really
        // multi-shard at threads=2 — the bitwise check must not pass
        // vacuously by both sides running the identical serial code.
        let (n, m) = (30_000, 70);
        assert!(crate::parallel::shard::Plan::for_work(n, 2 * m).shards > 1);
        let (t, rows, audit) = shard_linalg_rows(n, m, &[1, 2], 1e-5, 7);
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.bitwise_equal), "{rows:?}");
        assert!(rows[0].ssnal_speedup > 0.0);
        assert!(audit.dot4_seconds > 0.0 && audit.dot8_seconds > 0.0);
        assert!(audit.axpy4_seconds > 0.0 && audit.axpy8_seconds > 0.0);
        let js = shard_linalg_json(&rows, &audit, n, m);
        assert!(js.contains("shard_linalg"), "{js}");
        assert!(js.contains("width_audit"), "{js}");
    }

    #[test]
    fn sparse_design_rows_tiny() {
        let (t, rows, density) = sparse_design_rows(6_000, 60, &[1, 2], 1e-5, 11);
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        // the default MAF range produces a rare-variant (≪25% dense) cohort
        assert!(density > 0.0 && density < 0.25, "{density}");
        assert!(rows.iter().all(|r| r.bitwise_equal), "{rows:?}");
        for r in &rows {
            assert!(r.dense_aty_seconds > 0.0 && r.sparse_aty_seconds > 0.0);
            // The strict `speedup > 1` gate runs in the release bench
            // (`cmd_bench_parallel`), where skipping ~94% of the entries
            // wins by a wide margin — here (debug, tiny sizes) only guard
            // against gross inversions so timing jitter cannot flake the
            // unit suite.
            assert!(r.aty_speedup > 0.3, "{rows:?}");
            assert!(r.screen_speedup > 0.3, "{rows:?}");
        }
        let js = sparse_design_json(&rows, 6_000, 60, density);
        assert!(js.contains("sparse_design"), "{js}");
        assert!(js.contains("screen_speedup"), "{js}");
        assert!(js.contains("density"), "{js}");
    }

    #[test]
    fn serve_bench_rows_tiny() {
        let (t, rows, cold, warm) = serve_bench_rows(400, 30, &[1, 2], 2, 1e-5, 13);
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        // Byte-identical server responses are the load-bearing contract; the
        // strict warm < cold gate runs in the release bench
        // (`cmd_bench_parallel`) — here (debug, tiny sizes) only guard
        // against gross inversions so timing jitter cannot flake the suite.
        assert!(rows.iter().all(|r| r.bitwise_equal), "{rows:?}");
        assert!(cold > 0.0 && warm > 0.0);
        assert!(cold / warm > 0.2, "warm refit grossly slower than cold fit: {cold} vs {warm}");
        for r in &rows {
            assert!(r.p50_seconds > 0.0 && r.p95_seconds >= r.p50_seconds, "{rows:?}");
            assert_eq!(r.requests, r.clients * 2);
        }
        let js = serve_bench_json(&rows, 400, 30, 2, cold, warm, None);
        assert!(js.contains("\"bench\":\"serve\""), "{js}");
        assert!(js.contains("warm_speedup"), "{js}");
        assert!(js.contains("p95_seconds"), "{js}");
        assert!(!js.contains("\"queued\""), "{js}");
    }

    #[test]
    fn serve_queued_load_tiny() {
        let (t, row) = serve_queued_load(400, 30, 2, 1e-5, 13);
        assert_eq!(t.len(), 1);
        // The hard gates (ratio > 1, rejected == 0 at release sizes) run in
        // `cmd_bench_parallel`; here just pin the contract pieces that are
        // deterministic at any size: byte-identical responses, a queue deep
        // enough that nothing was rejected, and coherent counters.
        assert!(row.bitwise_equal, "{row:?}");
        assert_eq!(row.rejected_queue_full, 0, "{row:?}");
        assert_eq!(row.requests, row.clients * 2);
        assert!(row.p95_seconds >= row.p50_seconds, "{row:?}");
        assert!(
            row.coalesce_requests >= row.coalesce_batches,
            "batches served more requests than arrived: {row:?}"
        );
        assert!(row.workspace_hit_rate > 0.0, "warm session saw no cache hits: {row:?}");
        let js = serve_bench_json(&[], 400, 30, 2, 1e-3, 1e-4, Some(&row));
        assert!(js.contains("\"queued\""), "{js}");
        assert!(js.contains("coalesce_ratio"), "{js}");
        assert!(js.contains("rejected_queue_full"), "{js}");
    }
}
