//! Bench-regression comparison: diff a freshly produced `BENCH_*.json` table
//! against its committed baseline under `rust/benches/baselines/`.
//!
//! This is the logic behind `ssnal-en bench-check`, which CI's
//! `bench-regression` job runs for every bench artifact (and which is
//! equally runnable locally). The policy:
//!
//! * **hard failure** — structural drift: a baseline field missing from the
//!   current table, a field changing JSON type, a measured row (matched by
//!   its `threads` value) disappearing, a renamed `bench` identifier — or
//!   any `bitwise_equal: false` anywhere in the current table, which means
//!   the sharding determinism contract broke;
//! * **warning** (non-fatal; CI surfaces it as an annotation) — any
//!   `*seconds*` field regressing more than [`WALL_CLOCK_SLACK`] over its
//!   baseline by at least [`ABS_SLACK_SECONDS`]. Shared CI boxes are far too
//!   noisy for wall-clock to gate merges, but the trend should be visible.
//!
//! Extra fields or extra rows in the current table never fail: tables are
//! allowed to grow, only to shrink or diverge.

use crate::util::json::Json;

/// Multiplicative wall-clock slack before a timing regression is flagged.
pub const WALL_CLOCK_SLACK: f64 = 1.25;

/// Absolute floor (seconds) below which timing jitter is never flagged.
pub const ABS_SLACK_SECONDS: f64 = 1e-4;

/// Outcome of one baseline comparison.
#[derive(Debug, Default)]
pub struct CheckReport {
    /// Structural or determinism violations — the gate must fail.
    pub failures: Vec<String>,
    /// Wall-clock regressions — surfaced, never fatal.
    pub warnings: Vec<String>,
}

impl CheckReport {
    /// True when no hard failure was recorded.
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compare a current bench table against its committed baseline.
pub fn check_bench(current: &Json, baseline: &Json) -> CheckReport {
    let mut rep = CheckReport::default();
    match (baseline.get("bench"), current.get("bench")) {
        (Some(b), Some(c)) if b == c => {}
        (Some(b), Some(c)) => rep.failures.push(format!(
            "bench identifier changed: baseline {:?} vs current {:?}",
            b.as_str(),
            c.as_str()
        )),
        _ => rep.failures.push("missing top-level \"bench\" field".to_string()),
    }
    walk("$", "", baseline, current, &mut rep);
    scan_determinism("$", current, &mut rep);
    rep
}

/// Recursive structural diff: everything the baseline has, the current table
/// must also have, with matching types; timing leaves get the slack check.
fn walk(path: &str, key: &str, base: &Json, cur: &Json, rep: &mut CheckReport) {
    match (base, cur) {
        (Json::Obj(bm), Json::Obj(_)) => {
            for (k, bv) in bm {
                match cur.get(k) {
                    None => rep.failures.push(format!("{path}.{k}: missing field")),
                    Some(cv) => walk(&format!("{path}.{k}"), k, bv, cv, rep),
                }
            }
        }
        (Json::Arr(ba), Json::Arr(ca)) => {
            for (i, bv) in ba.iter().enumerate() {
                match match_row(bv, ca, i) {
                    None => rep.failures.push(format!("{path}[{i}]: missing row")),
                    Some(cv) => walk(&format!("{path}[{i}]"), key, bv, cv, rep),
                }
            }
        }
        (Json::Num(b), Json::Num(c)) => {
            if key.contains("seconds")
                && *c > *b * WALL_CLOCK_SLACK
                && *c - *b > ABS_SLACK_SECONDS
            {
                rep.warnings.push(format!(
                    "{path}: {c:.3e}s vs baseline {b:.3e}s (>{:.0}% wall-clock regression)",
                    (WALL_CLOCK_SLACK - 1.0) * 100.0
                ));
            }
        }
        (Json::Str(_), Json::Str(_))
        | (Json::Bool(_), Json::Bool(_))
        | (Json::Null, Json::Null) => {}
        _ => rep.failures.push(format!("{path}: field changed JSON type")),
    }
}

/// Find the current-table row matching a baseline row: by `threads` value
/// when both are objects carrying one (rows may reorder), else by index.
fn match_row<'a>(base_row: &Json, cur_rows: &'a [Json], index: usize) -> Option<&'a Json> {
    if let Some(bt) = base_row.get("threads") {
        if let Some(found) = cur_rows.iter().find(|c| c.get("threads") == Some(bt)) {
            return Some(found);
        }
        return None;
    }
    cur_rows.get(index)
}

/// Hard-fail on any `bitwise_equal: false` anywhere in the current table —
/// the determinism contract is load-bearing regardless of baseline shape.
fn scan_determinism(path: &str, cur: &Json, rep: &mut CheckReport) {
    match cur {
        Json::Obj(m) => {
            for (k, v) in m {
                if k == "bitwise_equal" && *v == Json::Bool(false) {
                    rep.failures.push(format!("{path}.{k}: determinism contract violated"));
                }
                scan_determinism(&format!("{path}.{k}"), v, rep);
            }
        }
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                scan_determinism(&format!("{path}[{i}]"), v, rep);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(secs: f64, bitwise: bool) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("pool_dispatch".into())),
            ("calls", Json::Num(100.0)),
            (
                "rows",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("threads", Json::Num(2.0)),
                        ("pool_seconds_per_call", Json::Num(secs)),
                        ("bitwise_equal", Json::Bool(bitwise)),
                    ]),
                    Json::obj(vec![
                        ("threads", Json::Num(4.0)),
                        ("pool_seconds_per_call", Json::Num(secs * 1.5)),
                        ("bitwise_equal", Json::Bool(true)),
                    ]),
                ]),
            ),
        ])
    }

    #[test]
    fn identical_tables_pass_clean() {
        let t = table(0.01, true);
        let rep = check_bench(&t, &t);
        assert!(rep.ok(), "{:?}", rep.failures);
        assert!(rep.warnings.is_empty(), "{:?}", rep.warnings);
    }

    #[test]
    fn missing_field_is_a_hard_failure() {
        let base = table(0.01, true);
        let mut cur = table(0.01, true);
        if let Json::Obj(m) = &mut cur {
            m.remove("calls");
        }
        let rep = check_bench(&cur, &base);
        assert!(!rep.ok());
        assert!(rep.failures.iter().any(|f| f.contains("calls")), "{:?}", rep.failures);
    }

    #[test]
    fn bitwise_false_is_a_hard_failure_even_with_matching_baseline() {
        let base = table(0.01, false);
        let cur = table(0.01, false);
        let rep = check_bench(&cur, &base);
        assert!(!rep.ok());
        assert!(rep.failures.iter().any(|f| f.contains("determinism")), "{:?}", rep.failures);
    }

    #[test]
    fn slow_timing_warns_but_does_not_fail() {
        let base = table(0.01, true);
        let cur = table(0.02, true); // 2x the baseline, well past 25%
        let rep = check_bench(&cur, &base);
        assert!(rep.ok(), "{:?}", rep.failures);
        assert!(!rep.warnings.is_empty());
        // tiny absolute times never warn, whatever the ratio
        let rep = check_bench(&table(4e-5, true), &table(1e-5, true));
        assert!(rep.warnings.is_empty(), "{:?}", rep.warnings);
    }

    fn rows_mut(t: &mut Json) -> &mut Vec<Json> {
        match t {
            Json::Obj(m) => match m.get_mut("rows") {
                Some(Json::Arr(rows)) => rows,
                _ => panic!("table has no rows array"),
            },
            _ => panic!("table is not an object"),
        }
    }

    #[test]
    fn rows_match_by_threads_not_position() {
        let base = table(0.01, true);
        let mut cur = table(0.01, true);
        rows_mut(&mut cur).reverse();
        let rep = check_bench(&cur, &base);
        assert!(rep.ok(), "{:?}", rep.failures);
        // a dropped thread budget is structural drift
        rows_mut(&mut cur).pop();
        let rep = check_bench(&cur, &base);
        assert!(!rep.ok());
    }

    #[test]
    fn type_and_bench_name_changes_fail() {
        let base = table(0.01, true);
        let mut cur = table(0.01, true);
        if let Json::Obj(m) = &mut cur {
            m.insert("calls".into(), Json::Str("100".into()));
        }
        let rep = check_bench(&cur, &base);
        assert!(rep.failures.iter().any(|f| f.contains("type")), "{:?}", rep.failures);

        let mut renamed = table(0.01, true);
        if let Json::Obj(m) = &mut renamed {
            m.insert("bench".into(), Json::Str("other".into()));
        }
        let rep = check_bench(&renamed, &base);
        assert!(rep.failures.iter().any(|f| f.contains("identifier")), "{:?}", rep.failures);
    }
}
