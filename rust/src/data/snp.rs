//! GWAS / SNP data simulator — the INSIGHT substitute (paper §4.2).
//!
//! The INSIGHT genotype data is privacy-protected, so this module generates
//! SNP matrices with the statistical structure GWAS designs actually have:
//!
//! * genotypes `g ∈ {0,1,2}` drawn as Binomial(2, MAF) with MAF ~ U(0.05, 0.5),
//! * **linkage-disequilibrium blocks**: SNPs come in contiguous blocks whose
//!   members are correlated (generated from a shared latent Gaussian with
//!   within-block correlation ρ_LD), mimicking haplotype structure,
//! * a handful of causal SNPs drive the phenotype plus polygenic noise —
//!   producing the "one dominant SNP + a small secondary set" pattern that
//!   the paper's Figure 2 tuning curves show.
//!
//! Two phenotypes are produced per cohort — `CWG`-like and `BMI`-like — with a
//! configurable correlation between them but **disjoint causal sets**, matching
//! the paper's observation that the selected sets for CWG and BMI do not overlap.

use std::fs::File;
use std::path::Path;

use crate::linalg::{CscMat, DesignStorage, Mat};
use crate::rng::Xoshiro256pp;

/// Cohort specification.
#[derive(Clone, Debug)]
pub struct SnpSpec {
    /// Individuals (paper: 226 for CWG, 210 for BMI).
    pub m: usize,
    /// SNPs (paper: ~342k; default benches scale this down).
    pub n_snps: usize,
    /// SNPs per LD block.
    pub block_size: usize,
    /// Within-block latent correlation (0 = independent SNPs).
    pub ld_rho: f64,
    /// Number of causal SNPs for the phenotype.
    pub n_causal: usize,
    /// Effect size of the dominant causal SNP; the rest get half of it.
    pub dominant_effect: f64,
    /// Phenotype noise standard deviation.
    pub noise_sd: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SnpSpec {
    fn default() -> Self {
        Self {
            m: 226,
            n_snps: 50_000,
            block_size: 20,
            ld_rho: 0.7,
            n_causal: 13,
            dominant_effect: 1.0,
            noise_sd: 1.0,
            seed: 2020,
        }
    }
}

/// A simulated GWAS cohort: standardized genotype design + phenotype.
#[derive(Clone, Debug)]
pub struct SnpCohort {
    /// Standardized genotype matrix (m × n_snps).
    pub a: Mat,
    /// Phenotype (centered), length m.
    pub b: Vec<f64>,
    /// Causal SNP indices (first is the dominant one).
    pub causal: Vec<usize>,
    /// True effect sizes aligned with `causal`.
    pub effects: Vec<f64>,
    /// SNP identifiers ("rs"-style synthetic names).
    pub snp_names: Vec<String>,
}

/// Standard normal CDF-based threshold pair for genotype dosage from a latent
/// Gaussian: P(g=0) = (1−p)², P(g=2) = p² (Hardy–Weinberg under MAF p).
fn hw_thresholds(p: f64) -> (f64, f64) {
    let p0 = (1.0 - p) * (1.0 - p);
    let p2 = p * p;
    (inv_norm_cdf(p0), inv_norm_cdf(1.0 - p2))
}

/// Acklam's rational approximation to the standard normal quantile (|err| < 1e-9).
fn inv_norm_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -inv_norm_cdf(1.0 - p)
    }
}

/// Generate a cohort per the spec.
pub fn generate(spec: &SnpSpec) -> SnpCohort {
    assert!(spec.n_causal <= spec.n_snps);
    assert!(spec.block_size >= 1);
    let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
    let m = spec.m;
    let n = spec.n_snps;

    let mut a = Mat::zeros(m, n);
    let sqrt_rho = spec.ld_rho.sqrt();
    let sqrt_rem = (1.0 - spec.ld_rho).sqrt();

    // latent shared factor per (individual, block)
    let mut shared = vec![0.0; m];
    for j in 0..n {
        if j % spec.block_size == 0 {
            rng.fill_gaussian(&mut shared);
        }
        let maf = 0.05 + 0.45 * rng.next_f64();
        let (t0, t2) = hw_thresholds(maf);
        let col = a.col_mut(j);
        for i in 0..m {
            let z = sqrt_rho * shared[i] + sqrt_rem * rng.next_gaussian();
            col[i] = if z <= t0 {
                0.0
            } else if z > t2 {
                2.0
            } else {
                1.0
            };
        }
    }

    // standardize genotype columns (GWAS convention)
    let std = crate::data::standardize::standardize(&a);
    let a = std.a;

    // causal SNPs spread across distinct blocks so LD doesn't merge them
    let n_blocks = n.div_ceil(spec.block_size);
    let causal_blocks = rng.sample_indices(n_blocks, spec.n_causal.min(n_blocks));
    let mut causal: Vec<usize> = causal_blocks
        .iter()
        .map(|&blk| {
            let lo = blk * spec.block_size;
            let hi = ((blk + 1) * spec.block_size).min(n);
            lo + rng.next_below(hi - lo)
        })
        .collect();
    // dominant SNP first
    if causal.len() > 1 {
        let k = rng.next_below(causal.len());
        causal.swap(0, k);
    }
    let mut effects = vec![0.0; causal.len()];
    for (idx, e) in effects.iter_mut().enumerate() {
        *e = if idx == 0 {
            spec.dominant_effect
        } else {
            0.5 * spec.dominant_effect * if rng.next_f64() < 0.5 { -1.0 } else { 1.0 }
        };
    }

    // phenotype = causal effects + noise, centered
    let mut b = vec![0.0; m];
    for (c, &j) in causal.iter().enumerate() {
        let col = a.col(j);
        for i in 0..m {
            b[i] += effects[c] * col[i];
        }
    }
    for v in b.iter_mut() {
        *v += spec.noise_sd * rng.next_gaussian();
    }
    let (b, _) = crate::data::standardize::center(&b);

    let snp_names = (0..n).map(|j| format!("rs{}", 100_000 + j * 7)).collect();
    SnpCohort { a, b, causal, effects, snp_names }
}

/// Spec for the **sparse** GWAS path: raw (unstandardized) dosages at low
/// minor-allele frequency, loaded straight into CSC storage.
///
/// Standardizing genotype columns subtracts the column mean from every entry
/// and therefore destroys sparsity, so this path keeps the raw `{0, 1, 2}`
/// dosage coding — at rare-variant MAFs (the default range) the design is
/// ≥ 90% zeros and the solve stack's sparse kernels skip all of them.
#[derive(Clone, Debug)]
pub struct SparseSnpSpec {
    /// The cohort structure (size, LD blocks, causal architecture, seed).
    pub base: SnpSpec,
    /// Minor-allele-frequency range `(lo, hi)`; expected column density is
    /// `E[1 − (1−p)²] ≈ 2·E[p]`, so the default rare-variant range
    /// (0.01, 0.05) gives ~6% density.
    pub maf_range: (f64, f64),
    /// Density above which the cohort is handed back densified — the storage
    /// heuristic: CSC only pays off while most entries are zeros.
    pub max_sparse_density: f64,
}

impl Default for SparseSnpSpec {
    fn default() -> Self {
        Self { base: SnpSpec::default(), maf_range: (0.01, 0.05), max_sparse_density: 0.25 }
    }
}

/// A simulated rare-variant GWAS cohort with automatically-chosen storage.
#[derive(Clone, Debug)]
pub struct SnpCohortSparse {
    /// Raw-dosage genotype design — [`DesignStorage::Sparse`] when the
    /// measured density is at most [`SparseSnpSpec::max_sparse_density`],
    /// [`DesignStorage::Dense`] otherwise.
    pub a: DesignStorage,
    /// Phenotype (centered), length m.
    pub b: Vec<f64>,
    /// Causal SNP indices (first is the dominant one).
    pub causal: Vec<usize>,
    /// True effect sizes aligned with `causal`.
    pub effects: Vec<f64>,
    /// SNP identifiers ("rs"-style synthetic names).
    pub snp_names: Vec<String>,
    /// Measured nonzero fraction of the dosage matrix.
    pub density: f64,
}

/// Generate a rare-variant cohort **directly into CSC storage** — nonzero
/// dosages are appended column by column, so the dense m × n matrix is never
/// materialized unless the density heuristic decides to densify at the end.
///
/// ```
/// use ssnal_en::api::{Design, EnetModel};
/// use ssnal_en::data::snp::{generate_sparse, SnpSpec, SparseSnpSpec};
///
/// let cohort = generate_sparse(&SparseSnpSpec {
///     base: SnpSpec { m: 40, n_snps: 300, n_causal: 3, ..Default::default() },
///     ..Default::default()
/// });
/// assert!(cohort.a.is_sparse(), "rare variants stay sparse ({})", cohort.density);
///
/// let design = Design::from_storage(cohort.a, cohort.b)?;
/// let fit = EnetModel::new().alpha_c(0.9, 0.5).fit(&design)?;
/// assert!(fit.result().converged);
/// # Ok::<(), ssnal_en::api::EnetError>(())
/// ```
pub fn generate_sparse(spec: &SparseSnpSpec) -> SnpCohortSparse {
    let base = &spec.base;
    assert!(base.n_causal <= base.n_snps);
    assert!(base.block_size >= 1);
    let (maf_lo, maf_hi) = spec.maf_range;
    assert!(
        0.0 < maf_lo && maf_lo <= maf_hi && maf_hi < 1.0,
        "MAF range must satisfy 0 < lo <= hi < 1"
    );
    let mut rng = Xoshiro256pp::seed_from_u64(base.seed);
    let m = base.m;
    let n = base.n_snps;

    let sqrt_rho = base.ld_rho.sqrt();
    let sqrt_rem = (1.0 - base.ld_rho).sqrt();

    let mut col_ptr = Vec::with_capacity(n + 1);
    col_ptr.push(0usize);
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    let mut shared = vec![0.0; m];
    for j in 0..n {
        if j % base.block_size == 0 {
            rng.fill_gaussian(&mut shared);
        }
        let maf = maf_lo + (maf_hi - maf_lo) * rng.next_f64();
        let (t0, t2) = hw_thresholds(maf);
        for i in 0..m {
            let z = sqrt_rho * shared[i] + sqrt_rem * rng.next_gaussian();
            let g = if z <= t0 {
                0.0
            } else if z > t2 {
                2.0
            } else {
                1.0
            };
            if g != 0.0 {
                row_idx.push(i);
                values.push(g);
            }
        }
        col_ptr.push(row_idx.len());
    }
    let csc = CscMat::new(m, n, col_ptr, row_idx, values);
    let density = csc.density();

    // causal SNPs spread across distinct blocks, as in the dense path
    let n_blocks = n.div_ceil(base.block_size);
    let causal_blocks = rng.sample_indices(n_blocks, base.n_causal.min(n_blocks));
    let mut causal: Vec<usize> = causal_blocks
        .iter()
        .map(|&blk| {
            let lo = blk * base.block_size;
            let hi = ((blk + 1) * base.block_size).min(n);
            lo + rng.next_below(hi - lo)
        })
        .collect();
    if causal.len() > 1 {
        let k = rng.next_below(causal.len());
        causal.swap(0, k);
    }
    let mut effects = vec![0.0; causal.len()];
    for (idx, e) in effects.iter_mut().enumerate() {
        *e = if idx == 0 {
            base.dominant_effect
        } else {
            0.5 * base.dominant_effect * if rng.next_f64() < 0.5 { -1.0 } else { 1.0 }
        };
    }

    // phenotype from the raw dosages (only stored entries contribute)
    let mut b = vec![0.0; m];
    for (c, &j) in causal.iter().enumerate() {
        let (rs, vs) = csc.col(j);
        for (&i, &v) in rs.iter().zip(vs.iter()) {
            b[i] += effects[c] * v;
        }
    }
    for v in b.iter_mut() {
        *v += base.noise_sd * rng.next_gaussian();
    }
    let (b, _) = crate::data::standardize::center(&b);

    let a = if density <= spec.max_sparse_density {
        DesignStorage::Sparse(csc)
    } else {
        DesignStorage::Dense(csc.to_dense())
    };
    let snp_names = (0..n).map(|j| format!("rs{}", 100_000 + j * 7)).collect();
    SnpCohortSparse { a, b, causal, effects, snp_names, density }
}

// ---------------------------------------------------------------------------
// PLINK 1.9 binary fileset reader (.bed / .bim / .fam)
// ---------------------------------------------------------------------------

/// A PLINK 1.9 binary fileset opened for streaming variant reads.
///
/// The `.bed` file stores genotypes SNP-major, 2 bits per sample, LSB-first
/// (sample `s` of a variant sits in byte `s/4` at bit `2·(s%4)`), with code
/// mapping `00` = homozygous A1 → dosage 2.0, `01` = missing, `10` =
/// heterozygous → 1.0, `11` = homozygous A2 → 0.0. Sample count comes from
/// the `.fam` line count, variant count from the `.bim` line count; the
/// `.bed` payload length is validated against both at open.
///
/// This reader feeds both `ssnal-en convert` (raw 2-bit repack into the
/// out-of-core block format — byte-for-byte, no decode) and direct
/// [`SnpCohortSparse`] ingestion via [`load_plink`].
pub struct PlinkBed {
    file: File,
    samples: usize,
    variants: usize,
    variant_ids: Vec<String>,
    phenotypes: Vec<f64>,
}

/// `.bed` magic bytes plus the SNP-major mode byte.
const BED_MAGIC: [u8; 3] = [0x6C, 0x1B, 0x01];

fn read_fam(path: &Path) -> Result<(usize, Vec<f64>), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut phenos = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() < 6 {
            return Err(format!(
                "{}: line {} has {} fields, expected 6 (FID IID father mother sex phenotype)",
                path.display(),
                lineno + 1,
                fields.len()
            ));
        }
        // PLINK codes a missing phenotype as -9 (or NA); treat both as 0.0
        // so downstream centering is well-defined.
        let p = match fields[5].parse::<f64>() {
            Ok(v) if v != -9.0 => v,
            _ => 0.0,
        };
        phenos.push(p);
    }
    Ok((phenos.len(), phenos))
}

fn read_bim(path: &Path) -> Result<Vec<String>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut ids = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut fields = line.split_whitespace();
        let id = fields.nth(1).ok_or_else(|| {
            format!(
                "{}: line {} is missing the variant-id field",
                path.display(),
                lineno + 1
            )
        })?;
        ids.push(id.to_string());
    }
    Ok(ids)
}

impl PlinkBed {
    /// Open a fileset by its `.bed` path; the sibling `.bim`/`.fam` files
    /// are derived by extension swap.
    pub fn open(bed_path: &Path) -> Result<PlinkBed, String> {
        let variant_ids = read_bim(&bed_path.with_extension("bim"))?;
        let (samples, phenotypes) = read_fam(&bed_path.with_extension("fam"))?;
        if samples == 0 || variant_ids.is_empty() {
            return Err(format!(
                "{}: empty fileset ({} samples, {} variants)",
                bed_path.display(),
                samples,
                variant_ids.len()
            ));
        }
        let file = File::open(bed_path).map_err(|e| format!("{}: {e}", bed_path.display()))?;
        let mut magic = [0u8; 3];
        crate::linalg::ooc::read_exact_at(&file, &mut magic, 0)
            .map_err(|e| format!("{}: {e}", bed_path.display()))?;
        if magic[..2] != BED_MAGIC[..2] {
            return Err(format!("{}: not a PLINK .bed file (bad magic)", bed_path.display()));
        }
        if magic[2] != BED_MAGIC[2] {
            return Err(format!(
                "{}: individual-major .bed files are not supported (mode byte {:#04x})",
                bed_path.display(),
                magic[2]
            ));
        }
        let variants = variant_ids.len();
        let bpv = samples.div_ceil(4);
        let expect = 3 + (variants * bpv) as u64;
        let actual = file
            .metadata()
            .map_err(|e| format!("{}: {e}", bed_path.display()))?
            .len();
        if actual != expect {
            return Err(format!(
                "{}: file length {actual} != expected {expect} for {samples} samples x \
                 {variants} variants",
                bed_path.display()
            ));
        }
        Ok(PlinkBed { file, samples, variants, variant_ids, phenotypes })
    }

    /// Samples (`.fam` rows).
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Variants (`.bim` rows).
    pub fn variants(&self) -> usize {
        self.variants
    }

    /// Variant identifiers (`.bim` column 2), in file order.
    pub fn variant_ids(&self) -> &[String] {
        &self.variant_ids
    }

    /// Phenotypes (`.fam` column 6; `-9`/unparseable → 0.0), in file order.
    pub fn phenotypes(&self) -> &[f64] {
        &self.phenotypes
    }

    /// Packed bytes per variant: `ceil(samples/4)`.
    pub fn bytes_per_variant(&self) -> usize {
        self.samples.div_ceil(4)
    }

    /// Read variant `j`'s packed 2-bit codes into `buf` (resized to
    /// [`PlinkBed::bytes_per_variant`]). These bytes repack into the
    /// out-of-core 2-bit encoding unchanged.
    pub fn read_variant_codes(&self, j: usize, buf: &mut Vec<u8>) -> Result<(), String> {
        if j >= self.variants {
            return Err(format!("variant index {j} out of range ({})", self.variants));
        }
        let bpv = self.bytes_per_variant();
        buf.clear();
        buf.resize(bpv, 0u8);
        crate::linalg::ooc::read_exact_at(&self.file, buf, 3 + (j * bpv) as u64)
            .map_err(|e| format!("variant {j}: {e}"))
    }

    /// Read and decode variant `j` into `{0,1,2}` dosages (`out` is resized
    /// to the sample count); missing genotypes decode to `missing_fill`.
    pub fn read_variant_dosages(
        &self,
        j: usize,
        missing_fill: f64,
        out: &mut Vec<f64>,
    ) -> Result<(), String> {
        let mut codes = Vec::new();
        self.read_variant_codes(j, &mut codes)?;
        out.clear();
        out.resize(self.samples, 0.0);
        crate::linalg::ooc::decode_plink_col(&codes, self.samples, missing_fill, out);
        Ok(())
    }
}

/// Load a PLINK fileset straight into a [`SnpCohortSparse`]: dosages go
/// directly to CSC storage (densified past `max_sparse_density`, like
/// [`generate_sparse`]), the phenotype is the centered `.fam` column 6, and
/// variant ids come from the `.bim`. Real data carries no ground truth, so
/// `causal`/`effects` are empty.
///
/// `missing_fill` is the dosage substituted for missing genotypes; the
/// common GWAS choice 0.0 also keeps missing entries unstored in CSC.
pub fn load_plink(
    bed_path: &Path,
    missing_fill: f64,
    max_sparse_density: f64,
) -> Result<SnpCohortSparse, String> {
    let bed = PlinkBed::open(bed_path)?;
    let (m, n) = (bed.samples(), bed.variants());
    let mut col_ptr = Vec::with_capacity(n + 1);
    col_ptr.push(0usize);
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    let mut dosages = Vec::new();
    for j in 0..n {
        bed.read_variant_dosages(j, missing_fill, &mut dosages)?;
        for (i, &g) in dosages.iter().enumerate() {
            if g != 0.0 {
                row_idx.push(i);
                values.push(g);
            }
        }
        col_ptr.push(row_idx.len());
    }
    let csc = CscMat::new(m, n, col_ptr, row_idx, values);
    let density = csc.density();
    let (b, _) = crate::data::standardize::center(bed.phenotypes());
    let a = if density <= max_sparse_density {
        DesignStorage::Sparse(csc)
    } else {
        DesignStorage::Dense(csc.to_dense())
    };
    Ok(SnpCohortSparse {
        a,
        b,
        causal: Vec::new(),
        effects: Vec::new(),
        snp_names: bed.variant_ids().to_vec(),
        density,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_norm_cdf_accuracy() {
        // known quantiles
        assert!((inv_norm_cdf(0.5)).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(0.9999) - 3.719016).abs() < 1e-3);
    }

    #[test]
    fn genotypes_standardized_and_shapes() {
        let spec = SnpSpec { m: 60, n_snps: 200, ..Default::default() };
        let c = generate(&spec);
        assert_eq!(c.a.rows(), 60);
        assert_eq!(c.a.cols(), 200);
        assert_eq!(c.b.len(), 60);
        assert_eq!(c.snp_names.len(), 200);
        // standardized columns
        for j in [0usize, 50, 199] {
            let col = c.a.col(j);
            let mean = col.iter().sum::<f64>() / 60.0;
            assert!(mean.abs() < 1e-10);
        }
        // centered phenotype
        let bm = c.b.iter().sum::<f64>() / 60.0;
        assert!(bm.abs() < 1e-10);
    }

    #[test]
    fn ld_blocks_are_correlated() {
        let spec = SnpSpec {
            m: 400,
            n_snps: 40,
            block_size: 20,
            ld_rho: 0.8,
            n_causal: 1,
            ..Default::default()
        };
        let c = generate(&spec);
        // average |corr| within block 0 should exceed cross-block
        let corr = |x: &[f64], y: &[f64]| {
            let n = x.len() as f64;
            let (mx, my) = (x.iter().sum::<f64>() / n, y.iter().sum::<f64>() / n);
            let mut num = 0.0;
            let mut dx = 0.0;
            let mut dy = 0.0;
            for i in 0..x.len() {
                num += (x[i] - mx) * (y[i] - my);
                dx += (x[i] - mx) * (x[i] - mx);
                dy += (y[i] - my) * (y[i] - my);
            }
            num / (dx.sqrt() * dy.sqrt() + 1e-30)
        };
        let mut within = 0.0;
        let mut count_w = 0;
        for a in 0..10 {
            for b in (a + 1)..10 {
                within += corr(c.a.col(a), c.a.col(b)).abs();
                count_w += 1;
            }
        }
        within /= count_w as f64;
        let mut cross = 0.0;
        let mut count_c = 0;
        for a in 0..10 {
            for b in 20..30 {
                cross += corr(c.a.col(a), c.a.col(b)).abs();
                count_c += 1;
            }
        }
        cross /= count_c as f64;
        assert!(within > cross + 0.1, "within={within} cross={cross}");
    }

    #[test]
    fn dominant_snp_most_correlated_with_phenotype() {
        let spec = SnpSpec {
            m: 300,
            n_snps: 500,
            n_causal: 5,
            dominant_effect: 2.0,
            noise_sd: 0.5,
            seed: 7,
            ..Default::default()
        };
        let c = generate(&spec);
        let dom = c.causal[0];
        let score = |j: usize| {
            crate::linalg::blas::dot(c.a.col(j), &c.b).abs()
        };
        let dom_score = score(dom);
        // dominant SNP should be among the very top marginal correlations
        let better = (0..500).filter(|&j| score(j) > dom_score * 1.001).count();
        assert!(better <= 5, "dominant not near top: {better} ahead");
    }

    #[test]
    fn sparse_cohort_is_sparse_and_deterministic() {
        let spec = SparseSnpSpec {
            base: SnpSpec { m: 50, n_snps: 400, n_causal: 4, ..Default::default() },
            ..Default::default()
        };
        let c1 = generate_sparse(&spec);
        let c2 = generate_sparse(&spec);
        assert!(c1.a.is_sparse(), "default MAF range must stay sparse");
        assert!(c1.density < 0.15, "density {}", c1.density);
        assert!(c1.density > 0.0, "cohort should have some minor alleles");
        assert_eq!((c1.a.rows(), c1.a.cols()), (50, 400));
        assert_eq!(c1.b, c2.b);
        match (&c1.a, &c2.a) {
            (DesignStorage::Sparse(s1), DesignStorage::Sparse(s2)) => assert_eq!(s1, s2),
            _ => panic!("expected sparse storage"),
        }
        // centered phenotype
        let bm = c1.b.iter().sum::<f64>() / 50.0;
        assert!(bm.abs() < 1e-10);
    }

    #[test]
    fn density_heuristic_densifies_common_variants() {
        let spec = SparseSnpSpec {
            base: SnpSpec { m: 40, n_snps: 60, ..Default::default() },
            maf_range: (0.3, 0.5),
            max_sparse_density: 0.25,
        };
        let c = generate_sparse(&spec);
        assert!(c.density > 0.25, "common variants are dense: {}", c.density);
        assert!(!c.a.is_sparse(), "heuristic must densify above the threshold");
    }

    #[test]
    fn sparse_dosages_are_raw_genotypes() {
        let spec = SparseSnpSpec {
            base: SnpSpec { m: 30, n_snps: 80, n_causal: 2, ..Default::default() },
            ..Default::default()
        };
        let c = generate_sparse(&spec);
        let DesignStorage::Sparse(csc) = &c.a else { panic!("expected sparse") };
        assert!(csc.values().iter().all(|&v| v == 1.0 || v == 2.0));
    }

    #[test]
    fn deterministic_and_distinct_seeds() {
        let spec = SnpSpec { m: 30, n_snps: 50, ..Default::default() };
        let c1 = generate(&spec);
        let c2 = generate(&spec);
        assert_eq!(c1.a, c2.a);
        assert_eq!(c1.b, c2.b);
        let c3 = generate(&SnpSpec { seed: 1, ..spec });
        assert_ne!(c1.b, c3.b);
    }

    // -- PLINK fileset fixture: 4 samples x 3 variants, hand-packed --------
    //
    // Dosages (missing marked `.`):
    //   rs1: [2, 1, 0, .]   -> codes 00 10 11 01 (LSB-first) -> 0x78
    //   rs2: [0, 0, 1, 2]   -> codes 11 11 10 00            -> 0x2F
    //   rs3: [1, 2, 2, 0]   -> codes 10 00 00 11            -> 0xC2
    // Phenotypes: [1.5, -0.5, 2.0, -9 (missing -> 0.0)].

    fn write_plink_fixture(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir();
        let stem = format!("ssnal_plink_{}_{tag}", std::process::id());
        let bed = dir.join(format!("{stem}.bed"));
        std::fs::write(&bed, [0x6C, 0x1B, 0x01, 0x78, 0x2F, 0xC2]).unwrap();
        std::fs::write(
            dir.join(format!("{stem}.bim")),
            "1 rs1 0 100 A G\n1 rs2 0 200 A G\n1 rs3 0 300 A G\n",
        )
        .unwrap();
        std::fs::write(
            dir.join(format!("{stem}.fam")),
            "f1 s1 0 0 1 1.5\nf2 s2 0 0 2 -0.5\nf3 s3 0 0 1 2.0\nf4 s4 0 0 2 -9\n",
        )
        .unwrap();
        bed
    }

    fn remove_plink_fixture(bed: &Path) {
        for ext in ["bed", "bim", "fam"] {
            let _ = std::fs::remove_file(bed.with_extension(ext));
        }
    }

    #[test]
    fn plink_bed_decodes_fixture_trio() {
        let bed_path = write_plink_fixture("decode");
        let bed = PlinkBed::open(&bed_path).unwrap();
        assert_eq!(bed.samples(), 4);
        assert_eq!(bed.variants(), 3);
        assert_eq!(bed.variant_ids(), ["rs1", "rs2", "rs3"]);
        assert_eq!(bed.phenotypes(), [1.5, -0.5, 2.0, 0.0]);
        assert_eq!(bed.bytes_per_variant(), 1);

        let mut codes = Vec::new();
        bed.read_variant_codes(0, &mut codes).unwrap();
        assert_eq!(codes, [0x78]);

        let mut d = Vec::new();
        bed.read_variant_dosages(0, -1.0, &mut d).unwrap();
        assert_eq!(d, [2.0, 1.0, 0.0, -1.0]);
        bed.read_variant_dosages(1, -1.0, &mut d).unwrap();
        assert_eq!(d, [0.0, 0.0, 1.0, 2.0]);
        bed.read_variant_dosages(2, -1.0, &mut d).unwrap();
        assert_eq!(d, [1.0, 2.0, 2.0, 0.0]);

        assert!(bed.read_variant_codes(3, &mut codes).is_err());
        remove_plink_fixture(&bed_path);
    }

    #[test]
    fn plink_load_builds_sparse_cohort() {
        let bed_path = write_plink_fixture("load");
        let cohort = load_plink(&bed_path, 0.0, 1.0).unwrap();
        remove_plink_fixture(&bed_path);

        assert_eq!(cohort.snp_names, ["rs1", "rs2", "rs3"]);
        assert!(cohort.causal.is_empty() && cohort.effects.is_empty());
        // Centered phenotype: mean of [1.5, -0.5, 2.0, 0.0] is 0.75.
        assert_eq!(cohort.b, [0.75, -1.25, 1.25, -0.75]);
        assert!((cohort.density - 7.0 / 12.0).abs() < 1e-12);

        let DesignStorage::Sparse(csc) = &cohort.a else { panic!("expected sparse") };
        assert_eq!(csc.rows(), 4);
        assert_eq!(csc.cols(), 3);
        assert_eq!(csc.col(0), (&[0usize, 1][..], &[2.0, 1.0][..]));
        assert_eq!(csc.col(1), (&[2usize, 3][..], &[1.0, 2.0][..]));
        assert_eq!(csc.col(2), (&[0usize, 1, 2][..], &[1.0, 2.0, 2.0][..]));
    }

    #[test]
    fn plink_load_densifies_past_threshold() {
        let bed_path = write_plink_fixture("densify");
        // Density 7/12 exceeds a 0.25 threshold: the heuristic densifies.
        let cohort = load_plink(&bed_path, 0.0, 0.25).unwrap();
        remove_plink_fixture(&bed_path);
        assert!(!cohort.a.is_sparse());
        let a = cohort.a.as_ref();
        assert_eq!(a.get(0, 0), 2.0);
        assert_eq!(a.get(3, 1), 2.0);
        assert_eq!(a.get(3, 2), 0.0);
    }

    #[test]
    fn plink_open_rejects_malformed_filesets() {
        // Bad magic.
        let bed_path = write_plink_fixture("badmagic");
        std::fs::write(&bed_path, [0x00, 0x1B, 0x01, 0x78, 0x2F, 0xC2]).unwrap();
        assert!(PlinkBed::open(&bed_path).unwrap_err().contains("bad magic"));
        // Individual-major mode byte.
        std::fs::write(&bed_path, [0x6C, 0x1B, 0x00, 0x78, 0x2F, 0xC2]).unwrap();
        assert!(PlinkBed::open(&bed_path).unwrap_err().contains("individual-major"));
        // Truncated payload.
        std::fs::write(&bed_path, [0x6C, 0x1B, 0x01, 0x78]).unwrap();
        assert!(PlinkBed::open(&bed_path).unwrap_err().contains("file length"));
        remove_plink_fixture(&bed_path);
    }
}
