//! Column standardization — the paper assumes "A is the standardized design
//! matrix"; glmnet-family solvers additionally center the response.

use crate::linalg::Mat;

/// A standardized design plus the statistics needed to map coefficients back.
#[derive(Clone, Debug)]
pub struct Standardized {
    /// Design with each column centered to mean 0 and scaled to unit standard
    /// deviation (columns with zero variance are left at 0).
    pub a: Mat,
    /// Per-column means of the original design.
    pub means: Vec<f64>,
    /// Per-column standard deviations (population, 1/m) of the original design.
    pub sds: Vec<f64>,
}

/// Standardize all columns of `a`.
pub fn standardize(a: &Mat) -> Standardized {
    let m = a.rows();
    let n = a.cols();
    let mut out = Mat::zeros(m, n);
    let mut means = vec![0.0; n];
    let mut sds = vec![0.0; n];
    for j in 0..n {
        let c = a.col(j);
        let mean = c.iter().sum::<f64>() / m as f64;
        let var = c.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m as f64;
        let sd = var.sqrt();
        means[j] = mean;
        sds[j] = sd;
        let oc = out.col_mut(j);
        if sd > 0.0 {
            let inv = 1.0 / sd;
            for i in 0..m {
                oc[i] = (c[i] - mean) * inv;
            }
        }
    }
    Standardized { a: out, means, sds }
}

/// Center a response vector; returns `(centered, mean)`.
pub fn center(b: &[f64]) -> (Vec<f64>, f64) {
    let mean = b.iter().sum::<f64>() / b.len().max(1) as f64;
    (b.iter().map(|v| v - mean).collect(), mean)
}

/// Map coefficients fit on the standardized design back to the original scale:
/// `β_orig[j] = β_std[j] / sd[j]`, intercept `= b_mean − Σ β_orig[j]·mean[j]`.
pub fn unstandardize_coefs(std: &Standardized, beta: &[f64], b_mean: f64) -> (Vec<f64>, f64) {
    assert_eq!(beta.len(), std.sds.len());
    let mut orig = vec![0.0; beta.len()];
    let mut intercept = b_mean;
    for j in 0..beta.len() {
        if std.sds[j] > 0.0 {
            orig[j] = beta[j] / std.sds[j];
            intercept -= orig[j] * std.means[j];
        }
    }
    (orig, intercept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn columns_have_zero_mean_unit_sd() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Mat::from_fn(100, 5, |_, _| 3.0 + 2.0 * rng.next_gaussian());
        let s = standardize(&a);
        for j in 0..5 {
            let c = s.a.col(j);
            let mean = c.iter().sum::<f64>() / 100.0;
            let var = c.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 100.0;
            assert!(mean.abs() < 1e-12, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-10, "col {j} var {var}");
        }
    }

    #[test]
    fn constant_column_left_zero() {
        let a = Mat::from_fn(10, 2, |i, j| if j == 0 { 7.0 } else { i as f64 });
        let s = standardize(&a);
        assert!(s.a.col(0).iter().all(|&v| v == 0.0));
        assert_eq!(s.sds[0], 0.0);
        assert_eq!(s.means[0], 7.0);
    }

    #[test]
    fn center_returns_mean() {
        let (c, mean) = center(&[1.0, 2.0, 3.0]);
        assert_eq!(mean, 2.0);
        assert_eq!(c, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn unstandardize_roundtrip_predictions() {
        // predictions from (std design, std coefs) must equal
        // predictions from (original design, unstd coefs + intercept)
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let a = Mat::from_fn(50, 3, |_, _| 1.0 + 0.5 * rng.next_gaussian());
        let s = standardize(&a);
        let beta_std = [0.7, -1.2, 0.1];
        let b_mean = 4.0;
        let (beta, intercept) = unstandardize_coefs(&s, &beta_std, b_mean);
        for i in 0..50 {
            let pred_std: f64 =
                (0..3).map(|j| s.a.get(i, j) * beta_std[j]).sum::<f64>() + b_mean;
            let pred_orig: f64 =
                (0..3).map(|j| a.get(i, j) * beta[j]).sum::<f64>() + intercept;
            assert!((pred_std - pred_orig).abs() < 1e-10);
        }
    }
}
