//! Data pipelines for every experiment in the paper:
//!
//! * [`synthetic`] — §4.1 Gaussian designs with sparse truth and SNR control
//!   (Tables 1, D.1, D.2, D.3, D.4),
//! * [`libsvm`] — LIBSVM-format parsing + synthesized base tables for the
//!   offline substitute of the Table 2 reference sets,
//! * [`polyexp`] — the polynomial basis expansion that creates Table 2's
//!   ultra-high-dimensional collinear designs,
//! * [`snp`] — the INSIGHT GWAS substitute (Figure 2, Table 3),
//! * [`standardize`] — design standardization / response centering.

pub mod libsvm;
pub mod polyexp;
pub mod snp;
pub mod standardize;
pub mod synthetic;

pub use standardize::{center, standardize, Standardized};
pub use synthetic::{generate as generate_synthetic, rho_hat, SyntheticProblem, SyntheticSpec};
