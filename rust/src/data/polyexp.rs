//! Polynomial basis expansion (Huang et al. 2010) — how the paper builds the
//! ultra-high-dimensional, highly collinear designs of Table 2.
//!
//! Given a base table with d features, the expansion contains **all monomials of
//! total degree 1..=k**: `x_{j1}·x_{j2}·…·x_{jt}` with `j1 ≤ j2 ≤ … ≤ jt`, t ≤ k.
//! That yields `C(d+k, k) − 1` columns, matching the paper's feature counts
//! (housing d=13, k=8 → 203 489; bodyfat d=14, k=8 → 319 769; triazines has 58
//! non-constant base features, k=4 → 557 844).
//!
//! Columns are produced in DFS order with a running partial product, so each new
//! column costs one length-m multiply and the expansion is O(m·n_expanded) total.

use crate::linalg::Mat;

/// Number of expanded features: `C(d+k, k) − 1` (checked arithmetic; panics on
/// overflow because such a request would be absurd anyway).
pub fn expanded_count(d: usize, k: usize) -> usize {
    // C(d+k, k) computed multiplicatively.
    let mut c: u128 = 1;
    for i in 1..=k as u128 {
        c = c * (d as u128 + i) / i;
    }
    let total = c - 1;
    assert!(total <= usize::MAX as u128, "expansion too large");
    total as usize
}

/// Expand `base` (m × d) to all monomials of degree 1..=k, visiting columns in
/// DFS order and stopping after `max_cols` columns (0 = no limit).
///
/// Returns the expanded matrix and, for bookkeeping, the multi-index (list of
/// base-feature indices, with repetition) of each produced column.
pub fn expand(base: &Mat, k: usize, max_cols: usize) -> (Mat, Vec<Vec<usize>>) {
    assert!(k >= 1, "expansion order must be ≥ 1");
    let m = base.rows();
    let d = base.cols();
    let full = expanded_count(d, k);
    let limit = if max_cols == 0 { full } else { max_cols.min(full) };
    let mut data: Vec<f64> = Vec::with_capacity(limit.saturating_mul(m));
    let mut indices: Vec<Vec<usize>> = Vec::with_capacity(limit);

    // DFS with an explicit stack of (next_start_feature, depth); partial products
    // are kept in a stack of buffers (one per depth level).
    let mut products: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut path: Vec<usize> = Vec::with_capacity(k);

    fn rec(
        base: &Mat,
        k: usize,
        limit: usize,
        start: usize,
        products: &mut Vec<Vec<f64>>,
        path: &mut Vec<usize>,
        data: &mut Vec<f64>,
        indices: &mut Vec<Vec<usize>>,
    ) -> bool {
        let m = base.rows();
        for j in start..base.cols() {
            if indices.len() >= limit {
                return true; // truncated
            }
            // new partial product = previous level product (or ones) * col_j
            let mut col = vec![0.0; m];
            match products.last() {
                Some(prev) => {
                    let cj = base.col(j);
                    for i in 0..m {
                        col[i] = prev[i] * cj[i];
                    }
                }
                None => col.copy_from_slice(base.col(j)),
            }
            path.push(j);
            data.extend_from_slice(&col);
            indices.push(path.clone());
            if path.len() < k {
                products.push(col);
                let truncated =
                    rec(base, k, limit, j, products, path, data, indices);
                products.pop();
                if truncated {
                    path.pop();
                    return true;
                }
            }
            path.pop();
        }
        false
    }

    rec(base, k, limit, 0, &mut products, &mut path, &mut data, &mut indices);
    let n = indices.len();
    (Mat::from_col_major(m, n, data), indices)
}

/// Drop (near-)constant columns of a base table before expansion — constant
/// features generate duplicate monomials and the paper's triazines count
/// implies they were removed.
pub fn drop_constant_columns(base: &Mat, tol: f64) -> (Mat, Vec<usize>) {
    let m = base.rows();
    let mut keep = Vec::new();
    for j in 0..base.cols() {
        let c = base.col(j);
        let mean = c.iter().sum::<f64>() / m as f64;
        let var = c.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m as f64;
        if var.sqrt() > tol {
            keep.push(j);
        }
    }
    (base.gather_cols(&keep), keep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn counts_match_paper_table2() {
        assert_eq!(expanded_count(13, 8), 203_489); // housing8
        assert_eq!(expanded_count(14, 8), 319_769); // bodyfat8
        assert_eq!(expanded_count(58, 4), 557_844); // triazines4 (58 non-constant)
    }

    #[test]
    fn small_expansion_by_hand() {
        // d=2, k=2: columns x0, x0², x0x1, x1, x1² (DFS order) → C(4,2)−1 = 5.
        let base = Mat::from_row_major(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let (ex, idx) = expand(&base, 2, 0);
        assert_eq!(ex.cols(), 5);
        assert_eq!(idx, vec![vec![0], vec![0, 0], vec![0, 1], vec![1], vec![1, 1]]);
        // x0 ⊙ x1 column
        assert_eq!(ex.col(2), &[1.0 * 2.0, 3.0 * 4.0, 5.0 * 6.0]);
        // x1² column
        assert_eq!(ex.col(4), &[4.0, 16.0, 36.0]);
    }

    #[test]
    fn degree_one_is_base() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let base = Mat::from_fn(10, 4, |_, _| rng.next_gaussian());
        let (ex, idx) = expand(&base, 1, 0);
        assert_eq!(ex.cols(), 4);
        for j in 0..4 {
            assert_eq!(ex.col(j), base.col(j));
            assert_eq!(idx[j], vec![j]);
        }
    }

    #[test]
    fn truncation_respects_limit() {
        let base = Mat::from_fn(5, 6, |i, j| (i + j) as f64 * 0.1 + 0.5);
        let (ex, idx) = expand(&base, 3, 17);
        assert_eq!(ex.cols(), 17);
        assert_eq!(idx.len(), 17);
    }

    #[test]
    fn columns_are_products_of_base() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let base = Mat::from_fn(7, 3, |_, _| rng.next_gaussian());
        let (ex, idx) = expand(&base, 3, 0);
        assert_eq!(ex.cols(), expanded_count(3, 3));
        for (c, mi) in idx.iter().enumerate() {
            for i in 0..7 {
                let expect: f64 = mi.iter().map(|&j| base.get(i, j)).product();
                assert!((ex.get(i, c) - expect).abs() < 1e-12);
            }
            // multi-index sorted (combinations with repetition)
            for w in mi.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn expansion_is_collinear() {
        // ρ̂ = λmax(AAᵀ)/n should be notably larger than for i.i.d. designs.
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let base = Mat::from_fn(40, 5, |_, _| rng.next_gaussian());
        let (ex, _) = expand(&base, 4, 0);
        let std = crate::data::standardize::standardize(&ex);
        let rho = crate::data::synthetic::rho_hat(&std.a, 40, 0);
        assert!(rho > 2.0, "expanded design should be collinear, rho={rho}");
    }

    #[test]
    fn drop_constants() {
        let base = Mat::from_fn(10, 3, |i, j| if j == 1 { 2.5 } else { i as f64 + j as f64 });
        let (reduced, keep) = drop_constant_columns(&base, 1e-9);
        assert_eq!(keep, vec![0, 2]);
        assert_eq!(reduced.cols(), 2);
        assert_eq!(reduced.col(0), base.col(0));
        assert_eq!(reduced.col(1), base.col(2));
    }
}
