//! LIBSVM regression-format parser (Chang & Lin 2011) — the format of the paper's
//! Table 2 reference data sets (housing, bodyfat, triazines).
//!
//! Each line: `<target> <index>:<value> <index>:<value> ...` with 1-based,
//! strictly increasing indices; omitted indices are zero. Comments start with `#`.
//!
//! The public LIBSVM site is unreachable from this offline environment, so
//! `synthesize_base` generates small base tables with the same (m, base-feature)
//! shapes and value ranges as the originals; `data::polyexp` then performs the
//! *real* polynomial expansion the paper uses to create ultra-high-dimensional,
//! highly collinear designs (substitution #2 in DESIGN.md).

use crate::linalg::Mat;
use crate::rng::Xoshiro256pp;

/// A parsed dense regression dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// m × d design (dense; the reference sets are small and dense after expansion).
    pub a: Mat,
    /// Target vector, length m.
    pub b: Vec<f64>,
}

/// Parse LIBSVM text into a dense dataset. `n_features = 0` infers the feature
/// count from the maximum index present.
pub fn parse_libsvm(text: &str, n_features: usize) -> Result<Dataset, String> {
    let mut targets = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let target: f64 = parts
            .next()
            .ok_or_else(|| format!("line {}: missing target", lineno + 1))?
            .parse()
            .map_err(|_| format!("line {}: bad target", lineno + 1))?;
        let mut feats = Vec::new();
        let mut prev = 0usize;
        for tok in parts {
            let (is, vs) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: usize = is
                .parse()
                .map_err(|_| format!("line {}: bad index {is:?}", lineno + 1))?;
            if idx == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
            }
            if idx <= prev {
                return Err(format!(
                    "line {}: indices must increase ({idx} after {prev})",
                    lineno + 1
                ));
            }
            prev = idx;
            let val: f64 = vs
                .parse()
                .map_err(|_| format!("line {}: bad value {vs:?}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        targets.push(target);
        rows.push(feats);
    }
    let d = if n_features > 0 { n_features } else { max_idx };
    if max_idx > d {
        return Err(format!("feature index {max_idx} exceeds declared count {d}"));
    }
    let m = targets.len();
    let mut a = Mat::zeros(m, d);
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            a.set(i, j, v);
        }
    }
    Ok(Dataset { a, b: targets })
}

/// Serialize to LIBSVM text (used by tests and example data dumps).
pub fn to_libsvm(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.b.len() {
        out.push_str(&format!("{}", ds.b[i]));
        for j in 0..ds.a.cols() {
            let v = ds.a.get(i, j);
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", j + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

/// Shapes of the paper's three reference sets (base features, before expansion).
/// housing: m=506, d=13 · bodyfat: m=252, d=14 · triazines: m=186, d=60.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReferenceSet {
    Housing,
    Bodyfat,
    Triazines,
}

impl ReferenceSet {
    /// `(name, m, d_base, expansion_order)` matching the paper's Table 2 header
    /// (housing8/bodyfat8 use order-8 truncated expansions, triazines4 order 4 —
    /// realized through `polyexp::expand_to_target` which matches the paper's n).
    pub fn spec(self) -> (&'static str, usize, usize, usize) {
        match self {
            ReferenceSet::Housing => ("housing8", 506, 13, 8),
            ReferenceSet::Bodyfat => ("bodyfat8", 252, 14, 8),
            ReferenceSet::Triazines => ("triazines4", 186, 60, 4),
        }
    }

    /// Paper's expanded feature count n for Table 2.
    pub fn paper_n(self) -> usize {
        match self {
            ReferenceSet::Housing => 203_489,
            ReferenceSet::Bodyfat => 319_769,
            ReferenceSet::Triazines => 557_844,
        }
    }
}

/// Synthesize a base table with the reference set's shape: bounded, positively
/// skewed feature marginals (like housing's crime/area variables) and a target
/// built from a smooth nonlinear function + noise, so polynomial expansion has
/// genuine signal to find.
pub fn synthesize_base(set: ReferenceSet, seed: u64) -> Dataset {
    let (_, m, d, _) = set.spec();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut a = Mat::zeros(m, d);
    for j in 0..d {
        // mix of uniform and log-normal-ish columns, all scaled to O(1)
        let lognormal = j % 3 == 0;
        for i in 0..m {
            let v = if lognormal {
                (0.5 * rng.next_gaussian()).exp() - 1.0
            } else {
                2.0 * rng.next_f64() - 1.0
            };
            a.set(i, j, v);
        }
    }
    // Nonlinear target: couple a few features with products and squares.
    let mut b = vec![0.0; m];
    for i in 0..m {
        let x0 = a.get(i, 0);
        let x1 = a.get(i, 1 % d);
        let x2 = a.get(i, 2 % d);
        b[i] = 3.0 * x0 - 2.0 * x1 * x2 + 1.5 * x0 * x0 + 0.5 * rng.next_gaussian();
    }
    Dataset { a, b }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let text = "1.5 1:2.0 3:-1.0\n-0.5 2:4.0\n";
        let ds = parse_libsvm(text, 0).unwrap();
        assert_eq!(ds.b, vec![1.5, -0.5]);
        assert_eq!(ds.a.rows(), 2);
        assert_eq!(ds.a.cols(), 3);
        assert_eq!(ds.a.get(0, 0), 2.0);
        assert_eq!(ds.a.get(0, 2), -1.0);
        assert_eq!(ds.a.get(1, 1), 4.0);
        assert_eq!(ds.a.get(1, 0), 0.0);
    }

    #[test]
    fn parse_comments_and_blank_lines() {
        let text = "# header\n1.0 1:1\n\n2.0 1:2 # trailing\n";
        let ds = parse_libsvm(text, 0).unwrap();
        assert_eq!(ds.b, vec![1.0, 2.0]);
    }

    #[test]
    fn parse_errors() {
        assert!(parse_libsvm("abc 1:1\n", 0).is_err(), "bad target");
        assert!(parse_libsvm("1.0 0:1\n", 0).is_err(), "0-based index");
        assert!(parse_libsvm("1.0 2:1 1:2\n", 0).is_err(), "decreasing index");
        assert!(parse_libsvm("1.0 1:x\n", 0).is_err(), "bad value");
        assert!(parse_libsvm("1.0 5:1\n", 3).is_err(), "index out of declared range");
    }

    #[test]
    fn roundtrip() {
        let text = "2 1:1.5 2:-0.25\n-1 2:3\n";
        let ds = parse_libsvm(text, 2).unwrap();
        let ser = to_libsvm(&ds);
        let ds2 = parse_libsvm(&ser, 2).unwrap();
        assert_eq!(ds.a, ds2.a);
        assert_eq!(ds.b, ds2.b);
    }

    #[test]
    fn synthesized_shapes_match_paper() {
        for set in [ReferenceSet::Housing, ReferenceSet::Bodyfat, ReferenceSet::Triazines] {
            let (_, m, d, _) = set.spec();
            let ds = synthesize_base(set, 7);
            assert_eq!(ds.a.rows(), m);
            assert_eq!(ds.a.cols(), d);
            assert_eq!(ds.b.len(), m);
        }
    }

    #[test]
    fn synthesized_has_signal() {
        let ds = synthesize_base(ReferenceSet::Housing, 1);
        // target correlates with feature 0 by construction
        let m = ds.b.len() as f64;
        let mb = ds.b.iter().sum::<f64>() / m;
        let col0: Vec<f64> = (0..ds.b.len()).map(|i| ds.a.get(i, 0)).collect();
        let ma = col0.iter().sum::<f64>() / m;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for i in 0..ds.b.len() {
            cov += (col0[i] - ma) * (ds.b[i] - mb);
            va += (col0[i] - ma) * (col0[i] - ma);
            vb += (ds.b[i] - mb) * (ds.b[i] - mb);
        }
        let corr = cov / (va.sqrt() * vb.sqrt());
        assert!(corr > 0.3, "corr={corr}");
    }
}
