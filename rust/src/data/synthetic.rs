//! Synthetic regression designs — paper §4.1.
//!
//! "The entries of the design matrix A ∈ R^{m×n} are drawn from a standard normal
//! distribution. We compute the response vector as b = A x_t + ε, where x_t is a
//! sparse vector with n₀ non-zero values all equal to x* = 5, and ε_i ~ N(0, s_ε).
//! We fix s_ε to have signal-to-noise ratio snr = var(A x_t)/s_ε² = 5."

use crate::linalg::Mat;
use crate::rng::Xoshiro256pp;

/// Parameters of the paper's generator.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Observations m.
    pub m: usize,
    /// Features n (n ≫ m).
    pub n: usize,
    /// Number of non-zero true coefficients n₀.
    pub n0: usize,
    /// Value of the non-zero coefficients (paper: x* = 5).
    pub x_star: f64,
    /// Signal-to-noise ratio (paper: 5).
    pub snr: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SyntheticSpec {
    /// The paper's three scenarios share (m=500, snr=5, x*=5) and vary n₀:
    /// sim1: n₀=100, sim2: n₀=20, sim3: n₀=5 (α differs at solve time, not here).
    pub fn sim(scenario: usize, n: usize, seed: u64) -> Self {
        let n0 = match scenario {
            1 => 100,
            2 => 20,
            3 => 5,
            other => panic!("unknown scenario sim{other}"),
        };
        Self { m: 500, n, n0, x_star: 5.0, snr: 5.0, seed }
    }
}

/// A generated problem instance.
#[derive(Clone, Debug)]
pub struct SyntheticProblem {
    /// Design matrix, column-major m × n.
    pub a: Mat,
    /// Response vector, length m.
    pub b: Vec<f64>,
    /// True coefficient vector (sparse), length n.
    pub x_true: Vec<f64>,
    /// Indices of the true support.
    pub support: Vec<usize>,
    /// Noise standard deviation actually used.
    pub noise_sd: f64,
}

/// Generate an instance per the paper's recipe.
pub fn generate(spec: &SyntheticSpec) -> SyntheticProblem {
    assert!(spec.n0 <= spec.n, "n0 must not exceed n");
    assert!(spec.m > 1, "need at least 2 observations");
    let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);

    // Design: i.i.d. standard normals, column-major fill (cache-friendly).
    let mut a = Mat::zeros(spec.m, spec.n);
    rng.fill_gaussian(a.as_mut_slice());

    // Sparse truth on a random support.
    let support = rng.sample_indices(spec.n, spec.n0);
    let mut x_true = vec![0.0; spec.n];
    for &j in &support {
        x_true[j] = spec.x_star;
    }

    // Signal and its empirical variance.
    let mut signal = vec![0.0; spec.m];
    a.mul_vec_support_into(&x_true, &support, &mut signal);
    let mean = signal.iter().sum::<f64>() / spec.m as f64;
    let var = signal.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / (spec.m - 1) as f64;

    // snr = var(Ax_t) / s_ε²  ⇒  s_ε = sqrt(var / snr)
    let noise_sd = if spec.n0 == 0 { 1.0 } else { (var / spec.snr).sqrt() };
    let b: Vec<f64> = signal.iter().map(|&s| s + noise_sd * rng.next_gaussian()).collect();

    SyntheticProblem { a, b, x_true, support, noise_sd }
}

/// Largest eigenvalue of `AAᵀ` via power iteration, normalized by n — the
/// collinearity gauge ρ̂ the paper reports beside Tables 1 and 2.
pub fn rho_hat(a: &Mat, iters: usize, seed: u64) -> f64 {
    let m = a.rows();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0; m];
    rng.fill_gaussian(&mut v);
    let mut atv = vec![0.0; a.cols()];
    let mut av = vec![0.0; m];
    let mut lam = 0.0;
    for _ in 0..iters {
        // w = A Aᵀ v
        a.t_mul_vec_into(&v, &mut atv);
        a.mul_vec_into(&atv, &mut av);
        let norm = crate::linalg::blas::nrm2(&av);
        if norm == 0.0 {
            return 0.0;
        }
        lam = norm; // Rayleigh approx since ‖v‖=1
        for i in 0..m {
            v[i] = av[i] / norm;
        }
    }
    lam / a.cols() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_support() {
        let spec = SyntheticSpec { m: 50, n: 200, n0: 7, x_star: 5.0, snr: 5.0, seed: 1 };
        let p = generate(&spec);
        assert_eq!(p.a.rows(), 50);
        assert_eq!(p.a.cols(), 200);
        assert_eq!(p.b.len(), 50);
        assert_eq!(p.support.len(), 7);
        let nnz = p.x_true.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, 7);
        for &j in &p.support {
            assert_eq!(p.x_true[j], 5.0);
        }
    }

    #[test]
    fn snr_is_respected() {
        let spec = SyntheticSpec { m: 2000, n: 100, n0: 10, x_star: 5.0, snr: 5.0, seed: 2 };
        let p = generate(&spec);
        // empirical: var(signal)/sd² should be ≈ snr
        let signal = p.a.mul_vec(&p.x_true);
        let mean = signal.iter().sum::<f64>() / signal.len() as f64;
        let var = signal.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / (signal.len() - 1) as f64;
        let snr = var / (p.noise_sd * p.noise_sd);
        assert!((snr - 5.0).abs() < 1e-9, "snr={snr}");
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec { m: 20, n: 50, n0: 3, x_star: 5.0, snr: 5.0, seed: 9 };
        let p1 = generate(&spec);
        let p2 = generate(&spec);
        assert_eq!(p1.a, p2.a);
        assert_eq!(p1.b, p2.b);
        let spec2 = SyntheticSpec { seed: 10, ..spec };
        let p3 = generate(&spec2);
        assert_ne!(p1.b, p3.b);
    }

    #[test]
    fn sim_scenarios_match_paper() {
        let s1 = SyntheticSpec::sim(1, 1000, 0);
        let s2 = SyntheticSpec::sim(2, 1000, 0);
        let s3 = SyntheticSpec::sim(3, 1000, 0);
        assert_eq!((s1.m, s1.n0), (500, 100));
        assert_eq!(s2.n0, 20);
        assert_eq!(s3.n0, 5);
        assert_eq!(s1.x_star, 5.0);
        assert_eq!(s1.snr, 5.0);
    }

    #[test]
    fn rho_hat_near_one_for_gaussian() {
        // For i.i.d. N(0,1), λ_max(AAᵀ)/n → (1+√(m/n))² ≈ 1 for n ≫ m (paper: ρ̂≈1).
        let spec = SyntheticSpec { m: 50, n: 5000, n0: 0, x_star: 0.0, snr: 5.0, seed: 3 };
        let p = generate(&spec);
        let rho = rho_hat(&p.a, 30, 0);
        assert!((0.8..1.6).contains(&rho), "rho={rho}");
    }

    #[test]
    fn rho_hat_large_for_duplicated_columns() {
        // Perfectly collinear design: A = [c c c ... c] ⇒ λmax(AAᵀ) = n‖c‖² ⇒ ρ̂ = ‖c‖².
        let m = 30;
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let mut c = vec![0.0; m];
        rng.fill_gaussian(&mut c);
        let a = Mat::from_fn(m, 100, |i, _| c[i]);
        let rho = rho_hat(&a, 50, 0);
        let c2: f64 = c.iter().map(|v| v * v).sum();
        assert!((rho - c2).abs() / c2 < 0.05, "rho={rho} c2={c2}");
    }
}
