//! Shared types for all Elastic Net solvers in this crate.

use crate::linalg::DesignRef;

/// A borrowed view of one Elastic Net instance:
/// `min_x ½‖Ax − b‖² + λ1‖x‖₁ + (λ2/2)‖x‖₂²` (paper Eq. 1).
///
/// The design is a storage-polymorphic [`DesignRef`] — dense and CSC-sparse
/// designs flow through every solver identically (and bitwise-identically;
/// see [`crate::linalg::sparse`]).
#[derive(Clone, Copy, Debug)]
pub struct EnetProblem<'a> {
    /// Design matrix view (m × n, typically n ≫ m), dense or CSC.
    pub a: DesignRef<'a>,
    /// Response vector, length m.
    pub b: &'a [f64],
    /// ℓ1 penalty weight λ1 ≥ 0.
    pub lam1: f64,
    /// squared-ℓ2 penalty weight λ2 ≥ 0.
    pub lam2: f64,
}

impl<'a> EnetProblem<'a> {
    /// Construct and validate. Accepts `&Mat`, `&CscMat`, `&DesignStorage`
    /// or an existing [`DesignRef`].
    pub fn new(a: impl Into<DesignRef<'a>>, b: &'a [f64], lam1: f64, lam2: f64) -> Self {
        let a = a.into();
        assert_eq!(a.rows(), b.len(), "A rows must match b length");
        assert!(lam1 >= 0.0 && lam2 >= 0.0, "penalties must be nonnegative");
        Self { a, b, lam1, lam2 }
    }

    /// Observations m.
    pub fn m(&self) -> usize {
        self.a.rows()
    }

    /// Features n.
    pub fn n(&self) -> usize {
        self.a.cols()
    }

    /// `λ^max = ‖Aᵀb‖∞ / α` — the smallest λ scale with an all-zero solution,
    /// under the paper's parametrization `λ1 = α·c·λ^max`, `λ2 = (1−α)·c·λ^max`
    /// (§4.1). `alpha = 1` gives the Lasso λ_max.
    pub fn lambda_max<'b>(a: impl Into<DesignRef<'b>>, b: &[f64], alpha: f64) -> f64 {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0,1]");
        crate::linalg::blas::nrm_inf(&a.into().t_mul_vec(b)) / alpha
    }

    /// The paper's `(λ1, λ2)` from `(α, c_λ, λ^max)`.
    pub fn lambdas_from_alpha(alpha: f64, c_lambda: f64, lambda_max: f64) -> (f64, f64) {
        (alpha * c_lambda * lambda_max, (1.0 - alpha) * c_lambda * lambda_max)
    }
}

/// Which algorithm produced a [`SolveResult`] (for harness reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's method.
    SsnalEn,
    /// Naive full-sweep coordinate descent (sklearn-like).
    CdNaive,
    /// Covariance-updating coordinate descent with active-set sweeps (glmnet-like).
    CdCovariance,
    /// FISTA / accelerated proximal gradient.
    Fista,
    /// Plain proximal gradient (ISTA).
    ProximalGradient,
    /// ADMM.
    Admm,
    /// Coordinate descent + Gap-Safe sphere screening (GSR-like).
    CdGapSafe,
    /// Working-set solver with dual extrapolation (celer-like).
    Celer,
}

impl Algorithm {
    /// Short display name used in benchmark tables.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::SsnalEn => "ssnal-en",
            Algorithm::CdNaive => "cd-naive",
            Algorithm::CdCovariance => "cd-cov",
            Algorithm::Fista => "fista",
            Algorithm::ProximalGradient => "prox-grad",
            Algorithm::Admm => "admm",
            Algorithm::CdGapSafe => "gap-safe",
            Algorithm::Celer => "celer",
        }
    }
}

/// Result of one Elastic Net solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Primal solution x (length n).
    pub x: Vec<f64>,
    /// Dual variable y (length m) — `y = Ax − b` at optimality; solvers that do
    /// not maintain a dual iterate report the primal residual here.
    pub y: Vec<f64>,
    /// Indices of the active (nonzero) coefficients.
    pub active_set: Vec<usize>,
    /// Features surviving the solver's final safe screen (`None` for
    /// algorithms that do not screen). The Gap-Safe solver reports the size
    /// of its last survivor set — an upper bound on, and near convergence
    /// close to, the active-set size.
    pub screen_survivors: Option<usize>,
    /// Primal objective value at `x`.
    pub objective: f64,
    /// Outer iterations (AL iterations for SsNAL; sweeps/epochs for others).
    pub iterations: usize,
    /// Total inner iterations (SsN steps for SsNAL; 0 for single-loop methods).
    pub inner_iterations: usize,
    /// Final stopping criterion value (solver-specific; KKT residual for SsNAL,
    /// duality gap or max coefficient change for baselines).
    pub residual: f64,
    /// Whether the tolerance was reached before the iteration cap.
    pub converged: bool,
    /// Which algorithm produced this.
    pub algorithm: Algorithm,
}

impl SolveResult {
    /// Number of active coefficients r = |J|.
    pub fn r(&self) -> usize {
        self.active_set.len()
    }
}

/// Strategy for solving the semi-smooth Newton linear system (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NewtonStrategy {
    /// Pick per-iteration based on (m, r) — the paper's recommendation.
    Auto,
    /// Cholesky on the m×m matrix `I + κ A_J A_Jᵀ`.
    Direct,
    /// Sherman–Morrison–Woodbury: factor the r×r matrix (Eq. 19).
    Woodbury,
    /// Matrix-free conjugate gradient.
    ConjugateGradient,
}

/// SsNAL-EN options (defaults follow §4.1 of the paper).
#[derive(Clone, Debug)]
pub struct SsnalOptions {
    /// KKT tolerance (paper: 1e-6).
    pub tol: f64,
    /// Max AL (outer) iterations.
    pub max_outer: usize,
    /// Max SsN (inner) iterations per outer iteration.
    pub max_inner: usize,
    /// Initial σ (paper: 5e-3).
    pub sigma0: f64,
    /// σ growth factor per outer iteration (paper: 5).
    pub sigma_mult: f64,
    /// σ cap (σ^∞ in Algorithm 1).
    pub sigma_max: f64,
    /// Armijo constant μ ∈ (0, ½) (paper: 0.2).
    pub ls_mu: f64,
    /// Line-search backtracking factor.
    pub ls_beta: f64,
    /// Max backtracking steps.
    pub max_ls: usize,
    /// Newton system strategy.
    pub strategy: NewtonStrategy,
    /// CG tolerance (when CG strategy is used).
    pub cg_tol: f64,
    /// CG iteration cap.
    pub cg_max_iters: usize,
    /// Print per-iteration diagnostics.
    pub verbose: bool,
}

impl Default for SsnalOptions {
    fn default() -> Self {
        Self {
            tol: 1e-6,
            max_outer: 100,
            max_inner: 100,
            sigma0: 5e-3,
            sigma_mult: 5.0,
            sigma_max: 1e8,
            ls_mu: 0.2,
            ls_beta: 0.5,
            max_ls: 40,
            strategy: NewtonStrategy::Auto,
            cg_tol: 1e-8,
            cg_max_iters: 500,
            verbose: false,
        }
    }
}

impl SsnalOptions {
    /// The σ schedule the paper uses for the screening-solver comparison
    /// (Supplement D.3): σ⁰ = 1, ×10 per iteration.
    pub fn screening_sigma() -> Self {
        Self { sigma0: 1.0, sigma_mult: 10.0, ..Self::default() }
    }
}

/// Options shared by the first-order baselines.
#[derive(Clone, Debug)]
pub struct BaselineOptions {
    /// Stopping tolerance (on the solver's own criterion).
    pub tol: f64,
    /// Max iterations / sweeps.
    pub max_iters: usize,
    /// Verbose diagnostics.
    pub verbose: bool,
}

impl Default for BaselineOptions {
    fn default() -> Self {
        Self { tol: 1e-6, max_iters: 100_000, verbose: false }
    }
}

/// Uniform configuration consumed by the [`crate::solver::Solver`] trait.
///
/// The shared knobs (`tol`, `max_iters`, `verbose`) are honored by **every**
/// registered algorithm — unlike the pre-facade `solve_with`, which rebuilt
/// default option structs and only forwarded `tol`. Algorithm-specific blocks
/// (`ssnal`, `admm`) ride along for the solvers that need them.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// Stopping tolerance on each solver's own criterion.
    pub tol: f64,
    /// Iteration cap: outer AL iterations for SsNAL-EN, sweeps/epochs for the
    /// first-order baselines. `None` keeps each algorithm's default cap. The
    /// round-based solvers (gap-safe, celer) clamp it to their 100/200-round
    /// safety nets — one round there is a full working-set convergence, not a
    /// sweep — so only tightening below those nets has an effect.
    pub max_iters: Option<usize>,
    /// Per-iteration diagnostics.
    pub verbose: bool,
    /// SsNAL-specific knobs (σ schedule, Newton strategy, line search, CG).
    /// The shared `tol`/`verbose`/`max_iters` fields above override the
    /// matching fields here, so the cross-algorithm knobs have one source of
    /// truth (see [`SolverConfig::ssnal_options`]).
    pub ssnal: SsnalOptions,
    /// ADMM-specific knobs (ρ, over-relaxation).
    pub admm: crate::solver::admm::AdmmOptions,
}

impl SolverConfig {
    /// Per-algorithm defaults at tolerance `tol`.
    pub fn new(tol: f64) -> Self {
        Self {
            tol,
            max_iters: None,
            verbose: false,
            ssnal: SsnalOptions::default(),
            admm: crate::solver::admm::AdmmOptions::default(),
        }
    }

    /// The effective [`SsnalOptions`]: `ssnal` with the shared `tol`,
    /// `verbose` and `max_iters` knobs folded in.
    pub fn ssnal_options(&self) -> SsnalOptions {
        let mut opts = self.ssnal.clone();
        opts.tol = self.tol;
        opts.verbose = self.verbose;
        if let Some(cap) = self.max_iters {
            opts.max_outer = cap;
        }
        opts
    }

    /// The effective [`BaselineOptions`] for the first-order solvers.
    pub fn baseline_options(&self) -> BaselineOptions {
        BaselineOptions {
            tol: self.tol,
            max_iters: self.max_iters.unwrap_or_else(|| BaselineOptions::default().max_iters),
            verbose: self.verbose,
        }
    }
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self::new(1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn lambda_parametrization_matches_paper() {
        // λ1 = α·c·λmax, λ2 = (1−α)·c·λmax
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.75, 0.5, 8.0);
        assert!((l1 - 3.0).abs() < 1e-15);
        assert!((l2 - 1.0).abs() < 1e-15);
        // α=1 is pure Lasso
        let (l1, l2) = EnetProblem::lambdas_from_alpha(1.0, 1.0, 4.0);
        assert_eq!(l1, 4.0);
        assert_eq!(l2, 0.0);
    }

    #[test]
    fn lambda_max_zero_solution_boundary() {
        let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -2.0]);
        let b = [1.0, 1.0];
        // Aᵀb = [1, 1, 0] → ‖·‖∞ = 1
        assert_eq!(EnetProblem::lambda_max(&a, &b, 1.0), 1.0);
        assert_eq!(EnetProblem::lambda_max(&a, &b, 0.5), 2.0);
    }

    #[test]
    fn defaults_match_paper() {
        let o = SsnalOptions::default();
        assert_eq!(o.tol, 1e-6);
        assert_eq!(o.sigma0, 5e-3);
        assert_eq!(o.sigma_mult, 5.0);
        assert_eq!(o.ls_mu, 0.2);
        let s = SsnalOptions::screening_sigma();
        assert_eq!(s.sigma0, 1.0);
        assert_eq!(s.sigma_mult, 10.0);
    }

    #[test]
    fn problem_validation() {
        let a = Mat::zeros(3, 2);
        let b = [0.0; 3];
        let p = EnetProblem::new(&a, &b, 1.0, 0.5);
        assert_eq!(p.m(), 3);
        assert_eq!(p.n(), 2);
    }

    #[test]
    #[should_panic(expected = "A rows")]
    fn problem_shape_mismatch_panics() {
        let a = Mat::zeros(3, 2);
        let b = [0.0; 4];
        let _ = EnetProblem::new(&a, &b, 1.0, 0.5);
    }

    #[test]
    fn solver_config_folds_shared_knobs_into_option_structs() {
        let mut cfg = SolverConfig::new(1e-4);
        cfg.max_iters = Some(7);
        cfg.verbose = true;
        cfg.ssnal.sigma0 = 1.0;
        let s = cfg.ssnal_options();
        assert_eq!(s.tol, 1e-4);
        assert_eq!(s.max_outer, 7);
        assert!(s.verbose);
        assert_eq!(s.sigma0, 1.0, "algorithm-specific knobs survive");
        let b = cfg.baseline_options();
        assert_eq!((b.tol, b.max_iters, b.verbose), (1e-4, 7, true));
        // no explicit cap → each algorithm's default cap
        let d = SolverConfig::new(1e-6).baseline_options();
        assert_eq!(d.max_iters, BaselineOptions::default().max_iters);
    }

    #[test]
    fn algorithm_names_unique() {
        let algos = [
            Algorithm::SsnalEn,
            Algorithm::CdNaive,
            Algorithm::CdCovariance,
            Algorithm::Fista,
            Algorithm::ProximalGradient,
            Algorithm::Admm,
            Algorithm::CdGapSafe,
            Algorithm::Celer,
        ];
        let names: std::collections::HashSet<&str> = algos.iter().map(|a| a.name()).collect();
        assert_eq!(names.len(), algos.len());
    }
}
