//! SsNAL-EN — the paper's algorithm (Algorithm 1).
//!
//! Outer loop: inexact augmented Lagrangian on the dual (D), multiplier `x`.
//! Inner loop: semi-smooth Newton on `ψ(y) = L_σ(y | z̄, x)` (Proposition 2),
//! with the generalized-Hessian system solved by [`crate::solver::ssn_system`].
//!
//! Cost anatomy per SsN step (m×n design, r active):
//!   * one `Aᵀd` — O(mn), the unavoidable dual sweep (kept *incremental*:
//!     `Aᵀ(y + s·d) = Aᵀy + s·Aᵀd`, so backtracking line search costs O(n), not O(mn)),
//!   * one `A_J u_J` — O(mr) (sparse primal mat-vec),
//!   * the Newton solve — O(r²m + r³) via Woodbury when r < m.
//!
//! The outer multiplier update uses the Moreau identity
//! `x − σ(Aᵀy + z) = prox_{σp}(x − σAᵀy)`, so `res(kkt₃) = ‖x − u‖/(σ·(1+‖y‖+‖z‖))`
//! costs O(n) instead of another O(mn) sweep.

use crate::linalg::{blas, NewtonWorkspace};
use crate::parallel::shard;
use crate::prox;
use crate::solver::objective::{primal_objective, support_of};
use crate::solver::ssn_system::{solve_newton_system_ws, ResolvedStrategy};
use crate::solver::types::{Algorithm, EnetProblem, SolveResult, SsnalOptions};

/// Detailed per-solve diagnostics (used by tests and the §Perf log).
#[derive(Clone, Debug, Default)]
pub struct SsnalTrace {
    /// res(kkt₃) after each outer iteration.
    pub outer_residuals: Vec<f64>,
    /// SsN iterations per outer iteration.
    pub inner_counts: Vec<usize>,
    /// Active-set size after each outer iteration.
    pub active_sizes: Vec<usize>,
    /// σ at the final iteration — the λ-path driver carries this into the next
    /// warm-started solve so nearby problems converge in ~1 outer iteration
    /// (paper §3.3).
    pub final_sigma: f64,
    /// Newton solves that fell back to CG after a direct/Woodbury
    /// factorization failed numerically (see
    /// [`crate::solver::ssn_system::ResolvedStrategy::CgFallback`]).
    pub cg_fallbacks: usize,
}

/// Solve with the default zero start.
pub fn solve(p: &EnetProblem, opts: &SsnalOptions) -> SolveResult {
    solve_warm(p, opts, None).0
}

/// Solve with an optional warm start `x0` (used by the λ-path driver, §3.3).
/// Returns the result and the iteration trace.
pub fn solve_warm(
    p: &EnetProblem,
    opts: &SsnalOptions,
    x0: Option<&[f64]>,
) -> (SolveResult, SsnalTrace) {
    let mut ws = NewtonWorkspace::new();
    solve_warm_ws(p, opts, x0, &mut ws)
}

/// [`solve_warm`] against a caller-owned [`NewtonWorkspace`]: every
/// Newton-step buffer (the direct m×m build, the Woodbury Gram + `w`, CG's
/// working vectors) and the active-set-aware factorization cache persist in
/// `ws` — across the inner SsN iterations of this solve and, when the caller
/// reuses `ws` (the λ-path's per-chain [`crate::path::WarmState`] does),
/// across warm-started λ-steps. Results are bitwise-identical to a fresh
/// workspace at every `SSNAL_THREADS` budget; steady-state Newton iterations
/// (stable active set, single-shard plans) perform zero heap allocations.
pub fn solve_warm_ws(
    p: &EnetProblem,
    opts: &SsnalOptions,
    x0: Option<&[f64]>,
    ws: &mut NewtonWorkspace,
) -> (SolveResult, SsnalTrace) {
    let m = p.m();
    let n = p.n();
    assert!(p.lam1 > 0.0 || p.lam2 > 0.0, "need a nontrivial penalty");

    // ---- state -------------------------------------------------------------
    let mut x: Vec<f64> = match x0 {
        Some(x0) => {
            assert_eq!(x0.len(), n);
            x0.to_vec()
        }
        None => vec![0.0; n],
    };
    // y is initialized at the KKT-consistent point y = Ax − b.
    let mut y: Vec<f64> = {
        let ax = p.a.mul_vec(&x);
        (0..m).map(|i| ax[i] - p.b[i]).collect()
    };
    let mut sigma = opts.sigma0;

    // ---- workspaces (allocated once; the hot loop is allocation-free) -------
    let mut aty = vec![0.0; n]; // Aᵀy, maintained incrementally
    let mut atd = vec![0.0; n]; // Aᵀd per Newton step
    let mut t = vec![0.0; n]; // x − σAᵀy
    let mut u = vec![0.0; n]; // prox_{σp}(t)
    let mut active: Vec<usize> = Vec::new();
    let mut grad = vec![0.0; m]; // ∇ψ(y)
    let mut neg_grad = vec![0.0; m]; // −∇ψ(y), the Newton rhs
    let mut d = vec![0.0; m]; // Newton direction
    let mut au = vec![0.0; m]; // A u (sparse)
    let mut z = vec![0.0; n];

    let bnorm = blas::nrm2(p.b);
    // n-length squared norms go through the sharded dot (single-shard — and
    // therefore bitwise-serial — until n·2 clears the shard work target).
    let xnorm_sq_of = |x: &[f64]| shard::dot(x, x);

    let mut trace = SsnalTrace::default();
    let mut total_inner = 0usize;
    let mut converged = false;
    let mut final_res = f64::INFINITY;

    // Inner tolerance schedule: start loose, tighten toward tol (standard
    // inexact-ALM practice; the paper fixes the final tolerance at 1e-6).
    // Early AL iterations only steer the multiplier, so solving them sharply
    // wastes O(mn) sweeps — see EXPERIMENTS.md §Perf.
    let mut inner_tol = (opts.tol * 3e4).min(3e-2).max(opts.tol);

    p_verbose(opts, || {
        format!("[ssnal] m={m} n={n} λ1={:.3e} λ2={:.3e} σ0={:.1e}", p.lam1, p.lam2, opts.sigma0)
    });

    let mut outer = 0usize;
    // Aᵀy is maintained incrementally across *all* iterations (y only changes
    // through y += s·d, and Aᵀ(y+s·d) = Aᵀy + s·Aᵀd). A periodic refresh wipes
    // accumulated floating-point drift. Saves one O(mn) sweep per outer
    // iteration — see EXPERIMENTS.md §Perf. The O(mn) sweeps go through the
    // sharded kernels: fanned over the worker pool on large problems, with
    // results invariant to the thread count (see parallel::shard's
    // determinism contract).
    shard::t_mul_vec_into(p.a, &y, &mut aty);
    let mut steps_since_refresh = 0usize;
    while outer < opts.max_outer {
        outer += 1;
        if steps_since_refresh >= 20 {
            shard::t_mul_vec_into(p.a, &y, &mut aty);
            steps_since_refresh = 0;
        }

        // ---- inner SsN loop ------------------------------------------------
        let mut inner = 0usize;
        let mut psi_val;
        loop {
            // t = x − σAᵀy ; u = prox_{σp}(t) ; J = active set (Eq. 17)
            for j in 0..n {
                t[j] = x[j] - sigma * aty[j];
            }
            prox::prox_enet_with_support(&t, sigma, p.lam1, p.lam2, &mut u, &mut active);

            // ∇ψ(y) = y + b − A u  (Eq. 15)
            shard::mul_vec_support_into(p.a, &u, &active, &mut au);
            for i in 0..m {
                grad[i] = y[i] + p.b[i] - au[i];
            }
            let res1 = blas::nrm2(&grad) / (1.0 + bnorm);
            if res1 <= inner_tol || inner >= opts.max_inner {
                break;
            }
            inner += 1;

            // ψ(y) (Proposition 2, part 1)
            let unorm_sq = shard::dot(&u, &u);
            psi_val = prox::h_star(&y, p.b)
                + (1.0 + sigma * p.lam2) / (2.0 * sigma) * unorm_sq
                - xnorm_sq_of(&x) / (2.0 * sigma);

            // Newton direction: V d = −∇ψ. When CG is used, an inexact-Newton
            // forcing term ties the CG accuracy to the current gradient norm
            // (Eisenstat–Walker): early steps don't deserve 1e-8 solves.
            let kappa = sigma / (1.0 + sigma * p.lam2);
            for i in 0..m {
                neg_grad[i] = -grad[i];
            }
            let cg_tol = (0.1 * res1).clamp(opts.cg_tol, 1e-2);
            let resolved = solve_newton_system_ws(
                p.a,
                &active,
                kappa,
                &neg_grad,
                &mut d,
                opts.strategy,
                cg_tol,
                opts.cg_max_iters,
                ws,
            );
            if resolved == ResolvedStrategy::CgFallback {
                trace.cg_fallbacks += 1;
            }

            // Armijo backtracking (Eq. 12) with incremental Aᵀ(y+s·d).
            shard::t_mul_vec_into(p.a, &d, &mut atd);
            let gtd = blas::dot(&grad, &d);
            debug_assert!(gtd <= 1e-12 * (1.0 + gtd.abs()), "d must be a descent direction");
            let mut s = 1.0;
            let mut accepted = false;
            for _ in 0..opts.max_ls {
                // ψ(y + s d) via the O(n) update of t
                let mut unorm_trial = 0.0;
                let thr = sigma * p.lam1;
                let scale = 1.0 / (1.0 + sigma * p.lam2);
                for j in 0..n {
                    let tj = t[j] - sigma * s * atd[j];
                    let uj = if tj > thr {
                        (tj - thr) * scale
                    } else if tj < -thr {
                        (tj + thr) * scale
                    } else {
                        0.0
                    };
                    unorm_trial += uj * uj;
                }
                // h*(y + s d) = h*(y) + s(yᵀd + bᵀd) + s²/2‖d‖²
                let hstar_trial = prox::h_star(&y, p.b)
                    + s * (blas::dot(&y, &d) + blas::dot(p.b, &d))
                    + 0.5 * s * s * blas::nrm2_sq(&d);
                let psi_trial = hstar_trial
                    + (1.0 + sigma * p.lam2) / (2.0 * sigma) * unorm_trial
                    - xnorm_sq_of(&x) / (2.0 * sigma);
                if psi_trial <= psi_val + opts.ls_mu * s * gtd {
                    accepted = true;
                    break;
                }
                s *= opts.ls_beta;
            }
            if !accepted {
                // step too small to make progress — accept the last s anyway
                p_verbose(opts, || format!("[ssnal]   line search exhausted at s={s:.2e}"));
            }

            // y ← y + s d ; maintain Aᵀy incrementally (O(n), not O(mn)).
            // The n-length update shards; element-wise, so bitwise-serial.
            blas::axpy(s, &d, &mut y);
            shard::axpy(s, &atd, &mut aty);
            steps_since_refresh += 1;
        }
        total_inner += inner;

        // ---- z-update (Proposition 2, part 2) and multiplier update ---------
        // z = prox_{p*/σ}(x/σ − Aᵀy); t = x − σAᵀy is current.
        prox::prox_enet_conj(&t, sigma, p.lam1, p.lam2, &mut z);

        // res(kkt₃) via the Moreau identity: Aᵀy + z = (x − u)/σ.
        let xu_dist = blas::dist2(&x, &u);
        let res3 = xu_dist / sigma / (1.0 + blas::nrm2(&y) + blas::nrm2(&z));
        final_res = res3;

        // multiplier update: x ← prox_{σp}(x − σAᵀy) = u
        x.copy_from_slice(&u);

        trace.outer_residuals.push(res3);
        trace.inner_counts.push(inner);
        trace.active_sizes.push(active.len());
        p_verbose(opts, || {
            format!(
                "[ssnal] outer {outer}: res3={res3:.3e} inner={inner} r={} σ={sigma:.1e}",
                active.len()
            )
        });

        if res3 <= opts.tol {
            converged = true;
            break;
        }
        sigma = (sigma * opts.sigma_mult).min(opts.sigma_max);
        inner_tol = (inner_tol * 0.1).max(opts.tol);
    }
    trace.final_sigma = sigma;

    let active_set = support_of(&x, 0.0);
    let objective = primal_objective(p, &x);
    (
        SolveResult {
            x,
            y,
            active_set,
            screen_survivors: None,
            objective,
            iterations: outer,
            inner_iterations: total_inner,
            residual: final_res,
            converged,
            algorithm: Algorithm::SsnalEn,
        },
        trace,
    )
}

#[inline]
fn p_verbose(opts: &SsnalOptions, msg: impl FnOnce() -> String) {
    if opts.verbose {
        eprintln!("{}", msg());
    }
}

/// [`crate::solver::Solver`] registry entry for SsNAL-EN (the paper's
/// algorithm).
#[derive(Clone, Copy, Debug, Default)]
pub struct SsnalSolver;

impl crate::solver::Solver for SsnalSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::SsnalEn
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve(p, &cfg.ssnal_options())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::linalg::Mat;
    use crate::solver::objective::{duality_gap, kkt_residuals};
    use crate::solver::types::NewtonStrategy;

    fn spec_small() -> SyntheticSpec {
        SyntheticSpec { m: 60, n: 300, n0: 8, x_star: 5.0, snr: 5.0, seed: 11 }
    }

    fn lambdas(a: &Mat, b: &[f64], alpha: f64, c: f64) -> (f64, f64) {
        let lmax = EnetProblem::lambda_max(a, b, alpha);
        EnetProblem::lambdas_from_alpha(alpha, c, lmax)
    }

    #[test]
    fn converges_and_satisfies_kkt() {
        let prob = generate_synthetic(&spec_small());
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.8, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = solve(&p, &SsnalOptions::default());
        assert!(res.converged, "residual {}", res.residual);
        assert!(res.iterations <= 12, "paper: few outer iterations, got {}", res.iterations);
        // full KKT check with the dual pair (y, z = −Aᵀy projected is implicit):
        let z: Vec<f64> = {
            // at optimality z = −Aᵀy
            p.a.t_mul_vec(&res.y).iter().map(|v| -v).collect()
        };
        let kkt = kkt_residuals(&p, &res.x, &res.y, &z);
        assert!(kkt.res1 < 1e-4, "{kkt:?}");
        assert!(kkt.res3 < 1e-4, "{kkt:?}");
        let gap = duality_gap(&p, &res.x, &res.y, &z);
        assert!(gap.abs() < 1e-3 * (1.0 + res.objective), "gap={gap}");
    }

    #[test]
    fn zero_solution_above_lambda_max() {
        let prob = generate_synthetic(&spec_small());
        let alpha = 0.9;
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(alpha, 1.05, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = solve(&p, &SsnalOptions::default());
        assert!(res.converged);
        assert_eq!(res.active_set.len(), 0, "x must be exactly 0 above λmax");
        assert!(res.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn recovers_sparse_truth_support() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 100,
            n: 400,
            n0: 5,
            x_star: 5.0,
            snr: 50.0,
            seed: 3,
        });
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.9, 0.2);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = solve(&p, &SsnalOptions::default());
        assert!(res.converged);
        // all true support should be selected at this λ with high SNR
        for &j in &prob.support {
            assert!(res.x[j].abs() > 1e-3, "missed true feature {j}");
        }
    }

    #[test]
    fn strategies_agree() {
        let prob = generate_synthetic(&spec_small());
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.7, 0.4);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let mut results = Vec::new();
        for strat in [
            NewtonStrategy::Direct,
            NewtonStrategy::Woodbury,
            NewtonStrategy::ConjugateGradient,
            NewtonStrategy::Auto,
        ] {
            let opts = SsnalOptions { strategy: strat, ..Default::default() };
            let res = solve(&p, &opts);
            assert!(res.converged, "{strat:?}");
            results.push(res);
        }
        let x0 = &results[0].x;
        for res in &results[1..] {
            let dist = blas::dist2(x0, &res.x);
            assert!(dist < 1e-4, "strategy solutions differ by {dist}");
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let prob = generate_synthetic(&spec_small());
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1a, l2a) = EnetProblem::lambdas_from_alpha(0.8, 0.5, lmax);
        let pa = EnetProblem::new(&prob.a, &prob.b, l1a, l2a);
        let cold = solve(&pa, &SsnalOptions::default());

        // nearby λ, warm-started from the previous solution
        let (l1b, l2b) = EnetProblem::lambdas_from_alpha(0.8, 0.45, lmax);
        let pb = EnetProblem::new(&prob.a, &prob.b, l1b, l2b);
        let (warm, _) = solve_warm(&pb, &SsnalOptions::default(), Some(&cold.x));
        let coldb = solve(&pb, &SsnalOptions::default());
        assert!(warm.converged);
        assert!(
            warm.iterations <= coldb.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            coldb.iterations
        );
    }

    #[test]
    fn matches_coordinate_descent_solution() {
        // cross-algorithm agreement is the strongest correctness signal we have
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 6,
            x_star: 5.0,
            snr: 5.0,
            seed: 21,
        });
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.75, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let ssnal = solve(&p, &SsnalOptions::default());
        let cd = crate::solver::cd::solve_naive(
            &p,
            &crate::solver::types::BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        let dist = blas::dist2(&ssnal.x, &cd.x);
        assert!(dist < 1e-4, "ssnal vs cd distance {dist}");
        assert!((ssnal.objective - cd.objective).abs() < 1e-6 * (1.0 + cd.objective));
    }

    #[test]
    fn objective_never_worse_than_truth_vector() {
        // x̂ minimizes the objective, so obj(x̂) ≤ obj(x_true)
        let prob = generate_synthetic(&spec_small());
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.8, 0.1);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = solve(&p, &SsnalOptions::default());
        assert!(res.objective <= primal_objective(&p, &prob.x_true) + 1e-8);
    }

    #[test]
    fn trace_records_iterations() {
        let prob = generate_synthetic(&spec_small());
        let (l1, l2) = lambdas(&prob.a, &prob.b, 0.8, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let (res, trace) = solve_warm(&p, &SsnalOptions::default(), None);
        assert_eq!(trace.outer_residuals.len(), res.iterations);
        assert_eq!(trace.inner_counts.len(), res.iterations);
        assert_eq!(trace.inner_counts.iter().sum::<usize>(), res.inner_iterations);
        // residuals should reach below tol at the end
        assert!(*trace.outer_residuals.last().unwrap() <= 1e-6);
    }

    #[test]
    fn pure_ridge_matches_closed_form() {
        // λ1 = 0 (allowed since λ2 > 0): solution solves (AᵀA + λ2I)x = Aᵀb.
        let prob = generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 20,
            n0: 5,
            x_star: 2.0,
            snr: 10.0,
            seed: 5,
        });
        let lam2 = 3.0;
        let p = EnetProblem::new(&prob.a, &prob.b, 0.0, lam2);
        let res = solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
        let idx: Vec<usize> = (0..20).collect();
        let gram = prob.a.gram_of_cols(&idx, lam2);
        let rhs = prob.a.t_mul_vec(&prob.b);
        let closed = crate::linalg::Cholesky::factor(&gram).unwrap().solve(&rhs);
        for j in 0..20 {
            assert!((res.x[j] - closed[j]).abs() < 1e-5, "j={j}");
        }
    }
}
