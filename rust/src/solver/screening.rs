//! Gap-Safe sphere screening (Ndiaye et al. 2017) — the "GSR" competitor of
//! Supplement D.3, plus the screening machinery reused by the celer-style
//! working-set solver.
//!
//! The Elastic Net is handled **exactly** through the standard augmented-Lasso
//! reduction: `½‖Ax−b‖² + λ1‖x‖₁ + (λ2/2)‖x‖₂² = ½‖Ãx−b̃‖² + λ1‖x‖₁` with
//! `Ã = [A; √λ2·I]`, `b̃ = [b; 0]`. The augmented rows are never materialized —
//! every inner product against `Ã` decomposes as `Ã_jᵀṽ = A_jᵀv_top + √λ2·v_j`.
//!
//! Lasso dual (on the augmented problem): `θ ∈ Δ = {θ : ‖Ãᵀθ‖∞ ≤ λ1}`,
//! optimal `θ* = (b̃ − Ãx*)/1` scaled by λ1. Gap-Safe sphere: any feature with
//! `|Ã_jᵀθ| + r·‖Ã_j‖ < λ1` where `r = √(2·gap)` can be *safely* discarded
//! (its coefficient is zero at the optimum).

use crate::linalg::blas;
use crate::parallel::shard;
use crate::solver::objective::{primal_objective, support_of};
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult};

/// Augmented-design helper: all screening math for `Ã = [A; √λ2 I]`.
pub struct AugmentedView<'a> {
    p: &'a EnetProblem<'a>,
    sqrt_lam2: f64,
    /// ‖Ã_j‖ = √(‖A_j‖² + λ2) for every feature.
    pub col_norms: Vec<f64>,
}

impl<'a> AugmentedView<'a> {
    /// Precompute augmented column norms (an O(mn) feature sweep — O(nnz) on
    /// CSC designs — sharded over the worker pool on large designs;
    /// per-column values identical to the serial loop at every thread count
    /// and every storage).
    pub fn new(p: &'a EnetProblem<'a>) -> Self {
        let lam2 = p.lam2;
        let a = p.a;
        let col_norms = shard::map_ranges(p.n(), 2 * p.m(), move |range| {
            range.map(|j| (a.col_nrm2_sq(j) + lam2).sqrt()).collect::<Vec<f64>>()
        })
        .into_iter()
        .flatten()
        .collect();
        Self { p, sqrt_lam2: p.lam2.sqrt(), col_norms }
    }

    /// Augmented residual `r̃ = b̃ − Ãx = [b − Ax; −√λ2·x]`, stored split.
    pub fn residual(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let (mut top, mut bottom) = (Vec::new(), Vec::new());
        self.residual_into(x, &mut top, &mut bottom);
        (top, bottom)
    }

    /// [`AugmentedView::residual`] into caller-reused buffers (resized and
    /// fully overwritten — bitwise the same values).
    pub fn residual_into(&self, x: &[f64], top: &mut Vec<f64>, bottom: &mut Vec<f64>) {
        let m = self.p.m();
        top.resize(m, 0.0);
        self.p.a.mul_vec_into(x, top);
        for (t, &b) in top.iter_mut().zip(self.p.b.iter()) {
            *t = b - *t;
        }
        bottom.resize(x.len(), 0.0);
        for (o, &v) in bottom.iter_mut().zip(x.iter()) {
            *o = -self.sqrt_lam2 * v;
        }
    }

    /// `Ã_jᵀ ṽ` for split vector `(v_top, v_bottom)`.
    #[inline]
    pub fn col_dot(&self, j: usize, v_top: &[f64], v_bottom: &[f64]) -> f64 {
        self.p.a.col_dot(j, v_top) + self.sqrt_lam2 * v_bottom[j]
    }

    /// Primal objective of the augmented Lasso = the Elastic Net objective.
    pub fn primal(&self, x: &[f64]) -> f64 {
        primal_objective(self.p, x)
    }

    /// Dual objective of the augmented Lasso at the **feasible** scaled point
    /// `θ = r̃·s` with `s = min(1, λ1/‖Ãᵀr̃‖∞)`:
    /// `D(θ) = ½‖b̃‖² − ½‖b̃ − θ‖²` (with the λ1 scaling folded in the classic
    /// way: D(θ) = ½‖b̃‖² − ½‖θ − b̃‖²). Returns `(dual_value, θ_top, θ_bottom)`.
    pub fn dual_point(&self, x: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        let (mut top, mut bottom) = (Vec::new(), Vec::new());
        let dual = self.dual_point_into(x, &mut top, &mut bottom);
        (dual, top, bottom)
    }

    /// [`AugmentedView::dual_point`] writing the scaled dual point into
    /// caller-reused buffers (the sweep-output reuse behind
    /// [`solve_gap_safe`]'s rounds); returns the dual value. Bitwise the
    /// same results as the allocating wrapper.
    pub fn dual_point_into(&self, x: &[f64], top: &mut Vec<f64>, bottom: &mut Vec<f64>) -> f64 {
        self.residual_into(x, top, bottom);
        // ‖Ãᵀr̃‖∞ — the O(mn) scoring sweep, sharded over feature ranges.
        // Every |Ã_jᵀr̃| is non-negative, so the max of the per-range maxima
        // is bitwise-equal to the serial ascending-j fold at every budget.
        let zmax = {
            let (top_r, bottom_r) = (&*top, &*bottom);
            shard::map_ranges(self.p.n(), 2 * self.p.m(), |range| {
                let mut zmax = 0.0f64;
                for j in range {
                    zmax = zmax.max(self.col_dot(j, top_r, bottom_r).abs());
                }
                zmax
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        let s = if zmax > self.p.lam1 && zmax > 0.0 { self.p.lam1 / zmax } else { 1.0 };
        for v in top.iter_mut() {
            *v *= s;
        }
        for v in bottom.iter_mut() {
            *v *= s;
        }
        // D(θ) = ½‖b̃‖² − ½‖b̃ − θ‖²; b̃ bottom = 0.
        let b_sq = blas::nrm2_sq(self.p.b);
        let mut diff_sq = 0.0;
        for i in 0..self.p.m() {
            let d = self.p.b[i] - top[i];
            diff_sq += d * d;
        }
        diff_sq += blas::nrm2_sq(bottom);
        0.5 * b_sq - 0.5 * diff_sq
    }

    /// Gap-Safe screen: returns the surviving feature indices given iterate `x`.
    /// Every discarded feature provably has `x*_j = 0`. The O(mn) survivor
    /// scoring is sharded over feature ranges; concatenating the per-range
    /// keeps in range order reproduces the serial ascending-j scan exactly.
    pub fn gap_safe_survivors(&self, x: &[f64]) -> Vec<usize> {
        let (mut top, mut bottom, mut out) = (Vec::new(), Vec::new(), Vec::new());
        self.gap_safe_survivors_into(x, &mut top, &mut bottom, &mut out);
        out
    }

    /// [`AugmentedView::gap_safe_survivors`] writing the scaled dual point
    /// and the survivor set into caller-reused buffers. Single-shard plans
    /// push straight into `out` (no per-range keep lists); multi-shard plans
    /// concatenate per-range keeps in range order — both reproduce the
    /// serial ascending-j scan exactly.
    pub fn gap_safe_survivors_into(
        &self,
        x: &[f64],
        theta_top: &mut Vec<f64>,
        theta_bottom: &mut Vec<f64>,
        out: &mut Vec<usize>,
    ) {
        let dual = self.dual_point_into(x, theta_top, theta_bottom);
        let gap = (self.primal(x) - dual).max(0.0);
        let radius = (2.0 * gap).sqrt();
        let (top, bottom) = (&*theta_top, &*theta_bottom);
        out.clear();
        let keep_range = |range: std::ops::Range<usize>, keep: &mut Vec<usize>| {
            for j in range {
                let score = self.col_dot(j, top, bottom).abs() + radius * self.col_norms[j];
                if score >= self.p.lam1 - 1e-12 {
                    keep.push(j);
                }
            }
        };
        let n = self.p.n();
        if shard::Plan::for_work(n, 2 * self.p.m()).shards <= 1 {
            keep_range(0..n, out);
            return;
        }
        for keep in shard::map_ranges(n, 2 * self.p.m(), |range| {
            let mut keep = Vec::new();
            keep_range(range, &mut keep);
            keep
        }) {
            out.extend_from_slice(&keep);
        }
    }
}

/// Coordinate descent restricted to a feature subset, on the *original*
/// problem (the λ2 term is handled in the CD update itself) — shared by the
/// GSR-like and celer-like solvers.
pub fn cd_on_set(
    p: &EnetProblem,
    x: &mut [f64],
    res: &mut [f64],
    col_sq: &[f64],
    set: &[usize],
    tol: f64,
    max_sweeps: usize,
) -> usize {
    let mut sweeps = 0;
    for _ in 0..max_sweeps {
        sweeps += 1;
        let mut max_change = 0.0f64;
        let mut max_x = 0.0f64;
        for &j in set {
            let cj = col_sq[j];
            if cj == 0.0 {
                continue;
            }
            let rho = p.a.col_dot(j, res) + cj * x[j];
            let new = crate::prox::soft_threshold(rho, p.lam1) / (cj + p.lam2);
            let delta = new - x[j];
            if delta != 0.0 {
                p.a.col_axpy(-delta, j, res);
                x[j] = new;
            }
            max_change = max_change.max(delta.abs());
            max_x = max_x.max(x[j].abs());
        }
        if max_change <= tol * max_x.max(1e-12) {
            break;
        }
    }
    sweeps
}

/// Gap-Safe screened coordinate descent (the GSR competitor).
///
/// Outer rounds: screen with the current iterate, then run CD on the survivors
/// until the *global* duality gap is below tolerance.
pub fn solve_gap_safe(p: &EnetProblem, opts: &BaselineOptions) -> SolveResult {
    let n = p.n();
    let aug = AugmentedView::new(p);
    let mut x = vec![0.0; n];
    let ax = p.a.mul_vec(&x);
    let mut res: Vec<f64> = (0..p.m()).map(|i| p.b[i] - ax[i]).collect();
    // O(mn) column-norm precompute (O(nnz) on CSC), sharded (per-column
    // values are identical to the serial sweep at every thread budget and
    // storage).
    let a = p.a;
    let col_sq: Vec<f64> = shard::map_ranges(p.n(), 2 * p.m(), move |range| {
        range.map(|j| a.col_nrm2_sq(j)).collect::<Vec<f64>>()
    })
    .into_iter()
    .flatten()
    .collect();

    let mut rounds = 0usize;
    let mut inner = 0usize;
    let mut converged = false;
    let mut last_gap = f64::INFINITY;
    let obj_scale = 1.0 + blas::nrm2_sq(p.b);
    let mut survivors: Vec<usize> = (0..n).collect();
    // Sweep-output buffers reused across screening rounds (the `_into`
    // variants resize + overwrite them fully each round).
    let (mut theta_top, mut theta_bottom) = (Vec::new(), Vec::new());

    // The caller's iteration cap bounds screening rounds, clamped to the
    // solver's 100-round safety net: one round is a full working-set CD
    // convergence plus an O(mn) screen — far coarser than the sweep/epoch
    // unit `max_iters` means elsewhere — so honoring a 100_000 default
    // verbatim would turn a bounded non-convergence into a near-hang. (The
    // old hard-coded cap ignored `opts.max_iters` entirely; tightening now
    // works.)
    while rounds < opts.max_iters.min(100) {
        rounds += 1;
        aug.gap_safe_survivors_into(&x, &mut theta_top, &mut theta_bottom, &mut survivors);
        // keep current nonzeros (they survive by definition, but be safe)
        inner += cd_on_set(p, &mut x, &mut res, &col_sq, &survivors, opts.tol, 1000);
        let dual = aug.dual_point_into(&x, &mut theta_top, &mut theta_bottom);
        last_gap = aug.primal(&x) - dual;
        if last_gap <= opts.tol * obj_scale {
            converged = true;
            break;
        }
    }

    let active_set = support_of(&x, 0.0);
    let objective = primal_objective(p, &x);
    let y: Vec<f64> = res.iter().map(|r| -r).collect();
    SolveResult {
        x,
        y,
        active_set,
        screen_survivors: Some(survivors.len()),
        objective,
        iterations: rounds,
        inner_iterations: inner,
        residual: last_gap,
        converged,
        algorithm: Algorithm::CdGapSafe,
    }
}

/// [`crate::solver::Solver`] registry entry for Gap-Safe screened CD
/// (GSR-like).
#[derive(Clone, Copy, Debug, Default)]
pub struct GapSafeSolver;

impl crate::solver::Solver for GapSafeSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::CdGapSafe
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_gap_safe(p, &cfg.baseline_options())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::solver::types::BaselineOptions;

    fn problem(seed: u64, alpha: f64, c: f64) -> (crate::data::SyntheticProblem, f64, f64) {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 200,
            n0: 5,
            x_star: 5.0,
            snr: 10.0,
            seed,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(alpha, c, lmax);
        (prob, l1, l2)
    }

    #[test]
    fn screening_is_safe() {
        // No feature of the true optimum's support may be screened out.
        let (prob, l1, l2) = problem(1, 0.9, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let exact = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        let aug = AugmentedView::new(&p);
        // screen at a crude iterate (x = 0)
        let survivors = aug.gap_safe_survivors(&vec![0.0; p.n()]);
        for &j in &exact.active_set {
            assert!(survivors.contains(&j), "safe rule discarded active feature {j}");
        }
    }

    #[test]
    fn screening_tightens_with_better_iterates() {
        let (prob, l1, l2) = problem(2, 0.9, 0.5);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let aug = AugmentedView::new(&p);
        let at_zero = aug.gap_safe_survivors(&vec![0.0; p.n()]).len();
        let exact = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-12, ..Default::default() },
        );
        let at_opt = aug.gap_safe_survivors(&exact.x).len();
        assert!(at_opt <= at_zero);
        // near the optimum the sphere is tiny: survivors ≈ active set
        assert!(
            at_opt <= exact.active_set.len() + 25,
            "survivors {at_opt} vs active {}",
            exact.active_set.len()
        );
    }

    #[test]
    fn gap_safe_solver_matches_cd() {
        let (prob, l1, l2) = problem(3, 0.999, 0.4);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let gs = solve_gap_safe(&p, &BaselineOptions { tol: 1e-9, ..Default::default() });
        let cd = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        assert!(gs.converged);
        assert!(blas::dist2(&gs.x, &cd.x) < 1e-4);
        // the final survivor count is surfaced on the result itself
        let surv = gs.screen_survivors.expect("gap-safe reports survivors");
        assert!(surv <= p.n(), "survivors {surv} > n {}", p.n());
        assert!(surv > 0, "converged solve screened everything out");
    }

    #[test]
    fn dual_point_is_feasible() {
        let (prob, l1, l2) = problem(4, 0.8, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let aug = AugmentedView::new(&p);
        for x_scale in [0.0, 0.1, 1.0] {
            let x: Vec<f64> = prob.x_true.iter().map(|v| v * x_scale).collect();
            let (_, top, bottom) = aug.dual_point(&x);
            for j in 0..p.n() {
                let v = aug.col_dot(j, &top, &bottom).abs();
                assert!(v <= p.lam1 + 1e-8, "infeasible dual at {j}: {v} > {}", p.lam1);
            }
        }
    }

    #[test]
    fn augmented_norms() {
        let (prob, l1, l2) = problem(5, 0.7, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let aug = AugmentedView::new(&p);
        for j in [0usize, 10, 199] {
            let expect = (blas::nrm2_sq(prob.a.col(j)) + l2).sqrt();
            assert!((aug.col_norms[j] - expect).abs() < 1e-12);
        }
    }
}
