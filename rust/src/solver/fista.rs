//! Proximal-gradient baselines: ISTA and FISTA (Beck & Teboulle 2009).
//!
//! The paper cites these as standard first-order competitors whose cost is
//! "more than two orders of magnitude larger" than SsNAL-EN on Elastic Net
//! instances (§4.1) — we reproduce them to verify that claim's shape.
//!
//! Iteration: `x⁺ = prox_{p/L}(x − ∇f(x)/L)` with `f(x) = ½‖Ax−b‖²`,
//! `∇f(x) = Aᵀ(Ax−b)`, `L = λ_max(AᵀA)` (power iteration), and the prox of the
//! full Elastic Net penalty (λ2 folded into the prox, not the gradient, which
//! keeps L independent of λ2). FISTA adds Nesterov momentum.

use crate::linalg::blas;
use crate::prox;
use crate::solver::objective::{dual_objective, primal_objective, support_of};
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult};

/// Estimate `L = λ_max(AᵀA)` by power iteration on `AᵀA` (via A and Aᵀ).
pub fn lipschitz_constant(p: &EnetProblem, iters: usize) -> f64 {
    let n = p.n();
    let mut v = vec![0.0; n];
    // deterministic start that is unlikely to be orthogonal to the top eigvec
    for (j, vj) in v.iter_mut().enumerate() {
        *vj = 1.0 + (j as f64 * 0.61803398875).fract();
    }
    let mut av = vec![0.0; p.m()];
    let mut atav = vec![0.0; n];
    let mut lam = 1.0;
    for _ in 0..iters {
        let norm = blas::nrm2(&v);
        if norm == 0.0 {
            return 1.0;
        }
        blas::scal(1.0 / norm, &mut v);
        p.a.mul_vec_into(&v, &mut av);
        p.a.t_mul_vec_into(&av, &mut atav);
        lam = blas::dot(&v, &atav);
        v.copy_from_slice(&atav);
    }
    lam.max(1e-12)
}

/// Solve with FISTA (`accelerated = true`) or ISTA (`accelerated = false`).
pub fn solve_fista(p: &EnetProblem, opts: &BaselineOptions, accelerated: bool) -> SolveResult {
    let m = p.m();
    let n = p.n();
    let lip = lipschitz_constant(p, 50) * 1.02; // small safety factor
    let step = 1.0 / lip;

    let mut x = vec![0.0; n];
    let mut v = x.clone(); // momentum point
    let mut t_momentum = 1.0f64;
    let mut av = vec![0.0; m];
    let mut grad = vec![0.0; n];
    let mut x_new = vec![0.0; n];

    let mut iters = 0usize;
    let mut converged = false;
    let mut last_gap = f64::INFINITY;
    let obj_scale = 1.0 + blas::nrm2_sq(p.b);
    let gap_check_every = 10;

    while iters < opts.max_iters {
        iters += 1;
        // ∇f(v) = Aᵀ(Av − b)
        p.a.mul_vec_into(&v, &mut av);
        for i in 0..m {
            av[i] -= p.b[i];
        }
        p.a.t_mul_vec_into(&av, &mut grad);
        // x⁺ = prox_{step·p}(v − step·∇f)
        for j in 0..n {
            let t = v[j] - step * grad[j];
            x_new[j] = prox::prox_enet_scalar(t, step, p.lam1, p.lam2);
        }
        if accelerated {
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_momentum * t_momentum).sqrt());
            let beta = (t_momentum - 1.0) / t_next;
            for j in 0..n {
                v[j] = x_new[j] + beta * (x_new[j] - x[j]);
            }
            t_momentum = t_next;
        } else {
            v.copy_from_slice(&x_new);
        }
        std::mem::swap(&mut x, &mut x_new);

        if iters % gap_check_every == 0 {
            last_gap = gap_at(p, &x);
            if last_gap <= opts.tol * obj_scale {
                converged = true;
                break;
            }
        }
    }
    if !converged {
        last_gap = gap_at(p, &x);
        converged = last_gap <= opts.tol * obj_scale;
    }

    let active_set = support_of(&x, 0.0);
    let objective = primal_objective(p, &x);
    let ax = p.a.mul_vec(&x);
    let y: Vec<f64> = (0..m).map(|i| ax[i] - p.b[i]).collect();
    SolveResult {
        x,
        y,
        active_set,
        screen_survivors: None,
        objective,
        iterations: iters,
        inner_iterations: 0,
        residual: last_gap,
        converged,
        algorithm: if accelerated { Algorithm::Fista } else { Algorithm::ProximalGradient },
    }
}

/// Duality gap with the natural dual pair (see `cd::CdState::gap`).
fn gap_at(p: &EnetProblem, x: &[f64]) -> f64 {
    let ax = p.a.mul_vec(x);
    let y: Vec<f64> = (0..p.m()).map(|i| ax[i] - p.b[i]).collect();
    let mut z = p.a.t_mul_vec(&y);
    for v in z.iter_mut() {
        *v = -*v;
    }
    if p.lam2 == 0.0 {
        let zmax = blas::nrm_inf(&z);
        if zmax > p.lam1 && zmax > 0.0 {
            let s = p.lam1 / zmax;
            let ys: Vec<f64> = y.iter().map(|v| v * s).collect();
            for v in z.iter_mut() {
                *v *= s;
            }
            return primal_objective(p, x) - dual_objective(p, &ys, &z);
        }
    }
    primal_objective(p, x) - dual_objective(p, &y, &z)
}

/// [`crate::solver::Solver`] registry entry for FISTA (accelerated proximal
/// gradient).
#[derive(Clone, Copy, Debug, Default)]
pub struct FistaSolver;

impl crate::solver::Solver for FistaSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Fista
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_fista(p, &cfg.baseline_options(), true)
    }
}

/// [`crate::solver::Solver`] registry entry for plain proximal gradient
/// (ISTA).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProximalGradientSolver;

impl crate::solver::Solver for ProximalGradientSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::ProximalGradient
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_fista(p, &cfg.baseline_options(), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    fn problem(seed: u64) -> (crate::data::SyntheticProblem, f64, f64) {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 100,
            n0: 5,
            x_star: 5.0,
            snr: 5.0,
            seed,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        (prob, l1, l2)
    }

    #[test]
    fn lipschitz_close_to_power_method_truth() {
        let (prob, l1, l2) = problem(1);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let lip = lipschitz_constant(&p, 100);
        // compare against a long power iteration
        let lip_ref = lipschitz_constant(&p, 500);
        assert!((lip - lip_ref).abs() / lip_ref < 1e-3);
    }

    #[test]
    fn fista_matches_cd_solution() {
        let (prob, l1, l2) = problem(2);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let opts = BaselineOptions { tol: 1e-10, max_iters: 50_000, verbose: false };
        let f = solve_fista(&p, &opts, true);
        let cd = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        assert!(f.converged, "gap={}", f.residual);
        assert!(blas::dist2(&f.x, &cd.x) < 1e-4);
    }

    #[test]
    fn fista_faster_than_ista() {
        let (prob, l1, l2) = problem(3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let opts = BaselineOptions { tol: 1e-8, max_iters: 100_000, verbose: false };
        let fista = solve_fista(&p, &opts, true);
        let ista = solve_fista(&p, &opts, false);
        assert!(fista.converged && ista.converged);
        assert!(
            fista.iterations <= ista.iterations,
            "fista {} vs ista {}",
            fista.iterations,
            ista.iterations
        );
    }

    #[test]
    fn objective_monotone_under_ista() {
        // ISTA is a descent method: objective decreases every iteration.
        let (prob, l1, l2) = problem(4);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        // run a few manual iterations and track the objective
        let lip = lipschitz_constant(&p, 50) * 1.02;
        let step = 1.0 / lip;
        let mut x = vec![0.0; p.n()];
        let mut prev = primal_objective(&p, &x);
        for _ in 0..20 {
            let ax = p.a.mul_vec(&x);
            let r: Vec<f64> = (0..p.m()).map(|i| ax[i] - p.b[i]).collect();
            let g = p.a.t_mul_vec(&r);
            for j in 0..p.n() {
                x[j] = prox::prox_enet_scalar(x[j] - step * g[j], step, p.lam1, p.lam2);
            }
            let obj = primal_objective(&p, &x);
            assert!(obj <= prev + 1e-10, "{obj} > {prev}");
            prev = obj;
        }
    }
}
