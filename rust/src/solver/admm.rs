//! ADMM baseline (Boyd et al. 2011) for the Elastic Net.
//!
//! Splitting: `min_x f(x) + g(w)` s.t. `x = w`, with
//! `f(x) = ½‖Ax−b‖² + (λ2/2)‖x‖²` and `g(w) = λ1‖w‖₁`.
//!
//! x-update solves `(AᵀA + (λ2+ρ)I) x = Aᵀb + ρ(w − u)`. For n ≫ m we apply the
//! matrix-inversion lemma once: with `c = λ2 + ρ`,
//! `(AᵀA + cI)⁻¹ v = (v − Aᵀ(AAᵀ + cI)⁻¹ A v)/c`, so a single m×m Cholesky
//! factorization is reused across all iterations.

use crate::linalg::{blas, Cholesky, DesignRef, Mat};
use crate::solver::objective::{primal_objective, support_of};
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult};

/// ADMM options beyond the shared baseline ones.
#[derive(Clone, Debug)]
pub struct AdmmOptions {
    /// Penalty parameter ρ.
    pub rho: f64,
    /// Over-relaxation (1.0 = none; 1.5–1.8 typical).
    pub alpha: f64,
}

impl Default for AdmmOptions {
    fn default() -> Self {
        Self { rho: 1.0, alpha: 1.5 }
    }
}

/// Solve with ADMM.
pub fn solve_admm(p: &EnetProblem, opts: &BaselineOptions, admm: &AdmmOptions) -> SolveResult {
    let m = p.m();
    let n = p.n();
    let rho = admm.rho;
    let c = p.lam2 + rho;

    // Factor (AAᵀ + cI) once — m×m. Both storage arms accumulate the lower
    // triangle in the same (j, a_, b_) order; the sparse arm only skips terms
    // where the stored column is exactly zero, which the dense arm's `s != 0.0`
    // guard (and the ±0.0 addition identity) already make bit-neutral.
    let mut aat = Mat::zeros(m, m);
    match p.a {
        DesignRef::Dense(dm) => {
            for j in 0..n {
                let col = dm.col(j);
                for a_ in 0..m {
                    let s = col[a_];
                    if s != 0.0 {
                        let cc = aat.col_mut(a_);
                        for b_ in a_..m {
                            cc[b_] += s * col[b_];
                        }
                    }
                }
            }
        }
        DesignRef::Sparse(sp) => {
            for j in 0..n {
                let (rs, vs) = sp.col(j);
                for (k, (&a_, &s)) in rs.iter().zip(vs.iter()).enumerate() {
                    let cc = aat.col_mut(a_);
                    for (&b_, &val) in rs[k..].iter().zip(vs[k..].iter()) {
                        cc[b_] += s * val;
                    }
                }
            }
        }
        DesignRef::OutOfCore(oc) => {
            // Dense arm verbatim over decoded panels (one pass, j-outer).
            for j in 0..n {
                oc.with_col(j, |col| {
                    for a_ in 0..m {
                        let s = col[a_];
                        if s != 0.0 {
                            let cc = aat.col_mut(a_);
                            for b_ in a_..m {
                                cc[b_] += s * col[b_];
                            }
                        }
                    }
                });
            }
        }
    }
    // symmetrize upper from lower not needed (Cholesky reads lower); add cI
    for i in 0..m {
        aat.set(i, i, aat.get(i, i) + c);
    }
    let ch = Cholesky::factor(&aat).expect("AAᵀ + cI is SPD");

    let atb = p.a.t_mul_vec(p.b);

    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut u = vec![0.0; n];
    let mut v = vec![0.0; n];
    let mut av = vec![0.0; m];
    let mut atav = vec![0.0; n];
    let mut w_old = vec![0.0; n];

    let mut iters = 0usize;
    let mut converged = false;
    let mut final_res = f64::INFINITY;

    while iters < opts.max_iters {
        iters += 1;
        // x-update: x = (AᵀA + cI)⁻¹ (Aᵀb + ρ(w − u))
        for j in 0..n {
            v[j] = atb[j] + rho * (w[j] - u[j]);
        }
        p.a.mul_vec_into(&v, &mut av);
        ch.solve_in_place(&mut av);
        p.a.t_mul_vec_into(&av, &mut atav);
        for j in 0..n {
            x[j] = (v[j] - atav[j]) / c;
        }
        // w-update with over-relaxation: ŵ = αx + (1−α)w
        w_old.copy_from_slice(&w);
        let thr = p.lam1 / rho;
        for j in 0..n {
            let xh = admm.alpha * x[j] + (1.0 - admm.alpha) * w_old[j];
            w[j] = crate::prox::soft_threshold(xh + u[j], thr);
            u[j] += xh - w[j];
        }
        // primal/dual residuals
        let prim: f64 = blas::dist2(&x, &w);
        let dual: f64 = rho * blas::dist2(&w, &w_old);
        let scale = 1.0 + blas::nrm2(&x).max(blas::nrm2(&w));
        final_res = (prim / scale).max(dual / scale);
        if final_res <= opts.tol {
            converged = true;
            break;
        }
    }

    let active_set = support_of(&w, 0.0);
    let objective = primal_objective(p, &w);
    let aw = p.a.mul_vec(&w);
    let y: Vec<f64> = (0..m).map(|i| aw[i] - p.b[i]).collect();
    SolveResult {
        x: w,
        y,
        active_set,
        screen_survivors: None,
        objective,
        iterations: iters,
        inner_iterations: 0,
        residual: final_res,
        converged,
        algorithm: Algorithm::Admm,
    }
}

/// [`crate::solver::Solver`] registry entry for ADMM, honoring the config's
/// `admm` block (ρ, over-relaxation).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmmSolver;

impl crate::solver::Solver for AdmmSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Admm
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_admm(p, &cfg.baseline_options(), &cfg.admm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    #[test]
    fn admm_matches_cd() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 100,
            n0: 5,
            x_star: 5.0,
            snr: 5.0,
            seed: 7,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let admm = solve_admm(
            &p,
            &BaselineOptions { tol: 1e-9, max_iters: 20_000, verbose: false },
            &AdmmOptions::default(),
        );
        let cd = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        assert!(admm.converged, "residual {}", admm.residual);
        assert!(blas::dist2(&admm.x, &cd.x) < 1e-4);
        assert!((admm.objective - cd.objective).abs() < 1e-5 * (1.0 + cd.objective));
    }

    #[test]
    fn admm_zero_above_lambda_max() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 30,
            n: 60,
            n0: 3,
            x_star: 5.0,
            snr: 5.0,
            seed: 8,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 1.0);
        let p = EnetProblem::new(&prob.a, &prob.b, lmax * 1.05, 0.5);
        let res = solve_admm(
            &p,
            &BaselineOptions { tol: 1e-8, max_iters: 20_000, verbose: false },
            &AdmmOptions::default(),
        );
        assert!(res.converged);
        assert_eq!(res.active_set.len(), 0);
    }

    #[test]
    fn rho_affects_iterations_not_solution() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 30,
            n: 80,
            n0: 4,
            x_star: 5.0,
            snr: 5.0,
            seed: 9,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.4, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let opts = BaselineOptions { tol: 1e-9, max_iters: 50_000, verbose: false };
        let r1 = solve_admm(&p, &opts, &AdmmOptions { rho: 0.5, alpha: 1.5 });
        let r2 = solve_admm(&p, &opts, &AdmmOptions { rho: 5.0, alpha: 1.5 });
        assert!(r1.converged && r2.converged);
        assert!(blas::dist2(&r1.x, &r2.x) < 1e-4);
    }
}
