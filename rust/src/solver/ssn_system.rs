//! The semi-smooth Newton linear system `V d = −∇ψ(y)` with
//! `V = I_m + κ A_J A_Jᵀ`, `κ = σ/(1+σλ2)` (paper §3.2, Eq. 16–19).
//!
//! Three strategies, chosen per-iteration from `(m, r)`:
//!
//! * **Direct** — form the m×m matrix and Cholesky it: `O(m²r + m³)`.
//! * **Woodbury** — Eq. (19): factor `κ⁻¹I_r + A_JᵀA_J` (r×r): `O(r²m + r³)`.
//!   The paper's headline trick when the Elastic Net solution is sparse (r < m).
//! * **CG** — matrix-free `v ↦ v + κ A_J(A_Jᵀv)`: `O(mr)` per iteration, for the
//!   early iterations where both m and r exceed ~10⁴.
//!
//! Columns of `A_J` are addressed in place (column-major `Mat` makes them
//! contiguous), so no gather/copy is performed.
//!
//! The Woodbury Gram build, its `A_Jᵀrhs`/`A_J w` sweeps, the CG mat-vec,
//! and the direct strategy's m×m rank-1 triangle build route through
//! [`crate::parallel::shard`]: on large problems they fan out over the
//! persistent worker pool. Per the shard module's determinism contract the
//! results are bitwise-invariant to the thread count (the Gram, `A_Jᵀrhs`
//! and rank-1 triangle sweeps are also bitwise-equal to the serial loops;
//! the `A_J w` accumulation matches serial exactly only while its plan is
//! single-shard).

use crate::linalg::{solve_cg, Cholesky, Mat};
use crate::parallel::shard;
use crate::solver::types::NewtonStrategy;

/// Which strategy actually ran (Auto resolves to one of the concrete three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolvedStrategy {
    Identity,
    Direct,
    Woodbury,
    Cg,
}

/// Solve `(I + κ A_J A_Jᵀ) d = rhs`, writing `d` (length m).
///
/// Returns the resolved strategy (for diagnostics / EXPERIMENTS.md §Perf).
pub fn solve_newton_system(
    a: &Mat,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    strategy: NewtonStrategy,
    cg_tol: f64,
    cg_max_iters: usize,
) -> ResolvedStrategy {
    let m = a.rows();
    let r = active.len();
    assert_eq!(rhs.len(), m);
    assert_eq!(d.len(), m);

    if r == 0 || kappa == 0.0 {
        // V = I
        d.copy_from_slice(rhs);
        return ResolvedStrategy::Identity;
    }

    let resolved = match strategy {
        NewtonStrategy::Direct => ResolvedStrategy::Direct,
        NewtonStrategy::Woodbury => ResolvedStrategy::Woodbury,
        NewtonStrategy::ConjugateGradient => ResolvedStrategy::Cg,
        NewtonStrategy::Auto => {
            // Cost-based choice (flop estimates):
            //   direct   ≈ m²·r/2 + m³/6       (gram build + Cholesky)
            //   woodbury ≈ r²·m/2 + r³/6       (Eq. 19)
            //   cg       ≈ 2·m·r·iters          (matrix-free)
            // CG's iteration count scales with √cond(V); V = I + κA_JA_Jᵀ has
            // cond ≤ 1 + κ·λmax(A_JA_Jᵀ) ≈ 1 + κ·r on standardized designs, so
            // with λ2 > 0 (κ = σ/(1+σλ2) small) CG converges in a handful of
            // iterations even when r ≫ m — the regime where direct/Woodbury
            // cost explodes. This refines the paper's §3.2 guidance ("use CG
            // when m and r are both large") with an explicit model.
            let mf = m as f64;
            let rf = r as f64;
            let cond_est = 1.0 + kappa * rf;
            let cg_iters_est = (6.0 * cond_est.sqrt()).clamp(8.0, 120.0);
            let cost_direct = 0.5 * mf * mf * rf + mf * mf * mf / 6.0;
            let cost_woodbury = 0.5 * rf * rf * mf + rf * rf * rf / 6.0;
            let cost_cg = 2.0 * mf * rf * cg_iters_est;
            if cost_woodbury <= cost_direct && cost_woodbury <= cost_cg {
                ResolvedStrategy::Woodbury
            } else if cost_direct <= cost_cg {
                ResolvedStrategy::Direct
            } else {
                ResolvedStrategy::Cg
            }
        }
    };

    match resolved {
        ResolvedStrategy::Identity => unreachable!(),
        ResolvedStrategy::Direct => solve_direct(a, active, kappa, rhs, d),
        ResolvedStrategy::Woodbury => solve_woodbury(a, active, kappa, rhs, d),
        ResolvedStrategy::Cg => solve_cg_strategy(a, active, kappa, rhs, d, cg_tol, cg_max_iters),
    }
    resolved
}

/// Direct: build `M = I + κ Σ_{j∈J} a_j a_jᵀ` and Cholesky-solve. The m×m
/// rank-1 lower-triangle build (the strategy's O(m²r) sweep; factor reads
/// lower) is sharded over the worker pool.
fn solve_direct(a: &Mat, active: &[usize], kappa: f64, rhs: &[f64], d: &mut [f64]) {
    let m = a.rows();
    let mut v = Mat::zeros(m, m);
    shard::rank1_lower_accum(a, active, kappa, &mut v);
    for i in 0..m {
        v.set(i, i, v.get(i, i) + 1.0);
    }
    let ch = Cholesky::factor(&v).expect("I + κ A_J A_Jᵀ is SPD");
    d.copy_from_slice(rhs);
    ch.solve_in_place(d);
}

/// Woodbury (Eq. 19): `V⁻¹ rhs = rhs − A_J (κ⁻¹I_r + A_JᵀA_J)⁻¹ A_Jᵀ rhs`.
fn solve_woodbury(a: &Mat, active: &[usize], kappa: f64, rhs: &[f64], d: &mut [f64]) {
    let g = shard::gram_of_cols(a, active, 1.0 / kappa);
    let ch = Cholesky::factor(&g).expect("κ⁻¹I + A_JᵀA_J is SPD");
    // w = A_Jᵀ rhs
    let mut w = vec![0.0; active.len()];
    shard::col_dots(a, active, rhs, 1.0, &mut w);
    ch.solve_in_place(&mut w);
    // d = rhs − A_J w
    d.copy_from_slice(rhs);
    for v in w.iter_mut() {
        *v = -*v;
    }
    shard::add_scaled_cols(a, active, &w, d);
}

/// Matrix-free CG on `v ↦ v + κ A_J (A_Jᵀ v)`.
fn solve_cg_strategy(
    a: &Mat,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    cg_tol: f64,
    cg_max_iters: usize,
) {
    d.iter_mut().for_each(|v| *v = 0.0);
    let mut coeffs = vec![0.0; active.len()];
    solve_cg(
        |v, out| {
            shard::col_dots(a, active, v, kappa, &mut coeffs);
            out.copy_from_slice(v);
            shard::add_scaled_cols(a, active, &coeffs, out);
        },
        rhs,
        d,
        cg_tol,
        cg_max_iters,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::rng::Xoshiro256pp;

    fn apply_v(a: &Mat, active: &[usize], kappa: f64, v: &[f64]) -> Vec<f64> {
        let mut out = v.to_vec();
        for &j in active {
            let c = blas::dot(a.col(j), v) * kappa;
            blas::axpy(c, a.col(j), &mut out);
        }
        out
    }

    fn random_case(m: usize, n: usize, r: usize, seed: u64) -> (Mat, Vec<usize>, Vec<f64>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
        let active = rng.sample_indices(n, r);
        let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
        (a, active, rhs)
    }

    fn check_strategy(strategy: NewtonStrategy, m: usize, n: usize, r: usize, seed: u64) {
        let (a, active, rhs) = random_case(m, n, r, seed);
        let kappa = 0.7;
        let mut d = vec![0.0; m];
        solve_newton_system(&a, &active, kappa, &rhs, &mut d, strategy, 1e-12, 2000);
        let back = apply_v(&a, &active, kappa, &d);
        for i in 0..m {
            assert!(
                (back[i] - rhs[i]).abs() < 1e-6,
                "{strategy:?} m={m} r={r}: residual {} at {i}",
                (back[i] - rhs[i]).abs()
            );
        }
    }

    #[test]
    fn direct_solves_exactly() {
        check_strategy(NewtonStrategy::Direct, 20, 100, 7, 1);
        check_strategy(NewtonStrategy::Direct, 30, 50, 40, 2); // r > m
    }

    #[test]
    fn woodbury_solves_exactly() {
        check_strategy(NewtonStrategy::Woodbury, 25, 120, 5, 3);
        check_strategy(NewtonStrategy::Woodbury, 25, 120, 24, 4);
    }

    #[test]
    fn cg_solves_to_tolerance() {
        check_strategy(NewtonStrategy::ConjugateGradient, 40, 200, 15, 5);
    }

    #[test]
    fn auto_matches_direct_result() {
        let (a, active, rhs) = random_case(30, 80, 6, 6);
        let kappa = 1.3;
        let mut d_auto = vec![0.0; 30];
        let mut d_dir = vec![0.0; 30];
        let res = solve_newton_system(
            &a, &active, kappa, &rhs, &mut d_auto, NewtonStrategy::Auto, 1e-12, 1000,
        );
        assert_eq!(res, ResolvedStrategy::Woodbury, "r < m should pick Woodbury");
        solve_newton_system(
            &a, &active, kappa, &rhs, &mut d_dir, NewtonStrategy::Direct, 1e-12, 1000,
        );
        for i in 0..30 {
            assert!((d_auto[i] - d_dir[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn empty_active_set_is_identity() {
        let (a, _, rhs) = random_case(10, 20, 0, 7);
        let mut d = vec![0.0; 10];
        let res = solve_newton_system(
            &a, &[], 0.9, &rhs, &mut d, NewtonStrategy::Auto, 1e-10, 100,
        );
        assert_eq!(res, ResolvedStrategy::Identity);
        assert_eq!(d, rhs);
    }

    #[test]
    fn auto_picks_direct_when_r_ge_m_small() {
        let (a, active, rhs) = random_case(15, 30, 20, 8);
        let mut d = vec![0.0; 15];
        let res = solve_newton_system(
            &a, &active, 0.5, &rhs, &mut d, NewtonStrategy::Auto, 1e-10, 100,
        );
        assert_eq!(res, ResolvedStrategy::Direct);
    }
}
