//! The semi-smooth Newton linear system `V d = −∇ψ(y)` with
//! `V = I_m + κ A_J A_Jᵀ`, `κ = σ/(1+σλ2)` (paper §3.2, Eq. 16–19).
//!
//! Three strategies, chosen per-iteration from `(m, r)`:
//!
//! * **Direct** — form the m×m matrix and Cholesky it: `O(m²r + m³)`.
//! * **Woodbury** — Eq. (19): factor `κ⁻¹I_r + A_JᵀA_J` (r×r): `O(r²m + r³)`.
//!   The paper's headline trick when the Elastic Net solution is sparse (r < m).
//! * **CG** — matrix-free `v ↦ v + κ A_J(A_Jᵀv)`: `O(mr)` per iteration, for the
//!   early iterations where both m and r exceed ~10⁴.
//!
//! Columns of `A_J` are addressed in place (column-major `Mat` makes them
//! contiguous), so no gather/copy is performed.
//!
//! The Woodbury Gram build, its `A_Jᵀrhs`/`A_J w` sweeps, the CG mat-vec,
//! and the direct strategy's m×m rank-1 triangle build route through
//! [`crate::parallel::shard`]: on large problems they fan out over the
//! persistent worker pool. Per the shard module's determinism contract the
//! results are bitwise-invariant to the thread count (the Gram, `A_Jᵀrhs`
//! and rank-1 triangle sweeps are also bitwise-equal to the serial loops;
//! the `A_J w` accumulation matches serial exactly only while its plan is
//! single-shard).

use crate::linalg::{solve_cg_with, DesignRef, NewtonWorkspace};
use crate::parallel::shard;
use crate::solver::types::NewtonStrategy;

/// Which strategy actually ran (Auto resolves to one of the concrete three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolvedStrategy {
    Identity,
    Direct,
    Woodbury,
    Cg,
    /// A direct/Woodbury factorization failed numerically and the solve fell
    /// back to CG (recorded in [`NewtonWorkspace::stats`] and the solver
    /// trace).
    CgFallback,
}

/// Solve `(I + κ A_J A_Jᵀ) d = rhs`, writing `d` (length m), with a fresh
/// workspace (allocates its buffers per call — tests and one-shot callers
/// only; the solver hot path holds a [`NewtonWorkspace`] and calls
/// [`solve_newton_system_ws`]).
///
/// Returns the resolved strategy (for diagnostics / EXPERIMENTS.md §Perf).
pub fn solve_newton_system<'a>(
    a: impl Into<DesignRef<'a>>,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    strategy: NewtonStrategy,
    cg_tol: f64,
    cg_max_iters: usize,
) -> ResolvedStrategy {
    let mut ws = NewtonWorkspace::new();
    solve_newton_system_ws(a, active, kappa, rhs, d, strategy, cg_tol, cg_max_iters, &mut ws)
}

/// [`solve_newton_system`] against a caller-owned [`NewtonWorkspace`]: all
/// strategy buffers are reused, and the direct/Woodbury factorizations go
/// through the workspace's active-set-aware cache — bitwise-identical to the
/// cold path (see [`crate::linalg::workspace`]'s module docs), with
/// steady-state calls (unchanged active set and κ, single-shard plans)
/// performing zero heap allocations. On a numerical factorization failure
/// the solve falls back to CG instead of panicking and reports
/// [`ResolvedStrategy::CgFallback`].
pub fn solve_newton_system_ws<'a>(
    a: impl Into<DesignRef<'a>>,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    strategy: NewtonStrategy,
    cg_tol: f64,
    cg_max_iters: usize,
    ws: &mut NewtonWorkspace,
) -> ResolvedStrategy {
    let a = a.into();
    let m = a.rows();
    let r = active.len();
    assert_eq!(rhs.len(), m);
    assert_eq!(d.len(), m);

    if r == 0 || kappa == 0.0 {
        // V = I
        d.copy_from_slice(rhs);
        return ResolvedStrategy::Identity;
    }

    let resolved = match strategy {
        NewtonStrategy::Direct => ResolvedStrategy::Direct,
        NewtonStrategy::Woodbury => ResolvedStrategy::Woodbury,
        NewtonStrategy::ConjugateGradient => ResolvedStrategy::Cg,
        NewtonStrategy::Auto => {
            // Cost-based choice (flop estimates):
            //   direct   ≈ m²·r/2 + m³/6       (gram build + Cholesky)
            //   woodbury ≈ r²·m/2 + r³/6       (Eq. 19)
            //   cg       ≈ 2·m·r·iters          (matrix-free)
            // CG's iteration count scales with √cond(V); V = I + κA_JA_Jᵀ has
            // cond ≤ 1 + κ·λmax(A_JA_Jᵀ) ≈ 1 + κ·r on standardized designs, so
            // with λ2 > 0 (κ = σ/(1+σλ2) small) CG converges in a handful of
            // iterations even when r ≫ m — the regime where direct/Woodbury
            // cost explodes. This refines the paper's §3.2 guidance ("use CG
            // when m and r are both large") with an explicit model.
            let mf = m as f64;
            let rf = r as f64;
            let cond_est = 1.0 + kappa * rf;
            let cg_iters_est = (6.0 * cond_est.sqrt()).clamp(8.0, 120.0);
            let cost_direct = 0.5 * mf * mf * rf + mf * mf * mf / 6.0;
            let cost_woodbury = 0.5 * rf * rf * mf + rf * rf * rf / 6.0;
            let cost_cg = 2.0 * mf * rf * cg_iters_est;
            if cost_woodbury <= cost_direct && cost_woodbury <= cost_cg {
                ResolvedStrategy::Woodbury
            } else if cost_direct <= cost_cg {
                ResolvedStrategy::Direct
            } else {
                ResolvedStrategy::Cg
            }
        }
    };

    match resolved {
        ResolvedStrategy::Identity | ResolvedStrategy::CgFallback => unreachable!(),
        ResolvedStrategy::Direct => {
            if solve_direct(a, active, kappa, rhs, d, ws).is_err() {
                ws.stats.cg_fallbacks += 1;
                solve_cg_strategy(a, active, kappa, rhs, d, cg_tol, cg_max_iters, ws);
                return ResolvedStrategy::CgFallback;
            }
        }
        ResolvedStrategy::Woodbury => {
            if solve_woodbury(a, active, kappa, rhs, d, ws).is_err() {
                ws.stats.cg_fallbacks += 1;
                solve_cg_strategy(a, active, kappa, rhs, d, cg_tol, cg_max_iters, ws);
                return ResolvedStrategy::CgFallback;
            }
        }
        ResolvedStrategy::Cg => {
            solve_cg_strategy(a, active, kappa, rhs, d, cg_tol, cg_max_iters, ws)
        }
    }
    resolved
}

/// Direct: build `M = I + κ Σ_{j∈J} a_j a_jᵀ` and Cholesky-solve. The m×m
/// rank-1 lower-triangle build (the strategy's O(m²r) sweep; factor reads
/// lower) is sharded over the worker pool; the build buffer and factor live
/// in the workspace and are reused outright when `(J, κ)` repeats. A
/// factorization failure (numerically non-SPD) surfaces as `Err` for the CG
/// fallback instead of panicking.
fn solve_direct(
    a: DesignRef<'_>,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    ws: &mut NewtonWorkspace,
) -> Result<(), ()> {
    let ch = ws.direct_factor(a, active, kappa).map_err(|_| ())?;
    d.copy_from_slice(rhs);
    ch.solve_in_place(d);
    Ok(())
}

/// Woodbury (Eq. 19): `V⁻¹ rhs = rhs − A_J (κ⁻¹I_r + A_JᵀA_J)⁻¹ A_Jᵀ rhs`.
/// The Gram, its Cholesky and the `w` buffer live in the workspace (cache
/// policy in [`crate::linalg::workspace`]); factorization failure surfaces
/// as `Err` for the CG fallback.
fn solve_woodbury(
    a: DesignRef<'_>,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    ws: &mut NewtonWorkspace,
) -> Result<(), ()> {
    ws.woodbury_factor(a, active, kappa).map_err(|_| ())?;
    let (ch, w) = ws.woodbury_parts();
    // w = A_Jᵀ rhs
    w.resize(active.len(), 0.0);
    shard::col_dots(a, active, rhs, 1.0, w);
    ch.solve_in_place(w);
    // d = rhs − A_J w
    d.copy_from_slice(rhs);
    for v in w.iter_mut() {
        *v = -*v;
    }
    shard::add_scaled_cols(a, active, w, d);
    Ok(())
}

/// Matrix-free CG on `v ↦ v + κ A_J (A_Jᵀ v)`; all four working vectors come
/// from the workspace.
fn solve_cg_strategy(
    a: DesignRef<'_>,
    active: &[usize],
    kappa: f64,
    rhs: &[f64],
    d: &mut [f64],
    cg_tol: f64,
    cg_max_iters: usize,
    ws: &mut NewtonWorkspace,
) {
    d.iter_mut().for_each(|v| *v = 0.0);
    let (coeffs, cg_r, cg_p, cg_ap) = ws.cg_parts();
    coeffs.resize(active.len(), 0.0);
    solve_cg_with(
        |v, out| {
            shard::col_dots(a, active, v, kappa, coeffs);
            out.copy_from_slice(v);
            shard::add_scaled_cols(a, active, coeffs, out);
        },
        rhs,
        d,
        cg_tol,
        cg_max_iters,
        cg_r,
        cg_p,
        cg_ap,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::rng::Xoshiro256pp;

    fn apply_v(a: &Mat, active: &[usize], kappa: f64, v: &[f64]) -> Vec<f64> {
        let mut out = v.to_vec();
        for &j in active {
            let c = blas::dot(a.col(j), v) * kappa;
            blas::axpy(c, a.col(j), &mut out);
        }
        out
    }

    fn random_case(m: usize, n: usize, r: usize, seed: u64) -> (Mat, Vec<usize>, Vec<f64>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
        let active = rng.sample_indices(n, r);
        let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
        (a, active, rhs)
    }

    fn check_strategy(strategy: NewtonStrategy, m: usize, n: usize, r: usize, seed: u64) {
        let (a, active, rhs) = random_case(m, n, r, seed);
        let kappa = 0.7;
        let mut d = vec![0.0; m];
        solve_newton_system(&a, &active, kappa, &rhs, &mut d, strategy, 1e-12, 2000);
        let back = apply_v(&a, &active, kappa, &d);
        for i in 0..m {
            assert!(
                (back[i] - rhs[i]).abs() < 1e-6,
                "{strategy:?} m={m} r={r}: residual {} at {i}",
                (back[i] - rhs[i]).abs()
            );
        }
    }

    #[test]
    fn direct_solves_exactly() {
        check_strategy(NewtonStrategy::Direct, 20, 100, 7, 1);
        check_strategy(NewtonStrategy::Direct, 30, 50, 40, 2); // r > m
    }

    #[test]
    fn woodbury_solves_exactly() {
        check_strategy(NewtonStrategy::Woodbury, 25, 120, 5, 3);
        check_strategy(NewtonStrategy::Woodbury, 25, 120, 24, 4);
    }

    #[test]
    fn cg_solves_to_tolerance() {
        check_strategy(NewtonStrategy::ConjugateGradient, 40, 200, 15, 5);
    }

    #[test]
    fn auto_matches_direct_result() {
        let (a, active, rhs) = random_case(30, 80, 6, 6);
        let kappa = 1.3;
        let mut d_auto = vec![0.0; 30];
        let mut d_dir = vec![0.0; 30];
        let res = solve_newton_system(
            &a, &active, kappa, &rhs, &mut d_auto, NewtonStrategy::Auto, 1e-12, 1000,
        );
        assert_eq!(res, ResolvedStrategy::Woodbury, "r < m should pick Woodbury");
        solve_newton_system(
            &a, &active, kappa, &rhs, &mut d_dir, NewtonStrategy::Direct, 1e-12, 1000,
        );
        for i in 0..30 {
            assert!((d_auto[i] - d_dir[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn empty_active_set_is_identity() {
        let (a, _, rhs) = random_case(10, 20, 0, 7);
        let mut d = vec![0.0; 10];
        let res = solve_newton_system(
            &a, &[], 0.9, &rhs, &mut d, NewtonStrategy::Auto, 1e-10, 100,
        );
        assert_eq!(res, ResolvedStrategy::Identity);
        assert_eq!(d, rhs);
    }

    #[test]
    fn woodbury_factor_failure_falls_back_to_cg_and_still_solves() {
        // κ < 0 with |κ|·λmax(A_JA_Jᵀ) < 1: V = I + κA_JA_Jᵀ stays SPD, but
        // the Woodbury matrix κ⁻¹I + A_JᵀA_J is negative-definite, so its
        // Cholesky must fail — the solve has to fall back to CG (and, V
        // being SPD, still produce the right direction) instead of panicking.
        let (a, active, rhs) = random_case(10, 30, 8, 99);
        let kappa = -0.01;
        let mut d = vec![0.0; 10];
        let res = solve_newton_system(
            &a,
            &active,
            kappa,
            &rhs,
            &mut d,
            NewtonStrategy::Woodbury,
            1e-12,
            2000,
        );
        assert_eq!(res, ResolvedStrategy::CgFallback);
        let back = apply_v(&a, &active, kappa, &d);
        for i in 0..10 {
            assert!((back[i] - rhs[i]).abs() < 1e-6, "fallback residual at {i}");
        }
    }

    #[test]
    fn direct_factor_failure_falls_back_without_panicking() {
        // κ ≪ 0 makes V itself indefinite: the direct factor fails and CG
        // cannot converge either — the contract is a clean CgFallback report
        // (and a finite d), never a mid-path panic.
        let (a, active, rhs) = random_case(10, 30, 8, 100);
        let mut d = vec![0.0; 10];
        let res = solve_newton_system(
            &a,
            &active,
            -10.0,
            &rhs,
            &mut d,
            NewtonStrategy::Direct,
            1e-10,
            50,
        );
        assert_eq!(res, ResolvedStrategy::CgFallback);
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn workspace_reuse_matches_fresh_solves() {
        // the same sequence of systems through one workspace must reproduce
        // fresh-workspace results exactly (cache hits return cold bits)
        let (a, active, rhs) = random_case(25, 80, 10, 101);
        for strategy in [NewtonStrategy::Direct, NewtonStrategy::Woodbury] {
            let mut ws = crate::linalg::NewtonWorkspace::new();
            for kappa in [0.7, 0.7, 1.9] {
                let mut d_warm = vec![0.0; 25];
                solve_newton_system_ws(
                    &a, &active, kappa, &rhs, &mut d_warm, strategy, 1e-12, 1000, &mut ws,
                );
                let mut d_cold = vec![0.0; 25];
                solve_newton_system(
                    &a, &active, kappa, &rhs, &mut d_cold, strategy, 1e-12, 1000,
                );
                assert_eq!(d_warm, d_cold, "{strategy:?} κ={kappa}");
            }
            assert!(ws.stats.factor_hits + ws.stats.direct_hits >= 1, "{:?}", ws.stats);
        }
    }

    #[test]
    fn auto_picks_direct_when_r_ge_m_small() {
        let (a, active, rhs) = random_case(15, 30, 20, 8);
        let mut d = vec![0.0; 15];
        let res = solve_newton_system(
            &a, &active, 0.5, &rhs, &mut d, NewtonStrategy::Auto, 1e-10, 100,
        );
        assert_eq!(res, ResolvedStrategy::Direct);
    }
}
