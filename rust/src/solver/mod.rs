//! Elastic Net solvers: the paper's SsNAL-EN and every baseline it is
//! benchmarked against.
//!
//! | module | algorithm | paper role |
//! |---|---|---|
//! | [`ssnal`] | semi-smooth Newton augmented Lagrangian | the contribution (§3) |
//! | [`cd`] | naive + covariance coordinate descent | sklearn / glmnet competitors |
//! | [`fista`] | ISTA / FISTA | first-order competitors (§4.1) |
//! | [`admm`] | ADMM | first-order competitor (§4.1) |
//! | [`screening`] | Gap-Safe sphere screening CD | GSR competitor (D.3) |
//! | [`celer`] | working set + dual extrapolation | celer competitor (D.3) |
//!
//! All solvers consume the same [`types::EnetProblem`] and produce the same
//! [`types::SolveResult`], so the benchmark harness and the agreement tests
//! treat them uniformly.

pub mod admm;
pub mod cd;
pub mod celer;
pub mod fista;
pub mod objective;
pub mod screening;
pub mod ssn_system;
pub mod ssnal;
pub mod types;

pub use objective::{duality_gap, kkt_residuals, primal_objective, support_of, KktResiduals};
pub use types::{
    Algorithm, BaselineOptions, EnetProblem, NewtonStrategy, SolveResult, SsnalOptions,
};

/// Solve one instance with the named algorithm and that algorithm's defaults —
/// the uniform entry point the bench harness uses.
pub fn solve_with(p: &EnetProblem, algo: Algorithm, tol: f64) -> SolveResult {
    let bopts = BaselineOptions { tol, ..Default::default() };
    match algo {
        Algorithm::SsnalEn => ssnal::solve(p, &SsnalOptions { tol, ..Default::default() }),
        Algorithm::CdNaive => cd::solve_naive(p, &bopts),
        Algorithm::CdCovariance => cd::solve_covariance(p, &bopts),
        Algorithm::Fista => fista::solve_fista(p, &bopts, true),
        Algorithm::ProximalGradient => fista::solve_fista(p, &bopts, false),
        Algorithm::Admm => admm::solve_admm(p, &bopts, &admm::AdmmOptions::default()),
        Algorithm::CdGapSafe => screening::solve_gap_safe(p, &bopts),
        Algorithm::Celer => celer::solve_celer(p, &bopts),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::linalg::blas;

    /// The paper's core claim precondition: all solvers minimize the same
    /// objective and converge to the same solution ("we investigated prediction
    /// performance — results are not reported since the three methods solve the
    /// same objective function and converge to the same solution", §4.1).
    #[test]
    fn all_algorithms_agree_on_one_instance() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 8.0,
            seed: 33,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let reference = solve_with(&p, Algorithm::CdNaive, 1e-10);
        for algo in [
            Algorithm::SsnalEn,
            Algorithm::CdCovariance,
            Algorithm::Fista,
            Algorithm::Admm,
            Algorithm::CdGapSafe,
            Algorithm::Celer,
        ] {
            // first-order methods use a gap criterion scaled by ‖b‖² (the
            // sklearn convention), so ask them for more digits
            let tol = match algo {
                Algorithm::Fista | Algorithm::Admm => 1e-10,
                _ => 1e-8,
            };
            let res = solve_with(&p, algo, tol);
            assert!(res.converged, "{algo:?} did not converge");
            let dist = blas::dist2(&reference.x, &res.x);
            assert!(dist < 1e-3, "{algo:?} deviates from reference by {dist}");
            assert!(
                (res.objective - reference.objective).abs()
                    < 1e-5 * (1.0 + reference.objective),
                "{algo:?} objective mismatch"
            );
        }
    }
}
