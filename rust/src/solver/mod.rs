//! Elastic Net solvers: the paper's SsNAL-EN and every baseline it is
//! benchmarked against.
//!
//! | module | algorithm | paper role |
//! |---|---|---|
//! | [`ssnal`] | semi-smooth Newton augmented Lagrangian | the contribution (§3) |
//! | [`cd`] | naive + covariance coordinate descent | sklearn / glmnet competitors |
//! | [`fista`] | ISTA / FISTA | first-order competitors (§4.1) |
//! | [`admm`] | ADMM | first-order competitor (§4.1) |
//! | [`screening`] | Gap-Safe sphere screening CD | GSR competitor (D.3) |
//! | [`celer`] | working set + dual extrapolation | celer competitor (D.3) |
//!
//! All solvers consume the same [`types::EnetProblem`] and produce the same
//! [`types::SolveResult`], and every algorithm registers a [`Solver`] trait
//! implementation, so the benchmark harness, the [`crate::api`] facade, the
//! oracle goldens and the CLI dispatch uniformly through [`registry`] /
//! [`solve_with_config`] instead of hard-coding per-algorithm matches.

pub mod admm;
pub mod cd;
pub mod celer;
pub mod fista;
pub mod objective;
pub mod screening;
pub mod ssn_system;
pub mod ssnal;
pub mod types;

pub use objective::{duality_gap, kkt_residuals, primal_objective, support_of, KktResiduals};
pub use types::{
    Algorithm, BaselineOptions, EnetProblem, NewtonStrategy, SolveResult, SolverConfig,
    SsnalOptions,
};

/// One registered Elastic Net algorithm behind an object-safe interface.
///
/// Implemented by a unit struct per [`Algorithm`] variant (eight in total);
/// [`registry`] enumerates them in declaration order and [`solver_for`] looks
/// one up. Every implementation honors the *whole* shared configuration —
/// `tol`, `max_iters`, `verbose` — not just the tolerance, plus its own block
/// of [`SolverConfig`] when it has one.
pub trait Solver: Sync {
    /// The [`Algorithm`] this solver implements.
    fn algorithm(&self) -> Algorithm;

    /// Short display name (bench tables, CLI).
    fn name(&self) -> &'static str {
        self.algorithm().name()
    }

    /// Solve one instance under the uniform configuration.
    fn solve(&self, p: &EnetProblem, cfg: &SolverConfig) -> SolveResult;
}

/// Every algorithm in the crate, in [`Algorithm`] declaration order.
pub fn registry() -> &'static [&'static dyn Solver] {
    static REGISTRY: [&dyn Solver; 8] = [
        &ssnal::SsnalSolver,
        &cd::NaiveCdSolver,
        &cd::CovarianceCdSolver,
        &fista::FistaSolver,
        &fista::ProximalGradientSolver,
        &admm::AdmmSolver,
        &screening::GapSafeSolver,
        &celer::CelerSolver,
    ];
    &REGISTRY
}

/// The registered [`Solver`] for `algo`.
pub fn solver_for(algo: Algorithm) -> &'static dyn Solver {
    registry()
        .iter()
        .copied()
        .find(|s| s.algorithm() == algo)
        .expect("every Algorithm variant is registered")
}

/// Solve one instance with the named algorithm at tolerance `tol` and that
/// algorithm's defaults otherwise — the convenience entry the bench harness
/// uses. See [`solve_with_config`] for full control.
pub fn solve_with(p: &EnetProblem, algo: Algorithm, tol: f64) -> SolveResult {
    solve_with_config(p, algo, &SolverConfig::new(tol))
}

/// Uniform dispatch through the [`Solver`] registry, honoring the whole
/// [`SolverConfig`] (`max_iters`, `verbose`, Newton strategy, ADMM knobs) —
/// not just `tol` like the pre-facade `solve_with` did.
pub fn solve_with_config(p: &EnetProblem, algo: Algorithm, cfg: &SolverConfig) -> SolveResult {
    solver_for(algo).solve(p, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::linalg::blas;

    /// The paper's core claim precondition: all solvers minimize the same
    /// objective and converge to the same solution ("we investigated prediction
    /// performance — results are not reported since the three methods solve the
    /// same objective function and converge to the same solution", §4.1).
    #[test]
    fn all_algorithms_agree_on_one_instance() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 8.0,
            seed: 33,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let reference = solve_with(&p, Algorithm::CdNaive, 1e-10);
        for algo in [
            Algorithm::SsnalEn,
            Algorithm::CdCovariance,
            Algorithm::Fista,
            Algorithm::Admm,
            Algorithm::CdGapSafe,
            Algorithm::Celer,
        ] {
            // first-order methods use a gap criterion scaled by ‖b‖² (the
            // sklearn convention), so ask them for more digits
            let tol = match algo {
                Algorithm::Fista | Algorithm::Admm => 1e-10,
                _ => 1e-8,
            };
            let res = solve_with(&p, algo, tol);
            assert!(res.converged, "{algo:?} did not converge");
            let dist = blas::dist2(&reference.x, &res.x);
            assert!(dist < 1e-3, "{algo:?} deviates from reference by {dist}");
            assert!(
                (res.objective - reference.objective).abs()
                    < 1e-5 * (1.0 + reference.objective),
                "{algo:?} objective mismatch"
            );
        }
    }

    /// Each of the eight algorithms registers exactly one trait object, and
    /// lookup round-trips.
    #[test]
    fn registry_covers_every_algorithm_once() {
        let algos: Vec<Algorithm> = registry().iter().map(|s| s.algorithm()).collect();
        assert_eq!(algos.len(), 8);
        let unique: std::collections::HashSet<&'static str> =
            registry().iter().map(|s| s.name()).collect();
        assert_eq!(unique.len(), 8, "names must be distinct");
        for &algo in &algos {
            assert_eq!(solver_for(algo).algorithm(), algo);
        }
    }

    /// The registry path must honor the shared `max_iters` knob — the defect
    /// the trait replaced: `solve_with` used to rebuild default options and
    /// forward only `tol`.
    #[test]
    fn solve_with_config_honors_max_iters() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 8.0,
            seed: 33,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let mut cfg = SolverConfig::new(1e-12);
        cfg.max_iters = Some(1);
        for s in registry() {
            let res = s.solve(&p, &cfg);
            assert!(
                res.iterations <= 1,
                "{} ran {} outer iterations under a cap of 1",
                s.name(),
                res.iterations
            );
        }
    }
}
