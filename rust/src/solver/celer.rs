//! Working-set solver with dual extrapolation — the celer-like competitor of
//! Supplement D.3 (Massias, Gramfort & Salmon 2018).
//!
//! Structure:
//! 1. keep a residual history and build an **extrapolated dual point** by
//!    Anderson acceleration over the last K residuals (celer's key idea: the
//!    extrapolated point gives far tighter gaps → tighter safe screening),
//! 2. rank features by the distance of `|Ã_jᵀθ|` to the constraint boundary
//!    and solve CD on a geometrically growing working set,
//! 3. global duality-gap stopping; Gap-Safe screening prunes between rounds.
//!
//! The Elastic Net is handled by the same `Ã = [A; √λ2 I]` augmentation as
//! [`crate::solver::screening`].

use crate::linalg::blas;
use crate::solver::objective::{primal_objective, support_of};
use crate::solver::screening::{cd_on_set, AugmentedView};
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult};

/// Number of residual snapshots used for Anderson extrapolation (celer uses 5).
const EXTRAPOLATION_K: usize = 5;
/// Initial working-set size.
const WS_START: usize = 100;

/// Anderson-style extrapolation: given residual snapshots `r_1..r_K` (split
/// top/bottom), find the affine combination minimizing `‖Σ c_k (r_{k+1}−r_k)‖`
/// and return `Σ c_k r_k`. Falls back to the last residual on failure.
fn extrapolate(history: &[(Vec<f64>, Vec<f64>)]) -> (Vec<f64>, Vec<f64>) {
    let k = history.len();
    let last = history.last().expect("non-empty history");
    if k < 3 {
        return last.clone();
    }
    // U_k = r_{k+1} − r_k (flattened over top+bottom), k = 1..K−1
    let dim = last.0.len() + last.1.len();
    let cols = k - 1;
    let mut u = vec![0.0; dim * cols];
    for c in 0..cols {
        let (t0, b0) = &history[c];
        let (t1, b1) = &history[c + 1];
        for i in 0..t0.len() {
            u[c * dim + i] = t1[i] - t0[i];
        }
        for i in 0..b0.len() {
            u[c * dim + t0.len() + i] = b1[i] - b0[i];
        }
    }
    // solve (UᵀU + εI) c = 1, normalize c to sum 1
    let mut gram = vec![0.0; cols * cols];
    for a in 0..cols {
        for b in a..cols {
            let d = blas::dot(&u[a * dim..(a + 1) * dim], &u[b * dim..(b + 1) * dim]);
            gram[a * cols + b] = d;
            gram[b * cols + a] = d;
        }
    }
    let trace: f64 = (0..cols).map(|i| gram[i * cols + i]).sum();
    let eps = 1e-10 * trace.max(1e-30);
    for i in 0..cols {
        gram[i * cols + i] += eps;
    }
    let gm = crate::linalg::Mat::from_row_major(cols, cols, &gram);
    let ch = match crate::linalg::Cholesky::factor(&gm) {
        Ok(c) => c,
        Err(_) => return last.clone(),
    };
    let c = ch.solve(&vec![1.0; cols]);
    let csum: f64 = c.iter().sum();
    if csum.abs() < 1e-30 || !csum.is_finite() {
        return last.clone();
    }
    let mut top = vec![0.0; last.0.len()];
    let mut bottom = vec![0.0; last.1.len()];
    for (kk, ck) in c.iter().enumerate() {
        let w = ck / csum;
        blas::axpy(w, &history[kk].0, &mut top);
        blas::axpy(w, &history[kk].1, &mut bottom);
    }
    (top, bottom)
}

/// Scale a candidate dual direction into the feasible set Δ and evaluate the
/// dual objective; returns `(value, θ_top, θ_bottom)`.
fn feasible_dual(
    aug: &AugmentedView,
    p: &EnetProblem,
    mut top: Vec<f64>,
    mut bottom: Vec<f64>,
) -> (f64, Vec<f64>, Vec<f64>) {
    let mut zmax = 0.0f64;
    for j in 0..p.n() {
        zmax = zmax.max(aug.col_dot(j, &top, &bottom).abs());
    }
    let s = if zmax > p.lam1 && zmax > 0.0 { p.lam1 / zmax } else { 1.0 };
    for v in top.iter_mut() {
        *v *= s;
    }
    for v in bottom.iter_mut() {
        *v *= s;
    }
    let b_sq = blas::nrm2_sq(p.b);
    let mut diff_sq = 0.0;
    for i in 0..p.m() {
        let d = p.b[i] - top[i];
        diff_sq += d * d;
    }
    diff_sq += blas::nrm2_sq(&bottom);
    (0.5 * b_sq - 0.5 * diff_sq, top, bottom)
}

/// Solve with the celer-like working-set algorithm.
pub fn solve_celer(p: &EnetProblem, opts: &BaselineOptions) -> SolveResult {
    let n = p.n();
    let aug = AugmentedView::new(p);
    let mut x = vec![0.0; n];
    let mut res: Vec<f64> = p.b.to_vec(); // b − Ax with x = 0
    let col_sq: Vec<f64> = (0..n).map(|j| p.a.col_nrm2_sq(j)).collect();

    let mut history: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
    let mut ws_size = WS_START.min(n);
    let mut rounds = 0usize;
    let mut inner = 0usize;
    let mut converged = false;
    let mut last_gap = f64::INFINITY;
    let obj_scale = 1.0 + blas::nrm2_sq(p.b);

    // The caller's iteration cap bounds working-set rounds, clamped to the
    // solver's 200-round safety net: one round is an O(n) scoring pass,
    // Anderson extrapolation, and a working-set CD convergence — far coarser
    // than the sweep/epoch unit `max_iters` means elsewhere, so the 100_000
    // default must not apply verbatim. (The old hard-coded cap ignored
    // `opts.max_iters` entirely; tightening now works.)
    while rounds < opts.max_iters.min(200) {
        rounds += 1;
        // dual candidates: plain residual and Anderson-extrapolated residual;
        // keep whichever gives the better (larger) dual value.
        let bottom: Vec<f64> = x.iter().map(|&v| -p.lam2.sqrt() * v).collect();
        history.push((res.clone(), bottom.clone()));
        if history.len() > EXTRAPOLATION_K {
            history.remove(0);
        }
        let (d_plain, t_plain, b_plain) =
            feasible_dual(&aug, p, res.clone(), bottom.clone());
        let (ex_top, ex_bottom) = extrapolate(&history);
        let (d_accel, t_accel, b_accel) = feasible_dual(&aug, p, ex_top, ex_bottom);
        let (dual_val, theta_top, theta_bottom) = if d_accel > d_plain {
            (d_accel, t_accel, b_accel)
        } else {
            (d_plain, t_plain, b_plain)
        };
        let primal = primal_objective(p, &x);
        last_gap = primal - dual_val;
        if last_gap <= opts.tol * obj_scale {
            converged = true;
            break;
        }

        // rank all features by constraint slack d_j = (λ1 − |Ã_jᵀθ|)/‖Ã_j‖
        let radius = (2.0 * last_gap.max(0.0)).sqrt();
        let mut scored: Vec<(f64, usize)> = Vec::with_capacity(n);
        for j in 0..n {
            let corr = aug.col_dot(j, &theta_top, &theta_bottom).abs();
            // Gap-Safe prune: provably-zero features never enter the WS
            if corr + radius * aug.col_norms[j] < p.lam1 - 1e-12 && x[j] == 0.0 {
                continue;
            }
            let slack = (p.lam1 - corr) / aug.col_norms[j].max(1e-30);
            // active features get priority (slack −∞)
            let key = if x[j] != 0.0 { f64::NEG_INFINITY } else { slack };
            scored.push((key, j));
        }
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        let take = ws_size.min(scored.len());
        let mut ws: Vec<usize> = scored[..take].iter().map(|&(_, j)| j).collect();
        ws.sort_unstable();

        // solve the subproblem to (tighter) tolerance on the working set
        inner += cd_on_set(p, &mut x, &mut res, &col_sq, &ws, opts.tol * 0.1, 2000);
        ws_size = (ws_size * 2).min(n);
    }

    let active_set = support_of(&x, 0.0);
    let objective = primal_objective(p, &x);
    let y: Vec<f64> = res.iter().map(|r| -r).collect();
    SolveResult {
        x,
        y,
        active_set,
        // working sets are heuristic, not a safe screen — report none
        screen_survivors: None,
        objective,
        iterations: rounds,
        inner_iterations: inner,
        residual: last_gap,
        converged,
        algorithm: Algorithm::Celer,
    }
}

/// [`crate::solver::Solver`] registry entry for the working-set solver with
/// dual extrapolation (celer-like).
#[derive(Clone, Copy, Debug, Default)]
pub struct CelerSolver;

impl crate::solver::Solver for CelerSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Celer
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_celer(p, &cfg.baseline_options())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    fn problem(seed: u64, alpha: f64, c: f64) -> (crate::data::SyntheticProblem, f64, f64) {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 300,
            n0: 8,
            x_star: 5.0,
            snr: 10.0,
            seed,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(alpha, c, lmax);
        (prob, l1, l2)
    }

    #[test]
    fn celer_matches_cd_lasso_like() {
        // D.3 uses α = 0.999 (≈ Lasso)
        let (prob, l1, l2) = problem(1, 0.999, 0.4);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let ce = solve_celer(&p, &BaselineOptions { tol: 1e-9, ..Default::default() });
        let cd = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        assert!(ce.converged, "gap {}", ce.residual);
        assert!(blas::dist2(&ce.x, &cd.x) < 1e-4);
    }

    #[test]
    fn celer_matches_cd_elastic_net() {
        let (prob, l1, l2) = problem(2, 0.7, 0.3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let ce = solve_celer(&p, &BaselineOptions { tol: 1e-9, ..Default::default() });
        let cd = crate::solver::cd::solve_naive(
            &p,
            &BaselineOptions { tol: 1e-10, ..Default::default() },
        );
        assert!(ce.converged);
        assert!(blas::dist2(&ce.x, &cd.x) < 1e-4);
    }

    #[test]
    fn working_set_stays_small_on_sparse_problems() {
        let (prob, l1, l2) = problem(3, 0.9, 0.6);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let ce = solve_celer(&p, &BaselineOptions { tol: 1e-8, ..Default::default() });
        assert!(ce.converged);
        // the final active set should be near the truth size, not the WS cap
        assert!(ce.active_set.len() < 60, "active {}", ce.active_set.len());
    }

    #[test]
    fn extrapolation_handles_degenerate_history() {
        // constant residuals (already converged): extrapolation must not blow up
        let r = (vec![1.0, 2.0], vec![0.5]);
        let hist = vec![r.clone(), r.clone(), r.clone(), r.clone()];
        let (t, b) = extrapolate(&hist);
        assert_eq!(t, r.0);
        assert_eq!(b, r.1);
    }
}
