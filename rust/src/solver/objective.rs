//! Objective values, KKT residuals (paper Eq. 8 & 20) and the duality gap —
//! the agreed-upon yardsticks every solver in the crate is tested against.

use crate::linalg::blas;
use crate::prox;
use crate::solver::types::EnetProblem;

/// Primal objective `½‖Ax − b‖² + λ1‖x‖₁ + (λ2/2)‖x‖₂²` (Eq. 1).
pub fn primal_objective(p: &EnetProblem, x: &[f64]) -> f64 {
    let ax = p.a.mul_vec(x);
    let mut loss = 0.0;
    for i in 0..p.m() {
        let d = ax[i] - p.b[i];
        loss += d * d;
    }
    0.5 * loss + prox::enet_penalty(x, p.lam1, p.lam2)
}

/// Dual objective `−(h*(y) + p*(z))` of (D); feasibility `Aᵀy + z = 0` is the
/// caller's concern (see [`kkt_residuals`]). Requires λ2 > 0 for the Elastic
/// Net conjugate; with λ2 = 0 the Lasso indicator is used.
pub fn dual_objective(p: &EnetProblem, y: &[f64], z: &[f64]) -> f64 {
    let pstar = if p.lam2 > 0.0 {
        prox::enet_conjugate(z, p.lam1, p.lam2)
    } else {
        prox::lasso_conjugate(z, p.lam1)
    };
    -(prox::h_star(y, p.b) + pstar)
}

/// Duality gap `primal(x) − dual(y, z)` — nonnegative for feasible pairs,
/// and → 0 at the optimum.
pub fn duality_gap(p: &EnetProblem, x: &[f64], y: &[f64], z: &[f64]) -> f64 {
    primal_objective(p, x) - dual_objective(p, y, z)
}

/// The three KKT residuals of Eq. (8), normalized per Eq. (20):
///
/// * `res1 = ‖y + b − Ax‖ / (1 + ‖b‖)` — dual-variable consistency,
/// * `res2 = ‖∇p*(z) − x‖ / (1 + ‖x‖)` — conjugate-gradient consistency
///   (λ2 > 0 required; reported as 0 when λ2 = 0 and z is dual-feasible),
/// * `res3 = ‖Aᵀy + z‖ / (1 + ‖y‖ + ‖z‖)` — dual feasibility.
#[derive(Clone, Copy, Debug)]
pub struct KktResiduals {
    pub res1: f64,
    pub res2: f64,
    pub res3: f64,
}

impl KktResiduals {
    /// Largest of the three.
    pub fn max(&self) -> f64 {
        self.res1.max(self.res2).max(self.res3)
    }
}

/// Evaluate all three KKT residuals at `(x, y, z)`.
pub fn kkt_residuals(p: &EnetProblem, x: &[f64], y: &[f64], z: &[f64]) -> KktResiduals {
    let m = p.m();
    let n = p.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    assert_eq!(z.len(), n);

    // res1: ∇h*(y) − Ax = y + b − Ax
    let ax = p.a.mul_vec(x);
    let mut s1 = 0.0;
    for i in 0..m {
        let d = y[i] + p.b[i] - ax[i];
        s1 += d * d;
    }
    let res1 = s1.sqrt() / (1.0 + blas::nrm2(p.b));

    // res2: ∇p*(z) − x with ∇p*(z) from Proposition 1 (λ2 > 0)
    let res2 = if p.lam2 > 0.0 {
        let mut s2 = 0.0;
        for j in 0..n {
            let g = if z[j] >= p.lam1 {
                (z[j] - p.lam1) / p.lam2
            } else if z[j] <= -p.lam1 {
                (z[j] + p.lam1) / p.lam2
            } else {
                0.0
            };
            let d = g - x[j];
            s2 += d * d;
        }
        s2.sqrt() / (1.0 + blas::nrm2(x))
    } else {
        0.0
    };

    // res3: Aᵀy + z
    let aty = p.a.t_mul_vec(y);
    let mut s3 = 0.0;
    for j in 0..n {
        let d = aty[j] + z[j];
        s3 += d * d;
    }
    let res3 = s3.sqrt() / (1.0 + blas::nrm2(y) + blas::nrm2(z));

    KktResiduals { res1, res2, res3 }
}

/// Extract the support (indices of nonzero coefficients) with a tolerance.
pub fn support_of(x: &[f64], tol: f64) -> Vec<usize> {
    x.iter()
        .enumerate()
        .filter(|(_, &v)| v.abs() > tol)
        .map(|(j, _)| j)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn tiny() -> (Mat, Vec<f64>) {
        let a = Mat::from_row_major(2, 3, &[1.0, 0.0, 1.0, 0.0, 1.0, -1.0]);
        let b = vec![1.0, 2.0];
        (a, b)
    }

    #[test]
    fn primal_objective_by_hand() {
        let (a, b) = tiny();
        let p = EnetProblem::new(&a, &b, 0.5, 1.0);
        let x = [1.0, 0.0, -1.0];
        // Ax = [0, 1]; ½‖Ax−b‖² = ½(1+1) = 1; λ1‖x‖₁ = 1; λ2/2‖x‖² = 1
        assert!((primal_objective(&p, &x) - 3.0).abs() < 1e-14);
    }

    #[test]
    fn gap_zero_at_optimum_of_unconstrained_case() {
        // With λ1 = 0, λ2 > 0: ridge regression; KKT solution known in closed form.
        // Use x* solving (AᵀA + λ2 I)x = Aᵀb, y* = Ax*−b, z* = −Aᵀy*.
        let (a, b) = tiny();
        let lam2 = 0.7;
        let p = EnetProblem::new(&a, &b, 0.0, lam2);
        // normal equations on the 3-feature problem
        let mut g = a.gram_of_cols(&[0, 1, 2], lam2);
        let rhs = a.t_mul_vec(&b);
        let x = crate::linalg::Cholesky::factor(&mut g).unwrap().solve(&rhs);
        let y: Vec<f64> = {
            let ax = a.mul_vec(&x);
            (0..2).map(|i| ax[i] - b[i]).collect()
        };
        let z: Vec<f64> = a.t_mul_vec(&y).iter().map(|v| -v).collect();
        let gap = duality_gap(&p, &x, &y, &z);
        assert!(gap.abs() < 1e-10, "gap={gap}");
        let res = kkt_residuals(&p, &x, &y, &z);
        assert!(res.max() < 1e-10, "{res:?}");
    }

    #[test]
    fn gap_positive_away_from_optimum() {
        let (a, b) = tiny();
        let p = EnetProblem::new(&a, &b, 0.3, 0.5);
        let x = [5.0, -5.0, 5.0];
        let y = vec![0.1, 0.1];
        let z: Vec<f64> = a.t_mul_vec(&y).iter().map(|v| -v).collect();
        assert!(duality_gap(&p, &x, &y, &z) > 0.0);
    }

    #[test]
    fn residuals_zero_only_with_consistent_triple() {
        let (a, b) = tiny();
        let p = EnetProblem::new(&a, &b, 0.3, 0.5);
        let x = vec![0.0; 3];
        let y = vec![-1.0, -2.0]; // = Ax − b with x = 0
        let z: Vec<f64> = a.t_mul_vec(&y).iter().map(|v| -v).collect();
        let res = kkt_residuals(&p, &x, &y, &z);
        assert!(res.res1 < 1e-14);
        assert!(res.res3 < 1e-14);
        // res2 may be nonzero (x=0 need not be optimal for these λ)
    }

    #[test]
    fn support_extraction() {
        let x = [0.0, 1e-12, -0.5, 2.0, -1e-9];
        assert_eq!(support_of(&x, 1e-8), vec![2, 3]);
        assert_eq!(support_of(&x, 0.0), vec![1, 2, 3, 4]);
    }
}
