//! Coordinate descent baselines (Friedman et al. 2010; Tseng & Yun 2009).
//!
//! Two variants matching the paper's two CD competitors:
//!
//! * [`solve_naive`] — full cyclic sweeps over all n coordinates, residual
//!   updates only. This mirrors `sklearn.linear_model.ElasticNet`'s behaviour:
//!   every sweep costs O(mn) regardless of sparsity.
//! * [`solve_covariance`] — glmnet-style: converge on the current working
//!   (active) set with cheap O(m·r) sweeps, then run one full O(mn) sweep to
//!   admit KKT violators; repeat until no feature enters. This is why glmnet
//!   is much faster than naive CD on sparse problems — and still loses to
//!   SsNAL-EN's second-order updates (paper Tables 1–2).
//!
//! Coordinate update for `½‖Ax−b‖² + λ1‖x‖₁ + (λ2/2)‖x‖₂²`:
//! `x_j ← soft(A_jᵀres + ‖A_j‖²·x_j, λ1) / (‖A_j‖² + λ2)` with `res = b − Ax`
//! maintained incrementally.

use crate::linalg::blas;
use crate::prox::soft_threshold;
use crate::solver::objective::{dual_objective, primal_objective, support_of};
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult};

/// Shared state for both CD variants.
struct CdState {
    x: Vec<f64>,
    /// res = b − Ax, maintained incrementally.
    res: Vec<f64>,
    /// squared column norms ‖A_j‖².
    col_sq: Vec<f64>,
}

impl CdState {
    fn new(p: &EnetProblem, x0: Option<&[f64]>) -> Self {
        let n = p.n();
        let x = x0.map(|v| v.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        let ax = p.a.mul_vec(&x);
        let res: Vec<f64> = (0..p.m()).map(|i| p.b[i] - ax[i]).collect();
        let col_sq: Vec<f64> = (0..n).map(|j| p.a.col_nrm2_sq(j)).collect();
        Self { x, res, col_sq }
    }

    /// One coordinate update; returns |Δx_j|.
    #[inline]
    fn update(&mut self, p: &EnetProblem, j: usize) -> f64 {
        let cj = self.col_sq[j];
        if cj == 0.0 {
            return 0.0;
        }
        let rho = p.a.col_dot(j, &self.res) + cj * self.x[j];
        let new = soft_threshold(rho, p.lam1) / (cj + p.lam2);
        let delta = new - self.x[j];
        if delta != 0.0 {
            p.a.col_axpy(-delta, j, &mut self.res);
            self.x[j] = new;
        }
        delta.abs()
    }

    /// Duality gap at the current iterate using the natural dual pair
    /// `y = −res` (=Ax−b), `z = −Aᵀy = Aᵀres` (feasible because the Elastic Net
    /// conjugate is finite everywhere when λ2 > 0; for λ2 = 0 the dual point is
    /// scaled into the `‖z‖∞ ≤ λ1` box).
    fn gap(&self, p: &EnetProblem) -> f64 {
        let y: Vec<f64> = self.res.iter().map(|r| -r).collect();
        let mut z = p.a.t_mul_vec(&self.res);
        if p.lam2 == 0.0 {
            let zmax = blas::nrm_inf(&z);
            if zmax > p.lam1 && zmax > 0.0 {
                let scale = p.lam1 / zmax;
                // scale both to keep Aᵀy + z = 0 ⇒ scale y too
                let ys: Vec<f64> = y.iter().map(|v| v * scale).collect();
                for v in z.iter_mut() {
                    *v *= scale;
                }
                return primal_objective(p, &self.x) - dual_objective(p, &ys, &z);
            }
        }
        primal_objective(p, &self.x) - dual_objective(p, &y, &z)
    }
}

/// Naive full-sweep cyclic coordinate descent (sklearn-like).
pub fn solve_naive(p: &EnetProblem, opts: &BaselineOptions) -> SolveResult {
    solve_naive_warm(p, opts, None)
}

/// Naive CD with warm start.
pub fn solve_naive_warm(
    p: &EnetProblem,
    opts: &BaselineOptions,
    x0: Option<&[f64]>,
) -> SolveResult {
    let n = p.n();
    let mut st = CdState::new(p, x0);
    let mut sweeps = 0usize;
    let mut converged = false;
    let mut last_gap = f64::INFINITY;
    let obj_scale = 1.0 + blas::nrm2_sq(p.b);
    while sweeps < opts.max_iters {
        sweeps += 1;
        let mut max_change = 0.0f64;
        let mut max_x = 0.0f64;
        for j in 0..n {
            let d = st.update(p, j);
            max_change = max_change.max(d);
            max_x = max_x.max(st.x[j].abs());
        }
        // sklearn-style: once coordinate movement stalls, confirm with the gap
        if max_change <= opts.tol * max_x.max(1e-12) {
            last_gap = st.gap(p);
            if last_gap <= opts.tol * obj_scale {
                converged = true;
                break;
            }
        }
    }
    finish(p, st, sweeps, converged, last_gap, Algorithm::CdNaive)
}

/// Covariance/active-set coordinate descent (glmnet-like).
pub fn solve_covariance(p: &EnetProblem, opts: &BaselineOptions) -> SolveResult {
    solve_covariance_warm(p, opts, None)
}

/// Covariance/active-set CD with warm start.
pub fn solve_covariance_warm(
    p: &EnetProblem,
    opts: &BaselineOptions,
    x0: Option<&[f64]>,
) -> SolveResult {
    let n = p.n();
    let mut st = CdState::new(p, x0);
    let mut total_sweeps = 0usize;
    let mut inner_sweeps = 0usize;
    let mut converged = false;
    let mut last_gap = f64::INFINITY;
    let obj_scale = 1.0 + blas::nrm2_sq(p.b);

    // working set = current nonzeros (or everything on the first pass)
    let mut working: Vec<usize> = support_of(&st.x, 0.0);

    while total_sweeps < opts.max_iters {
        // (a) converge on the working set with cheap sweeps
        if !working.is_empty() {
            for _ in 0..opts.max_iters {
                inner_sweeps += 1;
                let mut max_change = 0.0f64;
                let mut max_x = 0.0f64;
                for &j in &working {
                    let d = st.update(p, j);
                    max_change = max_change.max(d);
                    max_x = max_x.max(st.x[j].abs());
                }
                if max_change <= opts.tol * max_x.max(1e-12) {
                    break;
                }
            }
        }
        // (b) one full sweep to admit violators
        total_sweeps += 1;
        let mut entered = false;
        let mut max_change = 0.0f64;
        let mut max_x = 0.0f64;
        for j in 0..n {
            let was_zero = st.x[j] == 0.0;
            let d = st.update(p, j);
            max_change = max_change.max(d);
            max_x = max_x.max(st.x[j].abs());
            if was_zero && st.x[j] != 0.0 {
                entered = true;
            }
        }
        working = support_of(&st.x, 0.0);
        if !entered && max_change <= opts.tol * max_x.max(1e-12) {
            last_gap = st.gap(p);
            if last_gap <= opts.tol * obj_scale {
                converged = true;
                break;
            }
        }
    }
    let mut out = finish(p, st, total_sweeps, converged, last_gap, Algorithm::CdCovariance);
    out.inner_iterations = inner_sweeps;
    out
}

fn finish(
    p: &EnetProblem,
    st: CdState,
    sweeps: usize,
    converged: bool,
    gap: f64,
    algorithm: Algorithm,
) -> SolveResult {
    let active_set = support_of(&st.x, 0.0);
    let objective = primal_objective(p, &st.x);
    let y: Vec<f64> = st.res.iter().map(|r| -r).collect();
    SolveResult {
        x: st.x,
        y,
        active_set,
        screen_survivors: None,
        objective,
        iterations: sweeps,
        inner_iterations: 0,
        residual: gap,
        converged,
        algorithm,
    }
}

/// [`crate::solver::Solver`] registry entry for naive full-sweep CD
/// (sklearn-like).
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveCdSolver;

impl crate::solver::Solver for NaiveCdSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::CdNaive
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_naive(p, &cfg.baseline_options())
    }
}

/// [`crate::solver::Solver`] registry entry for covariance-updating
/// working-set CD (glmnet-like).
#[derive(Clone, Copy, Debug, Default)]
pub struct CovarianceCdSolver;

impl crate::solver::Solver for CovarianceCdSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::CdCovariance
    }

    fn solve(&self, p: &EnetProblem, cfg: &crate::solver::SolverConfig) -> SolveResult {
        solve_covariance(p, &cfg.baseline_options())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::linalg::Mat;

    fn problem(seed: u64) -> (crate::data::SyntheticProblem, f64, f64) {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 150,
            n0: 6,
            x_star: 5.0,
            snr: 5.0,
            seed,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        (prob, l1, l2)
    }

    #[test]
    fn naive_converges_to_small_gap() {
        let (prob, l1, l2) = problem(1);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = solve_naive(&p, &BaselineOptions { tol: 1e-8, ..Default::default() });
        assert!(res.converged);
        assert!(res.residual <= 1e-8 * (1.0 + blas::nrm2_sq(p.b)));
    }

    #[test]
    fn covariance_matches_naive() {
        let (prob, l1, l2) = problem(2);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let opts = BaselineOptions { tol: 1e-10, ..Default::default() };
        let a = solve_naive(&p, &opts);
        let b = solve_covariance(&p, &opts);
        assert!(b.converged);
        let dist = blas::dist2(&a.x, &b.x);
        assert!(dist < 1e-5, "dist={dist}");
        assert!((a.objective - b.objective).abs() < 1e-8 * (1.0 + a.objective));
    }

    #[test]
    fn lasso_mode_lambda2_zero() {
        let (prob, l1, _) = problem(3);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, 0.0);
        let res = solve_naive(&p, &BaselineOptions { tol: 1e-9, ..Default::default() });
        assert!(res.converged);
        // optimality: |A_jᵀres| ≤ λ1 (+tol) for inactive, = λ1 sign for active
        let grad = p.a.t_mul_vec(&res.y); // Aᵀ(Ax−b) = −Aᵀres
        for j in 0..p.n() {
            if res.x[j] == 0.0 {
                assert!(grad[j].abs() <= l1 + 1e-5, "j={j} grad={}", grad[j]);
            } else {
                assert!(
                    (grad[j] + l1 * res.x[j].signum()).abs() < 1e-4,
                    "active KKT at {j}"
                );
            }
        }
    }

    #[test]
    fn zero_above_lambda_max() {
        let (prob, _, _) = problem(4);
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 1.0);
        let p = EnetProblem::new(&prob.a, &prob.b, lmax * 1.01, 0.1);
        let res = solve_naive(&p, &BaselineOptions::default());
        assert_eq!(res.active_set.len(), 0);
    }

    #[test]
    fn warm_start_preserves_solution() {
        let (prob, l1, l2) = problem(5);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let opts = BaselineOptions { tol: 1e-9, ..Default::default() };
        let cold = solve_naive(&p, &opts);
        let warm = solve_naive_warm(&p, &opts, Some(&cold.x));
        assert!(warm.iterations <= 3, "warm start should converge immediately");
        assert!(blas::dist2(&cold.x, &warm.x) < 1e-8);
    }

    #[test]
    fn zero_variance_column_stays_zero() {
        let mut a = Mat::from_fn(10, 3, |i, j| ((i * 3 + j) as f64 * 0.37).sin());
        for i in 0..10 {
            a.set(i, 1, 0.0); // dead column
        }
        let b: Vec<f64> = (0..10).map(|i| (i as f64 * 0.21).cos()).collect();
        let p = EnetProblem::new(&a, &b, 0.01, 0.01);
        let res = solve_naive(&p, &BaselineOptions::default());
        assert_eq!(res.x[1], 0.0);
    }
}
