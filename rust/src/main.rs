//! `ssnal-en` — the command-line launcher.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §3):
//!
//! ```text
//! ssnal-en solve          one Elastic Net solve on synthetic data (native|pjrt)
//! ssnal-en path           warm-started λ-path
//! ssnal-en tune           GCV / e-BIC / CV tuning sweep
//! ssnal-en fig1           Figure 1 series → CSV
//! ssnal-en bench-table1   Table 1   (sim1–3 × n)
//! ssnal-en bench-table2   Table 2   (polynomial-expansion datasets)
//! ssnal-en bench-insight  Figure 2 + Table 3 (simulated INSIGHT cohorts)
//! ssnal-en bench-d1..d4   Supplement tables D.1–D.4
//! ssnal-en artifacts-check  verify the PJRT artifacts load and run
//! ```
//!
//! Paper-scale sizes are the defaults where feasible on this testbed; every
//! size is overridable (e.g. `--ns 1e4,1e5,1e6`).

use ssnal_en::api::{Backend, Design, EnetModel};
use ssnal_en::bench::tables;
use ssnal_en::data::libsvm::ReferenceSet;
use ssnal_en::data::snp::SnpSpec;
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::solver::types::{EnetProblem, NewtonStrategy};
use ssnal_en::util::csv::write_csv;
use ssnal_en::util::error::{Error, Result};
use ssnal_en::util::table::Table;
use ssnal_en::util::Args;
use std::path::PathBuf;

/// Counting system allocator: the instrument behind `bench-parallel
/// --newton-*`'s allocs/iter column (and the zero-allocation Newton-hot-path
/// gate). One relaxed atomic add per allocation — negligible against the
/// allocation itself.
#[global_allocator]
static ALLOC: ssnal_en::util::alloc_count::CountingAllocator =
    ssnal_en::util::alloc_count::CountingAllocator;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "solve" => cmd_solve(&args),
        "convert" => cmd_convert(&args),
        "path" => cmd_path(&args),
        "tune" => cmd_tune(&args),
        "fig1" => cmd_fig1(&args),
        "bench-table1" => cmd_table1(&args),
        "bench-table2" => cmd_table2(&args),
        "bench-insight" => cmd_insight(&args),
        "bench-d1" => cmd_d1(&args),
        "bench-d2" => cmd_d2(&args),
        "bench-d3" => cmd_d3(&args),
        "bench-d4" => cmd_d4(&args),
        "bench-ablation" => cmd_ablation(&args),
        "bench-parallel" => cmd_bench_parallel(&args),
        "bench-check" => cmd_bench_check(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ssnal-en — Semi-smooth Newton Augmented Lagrangian solver for the Elastic Net\n\
         \n\
         USAGE: ssnal-en <subcommand> [--key value]...\n\
         \n\
         SUBCOMMANDS\n\
         solve            --n 1e4 --m 500 --n0 10 --alpha 0.8 --c 0.5 --threads 1 --backend native|pjrt\n\
         \x20                [--design cohort.ooc [--pheno cohort.pheno] [--cache-bytes 268435456]]\n\
         convert          --from plink --bed cohort.bed --out cohort.ooc [--missing 0.0]\n\
         \x20                --from snp-sparse|snp-dense --out cohort.ooc --m 200 --n-snps 5e4\n\
         \x20                [--n0 10] [--seed 2020] [--block-cols 256]\n\
         path             --n 1e4 --m 500 --alpha 0.8 --grid 100 --max-active 100 --threads 0\n\
         tune             --n 1e4 --m 200 --alpha 0.9 --grid 30 --cv 0\n\
         fig1             --points 241 --out results/fig1.csv\n\
         bench-table1     --ns 1e4,1e5,5e5 --m 500 [--tol 1e-6]\n\
         bench-table2     --sets housing,bodyfat,triazines --max-n 50000\n\
         bench-insight    --n-snps 50000 --grid 25 --cv 0 --out-dir results\n\
         bench-d1         --ns 1e4,1e5 --reps 20\n\
         bench-d2         --ns 1e4,1e5\n\
         bench-d3         [--tol 1e-6]\n\
         bench-d4         --ns 1e5 --grid 100\n\
         bench-ablation   --n 5e4 --m 500\n\
         bench-parallel   --n 2e4 --m 200 --grid 40 --threads 1,2,4 [--no-screening] [--out BENCH_parallel_path.json]\n\
         \x20                --shard-n 1e5 --shard-m 500 --shard-threads 1,2,4 [--no-shard-bench]\n\
         \x20                [--shard-out BENCH_shard_linalg.json]\n\
         \x20                --sparse-n 5e4 --sparse-m 200 --sparse-threads 1,2,4 [--no-sparse-bench]\n\
         \x20                [--sparse-out BENCH_sparse_design.json]\n\
         \x20                --ooc-n 2e4 --ooc-m 200 --ooc-threads 1,2,4 [--no-ooc-bench]\n\
         \x20                [--ooc-small-cache 2097152] [--ooc-large-cache 268435456]\n\
         \x20                [--ooc-out BENCH_ooc_design.json]\n\
         \x20                --pool-calls 200 --pool-threads 2,4 [--no-pool-bench]\n\
         \x20                [--pool-out BENCH_pool_dispatch.json]\n\
         \x20                --newton-sizes 160:1200:40,320:2000:120 --newton-reps 3\n\
         \x20                [--no-newton-bench] [--newton-out BENCH_newton_workspace.json]\n\
         \x20                --warm-m 200 --warm-n 2000 --warm-r0 40 --warm-points 24\n\
         \x20                --warm-reps 3 [--no-warm-bench] [--warm-out BENCH_warm_path.json]\n\
         \x20                --serve-n 2000 --serve-m 100 --serve-clients 1,8,64 --serve-requests 4\n\
         \x20                [--no-serve-bench] [--serve-out BENCH_serve.json]\n\
         bench-check      --current BENCH_x.json --baseline benches/baselines/BENCH_x.json\n\
         artifacts-check  [--artifacts-dir artifacts]\n\
         serve            --host 127.0.0.1 --port 7878 --sessions 16 --max-inflight 32\n\
         \x20                --threads 0 --max-body-mb 256 --queue-depth 64\n\
         \x20                --request-timeout-ms 30000 --drain-timeout-ms 30000\n"
    );
}

fn parse_tol(args: &Args) -> Result<f64> {
    args.get_f64("tol", 1e-6).map_err(Error::msg)
}

fn maybe_write(table: &Table, args: &Args) -> Result<()> {
    table.print();
    if let Some(path) = args.get("out") {
        std::fs::create_dir_all(PathBuf::from(path).parent().unwrap_or(&PathBuf::from(".")))?;
        std::fs::write(path, table.to_csv())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let alpha = args.get_f64("alpha", 0.8).map_err(Error::msg)?;
    let c = args.get_f64("c", 0.5).map_err(Error::msg)?;
    let backend = Backend::parse(&args.get_str("backend", "native")).map_err(Error::msg)?;
    let tol = parse_tol(args)?;
    // Within-solve shard threads (also settable via SSNAL_THREADS); the
    // solution is bitwise-identical at every setting.
    let threads = args.get_usize("threads", 0).map_err(Error::msg)?;

    // `--design cohort.ooc` streams an out-of-core file written by
    // `ssnal-en convert` instead of generating a synthetic problem; the
    // phenotype rides in the `<design>.pheno` sidecar unless `--pheno`
    // points elsewhere. Without `--design`, the synthetic defaults apply.
    let (design, support) = if let Some(path) = args.get("design") {
        let design_path = PathBuf::from(path);
        let pheno_path = args
            .get("pheno")
            .map(PathBuf::from)
            .unwrap_or_else(|| design_path.with_extension("pheno"));
        let b = read_pheno(&pheno_path)?;
        let cache_bytes = args
            .get_usize("cache-bytes", ssnal_en::linalg::ooc::DEFAULT_CACHE_BYTES)
            .map_err(Error::msg)?;
        (Design::from_ooc_with_cache(&design_path, b, cache_bytes)?, None)
    } else {
        let n = args.get_usize("n", 10_000).map_err(Error::msg)?;
        let m = args.get_usize("m", 500).map_err(Error::msg)?;
        let n0 = args.get_usize("n0", 10).map_err(Error::msg)?;
        let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
        let prob = generate_synthetic(&SyntheticSpec { m, n, n0, x_star: 5.0, snr: 5.0, seed });
        let design =
            Design::from_storage(ssnal_en::linalg::DesignStorage::Dense(prob.a), prob.b)?;
        (design, Some(prob.support))
    };
    let (m, n) = (design.m(), design.n());

    let model = EnetModel::new()
        .alpha_c(alpha, c)
        .threads(threads)
        .verbose(args.get_flag("verbose"));
    let model = match backend {
        Backend::Native => model.tol(tol),
        // f32 artifacts: the matrix-free CG strategy and a looser tolerance.
        Backend::Pjrt => model
            .backend(Backend::Pjrt)
            .artifacts_dir(PathBuf::from(args.get_str("artifacts-dir", "artifacts")))
            .tol(1e-4)
            .newton(NewtonStrategy::ConjugateGradient),
    };
    let (fit, secs) = ssnal_en::util::timer::time_it(|| model.fit(&design));
    let fit = fit?;
    let (lam1, lam2) = fit.lambdas();
    let res = fit.result();
    println!(
        "solved m={m} n={n} λ1={lam1:.4} λ2={lam2:.4} backend={backend:?}\n\
         time={secs:.3}s outer={} inner={} active={} residual={:.2e} objective={:.6}",
        res.iterations,
        res.inner_iterations,
        res.active_set.len(),
        res.residual,
        res.objective
    );
    if design.is_out_of_core() {
        let stats = fit.workspace_stats();
        println!(
            "block cache: {} hits / {} misses (hit rate {:.1}%), {:.1} MiB read",
            stats.ooc_cache_hits,
            stats.ooc_cache_misses,
            stats.ooc_hit_rate() * 100.0,
            stats.ooc_bytes_read as f64 / (1 << 20) as f64
        );
    }
    if let Some(support) = support {
        let hits = support.iter().filter(|j| fit.coefficients()[**j] != 0.0).count();
        println!("true-support recovery: {hits}/{}", support.len());
    }
    Ok(())
}

/// Parse a whitespace-separated phenotype sidecar (one value per sample, the
/// format `ssnal-en convert` writes).
fn read_pheno(path: &std::path::Path) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("{}: {e}", path.display())))?;
    let mut b = Vec::new();
    for tok in text.split_whitespace() {
        b.push(tok.parse::<f64>().map_err(|_| {
            Error::msg(format!("{}: bad phenotype value {tok:?}", path.display()))
        })?);
    }
    if b.is_empty() {
        return Err(Error::msg(format!("{}: empty phenotype file", path.display())));
    }
    Ok(b)
}

/// `ssnal-en convert` — write an out-of-core design file (plus its
/// `<out>.pheno` sidecar) from a PLINK 1.9 fileset or a synthetic cohort.
///
/// PLINK input repacks the 2-bit genotype codes byte-for-byte (no decode);
/// `snp-sparse` writes raw {0,1,2} dosages 2-bit-coded; `snp-dense` writes
/// the standardized cohort as f64 columns.
fn cmd_convert(args: &Args) -> Result<()> {
    let from = args.get_str("from", "plink");
    let out = PathBuf::from(
        args.get("out").ok_or_else(|| Error::msg("convert requires --out <file.ooc>"))?,
    );
    let block_cols = args
        .get_usize("block-cols", ssnal_en::linalg::ooc::DEFAULT_BLOCK_COLS)
        .map_err(Error::msg)?;
    let missing = args.get_f64("missing", 0.0).map_err(Error::msg)?;
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }

    let (header, b) = match from.as_str() {
        "plink" => {
            let bed_path = PathBuf::from(
                args.get("bed")
                    .ok_or_else(|| Error::msg("convert --from plink requires --bed <file.bed>"))?,
            );
            let bed = ssnal_en::data::snp::PlinkBed::open(&bed_path).map_err(Error::msg)?;
            let mut w = ssnal_en::linalg::OocWriter::create(
                &out,
                bed.samples(),
                bed.variants(),
                block_cols,
                ssnal_en::linalg::OocEncoding::Plink2Bit,
                missing,
            )?;
            let mut codes = Vec::new();
            for j in 0..bed.variants() {
                bed.read_variant_codes(j, &mut codes).map_err(Error::msg)?;
                w.push_col_codes(&codes)?;
            }
            let (b, _) = ssnal_en::data::standardize::center(bed.phenotypes());
            (w.finish()?, b)
        }
        "snp-sparse" => {
            let spec = ssnal_en::data::snp::SparseSnpSpec {
                base: convert_snp_spec(args)?,
                ..Default::default()
            };
            let cohort = ssnal_en::data::snp::generate_sparse(&spec);
            let header = ssnal_en::linalg::ooc::write_design_plink2bit(
                &out,
                cohort.a.as_ref(),
                block_cols,
                missing,
            )?;
            (header, cohort.b)
        }
        "snp-dense" => {
            let cohort = ssnal_en::data::snp::generate(&convert_snp_spec(args)?);
            let header =
                ssnal_en::linalg::ooc::write_design_f64(&out, (&cohort.a).into(), block_cols)?;
            (header, cohort.b)
        }
        other => {
            return Err(Error::msg(format!(
                "unknown --from {other:?} (expected plink, snp-sparse, or snp-dense)"
            )))
        }
    };

    let pheno_path = out.with_extension("pheno");
    let mut text = String::with_capacity(b.len() * 20);
    for v in &b {
        text.push_str(&format!("{v}\n"));
    }
    std::fs::write(&pheno_path, text)?;

    let payload_bytes = header.cols * header.bytes_per_col();
    println!(
        "wrote {} ({} x {}, {:?}, block_cols={}, {:.1} MiB payload, content hash {:#018x})",
        out.display(),
        header.rows,
        header.cols,
        header.encoding,
        header.block_cols,
        payload_bytes as f64 / (1 << 20) as f64,
        header.content_hash
    );
    println!("wrote {} ({} phenotype values, centered)", pheno_path.display(), b.len());
    Ok(())
}

/// The synthetic-cohort sizing flags shared by `convert --from snp-*`.
fn convert_snp_spec(args: &Args) -> Result<SnpSpec> {
    Ok(SnpSpec {
        m: args.get_usize("m", 200).map_err(Error::msg)?,
        n_snps: args.get_usize("n-snps", 50_000).map_err(Error::msg)?,
        n_causal: args.get_usize("n0", 10).map_err(Error::msg)?,
        seed: args.get_usize("seed", 2020).map_err(Error::msg)? as u64,
        ..Default::default()
    })
}

fn cmd_path(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000).map_err(Error::msg)?;
    let m = args.get_usize("m", 500).map_err(Error::msg)?;
    let alpha = args.get_f64("alpha", 0.8).map_err(Error::msg)?;
    let grid = args.get_usize("grid", 100).map_err(Error::msg)?;
    let max_active = args.get_usize("max-active", 100).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;

    let threads = args.get_usize("threads", 0).map_err(Error::msg)?;
    let n0 = 100.min(n / 10).max(1);
    let prob = generate_synthetic(&SyntheticSpec { m, n, n0, x_star: 5.0, snr: 5.0, seed });
    let design = Design::new(&prob.a, &prob.b)?;
    let model = EnetModel::new()
        .alpha(alpha)
        .grid(1.0, 0.1, grid)
        .max_active(max_active)
        .tol(tol)
        .threads(threads)
        .chunking(ssnal_en::parallel::Chunking::Auto)
        .screening(!args.get_flag("no-screening"));
    let (engine_out, secs) = ssnal_en::util::timer::time_it(|| model.fit_path(&design));
    let engine_out = engine_out?;
    let mut t = Table::new(&["c_lambda", "active", "outer_iters", "objective"])
        .with_title(&format!(
            "λ-path: {} points in {secs:.3}s (truncated={}, threads={}, chains={})",
            engine_out.runs(),
            engine_out.truncated(),
            engine_out.threads(),
            engine_out.chains().len()
        ));
    for p in engine_out.points() {
        t.row(vec![
            format!("{:.4}", p.c_lambda),
            format!("{}", p.result.active_set.len()),
            format!("{}", p.result.iterations),
            format!("{:.4}", p.result.objective),
        ]);
    }
    maybe_write(&t, args)
}

fn cmd_tune(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000).map_err(Error::msg)?;
    let m = args.get_usize("m", 200).map_err(Error::msg)?;
    let alpha = args.get_f64("alpha", 0.9).map_err(Error::msg)?;
    let grid = args.get_usize("grid", 30).map_err(Error::msg)?;
    let cv = args.get_usize("cv", 0).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;

    let n0 = 10.min(n / 10).max(1);
    let prob = generate_synthetic(&SyntheticSpec { m, n, n0, x_star: 5.0, snr: 10.0, seed });
    let design = Design::new(&prob.a, &prob.b)?;
    let tr = EnetModel::new()
        .alpha(alpha)
        .grid(0.99, 0.05, grid)
        .max_active(50)
        .tol(tol)
        .cv(cv)
        .cv_seed(seed)
        .tune(&design)?;
    let mut t = Table::new(&["c_lambda", "active", "gcv", "ebic", "cv"])
        .with_title("tuning criteria (paper §3.3)");
    for p in tr.points() {
        t.row(vec![
            format!("{:.4}", p.c_lambda),
            format!("{}", p.active),
            format!("{:.5}", p.gcv),
            format!("{:.5}", p.ebic),
            p.cv.map(|v| format!("{v:.5}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    maybe_write(&t, args)?;
    let (gcv_pt, ebic_pt) = (&tr.points()[tr.best_gcv()], &tr.points()[tr.best_ebic()]);
    println!(
        "\nbest: gcv → c={:.4} (r={}), e-bic → c={:.4} (r={})",
        gcv_pt.c_lambda, gcv_pt.active, ebic_pt.c_lambda, ebic_pt.active
    );
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let points = args.get_usize("points", 241).map_err(Error::msg)?;
    let out = args.get_str("out", "results/fig1.csv");
    let (header, rows) = tables::fig1_series(points);
    write_csv(&PathBuf::from(&out), &header, &rows)?;
    println!("Figure 1 series ({points} points, λ1=λ2=σ=1) written to {out}");
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let ns = args.get_usize_list("ns", &[10_000, 100_000, 500_000]).map_err(Error::msg)?;
    let m = args.get_usize("m", 500).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;
    let t = tables::table1(&ns, m, seed, tol);
    maybe_write(&t, args)
}

fn cmd_table2(args: &Args) -> Result<()> {
    let sets_str = args.get_str("sets", "housing,bodyfat,triazines");
    let max_n = args.get_usize("max-n", 50_000).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;
    let mut sets = Vec::new();
    for s in sets_str.split(',') {
        sets.push(match s.trim() {
            "housing" => ReferenceSet::Housing,
            "bodyfat" => ReferenceSet::Bodyfat,
            "triazines" => ReferenceSet::Triazines,
            other => return Err(Error::msg(format!("unknown dataset {other:?}"))),
        });
    }
    let t = tables::table2(&sets, max_n, seed, tol);
    maybe_write(&t, args)
}

fn cmd_insight(args: &Args) -> Result<()> {
    let n_snps = args.get_usize("n-snps", 50_000).map_err(Error::msg)?;
    let grid = args.get_usize("grid", 25).map_err(Error::msg)?;
    let cv = args.get_usize("cv", 0).map_err(Error::msg)?;
    let out_dir = PathBuf::from(args.get_str("out-dir", "results"));
    let alphas = args.get_f64_list("alphas", &[0.9, 0.8, 0.6]).map_err(Error::msg)?;

    // the two INSIGHT cohorts: CWG-like (m=226, 13 causal) and BMI-like (m=210, 6 causal)
    let cohorts = [
        ("cwg", SnpSpec { m: 226, n_snps, n_causal: 13, seed: 2020, ..Default::default() }),
        ("bmi", SnpSpec { m: 210, n_snps, n_causal: 6, seed: 2021, ..Default::default() }),
    ];
    for (name, spec) in cohorts {
        println!("== cohort {name}: m={} n_snps={} causal={}", spec.m, spec.n_snps, spec.n_causal);
        let (run, secs) =
            ssnal_en::util::timer::time_it(|| tables::insight_run(&spec, &alphas, grid, cv));
        let curve_path = out_dir.join(format!("fig2_{name}.csv"));
        write_csv(&curve_path, &tables::INSIGHT_CURVE_HEADER, &run.curves)?;
        println!(
            "criteria curves → {} ({} rows, {secs:.1}s)",
            curve_path.display(),
            run.curves.len()
        );
        let mut t = Table::new(&["snp", "coef", "is_causal"])
            .with_title(&format!("Table 3 ({name}): SNPs selected at the e-BIC optimum"));
        for (snp, coef) in &run.selected {
            t.row(vec![
                snp.clone(),
                format!("{coef:.3}"),
                format!("{}", run.causal.contains(snp)),
            ]);
        }
        t.print();
        let hit = run.selected.iter().filter(|(s, _)| run.causal.contains(s)).count();
        println!("causal recovery: {hit}/{} selected are true causal SNPs\n", run.selected.len());
        std::fs::write(out_dir.join(format!("table3_{name}.csv")), t.to_csv())?;
    }
    Ok(())
}

fn cmd_d1(args: &Args) -> Result<()> {
    let ns = args.get_usize_list("ns", &[10_000, 100_000, 500_000]).map_err(Error::msg)?;
    let cs = args.get_f64_list("cs", &[0.5, 0.6, 0.7]).map_err(Error::msg)?;
    let reps = args.get_usize("reps", 20).map_err(Error::msg)?;
    let m = args.get_usize("m", 500).map_err(Error::msg)?;
    let tol = parse_tol(args)?;
    if ns.len() != cs.len() {
        return Err(Error::msg("--ns and --cs must have equal length"));
    }
    let t = tables::table_d1(&ns, &cs, m, reps, tol);
    maybe_write(&t, args)
}

fn cmd_d2(args: &Args) -> Result<()> {
    let ns = args.get_usize_list("ns", &[10_000, 100_000]).map_err(Error::msg)?;
    let tol = parse_tol(args)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let panels: Vec<(&str, f64)> = vec![
        ("m", 1000.0),
        ("m", 5000.0),
        ("snr", 10.0),
        ("snr", 2.0),
        ("snr", 1.0),
        ("alpha", 0.1),
        ("alpha", 0.3),
        ("alpha", 0.6),
        ("x*", 100.0),
        ("x*", 0.1),
        ("x*", 0.01),
    ];
    let t = tables::table_d2(&ns, &panels, tol, seed);
    maybe_write(&t, args)
}

fn cmd_d3(args: &Args) -> Result<()> {
    let tol = parse_tol(args)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    // paper scenarios: (n=1e4, m=5e3, n0=500) and (n=5e5, m=500, n0=100)
    let scen1_n = args.get_usize("scen1-n", 10_000).map_err(Error::msg)?;
    let scen1_m = args.get_usize("scen1-m", 5_000).map_err(Error::msg)?;
    let scen2_n = args.get_usize("scen2-n", 500_000).map_err(Error::msg)?;
    let scenarios = [(scen1_n, scen1_m, 500.min(scen1_n / 4)), (scen2_n, 500, 100)];
    let cs = args.get_f64_list("cs", &[0.9, 0.7, 0.5, 0.3]).map_err(Error::msg)?;
    let t = tables::table_d3(&scenarios, &cs, tol, seed);
    maybe_write(&t, args)
}

fn cmd_d4(args: &Args) -> Result<()> {
    let ns = args.get_usize_list("ns", &[100_000, 500_000]).map_err(Error::msg)?;
    let alphas = args.get_f64_list("alphas", &[0.8, 0.6]).map_err(Error::msg)?;
    let m = args.get_usize("m", 500).map_err(Error::msg)?;
    let grid = args.get_usize("grid", 100).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;
    let t = tables::table_d4(&ns, &alphas, m, grid, tol, seed);
    maybe_write(&t, args)
}

fn cmd_bench_parallel(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 20_000).map_err(Error::msg)?;
    let m = args.get_usize("m", 200).map_err(Error::msg)?;
    let grid = args.get_usize("grid", 40).map_err(Error::msg)?;
    let threads = args.get_usize_list("threads", &[1, 2, 4]).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;
    let screening = !args.get_flag("no-screening");

    let (table, rows, seq_secs) =
        tables::parallel_path_rows(n, m, grid, &threads, tol, seed, screening);
    table.print();
    if let Some(best) = rows.iter().map(|r| r.speedup).reduce(f64::max) {
        println!("\nbest speedup over the sequential path: {best:.2}x");
    }
    if let Some(path) = args.get("out") {
        let json = tables::parallel_path_json(&rows, n, m, grid, seq_secs, screening);
        if let Some(parent) = PathBuf::from(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, json)?;
        println!("wrote {path}");
    }

    // Within-solve sharding: single-λ SSNAL + kernel table at each thread
    // budget, plus the SIMD-width audit backing blas::UNROLL. The default
    // shard problem (500×1e5) is deliberately big; --no-shard-bench skips it
    // for path-only runs.
    let mut determinism_ok = true;
    if !args.get_flag("no-shard-bench") {
        let shard_threads = args.get_usize_list("shard-threads", &[1, 2, 4]).map_err(Error::msg)?;
        let shard_n = args.get_usize("shard-n", 100_000).map_err(Error::msg)?;
        let shard_m = args.get_usize("shard-m", 500).map_err(Error::msg)?;
        let (st, srows, audit) =
            tables::shard_linalg_rows(shard_n, shard_m, &shard_threads, tol, seed);
        println!();
        st.print();
        println!(
            "width audit (len {}): dot4 {:.3e}s vs dot8 {:.3e}s, axpy4 {:.3e}s vs axpy8 {:.3e}s",
            audit.len,
            audit.dot4_seconds,
            audit.dot8_seconds,
            audit.axpy4_seconds,
            audit.axpy8_seconds
        );
        if let Some(path) = args.get("shard-out") {
            let json = tables::shard_linalg_json(&srows, &audit, shard_n, shard_m);
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= srows.iter().all(|r| r.bitwise_equal);
    }

    // Sparse CSC design storage: the GWAS-scale comparison. The same
    // rare-variant cohort held dense and CSC, timed through the Aᵀy sweep,
    // the Gap-Safe screening sweep, and a full single-λ solve; the sparse
    // copy must reproduce the dense bits and win on the sweeps.
    if !args.get_flag("no-sparse-bench") {
        let sparse_threads =
            args.get_usize_list("sparse-threads", &[1, 2, 4]).map_err(Error::msg)?;
        let sparse_n = args.get_usize("sparse-n", 50_000).map_err(Error::msg)?;
        let sparse_m = args.get_usize("sparse-m", 200).map_err(Error::msg)?;
        let (spt, sprows, density) =
            tables::sparse_design_rows(sparse_n, sparse_m, &sparse_threads, tol, seed);
        println!();
        spt.print();
        if let Some(best) = sprows.iter().map(|r| r.aty_speedup).reduce(f64::max) {
            println!(
                "\nbest sparse Aᵀy speedup at {:.1}% density: {best:.2}x",
                density * 100.0
            );
        }
        if let Some(path) = args.get("sparse-out") {
            let json = tables::sparse_design_json(&sprows, sparse_n, sparse_m, density);
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= sprows.iter().all(|r| r.bitwise_equal);
        // The tentpole claim is a gate: at rare-variant density (~6% stored
        // entries) the CSC sweeps must beat their dense twins at every
        // thread budget — the expected margin is roughly 1/density, so this
        // does not flake on noisy boxes.
        if let Some(slow) =
            sprows.iter().find(|r| r.aty_speedup <= 1.0 || r.screen_speedup <= 1.0)
        {
            return Err(Error::msg(format!(
                "sparse sweeps no cheaper than dense at {} threads \
                 (Aᵀy {:.2e}s vs {:.2e}s, screen {:.2e}s vs {:.2e}s, density {:.1}%)",
                slow.threads,
                slow.sparse_aty_seconds,
                slow.dense_aty_seconds,
                slow.sparse_screen_seconds,
                slow.dense_screen_seconds,
                density * 100.0
            )));
        }
    }

    // Out-of-core design storage: the same cohort streamed from a 2-bit
    // block file at a heavy-eviction and a fully-resident cache budget,
    // through the same sharded kernels as the in-core dense copy.
    if !args.get_flag("no-ooc-bench") {
        let ooc_threads = args.get_usize_list("ooc-threads", &[1, 2, 4]).map_err(Error::msg)?;
        let ooc_n = args.get_usize("ooc-n", 20_000).map_err(Error::msg)?;
        let ooc_m = args.get_usize("ooc-m", 200).map_err(Error::msg)?;
        let small_cache = args.get_usize("ooc-small-cache", 2 << 20).map_err(Error::msg)?;
        let large_cache = args.get_usize("ooc-large-cache", 256 << 20).map_err(Error::msg)?;
        let (ot, orows, density) = tables::ooc_design_rows(
            ooc_n,
            ooc_m,
            &ooc_threads,
            small_cache,
            large_cache,
            tol,
            seed,
        );
        println!();
        ot.print();
        if let Some(r) = orows.first() {
            println!(
                "\nstreamed at {:.1}% density: warm Aᵀy {:.2}x over cold, {:.1} MiB read \
                 under the {} MiB budget",
                density * 100.0,
                r.ooc_cold_aty_seconds / r.ooc_warm_aty_seconds.max(1e-12),
                r.small_mib_read,
                small_cache >> 20
            );
        }
        if let Some(path) = args.get("ooc-out") {
            let json = tables::ooc_design_json(
                &orows,
                ooc_n,
                ooc_m,
                density,
                small_cache,
                large_cache,
            );
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= orows.iter().all(|r| r.bitwise_equal);
        // The tentpole claims are gates: the decoded-panel cache may never
        // exceed its byte budget, and a warm cache must make the streamed
        // sweep strictly cheaper than the cold read-and-decode pass at the
        // fully-resident budget (the margin is the whole file's I/O +
        // decode, so this does not flake on noisy boxes).
        if let Some(bad) = orows.iter().find(|r| !r.cache_within_budget) {
            return Err(Error::msg(format!(
                "out-of-core panel cache exceeded its byte budget at {} threads",
                bad.threads
            )));
        }
        if let Some(slow) = orows.iter().find(|r| !r.warm_cheaper_than_cold) {
            return Err(Error::msg(format!(
                "warm out-of-core sweep no cheaper than cold at {} threads \
                 ({:.2e}s vs {:.2e}s)",
                slow.threads, slow.ooc_warm_aty_seconds, slow.ooc_cold_aty_seconds
            )));
        }
    }

    // Persistent-pool dispatch overhead vs the scoped spawn-per-call
    // baseline — the tentpole claim: parked-worker wakeups must dispatch
    // cheaper than thread spawns at every measured budget.
    if !args.get_flag("no-pool-bench") {
        let pool_calls = args.get_usize("pool-calls", 200).map_err(Error::msg)?.max(1);
        let pool_threads = args.get_usize_list("pool-threads", &[2, 4]).map_err(Error::msg)?;
        let (pt, prows) = tables::pool_dispatch_rows(pool_calls, &pool_threads);
        println!();
        pt.print();
        if let Some(best) = prows.iter().map(|r| r.dispatch_speedup).reduce(f64::max) {
            println!("\nbest pool-vs-scoped dispatch speedup: {best:.2}x");
        }
        if let Some(path) = args.get("pool-out") {
            let json = tables::pool_dispatch_json(&prows, pool_calls);
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= prows.iter().all(|r| r.bitwise_equal);
        // The tentpole claim is a gate, not a table: parked-worker dispatch
        // must beat spawn-per-call at every measured budget (the expected
        // margin is several-fold, so this does not flake on noisy boxes).
        if let Some(slow) = prows.iter().find(|r| r.dispatch_speedup <= 1.0) {
            return Err(Error::msg(format!(
                "persistent pool dispatched no cheaper than scoped spawn at {} threads \
                 ({:.2e}s/call vs {:.2e}s/call)",
                slow.threads, slow.pool_seconds_per_call, slow.scoped_seconds_per_call
            )));
        }
    }

    // Newton workspace: cold vs warm buffers, cached vs cold factorization,
    // steady-state allocations per warm iteration.
    if !args.get_flag("no-newton-bench") {
        let sizes_str = args.get_str("newton-sizes", "160:1200:40,320:2000:120");
        let sizes = parse_newton_sizes(&sizes_str)?;
        let newton_reps = args.get_usize("newton-reps", 3).map_err(Error::msg)?;
        let (nt, nrows) = tables::newton_workspace_rows(&sizes, newton_reps);
        println!();
        nt.print();
        if let Some(path) = args.get("newton-out") {
            let json = tables::newton_workspace_json(&nrows, newton_reps);
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= nrows.iter().all(|r| r.bitwise_equal);
        // Workspace gates: warm factor-cache solves must be strictly cheaper
        // than cold at every measured size (cache hits skip the O(m²r+m³) /
        // O(r²m+r³) build entirely, so the margin is several-fold and does
        // not flake on noisy boxes; the buffer-reuse-only CG row is exempt),
        // and — with this binary's counting allocator installed — the warm
        // path must allocate nothing in steady state.
        if let Some(slow) = nrows.iter().find(|r| r.strategy != "cg" && r.warm_speedup <= 1.0) {
            return Err(Error::msg(format!(
                "warm {} workspace no cheaper than cold at m={} r={} \
                 ({:.2e}s vs {:.2e}s per solve)",
                slow.strategy, slow.m, slow.r, slow.warm_seconds, slow.cold_seconds
            )));
        }
        if let Some(leaky) = nrows.iter().find(|r| r.allocs_per_iter > 0.0) {
            return Err(Error::msg(format!(
                "steady-state {} Newton iterations allocate ({:.2} allocs/iter at m={} r={})",
                leaky.strategy, leaky.allocs_per_iter, leaky.m, leaky.r
            )));
        }
    }

    // Warm λ-chain: the same screened-chain-shaped active-set schedule solved
    // cold, warm-with-pivot-refactor, and warm-with-rank-1-edits.
    if !args.get_flag("no-warm-bench") {
        let warm_m = args.get_usize("warm-m", 200).map_err(Error::msg)?;
        let warm_n = args.get_usize("warm-n", 2_000).map_err(Error::msg)?;
        let warm_r0 = args.get_usize("warm-r0", 40).map_err(Error::msg)?;
        let warm_points = args.get_usize("warm-points", 24).map_err(Error::msg)?;
        let warm_reps = args.get_usize("warm-reps", 3).map_err(Error::msg)?;
        let (wt, wrows) = tables::warm_path_rows(warm_m, warm_n, warm_r0, warm_points, warm_reps);
        println!();
        wt.print();
        if let Some(path) = args.get("warm-out") {
            let json = tables::warm_path_json(&wrows, warm_reps);
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= wrows.iter().all(|r| r.bitwise_equal);
        // The tentpole claims are gates: along a screened-chain-shaped λ
        // schedule the rank-1 edit tier must beat both the pivot-refactor
        // tier and a cold workspace per point (the swap steps skip the
        // O(r²m) Gram rebuild and refactor from an interior pivot, so the
        // margin does not flake on noisy boxes); no edited refactor may
        // lose positive definiteness on this well-posed chain; and — with
        // this binary's counting allocator installed — the warm chain must
        // allocate nothing in steady state.
        if let Some(slow) = wrows.iter().find(|r| r.rank1_vs_pivot <= 1.0 || r.rank1_vs_cold <= 1.0)
        {
            return Err(Error::msg(format!(
                "rank-1 warm chain no cheaper than the fallback tiers for {} \
                 (rank1 {:.2e}s vs pivot {:.2e}s vs cold {:.2e}s)",
                slow.strategy, slow.rank1_seconds, slow.pivot_seconds, slow.cold_seconds
            )));
        }
        if let Some(bad) = wrows.iter().find(|r| r.downdate_fallbacks > 0) {
            return Err(Error::msg(format!(
                "edited refactors lost positive definiteness {} time(s) on a \
                 well-posed {} chain",
                bad.downdate_fallbacks, bad.strategy
            )));
        }
        if let Some(leaky) = wrows.iter().find(|r| r.allocs_per_point > 0.0) {
            return Err(Error::msg(format!(
                "steady-state warm {} chain allocates ({:.2} allocs/point)",
                leaky.strategy, leaky.allocs_per_point
            )));
        }
    }

    // Serve front end: cold fit vs warm refit through the HTTP path, plus
    // latency percentiles at each concurrency level, every response checked
    // byte-for-byte against the direct api:: call it must equal.
    if !args.get_flag("no-serve-bench") {
        let serve_clients =
            args.get_usize_list("serve-clients", &[1, 8, 64]).map_err(Error::msg)?;
        let serve_n = args.get_usize("serve-n", 2_000).map_err(Error::msg)?;
        let serve_m = args.get_usize("serve-m", 100).map_err(Error::msg)?;
        let serve_requests = args.get_usize("serve-requests", 4).map_err(Error::msg)?;
        let (vt, vrows, cold, warm) =
            tables::serve_bench_rows(serve_n, serve_m, &serve_clients, serve_requests, tol, seed);
        println!();
        vt.print();
        println!("\nwarm refit vs cold fit through the server: {:.2}x", cold / warm.max(1e-12));
        // Queued load: offer 2× the in-flight cap against one warm session
        // and read the admission/coalescing counters back through /v1/stats.
        let (qt, qrow) = tables::serve_queued_load(serve_n, serve_m, serve_requests, tol, seed);
        println!();
        qt.print();
        println!(
            "\nqueued load: {} queued, {} rejected, coalesce ratio {:.2}x \
             ({} requests in {} batches)",
            qrow.queued_total,
            qrow.rejected_queue_full,
            qrow.coalesce_ratio,
            qrow.coalesce_requests,
            qrow.coalesce_batches
        );
        if let Some(path) = args.get("serve-out") {
            let json = tables::serve_bench_json(
                &vrows,
                serve_n,
                serve_m,
                serve_requests,
                cold,
                warm,
                Some(&qrow),
            );
            if let Some(parent) = PathBuf::from(path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, json)?;
            println!("wrote {path}");
        }
        determinism_ok &= vrows.iter().all(|r| r.bitwise_equal) && qrow.bitwise_equal;
        // The warm-session claim is a gate: a refit through a warm server
        // session skips session construction and hits the Gram/Cholesky
        // cache, so it must be strictly cheaper than the cold fit (the
        // margin is wide enough not to flake on noisy boxes).
        if warm >= cold {
            return Err(Error::msg(format!(
                "warm server refit no cheaper than cold fit ({warm:.2e}s vs {cold:.2e}s)"
            )));
        }
        // Admission gates: the default queue must absorb a burst at 2× the
        // in-flight cap without a single 503, and concurrent single-b refits
        // on one session must actually coalesce (ratio > 1 means at least
        // one refit_many batch carried more than one request).
        if qrow.rejected_queue_full > 0 {
            return Err(Error::msg(format!(
                "admission queue rejected {} requests at 2x offered load \
                 ({} clients vs cap {})",
                qrow.rejected_queue_full, qrow.clients, qrow.max_inflight
            )));
        }
        if qrow.coalesce_ratio <= 1.0 {
            return Err(Error::msg(format!(
                "concurrent refits never coalesced (ratio {:.2} over {} batches)",
                qrow.coalesce_ratio, qrow.coalesce_batches
            )));
        }
    }

    // The determinism contract is load-bearing: a bench run that observes a
    // bitwise divergence must fail loudly (CI runs this on every push).
    if !determinism_ok {
        return Err(Error::msg(
            "sharded kernels produced thread-dependent bits (see bench tables)",
        ));
    }
    Ok(())
}

/// Parse `--newton-sizes` triples `m:n:r[,m:n:r...]`.
fn parse_newton_sizes(s: &str) -> Result<Vec<(usize, usize, usize)>> {
    let mut sizes = Vec::new();
    for triple in s.split(',') {
        let parts: Vec<&str> = triple.trim().split(':').collect();
        if parts.len() != 3 {
            return Err(Error::msg(format!("--newton-sizes expects m:n:r, got {triple:?}")));
        }
        let parse = |p: &str| {
            p.trim()
                .parse::<f64>()
                .ok()
                .filter(|v| *v >= 1.0)
                .map(|v| v as usize)
                .ok_or_else(|| Error::msg(format!("bad size component {p:?}")))
        };
        sizes.push((parse(parts[0])?, parse(parts[1])?, parse(parts[2])?));
    }
    Ok(sizes)
}

/// Diff a fresh `BENCH_*.json` against its committed baseline (the CI
/// `bench-regression` gate; see `rust/src/bench/check.rs` for the policy).
/// Warnings print as GitHub annotations and never fail; structural drift or
/// a determinism violation exits non-zero.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let current = args
        .get("current")
        .ok_or_else(|| Error::msg("bench-check requires --current <BENCH_*.json>"))?;
    let baseline = args
        .get("baseline")
        .ok_or_else(|| Error::msg("bench-check requires --baseline <BENCH_*.json>"))?;
    let cur = ssnal_en::util::json::Json::parse(&std::fs::read_to_string(current)?)
        .map_err(|e| Error::msg(format!("{current}: {e}")))?;
    let base = ssnal_en::util::json::Json::parse(&std::fs::read_to_string(baseline)?)
        .map_err(|e| Error::msg(format!("{baseline}: {e}")))?;
    let rep = ssnal_en::bench::check_bench(&cur, &base);
    for w in &rep.warnings {
        println!("::warning title=bench-regression::{w}");
    }
    for f in &rep.failures {
        println!("::error title=bench-regression::{f}");
    }
    if !rep.ok() {
        return Err(Error::msg(format!(
            "{} hard failure(s) comparing {current} against {baseline}",
            rep.failures.len()
        )));
    }
    println!("bench-check ok: {current} vs {baseline} ({} warning(s))", rep.warnings.len());
    Ok(())
}

/// `ssnal-en serve` — run the HTTP front end on the calling thread until a
/// SIGTERM begins a graceful drain (see `ssnal_en::serve` for the wire
/// format and overload behavior).
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ssnal_en::serve::ServerConfig {
        host: args.get_str("host", "127.0.0.1"),
        port: args.get_usize("port", 7878).map_err(Error::msg)? as u16,
        sessions: args.get_usize("sessions", 16).map_err(Error::msg)?,
        max_inflight: args.get_usize("max-inflight", 32).map_err(Error::msg)?,
        threads: args.get_usize("threads", 0).map_err(Error::msg)?,
        max_body: args.get_usize("max-body-mb", 256).map_err(Error::msg)? << 20,
        queue_depth: args.get_usize("queue-depth", 64).map_err(Error::msg)?,
        request_timeout_ms: args.get_usize("request-timeout-ms", 30_000).map_err(Error::msg)?
            as u64,
        drain_timeout_ms: args.get_usize("drain-timeout-ms", 30_000).map_err(Error::msg)? as u64,
    };
    ssnal_en::serve::install_sigterm_drain();
    let server = ssnal_en::serve::Server::bind(cfg.clone())?;
    let addr = server.local_addr()?;
    println!(
        "ssnal-en serve listening on http://{addr} (sessions={}, max-inflight={}, \
         queue-depth={}, request-timeout-ms={}, threads={})",
        cfg.sessions,
        cfg.max_inflight,
        cfg.queue_depth,
        cfg.request_timeout_ms,
        ssnal_en::parallel::resolve_threads(cfg.threads)
    );
    println!(
        "routes: GET /v1/health /v1/stats · POST /v1/designs /v1/fit /v1/refit /v1/predict \
         /v1/path"
    );
    server.run()?;
    println!("ssnal-en serve drained cleanly");
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_str("artifacts-dir", "artifacts"));
    // validation (manifest + files) must succeed even without a PJRT binding
    let manifest = ssnal_en::runtime::PjrtEngine::validate_dir(&dir)?;
    println!(
        "validated {} artifacts ({}) at {}",
        manifest.artifacts.len(),
        manifest.dtype,
        dir.display()
    );
    for (m, n) in manifest.shapes() {
        println!("  shape ({m}, {n})");
    }
    // best-effort: a tiny end-to-end pjrt solve on the smallest shape (only
    // possible in builds that link an XLA/PJRT binding)
    let (m, n) = manifest.shapes().first().copied().expect("at least one shape");
    let prob = generate_synthetic(&SyntheticSpec { m, n, n0: 5, x_star: 5.0, snr: 5.0, seed: 1 });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.4, lmax);
    let design = Design::new(&prob.a, &prob.b)?;
    let model = EnetModel::new()
        .lambda(l1, l2)
        .backend(Backend::Pjrt)
        .artifacts_dir(dir)
        .tol(1e-4)
        .newton(NewtonStrategy::ConjugateGradient);
    match model.fit(&design) {
        Ok(fit) => {
            let res = fit.result();
            println!(
                "pjrt solve ({m}×{n}): converged={} active={} outer={}",
                res.converged,
                res.active_set.len(),
                res.iterations
            );
        }
        Err(e) => println!("pjrt execution unavailable in this build: {e}"),
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 50_000).map_err(Error::msg)?;
    let m = args.get_usize("m", 500).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 2020).map_err(Error::msg)? as u64;
    let tol = parse_tol(args)?;
    let ta = ssnal_en::bench::tables::ablation_newton(n, m, tol, seed);
    ta.print();
    println!();
    let tb = ssnal_en::bench::tables::ablation_sigma(n, m, tol, seed);
    tb.print();
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{}\n{}", ta.to_csv(), tb.to_csv()))?;
    }
    Ok(())
}
