//! SsNAL-EN with the inner computations executed as AOT-compiled JAX/Pallas
//! graphs via PJRT — the full three-layer stack on the solve path.
//!
//! The control flow (AL outer loop, SsN inner loop, CG, line search, σ
//! schedule) stays in Rust (L3). The numerical building blocks run as two
//! compiled graphs produced by `python/compile/aot.py`:
//!
//! * `dual_prox_grad(at, b, x, y, σ, λ1, λ2) → (∇ψ, u, mask, ψ)` — the fused
//!   Aᵀy → prox/mask sweep implemented as the L1 Pallas kernel inside the
//!   L2 jax function,
//! * `hess_vec(at, mask, κ, d) → V·d` — the generalized-Hessian mat-vec used
//!   by the matrix-free CG solve.
//!
//! Artifacts are f32, so the backend targets a 1e-4 KKT tolerance: it is a
//! stack-composition demonstrator, not the performance path (the native f64
//! backend is; see DESIGN.md §Perf).

use crate::linalg::blas;
use crate::runtime::{
    literal_at, literal_from_f64, literal_scalar, literal_to_f64, Literal, PjrtEngine,
};
use crate::solver::objective::{primal_objective, support_of};
use crate::solver::types::{Algorithm, EnetProblem, SolveResult, SsnalOptions};
use crate::util::error::{Error, Result};

/// One `dual_prox_grad` evaluation via PJRT.
struct ProxGradOut {
    grad: Vec<f64>,
    u: Vec<f64>,
    mask: Vec<f64>,
    psi: f64,
}

fn dual_prox_grad(
    engine: &PjrtEngine,
    at_lit: &Literal,
    b_lit: &Literal,
    x: &[f64],
    y: &[f64],
    sigma: f64,
    p: &EnetProblem,
) -> Result<ProxGradOut> {
    let g = engine.graph("dual_prox_grad", p.m(), p.n())?;
    let x_lit = literal_from_f64(x, &[p.n()])?;
    let y_lit = literal_from_f64(y, &[p.m()])?;
    let outs = g.run(&[
        at_lit.clone(),
        b_lit.clone(),
        x_lit,
        y_lit,
        literal_scalar(sigma),
        literal_scalar(p.lam1),
        literal_scalar(p.lam2),
    ])?;
    if outs.len() != 4 {
        return Err(Error::msg(format!("dual_prox_grad returns 4 outputs, got {}", outs.len())));
    }
    Ok(ProxGradOut {
        grad: literal_to_f64(&outs[0])?,
        u: literal_to_f64(&outs[1])?,
        mask: literal_to_f64(&outs[2])?,
        psi: literal_to_f64(&outs[3])?[0],
    })
}

fn hess_vec(
    engine: &PjrtEngine,
    at_lit: &Literal,
    mask: &[f64],
    kappa: f64,
    d: &[f64],
    p: &EnetProblem,
) -> Result<Vec<f64>> {
    let g = engine.graph("hess_vec", p.m(), p.n())?;
    let mask_lit = literal_from_f64(mask, &[p.n()])?;
    let d_lit = literal_from_f64(d, &[p.m()])?;
    let outs = g.run(&[at_lit.clone(), mask_lit, literal_scalar(kappa), d_lit])?;
    if outs.len() != 1 {
        return Err(Error::msg("hess_vec returns 1 output"));
    }
    literal_to_f64(&outs[0])
}

/// Solve one Elastic Net instance on the PJRT backend.
pub fn solve_pjrt(
    engine: &PjrtEngine,
    p: &EnetProblem,
    opts: &SsnalOptions,
) -> Result<SolveResult> {
    let m = p.m();
    let n = p.n();
    // The AOT graphs take the design as one dense f32 literal; CSC storage has
    // no PJRT lowering yet, so reject it up front with an actionable error.
    let a_dense = p.a.as_dense().ok_or_else(|| {
        Error::msg(
            "the PJRT backend requires dense design storage; \
             densify the design (CscMat::to_dense) or use the native backend",
        )
    })?;
    let at_lit = literal_at(a_dense)?;
    let b_lit = literal_from_f64(p.b, &[m])?;

    let mut x = vec![0.0; n];
    let mut y: Vec<f64> = p.b.iter().map(|v| -v).collect(); // y = Ax − b at x=0
    let mut sigma = opts.sigma0;
    let bnorm = blas::nrm2(p.b);

    let mut total_inner = 0usize;
    let mut converged = false;
    let mut final_res = f64::INFINITY;
    let mut outer = 0usize;
    // f32 graphs: cap the effective precision we ask of the inner loop
    let tol = opts.tol.max(5e-5);
    let mut inner_tol = (tol * 1e2).min(1e-2).max(tol);

    while outer < opts.max_outer {
        outer += 1;
        let mut inner = 0usize;
        let mut last_u: Vec<f64>;
        loop {
            let eval = dual_prox_grad(engine, &at_lit, &b_lit, &x, &y, sigma, p)?;
            last_u = eval.u;
            let res1 = blas::nrm2(&eval.grad) / (1.0 + bnorm);
            if res1 <= inner_tol || inner >= opts.max_inner {
                break;
            }
            inner += 1;

            // CG on V d = −grad with the PJRT hess_vec operator. The CG
            // driver's matvec closure cannot return a Result, so a graph
            // failure is captured in `hv_err` (zeroing the output so CG
            // terminates benignly) and surfaced once the solve returns.
            let kappa = sigma / (1.0 + sigma * p.lam2);
            let rhs: Vec<f64> = eval.grad.iter().map(|g| -g).collect();
            let mut d = vec![0.0; m];
            let mask = eval.mask.clone();
            let mut hv_err: Option<Error> = None;
            crate::linalg::solve_cg(
                |v, out| {
                    if hv_err.is_some() {
                        out.iter_mut().for_each(|o| *o = 0.0);
                        return;
                    }
                    match hess_vec(engine, &at_lit, &mask, kappa, v, p) {
                        Ok(hv) => out.copy_from_slice(&hv),
                        Err(e) => {
                            hv_err = Some(e);
                            out.iter_mut().for_each(|o| *o = 0.0);
                        }
                    }
                },
                &rhs,
                &mut d,
                1e-6,
                200,
            );
            if let Some(e) = hv_err {
                return Err(Error::msg(format!("pjrt hess_vec failed: {e}")));
            }

            // Armijo backtracking using ψ from the graph
            let gtd = blas::dot(&eval.grad, &d);
            let mut s = 1.0;
            let mut y_trial = vec![0.0; m];
            let mut accepted = false;
            for _ in 0..opts.max_ls {
                for i in 0..m {
                    y_trial[i] = y[i] + s * d[i];
                }
                let trial = dual_prox_grad(engine, &at_lit, &b_lit, &x, &y_trial, sigma, p)?;
                if trial.psi <= eval.psi + opts.ls_mu * s * gtd {
                    accepted = true;
                    break;
                }
                s *= opts.ls_beta;
            }
            if !accepted {
                // keep the smallest step; f32 ψ comparisons can be noisy
            }
            y.copy_from_slice(&y_trial);
        }
        total_inner += inner;

        // multiplier update x ← u and kkt3 via the Moreau identity
        let xu = blas::dist2(&x, &last_u);
        let ynorm = blas::nrm2(&y);
        let res3 = xu / sigma / (1.0 + ynorm + 1.0);
        final_res = res3;
        x.copy_from_slice(&last_u);
        if res3 <= tol {
            converged = true;
            break;
        }
        sigma = (sigma * opts.sigma_mult).min(opts.sigma_max);
        inner_tol = (inner_tol * 0.1).max(tol);
    }

    // sparsify tiny f32 round-off
    for v in x.iter_mut() {
        if v.abs() < 1e-7 {
            *v = 0.0;
        }
    }
    let active_set = support_of(&x, 0.0);
    let objective = primal_objective(p, &x);
    Ok(SolveResult {
        x,
        y,
        active_set,
        screen_survivors: None,
        objective,
        iterations: outer,
        inner_iterations: total_inner,
        residual: final_res,
        converged,
        algorithm: Algorithm::SsnalEn,
    })
}
