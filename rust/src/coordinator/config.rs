//! Coordinator configuration (the [`Backend`] enum itself now lives in the
//! facade, [`crate::api`], and is re-exported here for compatibility).

use crate::solver::types::{NewtonStrategy, SsnalOptions};
use std::path::PathBuf;

pub use crate::api::Backend;

/// High-level configuration for [`super::Coordinator`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Execution backend.
    pub backend: Backend,
    /// Artifacts directory for the PJRT backend.
    pub artifacts_dir: PathBuf,
    /// Solver options (tolerance, σ schedule, Newton strategy, ...).
    pub ssnal: SsnalOptions,
    /// Worker threads for λ-paths and CV sweeps (`0` = all available cores,
    /// `1` = single-threaded). The coordinator pins the chain split to
    /// [`crate::parallel::DEFAULT_CHAINS`], so every `num_threads` value
    /// yields identical results — the setting only changes wall-clock.
    pub num_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Native,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            ssnal: SsnalOptions::default(),
            num_threads: 0,
        }
    }
}

impl CoordinatorConfig {
    /// Convenience: native backend with a given tolerance.
    pub fn native(tol: f64) -> Self {
        Self { ssnal: SsnalOptions { tol, ..Default::default() }, ..Default::default() }
    }

    /// Convenience: PJRT backend (looser default tolerance — artifacts are f32).
    pub fn pjrt(artifacts_dir: PathBuf) -> Self {
        Self {
            backend: Backend::Pjrt,
            artifacts_dir,
            ssnal: SsnalOptions {
                tol: 1e-4,
                strategy: NewtonStrategy::ConjugateGradient,
                ..Default::default()
            },
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_native() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.backend, Backend::Native);
        assert_eq!(c.ssnal.tol, 1e-6);
    }

    #[test]
    fn pjrt_config_loosens_tolerance() {
        let c = CoordinatorConfig::pjrt(PathBuf::from("artifacts"));
        assert_eq!(c.backend, Backend::Pjrt);
        assert!(c.ssnal.tol >= 1e-5, "f32 artifacts need a looser tol");
    }
}
