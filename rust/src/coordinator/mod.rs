//! The coordinator — the high-level entry point a downstream user works with.
//!
//! Owns backend selection (native f64 kernels vs PJRT-executed JAX/Pallas
//! artifacts), lazy engine initialization, and the high-level operations:
//! single solves, warm-started λ-paths, and parameter tuning.

pub mod config;
mod pjrt_solver;

pub use config::{Backend, CoordinatorConfig};

use crate::linalg::Mat;
use crate::parallel::{
    solve_path_parallel, Chunking, ParallelPathOptions, ParallelPathResult, DEFAULT_CHAINS,
};
use crate::path::{PathOptions, PathResult};
use crate::runtime::PjrtEngine;
use crate::solver::ssnal;
use crate::solver::types::{EnetProblem, SolveResult};
use crate::tuning::{tune_with_threads, TuningOptions, TuningResult};
use crate::util::error::{Context, Result};
use std::cell::OnceCell;

/// High-level solver coordinator.
pub struct Coordinator {
    config: CoordinatorConfig,
    engine: OnceCell<PjrtEngine>,
}

impl Coordinator {
    /// Create a coordinator; the PJRT engine (if configured) loads lazily on
    /// first use so native-only runs never touch the artifacts directory.
    pub fn new(config: CoordinatorConfig) -> Self {
        Self { config, engine: OnceCell::new() }
    }

    /// Access the configuration.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    /// The PJRT engine (loading it on first call).
    pub fn engine(&self) -> Result<&PjrtEngine> {
        if self.engine.get().is_none() {
            let engine = PjrtEngine::load_dir(&self.config.artifacts_dir).with_context(|| {
                format!("loading artifacts from {}", self.config.artifacts_dir.display())
            })?;
            let _ = self.engine.set(engine);
        }
        Ok(self.engine.get().expect("just set"))
    }

    /// Solve one Elastic Net instance on the configured backend.
    pub fn solve(&self, a: &Mat, b: &[f64], lam1: f64, lam2: f64) -> Result<SolveResult> {
        let p = EnetProblem::new(a, b, lam1, lam2);
        match self.config.backend {
            Backend::Native => Ok(ssnal::solve(&p, &self.config.ssnal)),
            Backend::Pjrt => pjrt_solver::solve_pjrt(self.engine()?, &p, &self.config.ssnal),
        }
    }

    /// Solve with an explicit warm start (native backend; the PJRT demo
    /// backend ignores the warm start).
    pub fn solve_warm(
        &self,
        a: &Mat,
        b: &[f64],
        lam1: f64,
        lam2: f64,
        x0: Option<&[f64]>,
    ) -> Result<SolveResult> {
        let p = EnetProblem::new(a, b, lam1, lam2);
        match self.config.backend {
            Backend::Native => Ok(ssnal::solve_warm(&p, &self.config.ssnal, x0).0),
            Backend::Pjrt => pjrt_solver::solve_pjrt(self.engine()?, &p, &self.config.ssnal),
        }
    }

    /// Warm-started λ-path (always native — the path driver is the
    /// performance-critical mode the paper benchmarks). Routed through the
    /// parallel engine with a *fixed* chain split ([`DEFAULT_CHAINS`]), so the
    /// result is identical for every `config.num_threads` value;
    /// `num_threads == 1` is the single-threaded fallback (no workers
    /// spawned). Solutions agree with [`crate::path::solve_path`] to solver
    /// tolerance; for bit-identical sequential output call the engine with
    /// [`ParallelPathOptions::sequential`].
    pub fn solve_path(&self, a: &Mat, b: &[f64], opts: &PathOptions) -> PathResult {
        self.solve_path_parallel(a, b, opts).path
    }

    /// Warm-started λ-path with the engine's diagnostics (chain reports,
    /// survivor fractions, thread count).
    pub fn solve_path_parallel(
        &self,
        a: &Mat,
        b: &[f64],
        opts: &PathOptions,
    ) -> ParallelPathResult {
        let popts = ParallelPathOptions {
            base: opts.clone(),
            num_threads: self.config.num_threads,
            chunking: Chunking::Chains(DEFAULT_CHAINS),
            screening: true,
        };
        solve_path_parallel(a, b, &popts)
    }

    /// Parameter tuning sweep (§3.3): path + GCV/e-BIC (+ optional k-fold CV),
    /// with the per-point criteria fanned out over `config.num_threads`.
    pub fn tune(&self, a: &Mat, b: &[f64], opts: &TuningOptions) -> TuningResult {
        tune_with_threads(a, b, opts, self.config.num_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    #[test]
    fn native_solve_via_coordinator() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 5.0,
            seed: 3,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let coord = Coordinator::new(CoordinatorConfig::native(1e-6));
        let fit = coord.solve(&prob.a, &prob.b, l1, l2).unwrap();
        assert!(fit.converged);
        assert!(!fit.active_set.is_empty());
    }

    #[test]
    fn pjrt_backend_without_artifacts_errors_helpfully() {
        let cfg = CoordinatorConfig::pjrt(std::path::PathBuf::from("/nonexistent_artifacts"));
        let coord = Coordinator::new(cfg);
        let a = Mat::zeros(2, 3);
        let b = [1.0, 2.0];
        let err = coord.solve(&a, &b, 0.5, 0.5).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("artifacts"), "{msg}");
    }

    #[test]
    fn path_and_tune_through_coordinator() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 100,
            n0: 4,
            x_star: 5.0,
            snr: 20.0,
            seed: 5,
        });
        let coord = Coordinator::new(CoordinatorConfig::default());
        let popts = PathOptions {
            alpha: 0.9,
            c_grid: crate::path::c_lambda_grid(0.9, 0.2, 6),
            max_active: 0,
            tol: 1e-6,
            ..Default::default()
        };
        let path = coord.solve_path(&prob.a, &prob.b, &popts);
        assert_eq!(path.runs, 6);
        let topts = TuningOptions { path: popts, cv_folds: 0, cv_seed: 0 };
        let tuned = coord.tune(&prob.a, &prob.b, &topts);
        assert_eq!(tuned.points.len(), 6);
    }
}
