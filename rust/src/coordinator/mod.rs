//! Compatibility shim over the [`crate::api`] facade.
//!
//! The [`Coordinator`] was the crate's high-level entry point before the
//! estimator facade landed; it survives (deprecated) so downstream callers
//! keep compiling, but every operation now delegates to
//! [`crate::api::EnetModel`] / [`crate::api::Design`] — it is a thin mapping
//! layer, not a parallel code path. New code should use the facade directly:
//!
//! * `Coordinator::solve` → [`crate::api::EnetModel::fit`]
//! * `Coordinator::solve_path` → [`crate::api::EnetModel::fit_path`]
//! * `Coordinator::tune` → [`crate::api::EnetModel::tune`]

pub mod config;
pub(crate) mod pjrt_solver;

pub use config::{Backend, CoordinatorConfig};

use crate::api::{Design, EnetModel};
use crate::linalg::Mat;
use crate::parallel::{Chunking, ParallelPathResult, DEFAULT_CHAINS};
use crate::path::{PathOptions, PathResult};
use crate::runtime::PjrtEngine;
use crate::solver::types::SolveResult;
use crate::tuning::{TuningOptions, TuningResult};
use crate::util::error::{Context, Result};
use std::cell::OnceCell;

/// High-level solver coordinator — deprecated compatibility shim over the
/// estimator facade (see the module docs).
#[deprecated(note = "use crate::api::{Design, EnetModel} — the Coordinator is a \
                     compatibility shim over the facade")]
pub struct Coordinator {
    config: CoordinatorConfig,
    engine: OnceCell<PjrtEngine>,
}

#[allow(deprecated)]
impl Coordinator {
    /// Create a coordinator; the PJRT engine (if configured) loads lazily on
    /// first use so native-only runs never touch the artifacts directory.
    pub fn new(config: CoordinatorConfig) -> Self {
        Self { config, engine: OnceCell::new() }
    }

    /// Access the configuration.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    /// The PJRT engine (loading it on first call). Kept for artifact
    /// introspection; the facade caches its own engine per [`crate::api::Fit`]
    /// session.
    pub fn engine(&self) -> Result<&PjrtEngine> {
        if let Some(engine) = self.engine.get() {
            return Ok(engine);
        }
        let engine = PjrtEngine::load_dir(&self.config.artifacts_dir).with_context(|| {
            format!("loading artifacts from {}", self.config.artifacts_dir.display())
        })?;
        Ok(self.engine.get_or_init(|| engine))
    }

    /// The facade model equivalent to this coordinator's configuration.
    fn model(&self) -> EnetModel {
        EnetModel::new()
            .tol(self.config.ssnal.tol)
            .verbose(self.config.ssnal.verbose)
            .ssnal_options(self.config.ssnal.clone())
            .threads(self.config.num_threads)
            .backend(self.config.backend)
            .artifacts_dir(self.config.artifacts_dir.clone())
    }

    /// Solve one Elastic Net instance on the configured backend.
    pub fn solve(&self, a: &Mat, b: &[f64], lam1: f64, lam2: f64) -> Result<SolveResult> {
        let design = Design::new(a, b)?;
        Ok(self.model().lambda(lam1, lam2).fit(&design)?.into_result())
    }

    /// Solve with an explicit warm start (native backend; the PJRT demo
    /// backend ignores the warm start).
    pub fn solve_warm(
        &self,
        a: &Mat,
        b: &[f64],
        lam1: f64,
        lam2: f64,
        x0: Option<&[f64]>,
    ) -> Result<SolveResult> {
        let design = Design::new(a, b)?;
        Ok(self.model().lambda(lam1, lam2).fit_warm(&design, x0)?.into_result())
    }

    /// Warm-started λ-path (always native — the path driver is the
    /// performance-critical mode the paper benchmarks). Routed through the
    /// parallel engine with a *fixed* chain split ([`DEFAULT_CHAINS`]), so the
    /// result is identical for every `config.num_threads` value;
    /// `num_threads == 1` is the single-threaded fallback (no workers
    /// spawned). Solutions agree with [`crate::path::solve_path`] to solver
    /// tolerance; for bit-identical sequential output use
    /// [`crate::api::EnetModel::sequential`].
    pub fn solve_path(&self, a: &Mat, b: &[f64], opts: &PathOptions) -> PathResult {
        self.solve_path_parallel(a, b, opts).path
    }

    /// Warm-started λ-path with the engine's diagnostics (chain reports,
    /// survivor fractions, thread count). Invalid input panics here for
    /// signature compatibility — the facade returns typed errors instead.
    pub fn solve_path_parallel(
        &self,
        a: &Mat,
        b: &[f64],
        opts: &PathOptions,
    ) -> ParallelPathResult {
        let design =
            Design::new(a, b).unwrap_or_else(|e| panic!("invalid path request: {e}"));
        self.model()
            .alpha(opts.alpha)
            .c_grid(opts.c_grid.clone())
            .max_active(opts.max_active)
            .tol(opts.tol)
            .algorithm(opts.algorithm)
            .backend(Backend::Native)
            .chunking(Chunking::Chains(DEFAULT_CHAINS))
            .screening(true)
            .fit_path(&design)
            .unwrap_or_else(|e| panic!("invalid path request: {e}"))
            .into_inner()
    }

    /// Parameter tuning sweep (§3.3): path + GCV/e-BIC (+ optional k-fold CV),
    /// with the per-point criteria fanned out over `config.num_threads`.
    /// Invalid input panics here for signature compatibility — the facade
    /// returns typed errors instead.
    pub fn tune(&self, a: &Mat, b: &[f64], opts: &TuningOptions) -> TuningResult {
        let design =
            Design::new(a, b).unwrap_or_else(|e| panic!("invalid tuning request: {e}"));
        self.model()
            .alpha(opts.path.alpha)
            .c_grid(opts.path.c_grid.clone())
            .max_active(opts.path.max_active)
            .tol(opts.path.tol)
            .algorithm(opts.path.algorithm)
            .backend(Backend::Native)
            .cv(opts.cv_folds)
            .cv_seed(opts.cv_seed)
            .tune(&design)
            .unwrap_or_else(|e| panic!("invalid tuning request: {e}"))
            .into_inner()
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};
    use crate::solver::types::EnetProblem;

    #[test]
    fn native_solve_via_coordinator() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 120,
            n0: 5,
            x_star: 5.0,
            snr: 5.0,
            seed: 3,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let coord = Coordinator::new(CoordinatorConfig::native(1e-6));
        let fit = coord.solve(&prob.a, &prob.b, l1, l2).unwrap();
        assert!(fit.converged);
        assert!(!fit.active_set.is_empty());
    }

    /// The shim must match the facade bit for bit — it is a mapping layer,
    /// not a second code path.
    #[test]
    fn shim_solve_matches_facade_bitwise() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 30,
            n: 90,
            n0: 4,
            x_star: 5.0,
            snr: 8.0,
            seed: 11,
        });
        let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
        let coord = Coordinator::new(CoordinatorConfig::native(1e-6));
        let shim = coord.solve(&prob.a, &prob.b, l1, l2).unwrap();
        let design = Design::new(&prob.a, &prob.b).unwrap();
        let facade =
            EnetModel::new().lambda(l1, l2).tol(1e-6).fit(&design).unwrap().into_result();
        assert_eq!(shim.x, facade.x);
        assert_eq!(shim.objective.to_bits(), facade.objective.to_bits());
    }

    #[test]
    fn invalid_design_is_an_error_not_a_panic() {
        let coord = Coordinator::new(CoordinatorConfig::native(1e-6));
        let a = Mat::zeros(3, 2);
        let b = [0.0; 4]; // shape mismatch
        let err = coord.solve(&a, &b, 1.0, 0.5).unwrap_err();
        assert!(format!("{err}").contains("rows"), "{err}");
    }

    #[test]
    fn pjrt_backend_without_artifacts_errors_helpfully() {
        let cfg = CoordinatorConfig::pjrt(std::path::PathBuf::from("/nonexistent_artifacts"));
        let coord = Coordinator::new(cfg);
        let a = Mat::zeros(2, 3);
        let b = [1.0, 2.0];
        let err = coord.solve(&a, &b, 0.5, 0.5).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("artifacts"), "{msg}");
    }

    #[test]
    fn path_and_tune_through_coordinator() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: 40,
            n: 100,
            n0: 4,
            x_star: 5.0,
            snr: 20.0,
            seed: 5,
        });
        let coord = Coordinator::new(CoordinatorConfig::default());
        let popts = PathOptions {
            alpha: 0.9,
            c_grid: crate::path::c_lambda_grid(0.9, 0.2, 6),
            max_active: 0,
            tol: 1e-6,
            ..Default::default()
        };
        let path = coord.solve_path(&prob.a, &prob.b, &popts);
        assert_eq!(path.runs, 6);
        let topts = TuningOptions { path: popts, cv_folds: 0, cv_seed: 0 };
        let tuned = coord.tune(&prob.a, &prob.b, &topts);
        assert_eq!(tuned.points.len(), 6);
    }
}
