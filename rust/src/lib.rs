//! # SsNAL-EN — Semi-smooth Newton Augmented Lagrangian method for the Elastic Net
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *An Efficient Semi-smooth Newton Augmented Lagrangian Method for Elastic Net*
//! (Boschi, Reimherr, Chiaromonte, 2020).
//!
//! The crate is organized as:
//!
//! * [`solver`] — the paper's contribution: the SsNAL-EN solver plus every
//!   baseline it is benchmarked against (coordinate descent, FISTA, ADMM,
//!   Gap-Safe screening, celer-style working sets),
//! * [`prox`] — the Elastic Net proximal/conjugate toolbox (paper §2),
//! * [`path`] / [`tuning`] — warm-started λ-paths and CV/GCV/e-BIC tuning (§3.3),
//! * [`parallel`] — the two-layer execution engine over one **persistent
//!   worker pool** (long-lived parked `std::thread` workers, woken per
//!   kernel call; see [`parallel::pool`]). Layer 1 parallelizes *across*
//!   the λ-grid: contiguous warm-start chains over work-stealing deques,
//!   with per-chain Gap-Safe screening and cross-chain truncation
//!   coordination. Layer 2 ([`parallel::shard`]) parallelizes *within* one
//!   solve: the `Aᵀy`/`A_J u`/Gram/CG-mat-vec/direct-Newton-triangle
//!   kernels and the Gap-Safe scoring sweeps shard their column dimension
//!   over the same pool with fixed-order tree reductions. Both layers are
//!   bitwise-deterministic: for a fixed chain split and problem shape the
//!   output is identical at every thread count and pool warmth
//!   (`SSNAL_THREADS` governs the within-solve budget),
//! * [`data`] — synthetic, LIBSVM/polynomial-expansion and SNP/GWAS pipelines (§4),
//! * [`runtime`] — the artifact manifest/buffer contract for the AOT-compiled
//!   JAX/Pallas graphs (execution needs an XLA/PJRT binding the offline
//!   toolchain does not ship; the engine degrades to a descriptive error),
//! * [`coordinator`] — the high-level API tying solver, path, tuning, data and
//!   backend selection together,
//! * [`linalg`] / [`rng`] / [`util`] / [`bench`] — the from-scratch substrates
//!   (the offline build has no BLAS, rand, clap, serde, anyhow or criterion).
//!   [`linalg::workspace`] holds the solver-wide buffer arena and the
//!   active-set-aware Gram/Cholesky cache behind the zero-allocation Newton
//!   hot path: steady-state SsN iterations reuse every buffer and factor
//!   (bitwise-identically to cold rebuilds; a counting-allocator test pins
//!   the hot path to zero heap allocations).
//!
//! ## Continuous integration
//!
//! `.github/workflows/ci.yml` gates every push/PR on `cargo build --release`,
//! `cargo test -q` (run twice, under `SSNAL_THREADS=1` and `=4`, so the
//! sharding determinism contract is exercised on every push), `cargo fmt
//! --check` and `cargo clippy -- -D warnings`, plus a bench-smoke job that
//! runs the parallel-path, shard-linalg, pool-dispatch and Newton-workspace
//! benchmarks on tiny synthetic problems and uploads the resulting four
//! `BENCH_*.json` tables (the Newton section also gates warm-vs-cold
//! workspace cost and steady-state allocations), and a bench-regression job
//! that diffs them against the committed baselines in
//! `rust/benches/baselines/` via `ssnal-en bench-check` ([`bench::check`]:
//! structural drift and determinism violations hard-fail; wall-clock
//! regressions >25% annotate without failing).

// Numeric-kernel idioms this codebase uses deliberately (index loops that
// mirror the paper's math, solver entry points with many tuning knobs).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::inherent_to_string)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod parallel;
pub mod path;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod tuning;
pub mod util;
